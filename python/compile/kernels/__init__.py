"""L1: Pallas kernels for HASFL's compute hot spots.

- ``matmul.matmul_bias_act`` — tiled GEMM with fused bias + ReLU epilogue
  (drives dense layers and im2col convolutions).
- ``softmax_xent.softmax_xent`` — fused softmax cross-entropy per-row loss.
- ``ref`` — pure-jnp oracles used by the pytest/hypothesis suite.
"""

from compile.kernels.matmul import matmul_bias_act
from compile.kernels.softmax_xent import softmax_xent

__all__ = ["matmul_bias_act", "softmax_xent"]
