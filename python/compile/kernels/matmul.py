"""L1 Pallas kernel: tiled matmul with fused bias + activation epilogue.

This is the compute hot spot of HASFL's split CNN: every convolution is
lowered to im2col + this GEMM, and every dense layer calls it directly.

Hardware-adaptation notes (see DESIGN.md §Hardware-Adaptation): the paper's
edge-GPU hot spot is cuDNN conv/GEMM; on the TPU-shaped Pallas abstraction we
tile the GEMM into (bm, bk, bn) blocks sized for VMEM, accumulate in f32 over
the k-grid, and fuse bias+ReLU into the epilogue so the output tile makes a
single HBM round trip. ``interpret=True`` is mandatory here: the CPU PJRT
plugin cannot execute Mosaic custom-calls, and interpret-mode lowers the
kernel body to plain HLO ops that any backend runs natively.

The kernel is wrapped in ``jax.custom_vjp`` because JAX cannot autodiff
through ``pallas_call``; the backward pass is expressed with the same kernel
(two transposed GEMMs), so the hot path is Pallas in both directions.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shapes.  For the CPU/interpret build each grid step lowers
# to an HLO loop iteration, and the loop overhead dominates wallclock
# (measured in EXPERIMENTS.md §Perf: grid=1 is ~6x faster than bm=2048 on
# the im2col GEMMs), so the CPU defaults are large enough that every GEMM
# in SplitCNN-8 at bucket<=64 is a single tile.  These would blow the
# 16 MiB VMEM budget on a real TPU — the TPU-shaped tiling is (512, 512,
# 128); see python/compile/perf_analysis.py for the footprint/MXU table.
DEFAULT_BM = 65536
DEFAULT_BK = 2048
DEFAULT_BN = 512


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_blocks(m: int, k: int, n: int, bm: int, bk: int, bn: int):
    """Clamp requested block sizes to the (padded) problem size."""
    bm = min(bm, _ceil_to(m, 8))
    bk = min(bk, _ceil_to(k, 8))
    bn = min(bn, _ceil_to(n, 8))
    return bm, bk, bn


def _mm_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, activation: Optional[str]):
    """One (bm, bn) output tile; accumulates over the k-grid into o_ref.

    o_ref is revisited across the k dimension (its index_map ignores the k
    grid axis), which is the standard Pallas accumulation idiom: initialise
    at k==0, add partial products, run the fused epilogue at k==nk-1.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        acc = o_ref[...]
        acc = acc + b_ref[...]
        if activation == "relu":
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc


def _matmul_raw(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    activation: Optional[str],
    bm: int,
    bk: int,
    bn: int,
) -> jax.Array:
    """Padded, tiled pallas GEMM: relu(x @ w + b) with f32 accumulation."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {w.shape}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"

    bm, bk, bn = _pick_blocks(m, k, n, bm, bk, bn)
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)

    xp = jnp.pad(x, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else x
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else w
    bp = jnp.pad(b, (0, np_ - n)) if np_ != n else b
    bp = bp.reshape(1, np_)

    nk = kp // bk
    grid = (mp // bm, np_ // bn, nk)

    out = pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(xp, wp, bp)

    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def matmul_bias_act(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    activation: Optional[str] = None,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
) -> jax.Array:
    """``act(x @ w + b)`` as a Pallas kernel with a custom VJP.

    Args:
      x: ``[m, k]`` activations.
      w: ``[k, n]`` weights.
      b: ``[n]`` bias.
      activation: ``None`` or ``"relu"`` — fused into the kernel epilogue.
      bm/bk/bn: tile shape; clamped to the problem size.

    Returns:
      ``[m, n]`` float32 output.
    """
    return _matmul_raw(x, w, b, activation, bm, bk, bn)


def _mba_fwd(x, w, b, activation, bm, bk, bn):
    out = _matmul_raw(x, w, b, activation, bm, bk, bn)
    # For relu, post-activation output > 0 iff pre-activation > 0, so `out`
    # doubles as the mask residual and we never materialise the pre-act.
    return out, (x, w, out)


def _mba_bwd(activation, bm, bk, bn, res, g):
    x, w, out = res
    if activation == "relu":
        g = g * (out > 0.0).astype(g.dtype)
    n = w.shape[1]
    k = w.shape[0]
    m = x.shape[0]
    zk = jnp.zeros((k,), jnp.float32)
    zn = jnp.zeros((n,), jnp.float32)
    # dx = g @ w.T ; dw = x.T @ g — both through the same Pallas kernel so
    # the backward pass is tiled identically to the forward pass.
    dx = _matmul_raw(g, w.T, zk, None, bm, bk, bn)
    dw = _matmul_raw(x.T, g, zn, None, bm, bk, bn)
    db = jnp.sum(g, axis=0)
    del m
    return dx, dw, db


matmul_bias_act.defvjp(_mba_fwd, _mba_bwd)
