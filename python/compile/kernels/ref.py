"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: ``python/tests/test_kernels.py``
sweeps shapes/dtypes with hypothesis and asserts ``assert_allclose`` between
each kernel and its oracle, including gradients (the custom VJPs must match
jax autodiff through the oracle).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def matmul_bias_act_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, activation: Optional[str] = None
) -> jax.Array:
    """Oracle for kernels.matmul.matmul_bias_act."""
    out = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation is not None:
        raise ValueError(f"unknown activation {activation!r}")
    return out


def softmax_xent_ref(logits: jax.Array, onehot: jax.Array) -> jax.Array:
    """Oracle for kernels.softmax_xent.softmax_xent (per-row loss)."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    return lse - jnp.sum(logits * onehot, axis=-1)


def im2col_ref(x: jax.Array, kh: int, kw: int) -> jax.Array:
    """SAME-padded im2col, feature order (i, j, c) — oracle for model._im2col."""
    b, h, w, c = x.shape
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    cols = [
        xp[:, i : i + h, j : j + w, :] for i in range(kh) for j in range(kw)
    ]
    return jnp.concatenate(cols, axis=-1)


def conv2d_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Direct SAME conv oracle (NHWC, HWIO weights) via lax.conv."""
    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + b
