"""L1 Pallas kernel: fused softmax cross-entropy (loss + logits-gradient).

Fuses max / exp / sum / log and the gradient ``p - y`` so the logits tile is
read from HBM exactly once.  Returns the *per-row* loss vector; the caller
applies the per-row weights (used for batch-bucket padding — padded rows get
weight 0, making the bucketed gradient exactly equal to the true-batch
gradient, see DESIGN.md §2).

Wrapped in ``jax.custom_vjp`` (pallas_call is not autodiff-able); the
residual is the softmax ``p`` computed in the forward kernel, so the backward
pass is a cheap elementwise kernel-free expression.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BR = 1024  # row-block; clamped to the batch.


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _xent_kernel(logits_ref, onehot_ref, loss_ref, p_ref):
    z = logits_ref[...]
    y = onehot_ref[...]
    zmax = jnp.max(z, axis=-1, keepdims=True)
    ez = jnp.exp(z - zmax)
    denom = jnp.sum(ez, axis=-1, keepdims=True)
    p = ez / denom
    # loss_r = logsumexp(z) - z[y] = log(denom) + zmax - sum(z * y)
    lse = jnp.log(denom) + zmax
    loss = lse[:, 0] - jnp.sum(z * y, axis=-1)
    loss_ref[...] = loss
    p_ref[...] = p


def _xent_raw(logits: jax.Array, onehot: jax.Array, br: int):
    m, c = logits.shape
    assert onehot.shape == (m, c)
    br = min(br, _ceil_to(m, 8))
    mp = _ceil_to(m, br)
    zp = jnp.pad(logits, ((0, mp - m), (0, 0))) if mp != m else logits
    yp = jnp.pad(onehot, ((0, mp - m), (0, 0))) if mp != m else onehot

    loss, p = pl.pallas_call(
        _xent_kernel,
        grid=(mp // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, c), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br,), lambda i: (i,)),
            pl.BlockSpec((br, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp,), jnp.float32),
            jax.ShapeDtypeStruct((mp, c), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(zp, yp)
    if mp != m:
        loss, p = loss[:m], p[:m]
    return loss, p


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def softmax_xent(logits: jax.Array, onehot: jax.Array, br: int = DEFAULT_BR):
    """Per-row softmax cross-entropy loss.

    Args:
      logits: ``[b, c]`` raw scores.
      onehot: ``[b, c]`` one-hot labels (float32).
      br: row-block size.

    Returns:
      ``[b]`` per-row loss vector (reduce with weights outside).
    """
    loss, _ = _xent_raw(logits, onehot, br)
    return loss


def _sx_fwd(logits, onehot, br):
    loss, p = _xent_raw(logits, onehot, br)
    return loss, (p, onehot)


def _sx_bwd(br, res, g):
    p, onehot = res
    # d loss_r / d logits = p - y ; cotangent g is per-row.
    dlogits = (p - onehot) * g[:, None]
    return dlogits, None


softmax_xent.defvjp(_sx_fwd, _sx_bwd)
