"""L1 performance analysis: VMEM footprint + MXU utilisation *estimates*
for the Pallas GEMM kernel's block configurations.

interpret=True wallclock is CPU-numpy and is NOT a TPU proxy (see
DESIGN.md §Perf), so real-TPU performance is estimated structurally:

- VMEM footprint per grid step: x-tile (bm x bk) + w-tile (bk x bn) +
  out/acc tile (bm x bn) + bias (1 x bn), f32 (or bf16 inputs).
- MXU utilisation estimate: fraction of the 128x128 systolic array kept
  busy, = (min(bm,128)/128) * (min(bn,128)/128) discounted by the k-loop
  fill/drain overhead bk/(bk+128), times the padding efficiency
  (true_dim/padded_dim per axis).

Usage:
    cd python && python -m compile.perf_analysis
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

VMEM_BYTES = 16 * 1024 * 1024  # v4/v5e-class core VMEM


@dataclasses.dataclass
class GemmShape:
    name: str
    m: int
    k: int
    n: int


# The GEMMs SplitCNN-8 actually runs at bucket 64 (im2col conv + dense).
SPLITCNN8_GEMMS: List[GemmShape] = [
    GemmShape("conv1 (im2col)", 64 * 32 * 32, 27, 16),
    GemmShape("conv2 (im2col)", 64 * 32 * 32, 144, 16),
    GemmShape("conv3 (im2col)", 64 * 16 * 16, 144, 32),
    GemmShape("conv4 (im2col)", 64 * 16 * 16, 288, 32),
    GemmShape("conv5 (im2col)", 64 * 8 * 8, 288, 64),
    GemmShape("fc1", 64, 1024, 128),
    GemmShape("fc2", 64, 128, 64),
    GemmShape("fc3", 64, 64, 10),
]


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def pad_to(x: int, m: int) -> int:
    return ceil_div(x, m) * m


def vmem_footprint(bm: int, bk: int, bn: int, bytes_per_el: int = 4) -> int:
    """Per-grid-step VMEM residency of the kernel's tiles."""
    return bytes_per_el * (bm * bk + bk * bn + bm * bn + bn)


def mxu_utilisation(shape: GemmShape, bm: int, bk: int, bn: int) -> float:
    """Structural estimate of 128x128 MXU occupancy for this tiling."""
    bm_eff = min(bm, pad_to(shape.m, 8))
    bn_eff = min(bn, pad_to(shape.n, 8))
    bk_eff = min(bk, pad_to(shape.k, 8))
    # Systolic array occupancy per macro-op.
    occ = min(bm_eff, 128) / 128.0 * min(bn_eff, 128) / 128.0
    # Pipeline fill/drain discount for the k dimension.
    pipe = bk_eff / (bk_eff + 128.0)
    # Padding efficiency: wasted lanes on the true problem.
    pad_m = shape.m / pad_to(shape.m, min(bm_eff, max(shape.m, 1)))
    pad_n = shape.n / max(bn_eff, shape.n) if shape.n < bn_eff else 1.0
    pad_n = shape.n / pad_to(shape.n, 8) if shape.n < 8 else pad_n
    return occ * pipe * pad_m * pad_n


def analyse(
    configs: List[Tuple[str, int, int, int]],
    gemms: List[GemmShape] = SPLITCNN8_GEMMS,
) -> None:
    print(f"{'config':<24} {'gemm':<18} {'VMEM':>10} {'fits':>5} {'MXU est':>8}")
    for label, bm, bk, bn in configs:
        for g in gemms:
            bm_c = min(bm, pad_to(g.m, 8))
            bk_c = min(bk, pad_to(g.k, 8))
            bn_c = min(bn, pad_to(g.n, 8))
            v = vmem_footprint(bm_c, bk_c, bn_c)
            fits = "yes" if v <= VMEM_BYTES else "NO"
            u = mxu_utilisation(g, bm_c, bk_c, bn_c)
            print(
                f"{label:<24} {g.name:<18} {v / 1024.0:>8.0f}Ki {fits:>5} {u:>7.1%}"
            )
        print()


def main() -> None:
    print("= Pallas GEMM block analysis (TPU-shaped estimates) =\n")
    print(f"VMEM budget: {VMEM_BYTES // (1024 * 1024)} MiB\n")
    analyse(
        [
            # The TPU-shaped tiling DESIGN.md §Perf recommends.
            ("tpu (128,512,128)", 128, 512, 128),
            # A bigger m-tile: better for the skinny im2col GEMMs.
            ("tpu (512,512,128)", 512, 512, 128),
            # The CPU-run tiling (grid=1): VMEM-infeasible on TPU for the
            # conv GEMMs — which is exactly why the defaults differ.
            ("cpu (65536,2048,512)", 65536, 2048, 512),
        ]
    )
    print(
        "Takeaway: on CPU (interpret mode) grid-step loop overhead dominates\n"
        "and one big tile wins; on TPU the (512,512,128) tiling keeps every\n"
        "conv GEMM inside the 16 MiB VMEM budget with ~2-3x better estimated\n"
        "MXU occupancy than (128,512,128) on the skinny im2col shapes."
    )


if __name__ == "__main__":
    main()
