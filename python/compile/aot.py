"""AOT exporter: lower the L2 split model to HLO **text** artifacts.

Python runs once, at build time (``make artifacts``); the Rust coordinator
loads these artifacts via the ``xla`` crate's PJRT CPU client and never
touches Python on the training path.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts are shape-specialised, so we export one executable per
(function, cut layer, batch bucket).  Buckets are powers of two; the Rust
runtime pads real batches up to the bucket with zero-weighted rows, which
keeps numerics exactly equal to the true batch (weighted reductions in the
model).  A ``manifest.json`` describes every artifact's argument/output
layout plus the per-block cost tables consumed by the latency model.

Usage:
    cd python && python -m compile.aot --out-dir ../artifacts \
        [--cuts 1,2,...,7] [--buckets 1,2,4,8,16,32,64] [--classes 10]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M

F32 = "f32"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape: Tuple[int, ...]) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _arg_entry(name: str, shape: Sequence[int]) -> dict:
    return {"name": name, "shape": list(shape), "dtype": F32}


def _param_arg_entries(
    prefix: str, shapes: List[Tuple[Tuple[int, ...], Tuple[int, ...]]], blocks: range
) -> List[dict]:
    out = []
    for bi in blocks:
        w, b = shapes[bi]
        out.append(_arg_entry(f"{prefix}.block{bi + 1}.w", w))
        out.append(_arg_entry(f"{prefix}.block{bi + 1}.b", b))
    return out


def build_exports(cuts: Sequence[int], buckets: Sequence[int], num_classes: int):
    """Yield (name, lowered_fn, arg_entries, out_entries, meta) tuples."""
    shapes = M.param_shapes(num_classes)
    L = M.NUM_BLOCKS

    for bsz in buckets:
        x_spec = _spec((bsz, M.IMG, M.IMG, M.IN_CH))
        y_spec = _spec((bsz, num_classes))
        w_spec = _spec((bsz,))

        for cut in cuts:
            a_shape = M.activation_shape(cut, bsz, num_classes)
            a_spec = _spec(a_shape)
            cp_specs = [_spec(s) for pair in shapes[:cut] for s in pair]
            sp_specs = [_spec(s) for pair in shapes[cut:] for s in pair]

            # -- client_fwd --------------------------------------------------
            def cf(x, *cp, _cut=cut):
                return M.client_fwd(x, cp, _cut, num_classes)

            yield (
                f"client_fwd_c{cut}_b{bsz}",
                jax.jit(cf).lower(x_spec, *cp_specs),
                [_arg_entry("x", x_spec.shape)]
                + _param_arg_entries("client", shapes, range(0, cut)),
                [_arg_entry("a", a_shape)],
                {"fn": "client_fwd", "cut": cut, "bucket": bsz},
            )

            # -- server_step -------------------------------------------------
            def ss(a, y, w, *sp, _cut=cut):
                return M.server_step(a, y, w, sp, _cut, num_classes)

            out_entries = [
                _arg_entry("loss", ()),
                _arg_entry("correct", ()),
                _arg_entry("grad_a", a_shape),
            ]
            for bi in range(cut, L):
                wsh, bsh = shapes[bi]
                out_entries.append(_arg_entry(f"grad.block{bi + 1}.w", wsh))
                out_entries.append(_arg_entry(f"grad.block{bi + 1}.b", bsh))
            yield (
                f"server_step_c{cut}_b{bsz}",
                jax.jit(ss).lower(a_spec, y_spec, w_spec, *sp_specs),
                [
                    _arg_entry("a", a_shape),
                    _arg_entry("onehot", y_spec.shape),
                    _arg_entry("weights", w_spec.shape),
                ]
                + _param_arg_entries("server", shapes, range(cut, L)),
                out_entries,
                {"fn": "server_step", "cut": cut, "bucket": bsz},
            )

            # -- client_bwd --------------------------------------------------
            def cb(x, ga, *cp, _cut=cut):
                return M.client_bwd(x, cp, ga, _cut, num_classes)

            out_entries = []
            for bi in range(0, cut):
                wsh, bsh = shapes[bi]
                out_entries.append(_arg_entry(f"grad.block{bi + 1}.w", wsh))
                out_entries.append(_arg_entry(f"grad.block{bi + 1}.b", bsh))
            yield (
                f"client_bwd_c{cut}_b{bsz}",
                jax.jit(cb).lower(x_spec, a_spec, *cp_specs),
                [_arg_entry("x", x_spec.shape), _arg_entry("grad_a", a_shape)]
                + _param_arg_entries("client", shapes, range(0, cut)),
                out_entries,
                {"fn": "client_bwd", "cut": cut, "bucket": bsz},
            )

        # -- monolithic oracle + eval (per bucket, no cut) --------------------
        p_specs = [_spec(s) for pair in shapes for s in pair]

        def fs(x, y, w, *ps):
            return M.full_step(x, y, w, ps, num_classes)

        out_entries = [_arg_entry("loss", ()), _arg_entry("correct", ())]
        for bi in range(L):
            wsh, bsh = shapes[bi]
            out_entries.append(_arg_entry(f"grad.block{bi + 1}.w", wsh))
            out_entries.append(_arg_entry(f"grad.block{bi + 1}.b", bsh))
        yield (
            f"full_step_b{bsz}",
            jax.jit(fs).lower(x_spec, y_spec, w_spec, *p_specs),
            [
                _arg_entry("x", x_spec.shape),
                _arg_entry("onehot", y_spec.shape),
                _arg_entry("weights", w_spec.shape),
            ]
            + _param_arg_entries("model", shapes, range(L)),
            out_entries,
            {"fn": "full_step", "cut": 0, "bucket": bsz},
        )

        def ff(x, *ps):
            return M.full_fwd(x, ps, num_classes)

        yield (
            f"full_fwd_b{bsz}",
            jax.jit(ff).lower(x_spec, *p_specs),
            [_arg_entry("x", x_spec.shape)]
            + _param_arg_entries("model", shapes, range(L)),
            [_arg_entry("logits", (bsz, num_classes))],
            {"fn": "full_fwd", "cut": 0, "bucket": bsz},
        )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file smoke path")
    ap.add_argument("--cuts", default=",".join(str(c) for c in M.VALID_CUTS))
    ap.add_argument("--buckets", default="1,2,4,8,16,32,64")
    ap.add_argument("--classes", type=int, default=10)
    args = ap.parse_args()

    cuts = [int(c) for c in args.cuts.split(",") if c]
    buckets = sorted({int(b) for b in args.buckets.split(",") if b})
    for c in cuts:
        assert c in M.VALID_CUTS, f"cut {c} outside {M.VALID_CUTS}"

    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    manifest = {
        "model": "splitcnn8",
        "num_classes": args.classes,
        "img": M.IMG,
        "in_ch": M.IN_CH,
        "num_blocks": M.NUM_BLOCKS,
        "valid_cuts": list(M.VALID_CUTS),
        "buckets": buckets,
        "param_shapes": [
            {"w": list(w), "b": list(b)} for (w, b) in M.param_shapes(args.classes)
        ],
        "block_table": M.block_table(args.classes),
        "artifacts": [],
    }

    t0 = time.time()
    n = 0
    for name, lowered, arg_entries, out_entries, meta in build_exports(
        cuts, buckets, args.classes
    ):
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "path": f"{name}.hlo.txt",
                "args": arg_entries,
                "outputs": out_entries,
                "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                **meta,
            }
        )
        n += 1
        if n % 20 == 0:
            print(f"  [{n}] {name} ({time.time() - t0:.1f}s)", flush=True)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(
        f"wrote {n} artifacts + manifest.json to {out_dir} "
        f"in {time.time() - t0:.1f}s"
    )

    # Legacy smoke path used by the original scaffold Makefile.
    if args.out:
        with open(args.out, "w") as f:
            f.write("see manifest.json")


if __name__ == "__main__":
    main()
