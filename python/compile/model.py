"""L2: HASFL's split CNN as pure JAX, built on the L1 Pallas kernels.

The executable model is **SplitCNN-8**, a VGG-style 8-block CNN for 32x32x3
inputs (the paper trains VGG-16/ResNet-18 on CIFAR; the analytic layer
profiles of those live in ``rust/src/model/profiles.rs`` and drive the
paper-scale latency simulations, while this model is the one actually
trained end-to-end through PJRT — see DESIGN.md §4).

Split semantics (paper §III): a cut at ``c`` puts blocks ``1..c`` on the
device (client-side sub-model ``w_c``) and blocks ``c+1..L`` on the edge
server (``w_s``).  The exported functions are exactly the five HASFL steps:

- ``client_fwd``  — step a1: mini-batch -> activations at the cut.
- ``server_step`` — step a3: activations + labels -> loss, accuracy,
  server-side grads, and the activations' gradient (sent back in a4).
- ``client_bwd``  — step a5: recompute-based VJP of the client sub-model.
- ``full_step``   — monolithic oracle used to prove split == centralized.
- ``full_fwd``    — inference path for test-set evaluation.

Every GEMM (conv via explicit im2col, dense) goes through the Pallas
``matmul_bias_act`` kernel and the loss through the Pallas ``softmax_xent``
kernel, so the L1 hot spot is on the path in both directions.

Per-row weights: batch buckets are power-of-two (HLO is shape-specialised),
so real batches are padded and padded rows carry weight 0.  All reductions
here are weighted sums, which makes bucketed numerics *exactly* equal to
true-batch numerics.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import matmul_bias_act, softmax_xent

# ---------------------------------------------------------------------------
# Architecture definition
# ---------------------------------------------------------------------------

IMG = 32
IN_CH = 3


@dataclasses.dataclass(frozen=True)
class Block:
    """One cuttable block of SplitCNN-8."""

    name: str
    kind: str  # "conv" (3x3 SAME, relu, optional 2x2 maxpool) or "dense"
    cin: int
    cout: int
    pool: bool = False  # conv only
    relu: bool = True
    # spatial size of the *output* feature map (1 for dense blocks)
    out_hw: int = 0


def _build_arch(num_classes: int) -> List[Block]:
    return [
        Block("conv1", "conv", IN_CH, 16, pool=False, out_hw=32),
        Block("conv2", "conv", 16, 16, pool=True, out_hw=16),
        Block("conv3", "conv", 16, 32, pool=False, out_hw=16),
        Block("conv4", "conv", 32, 32, pool=True, out_hw=8),
        Block("conv5", "conv", 32, 64, pool=True, out_hw=4),
        Block("fc1", "dense", 4 * 4 * 64, 128, out_hw=1),
        Block("fc2", "dense", 128, 64, out_hw=1),
        Block("fc3", "dense", 64, num_classes, relu=False, out_hw=1),
    ]


ARCH10 = _build_arch(10)
ARCH100 = _build_arch(100)
NUM_BLOCKS = len(ARCH10)  # L = 8
# Valid cut layers: 1..7 (cut=c keeps blocks 1..c on the device).
VALID_CUTS = tuple(range(1, NUM_BLOCKS))


def arch(num_classes: int = 10) -> List[Block]:
    if num_classes == 10:
        return ARCH10
    if num_classes == 100:
        return ARCH100
    return _build_arch(num_classes)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def param_shapes(num_classes: int = 10) -> List[Tuple[Tuple[int, ...], Tuple[int, ...]]]:
    """Per-block (weight_shape, bias_shape)."""
    shapes = []
    for blk in arch(num_classes):
        if blk.kind == "conv":
            shapes.append(((3, 3, blk.cin, blk.cout), (blk.cout,)))
        else:
            shapes.append(((blk.cin, blk.cout), (blk.cout,)))
    return shapes


def init_params(rng: jax.Array, num_classes: int = 10) -> List[jax.Array]:
    """He-init, returned as a flat list [w1, b1, w2, b2, ...]."""
    params: List[jax.Array] = []
    for (wshape, bshape) in param_shapes(num_classes):
        rng, sub = jax.random.split(rng)
        fan_in = 1
        for d in wshape[:-1]:
            fan_in *= d
        w = jax.random.normal(sub, wshape, jnp.float32) * jnp.sqrt(2.0 / fan_in)
        params.append(w)
        params.append(jnp.zeros(bshape, jnp.float32))
    return params


def params_per_block() -> int:
    return 2  # (w, b)


def split_params(
    params: Sequence[jax.Array], cut: int
) -> Tuple[List[jax.Array], List[jax.Array]]:
    """client params (blocks 1..cut), server params (blocks cut+1..L)."""
    k = cut * params_per_block()
    return list(params[:k]), list(params[k:])


# ---------------------------------------------------------------------------
# Forward building blocks (all GEMMs via the Pallas kernel)
# ---------------------------------------------------------------------------


def _im2col(x: jax.Array, kh: int = 3, kw: int = 3) -> jax.Array:
    """SAME-padded im2col with explicit (i, j, c) feature order.

    Kept deliberately explicit (slice + concat, all differentiable) so the
    weight reshape ``[kh,kw,cin,cout] -> [kh*kw*cin, cout]`` matches the
    column order by construction.
    """
    b, h, w, c = x.shape
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    cols = [
        xp[:, i : i + h, j : j + w, :] for i in range(kh) for j in range(kw)
    ]
    return jnp.concatenate(cols, axis=-1)


def _conv_block(x: jax.Array, w: jax.Array, b: jax.Array, blk: Block) -> jax.Array:
    bsz, h, wd, _ = x.shape
    kh, kw, cin, cout = w.shape
    cols = _im2col(x, kh, kw).reshape(bsz * h * wd, kh * kw * cin)
    act = "relu" if blk.relu else None
    out = matmul_bias_act(cols, w.reshape(kh * kw * cin, cout), b, act)
    out = out.reshape(bsz, h, wd, cout)
    if blk.pool:
        out = out.reshape(bsz, h // 2, 2, wd // 2, 2, cout).max(axis=(2, 4))
    return out


def _dense_block(x: jax.Array, w: jax.Array, b: jax.Array, blk: Block) -> jax.Array:
    if x.ndim > 2:
        x = x.reshape(x.shape[0], -1)
    act = "relu" if blk.relu else None
    return matmul_bias_act(x, w, b, act)


def _apply_blocks(
    x: jax.Array,
    params: Sequence[jax.Array],
    blocks: Sequence[Block],
) -> jax.Array:
    h = x
    for i, blk in enumerate(blocks):
        w, b = params[2 * i], params[2 * i + 1]
        if blk.kind == "conv":
            h = _conv_block(h, w, b, blk)
        else:
            h = _dense_block(h, w, b, blk)
    return h


def _loss_from_logits(
    logits: jax.Array, onehot: jax.Array, weights: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Weighted mean loss and weighted correct-count (both scalars).

    ``weights`` are per-row; padded rows carry 0.  The caller normalises by
    sum(weights) (== true batch size when weights are 1/0 indicators).
    """
    per_row = softmax_xent(logits, onehot)
    loss = jnp.sum(per_row * weights) / jnp.maximum(jnp.sum(weights), 1.0)
    pred = jnp.argmax(logits, axis=-1)
    truth = jnp.argmax(onehot, axis=-1)
    correct = jnp.sum((pred == truth).astype(jnp.float32) * weights)
    return loss, correct


# ---------------------------------------------------------------------------
# The five exported HASFL step functions
# ---------------------------------------------------------------------------


def client_fwd(
    x: jax.Array, client_params: Sequence[jax.Array], cut: int, num_classes: int = 10
) -> Tuple[jax.Array]:
    """Step a1: client-side forward propagation -> activations at the cut."""
    blocks = arch(num_classes)[:cut]
    return (_apply_blocks(x, client_params, blocks),)


def _server_obj(a, server_params, onehot, weights, blocks):
    logits = _apply_blocks(a, server_params, blocks)
    loss, correct = _loss_from_logits(logits, onehot, weights)
    return loss, correct


def server_step(
    a: jax.Array,
    onehot: jax.Array,
    weights: jax.Array,
    server_params: Sequence[jax.Array],
    cut: int,
    num_classes: int = 10,
):
    """Step a3: server-side FP + BP.

    Returns ``(loss, correct, grad_a, *grads_server)``; the Rust coordinator
    splits ``grads_server`` into common (blocks > L_c) and non-common parts
    per Eqns (4)-(5) and sends ``grad_a`` back to the device (step a4).
    """
    blocks = arch(num_classes)[cut:]
    grad_fn = jax.value_and_grad(
        lambda a_, ps: _server_obj(a_, ps, onehot, weights, blocks),
        argnums=(0, 1),
        has_aux=True,
    )
    (loss, correct), (ga, gps) = grad_fn(a, list(server_params))
    return (loss, correct, ga, *gps)


def client_bwd(
    x: jax.Array,
    client_params: Sequence[jax.Array],
    ga: jax.Array,
    cut: int,
    num_classes: int = 10,
):
    """Step a5: recompute-based VJP of the client sub-model.

    The client re-runs its forward (cheap: shallow sub-model) and pulls the
    received activations' gradient through it.  Stateless — no residual has
    to survive between the a1 and a5 executions, which keeps the PJRT
    artifacts independent.
    """
    blocks = arch(num_classes)[:cut]

    def fwd(ps):
        return _apply_blocks(x, ps, blocks)

    _, vjp = jax.vjp(fwd, list(client_params))
    (gps,) = vjp(ga)
    return tuple(gps)


def full_step(
    x: jax.Array,
    onehot: jax.Array,
    weights: jax.Array,
    params: Sequence[jax.Array],
    num_classes: int = 10,
):
    """Monolithic training step — the centralized-equivalence oracle."""
    blocks = arch(num_classes)
    grad_fn = jax.value_and_grad(
        lambda ps: _server_obj(x, ps, onehot, weights, blocks),
        has_aux=True,
    )
    (loss, correct), gps = grad_fn(list(params))
    return (loss, correct, *gps)


def full_fwd(x: jax.Array, params: Sequence[jax.Array], num_classes: int = 10):
    """Inference: logits for test-set evaluation."""
    return (_apply_blocks(x, params, arch(num_classes)),)


# ---------------------------------------------------------------------------
# Analytic per-block cost tables (exported into the artifact manifest and
# consumed by rust/src/model + rust/src/latency).
# ---------------------------------------------------------------------------


def block_table(num_classes: int = 10) -> List[dict]:
    """Per-block profile: FLOPs, activation bytes, param bytes.

    - ``fwd_flops`` (rho_j increments) — 2*K*M MACs-as-FLOPs per sample.
    - ``bwd_flops`` (varpi_j increments) — 2x fwd (dx + dw GEMMs).
    - ``act_bytes`` (psi_j == chi_j) — f32 activation size at the block
      output *per sample* (what crosses the network if the cut is here).
    - ``param_bytes`` (delta_j increments) — f32 parameter size.
    """
    rows = []
    for blk in arch(num_classes):
        if blk.kind == "conv":
            # out spatial before pooling equals input spatial
            in_hw = blk.out_hw * 2 if blk.pool else blk.out_hw
            macs = 9 * blk.cin * blk.cout * in_hw * in_hw
            act_elems = blk.out_hw * blk.out_hw * blk.cout
            nparams = 9 * blk.cin * blk.cout + blk.cout
        else:
            macs = blk.cin * blk.cout
            act_elems = blk.cout
            nparams = blk.cin * blk.cout + blk.cout
        rows.append(
            dict(
                name=blk.name,
                kind=blk.kind,
                fwd_flops=2.0 * macs,
                bwd_flops=4.0 * macs,
                act_bytes=4 * act_elems,
                param_bytes=4 * nparams,
                n_params=nparams,
            )
        )
    return rows


def activation_shape(cut: int, batch: int, num_classes: int = 10) -> Tuple[int, ...]:
    """Shape of the smashed data at cut ``cut`` for batch ``batch``."""
    blk = arch(num_classes)[cut - 1]
    if blk.kind == "conv":
        return (batch, blk.out_hw, blk.out_hw, blk.cout)
    return (batch, blk.cout)
