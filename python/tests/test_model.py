"""L2 split-model correctness.

The core invariant of split federated learning: running the five-step split
pipeline (client_fwd -> server_step -> client_bwd) must produce EXACTLY the
same loss and gradients as the monolithic full_step, for every cut layer.
Also checks the padding/weighting contract the batch-bucket runtime relies
on, and that a few SGD steps actually reduce the loss.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

RTOL = 3e-4
ATOL = 3e-6


@pytest.fixture(scope="module")
def setup():
    rng = jax.random.PRNGKey(42)
    params = M.init_params(rng)
    r1, r2 = jax.random.split(rng)
    x = jax.random.normal(r1, (8, 32, 32, 3), jnp.float32)
    labels = jax.random.randint(r2, (8,), 0, 10)
    onehot = jax.nn.one_hot(labels, 10, dtype=jnp.float32)
    weights = jnp.ones((8,), jnp.float32)
    full = M.full_step(x, onehot, weights, params)
    return params, x, onehot, weights, full


@pytest.mark.parametrize("cut", list(M.VALID_CUTS))
def test_split_equals_full(setup, cut):
    params, x, onehot, weights, full = setup
    cp, sp = M.split_params(params, cut)
    (a,) = M.client_fwd(x, cp, cut)
    res = M.server_step(a, onehot, weights, sp, cut)
    loss_s, corr_s, ga = res[0], res[1], res[2]
    gc = M.client_bwd(x, cp, ga, cut)

    np.testing.assert_allclose(float(loss_s), float(full[0]), rtol=1e-5)
    np.testing.assert_allclose(float(corr_s), float(full[1]), rtol=1e-6)
    split_grads = list(gc) + list(res[3:])
    full_grads = list(full[2:])
    assert len(split_grads) == len(full_grads) == 2 * M.NUM_BLOCKS
    for g1, g2 in zip(split_grads, full_grads):
        np.testing.assert_allclose(
            np.asarray(g1), np.asarray(g2), rtol=RTOL, atol=ATOL
        )


def test_activation_shape_matches_client_fwd(setup):
    params, x, *_ = setup
    for cut in M.VALID_CUTS:
        cp, _ = M.split_params(params, cut)
        (a,) = M.client_fwd(x, cp, cut)
        assert tuple(a.shape) == M.activation_shape(cut, x.shape[0])


def test_padding_weights_exactness(setup):
    """Bucket padding with zero weights must be numerically exact.

    A true batch of 5 padded to bucket 8 (rows 5..7 weight 0) must give the
    same loss and the same gradients as the unpadded batch of 5.
    """
    params, x, onehot, _, _ = setup
    xt, yt = x[:5], onehot[:5]
    wt = jnp.ones((5,), jnp.float32)
    true = M.full_step(xt, yt, wt, params)

    xp = jnp.concatenate([xt, jnp.zeros((3, 32, 32, 3), jnp.float32)])
    yp = jnp.concatenate([yt, jnp.zeros((3, 10), jnp.float32)])
    # NB: padded onehot rows are all-zero; weights kill their contribution.
    yp = yp.at[5:, 0].set(1.0)  # give them a valid one-hot anyway
    wp = jnp.concatenate([wt, jnp.zeros((3,), jnp.float32)])
    padded = M.full_step(xp, yp, wp, params)

    np.testing.assert_allclose(float(padded[0]), float(true[0]), rtol=1e-5)
    np.testing.assert_allclose(float(padded[1]), float(true[1]), rtol=1e-6)
    for g1, g2 in zip(padded[2:], true[2:]):
        np.testing.assert_allclose(
            np.asarray(g1), np.asarray(g2), rtol=RTOL, atol=ATOL
        )


def test_sgd_reduces_loss():
    rng = jax.random.PRNGKey(0)
    params = M.init_params(rng)
    r1, r2 = jax.random.split(rng)
    x = jax.random.normal(r1, (16, 32, 32, 3), jnp.float32)
    labels = jax.random.randint(r2, (16,), 0, 10)
    onehot = jax.nn.one_hot(labels, 10, dtype=jnp.float32)
    w = jnp.ones((16,), jnp.float32)
    step = jax.jit(lambda *a: M.full_step(*a[:3], a[3:], 10))
    losses = []
    lr = 0.05
    for _ in range(6):
        out = step(x, onehot, w, *params)
        losses.append(float(out[0]))
        params = [p - lr * g for p, g in zip(params, out[2:])]
    assert losses[-1] < losses[0], losses


def test_full_fwd_logits_shape(setup):
    params, x, *_ = setup
    (logits,) = M.full_fwd(x, params)
    assert logits.shape == (8, 10)


def test_block_table_consistency():
    table = M.block_table(10)
    assert len(table) == M.NUM_BLOCKS
    shapes = M.param_shapes(10)
    for row, (wsh, bsh) in zip(table, shapes):
        n = int(np.prod(wsh)) + int(np.prod(bsh))
        assert row["n_params"] == n
        assert row["param_bytes"] == 4 * n
        assert row["fwd_flops"] > 0 and row["bwd_flops"] == 2 * row["fwd_flops"]


def test_block_table_act_bytes_match_shapes():
    for cut in M.VALID_CUTS:
        shp = M.activation_shape(cut, 1)
        elems = int(np.prod(shp))
        assert M.block_table(10)[cut - 1]["act_bytes"] == 4 * elems


def test_cifar100_head():
    params = M.init_params(jax.random.PRNGKey(1), num_classes=100)
    x = jnp.zeros((2, 32, 32, 3), jnp.float32)
    (logits,) = M.full_fwd(x, params, num_classes=100)
    assert logits.shape == (2, 100)
