"""L1 kernel correctness: Pallas vs pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and block configurations; every check is an
``assert_allclose`` against the oracle, for values AND gradients (the
custom VJPs must agree with autodiff through the oracle).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_bias_act, softmax_xent
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

RTOL = 2e-4
ATOL = 2e-5


def _rand(rng, shape):
    return jax.random.normal(rng, shape, jnp.float32)


# ---------------------------------------------------------------------------
# matmul_bias_act
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 33),
    k=st.integers(1, 40),
    n=st.integers(1, 24),
    act=st.sampled_from([None, "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_value_matches_ref(m, k, n, act, seed):
    rng = jax.random.PRNGKey(seed)
    r1, r2, r3 = jax.random.split(rng, 3)
    x, w, b = _rand(r1, (m, k)), _rand(r2, (k, n)), _rand(r3, (n,))
    got = matmul_bias_act(x, w, b, act)
    want = ref.matmul_bias_act_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(2, 17),
    k=st.integers(2, 19),
    n=st.integers(2, 13),
    act=st.sampled_from([None, "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_grads_match_ref(m, k, n, act, seed):
    rng = jax.random.PRNGKey(seed)
    r1, r2, r3, r4 = jax.random.split(rng, 4)
    x, w, b = _rand(r1, (m, k)), _rand(r2, (k, n)), _rand(r3, (n,))
    ct = _rand(r4, (m, n))  # random cotangent, not all-ones

    def f_kernel(x_, w_, b_):
        return jnp.sum(matmul_bias_act(x_, w_, b_, act) * ct)

    def f_ref(x_, w_, b_):
        return jnp.sum(ref.matmul_bias_act_ref(x_, w_, b_, act) * ct)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a_, b_ in zip(gk, gr):
        np.testing.assert_allclose(a_, b_, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("bm,bk,bn", [(8, 8, 8), (16, 32, 8), (64, 64, 64), (2048, 2048, 512)])
def test_matmul_block_shape_invariance(bm, bk, bn):
    """Tiling must not change the numbers (block-shape sweep for §Perf)."""
    rng = jax.random.PRNGKey(7)
    r1, r2, r3 = jax.random.split(rng, 3)
    x, w, b = _rand(r1, (37, 45)), _rand(r2, (45, 21)), _rand(r3, (21,))
    got = matmul_bias_act(x, w, b, "relu", bm, bk, bn)
    want = ref.matmul_bias_act_ref(x, w, b, "relu")
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_matmul_batch_one():
    rng = jax.random.PRNGKey(3)
    x, w, b = _rand(rng, (1, 5)), _rand(rng, (5, 4)), _rand(rng, (4,))
    np.testing.assert_allclose(
        matmul_bias_act(x, w, b, None),
        ref.matmul_bias_act_ref(x, w, b, None),
        rtol=RTOL,
        atol=ATOL,
    )


def test_matmul_relu_clamps_negative():
    x = jnp.array([[-1.0, 2.0]])
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros((2,), jnp.float32)
    out = matmul_bias_act(x, w, b, "relu")
    assert float(out[0, 0]) == 0.0 and float(out[0, 1]) == 2.0


def test_matmul_rejects_bad_shapes():
    x = jnp.zeros((2, 3))
    w = jnp.zeros((4, 5))
    b = jnp.zeros((5,))
    with pytest.raises(AssertionError):
        matmul_bias_act(x, w, b, None)


# ---------------------------------------------------------------------------
# softmax_xent
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 40),
    c=st.integers(2, 100),
    scale=st.floats(0.1, 30.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_xent_value_matches_ref(b, c, scale, seed):
    rng = jax.random.PRNGKey(seed)
    r1, r2 = jax.random.split(rng)
    logits = _rand(r1, (b, c)) * scale  # scale stresses the max-shift path
    labels = jax.random.randint(r2, (b,), 0, c)
    onehot = jax.nn.one_hot(labels, c, dtype=jnp.float32)
    got = softmax_xent(logits, onehot)
    want = ref.softmax_xent_ref(logits, onehot)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 24), c=st.integers(2, 32), seed=st.integers(0, 2**31 - 1))
def test_xent_grads_match_ref(b, c, seed):
    rng = jax.random.PRNGKey(seed)
    r1, r2, r3 = jax.random.split(rng, 3)
    logits = _rand(r1, (b, c))
    labels = jax.random.randint(r2, (b,), 0, c)
    onehot = jax.nn.one_hot(labels, c, dtype=jnp.float32)
    wvec = jax.nn.softplus(_rand(r3, (b,)))  # positive per-row weights

    def f_kernel(z):
        return jnp.sum(softmax_xent(z, onehot) * wvec)

    def f_ref(z):
        return jnp.sum(ref.softmax_xent_ref(z, onehot) * wvec)

    np.testing.assert_allclose(
        jax.grad(f_kernel)(logits), jax.grad(f_ref)(logits), rtol=RTOL, atol=1e-5
    )


def test_xent_extreme_logits_stable():
    """Large logits must not overflow (max-shift inside the kernel)."""
    logits = jnp.array([[1000.0, 0.0], [-1000.0, 0.0]], jnp.float32)
    onehot = jnp.array([[1.0, 0.0], [0.0, 1.0]], jnp.float32)
    loss = softmax_xent(logits, onehot)
    assert bool(jnp.all(jnp.isfinite(loss)))
    np.testing.assert_allclose(loss[0], 0.0, atol=1e-5)


def test_xent_uniform_logits():
    c = 10
    logits = jnp.zeros((4, c), jnp.float32)
    onehot = jax.nn.one_hot(jnp.arange(4) % c, c, dtype=jnp.float32)
    loss = softmax_xent(logits, onehot)
    np.testing.assert_allclose(loss, jnp.full((4,), jnp.log(c)), rtol=1e-5)


# ---------------------------------------------------------------------------
# im2col helper (feature ordering is load-bearing for the conv lowering)
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 4),
    hw=st.sampled_from([4, 8]),
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_im2col_conv_matches_lax_conv(b, hw, cin, cout, seed):
    from compile.model import _im2col

    rng = jax.random.PRNGKey(seed)
    r1, r2, r3 = jax.random.split(rng, 3)
    x = _rand(r1, (b, hw, hw, cin))
    w = _rand(r2, (3, 3, cin, cout))
    bias = _rand(r3, (cout,))
    cols = _im2col(x).reshape(b * hw * hw, 9 * cin)
    got = (cols @ w.reshape(9 * cin, cout) + bias).reshape(b, hw, hw, cout)
    want = ref.conv2d_ref(x, w, bias)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
