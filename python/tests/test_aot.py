"""AOT exporter smoke tests: HLO text round-trips and manifest consistency."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")


def test_to_hlo_text_roundtrip():
    """Lowered HLO text must be parseable (non-empty, ENTRY present)."""

    def f(x):
        return (x * 2.0 + 1.0,)

    low = jax.jit(f).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(low)
    assert "ENTRY" in text and "HloModule" in text


def test_build_exports_structure():
    exports = list(aot.build_exports(cuts=[2], buckets=[4], num_classes=10))
    names = [e[0] for e in exports]
    assert names == [
        "client_fwd_c2_b4",
        "server_step_c2_b4",
        "client_bwd_c2_b4",
        "full_step_b4",
        "full_fwd_b4",
    ]
    for name, lowered, args, outs, meta in exports:
        assert meta["bucket"] == 4
        # Arg/output entries carry explicit shapes for the Rust loader.
        for ent in args + outs:
            assert "shape" in ent and "dtype" in ent


def test_export_one_artifact(tmp_path):
    """Full exporter run on a minimal (1 cut x 1 bucket) grid."""
    cmd = [
        sys.executable,
        "-m",
        "compile.aot",
        "--out-dir",
        str(tmp_path),
        "--cuts",
        "3",
        "--buckets",
        "2",
    ]
    env = dict(os.environ)
    subprocess.run(cmd, check=True, cwd=os.path.dirname(os.path.dirname(__file__)), env=env)
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["model"] == "splitcnn8"
    assert len(manifest["artifacts"]) == 5
    for art in manifest["artifacts"]:
        p = tmp_path / art["path"]
        assert p.exists() and p.stat().st_size > 100
        text = p.read_text()
        assert "ENTRY" in text


def test_server_step_arg_count_matches_model():
    exports = list(aot.build_exports(cuts=[5], buckets=[1], num_classes=10))
    ss = [e for e in exports if e[0].startswith("server_step")][0]
    _, _, args, outs, _ = ss
    # a, onehot, weights + 2*(L-cut) params
    assert len(args) == 3 + 2 * (M.NUM_BLOCKS - 5)
    # loss, correct, grad_a + 2*(L-cut) grads
    assert len(outs) == 3 + 2 * (M.NUM_BLOCKS - 5)


def test_manifest_block_table_matches_model():
    assert M.block_table(10) == M.block_table(10)
    t10 = M.block_table(10)
    t100 = M.block_table(100)
    # Only the classifier head differs between CIFAR-10/100 variants.
    assert t10[:-1] == t100[:-1]
    assert t100[-1]["n_params"] > t10[-1]["n_params"]
