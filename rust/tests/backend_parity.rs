//! Cross-backend parity suite (DESIGN.md §11).
//!
//! Always-runnable half: the native backend must be bit-deterministic
//! across sequential, pooled-concurrent, and checkpoint-resumed modes.
//! PJRT half (runs when AOT artifacts are present, standardized
//! `SKIPPED:` line otherwise): the synthesized native manifest must match
//! the on-disk one, and native-vs-PJRT outputs must agree within float
//! tolerance — at the engine level for every step function and at the
//! session level over several (cut, batch) pairs. Exact equality across
//! backends is *not* expected: XLA fuses and reorders f32 reductions.

use std::path::PathBuf;

use hasfl::backend::{skip_pjrt_only, BackendKind, ModelSpec};
use hasfl::config::{Config, StrategyKind};
use hasfl::experiment::Experiment;
use hasfl::model::{Manifest, Params};
use hasfl::runtime::{tensor_to_host, EngineHandle, EngineSpec, HostTensor, StepArtifacts};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The artifacts dir when the PJRT half can run, else a standardized skip.
fn pjrt_dir(what: &str) -> Option<PathBuf> {
    let dir = artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        skip_pjrt_only(&format!(
            "{what} needs on-disk AOT artifacts (run `make artifacts`); \
             the native half of this suite still gates every machine"
        ));
        None
    }
}

fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= atol + rtol * y.abs(),
            "{what}[{i}]: native {x} vs pjrt {y}"
        );
    }
}

fn fake_batch(
    bucket: usize,
    classes: usize,
    true_b: usize,
) -> (HostTensor, HostTensor, HostTensor) {
    let mut rng = hasfl::rng::Pcg32::seeded(4242);
    let px = 32 * 32 * 3;
    let x: Vec<f32> = (0..bucket * px).map(|_| rng.normal() as f32 * 0.5).collect();
    let mut onehot = vec![0.0f32; bucket * classes];
    let mut weights = vec![0.0f32; bucket];
    for r in 0..bucket {
        onehot[r * classes + (r % classes)] = 1.0;
        if r < true_b {
            weights[r] = 1.0;
        }
    }
    (
        HostTensor { shape: vec![bucket, 32, 32, 3], data: x },
        HostTensor { shape: vec![bucket, classes], data: onehot },
        HostTensor { shape: vec![bucket], data: weights },
    )
}

// ---- native determinism (always runs) ------------------------------------

fn native_config(rounds: usize) -> Config {
    let mut cfg = Config::small();
    cfg.fleet.n_devices = 3;
    cfg.train.rounds = rounds;
    cfg.train.agg_interval = 2;
    cfg.train.eval_every = rounds;
    cfg.train.train_samples = 192;
    cfg.train.test_samples = 48;
    cfg.train.batch_cap = 16;
    cfg.strategy = StrategyKind::Fixed;
    cfg.fixed_batch = 8;
    cfg.fixed_cut = 4;
    cfg
}

#[test]
fn an_execution_engine_is_always_available() {
    // The HASFL_REQUIRE_ENGINE tripwire, wired to a live call site:
    // building a session must succeed on every machine (the native
    // backend needs no artifacts, no Python, no XLA). If this ever stops
    // holding, `skip_engine_test` reports it — as a standardized
    // `SKIPPED:` line locally, and as a hard failure under the gate of
    // record's HASFL_REQUIRE_ENGINE=1.
    match Experiment::builder().config(native_config(1)).artifacts(artifacts_dir()).build() {
        Ok(session) => {
            session.finish().expect("finish");
        }
        Err(e) => hasfl::backend::skip_engine_test(&format!("no execution engine: {e}")),
    }
}

#[test]
fn native_is_bit_identical_across_sequential_pooled_and_resumed() {
    let ckpt_dir = std::env::temp_dir()
        .join(format!("hasfl_backend_parity_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    std::fs::create_dir_all(&ckpt_dir).unwrap();
    let ckpt = ckpt_dir.join("mid.hckpt");

    // Sequential reference run, checkpointing at round 2.
    let mut seq = Experiment::builder()
        .config(native_config(4))
        .backend(BackendKind::Native)
        .artifacts(artifacts_dir())
        .build()
        .expect("sequential session");
    let mut seq_losses = Vec::new();
    while !seq.is_done() {
        seq_losses.push(seq.step().expect("step").outcome.mean_loss);
        if seq.round() == 2 {
            seq.checkpoint(&ckpt).expect("checkpoint");
        }
    }
    let seq_params = seq.trainer().params().to_vec();
    let seq_hist = seq.finish().expect("finish");

    // Pooled-concurrent run: same numerics, different execution shape.
    let mut pooled = Experiment::builder()
        .config(native_config(4))
        .backend(BackendKind::Native)
        .engine_pool(3)
        .concurrent(true)
        .artifacts(artifacts_dir())
        .build()
        .expect("pooled session");
    pooled.run_to_completion().expect("run");
    assert_eq!(seq_hist.records, pooled.history().records.clone(), "pooled history");
    assert_eq!(seq_params, pooled.trainer().params().to_vec(), "pooled params");
    pooled.finish().expect("finish");

    // Warm restart from round 2: rounds 3..4 must replay bit-identically.
    let mut resumed = Experiment::builder()
        .resume_from(&ckpt)
        .artifacts(artifacts_dir())
        .build()
        .expect("resumed session");
    assert_eq!(resumed.config().backend, BackendKind::Native);
    let mut resumed_losses = Vec::new();
    while !resumed.is_done() {
        resumed_losses.push(resumed.step().expect("step").outcome.mean_loss);
    }
    assert_eq!(&seq_losses[2..], &resumed_losses[..], "resumed losses");
    assert_eq!(seq_params, resumed.trainer().params().to_vec(), "resumed params");
    let resumed_hist = resumed.finish().expect("finish");
    assert_eq!(seq_hist.records, resumed_hist.records, "resumed history");

    let _ = std::fs::remove_dir_all(&ckpt_dir);
}

#[test]
fn native_thread_budget_is_bit_neutral_at_the_engine_boundary() {
    // The per-lane thread budget (DESIGN.md §14) may only change speed,
    // never bits: the kernels partition work over independent output rows
    // and keep every per-element reduction sequential, so a 1-thread and
    // a 4-thread engine must produce identical f32 bit patterns. Bucket
    // 32 pushes the conv GEMMs past GEMM_PAR_MIN_MACS, so the 4-thread
    // run genuinely exercises the parallel paths.
    let manifest = ModelSpec::splitcnn8(10).manifest();
    let params = Params::init(&manifest, 9);
    let (x, y, w) = fake_batch(32, 10, 29);
    let name = Manifest::full_name("full_step", 32);
    let mut inputs = vec![x, y, w];
    inputs.extend(params.tensors.iter().map(tensor_to_host));

    let run = |threads: usize| {
        let spec = EngineSpec::Native { classes: 10, threads };
        let engine = EngineHandle::spawn_backend(spec, 1).expect("engine");
        let out = engine.execute_blocking(&name, inputs.clone()).expect("full_step");
        engine.shutdown();
        out
    };
    let one = run(1);
    let four = run(4);
    assert_eq!(one.len(), four.len());
    for (k, (a, b)) in one.iter().zip(&four).enumerate() {
        assert_eq!(a.shape, b.shape, "out {k}: shape");
        let ab: Vec<u32> = a.data.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = b.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ab, bb, "out {k}: 1-thread vs 4-thread bits differ");
    }
}

// ---- PJRT halves (standardized skip without artifacts) -------------------

#[test]
fn synthesized_manifest_matches_on_disk_manifest() {
    let Some(dir) = pjrt_dir("manifest cross-check") else { return };
    let disk = Manifest::load(&dir).expect("manifest");
    let native = ModelSpec::splitcnn8(disk.num_classes).manifest();

    assert_eq!(native.model, disk.model);
    assert_eq!(native.num_classes, disk.num_classes);
    assert_eq!(native.img, disk.img);
    assert_eq!(native.in_ch, disk.in_ch);
    assert_eq!(native.num_blocks, disk.num_blocks);
    assert_eq!(native.valid_cuts, disk.valid_cuts);
    assert_eq!(native.buckets, disk.buckets);
    assert_eq!(native.param_shapes, disk.param_shapes);
    assert_eq!(native.block_table, disk.block_table);

    assert_eq!(native.artifacts.len(), disk.artifacts.len(), "artifact count");
    for d in &disk.artifacts {
        let n = native
            .get(&d.name)
            .unwrap_or_else(|| panic!("native manifest is missing artifact {}", d.name));
        assert_eq!(n.func, d.func, "{}", d.name);
        assert_eq!(n.cut, d.cut, "{}", d.name);
        assert_eq!(n.bucket, d.bucket, "{}", d.name);
        assert_eq!(n.args, d.args, "{}: args", d.name);
        assert_eq!(n.outputs, d.outputs, "{}: outputs", d.name);
    }
}

#[test]
fn engine_outputs_agree_across_backends() {
    let Some(dir) = pjrt_dir("engine-level parity") else { return };
    let pjrt = EngineHandle::spawn(dir.clone()).expect("pjrt engine");
    let native = EngineHandle::spawn_native(10).expect("native engine");
    let manifest = Manifest::load(&dir).expect("manifest");
    let params = Params::init(&manifest, 77);
    let classes = manifest.num_classes;

    for (cut, bucket, true_b) in [(2usize, 8u32, 8usize), (5, 16, 11), (7, 4, 4)] {
        let (x, y, w) = fake_batch(bucket as usize, classes, true_b);
        let sa = StepArtifacts::resolve(&manifest, cut, true_b as u32).unwrap();
        assert_eq!(sa.bucket, bucket);

        // a1: activations at the cut.
        let mut cf_in = vec![x.clone()];
        cf_in.extend(params.client_slice(cut).iter().map(tensor_to_host));
        let a_p = pjrt.execute_blocking(&sa.client_fwd, cf_in.clone()).expect("pjrt cf");
        let a_n = native.execute_blocking(&sa.client_fwd, cf_in).expect("native cf");
        assert_close(&a_n[0].data, &a_p[0].data, 1e-4, 1e-4, &sa.client_fwd);

        // a3: loss, correct, grad_a, server grads (feed both the PJRT
        // activations so the comparison isolates the server step).
        let mut ss_in = vec![a_p[0].clone(), y.clone(), w.clone()];
        ss_in.extend(params.server_slice(cut).iter().map(tensor_to_host));
        let ss_p = pjrt.execute_blocking(&sa.server_step, ss_in.clone()).expect("pjrt ss");
        let ss_n = native.execute_blocking(&sa.server_step, ss_in).expect("native ss");
        assert_eq!(ss_n.len(), ss_p.len());
        for (k, (n, p)) in ss_n.iter().zip(&ss_p).enumerate() {
            assert_eq!(n.shape, p.shape, "{}: output {k} shape", sa.server_step);
            assert_close(&n.data, &p.data, 1e-4, 2e-3, &format!("{} out {k}", sa.server_step));
        }

        // a5: client grads from the same upstream gradient.
        let mut cb_in = vec![x.clone(), ss_p[2].clone()];
        cb_in.extend(params.client_slice(cut).iter().map(tensor_to_host));
        let cb_p = pjrt.execute_blocking(&sa.client_bwd, cb_in.clone()).expect("pjrt cb");
        let cb_n = native.execute_blocking(&sa.client_bwd, cb_in).expect("native cb");
        for (k, (n, p)) in cb_n.iter().zip(&cb_p).enumerate() {
            assert_close(&n.data, &p.data, 1e-4, 2e-3, &format!("{} out {k}", sa.client_bwd));
        }
    }

    // Monolithic oracle + eval path.
    let (x, y, w) = fake_batch(8, classes, 8);
    let name = Manifest::full_name("full_step", 8);
    let mut inputs = vec![x.clone(), y, w];
    inputs.extend(params.tensors.iter().map(tensor_to_host));
    let fs_p = pjrt.execute_blocking(&name, inputs.clone()).expect("pjrt fs");
    let fs_n = native.execute_blocking(&name, inputs).expect("native fs");
    for (k, (n, p)) in fs_n.iter().zip(&fs_p).enumerate() {
        assert_close(&n.data, &p.data, 1e-4, 2e-3, &format!("full_step out {k}"));
    }
    let name = Manifest::full_name("full_fwd", 8);
    let mut inputs = vec![x];
    inputs.extend(params.tensors.iter().map(tensor_to_host));
    let ff_p = pjrt.execute_blocking(&name, inputs.clone()).expect("pjrt ff");
    let ff_n = native.execute_blocking(&name, inputs).expect("native ff");
    assert_close(&ff_n[0].data, &ff_p[0].data, 1e-4, 1e-4, "full_fwd logits");

    pjrt.shutdown();
    native.shutdown();
}

#[test]
fn training_sessions_agree_across_backends() {
    let Some(dir) = pjrt_dir("session-level parity") else { return };

    // Fixed decisions pin (cut, batch) so the two runs stay structurally
    // identical and only the engine numerics differ.
    let run = |backend: BackendKind, cut: usize, batch: u32| {
        let mut cfg = native_config(3);
        cfg.fixed_cut = cut;
        cfg.fixed_batch = batch;
        let mut session = Experiment::builder()
            .config(cfg)
            .backend(backend)
            .artifacts(&dir)
            .build()
            .expect("session");
        let mut losses = Vec::new();
        while !session.is_done() {
            losses.push(session.step().expect("step").outcome.mean_loss);
        }
        let params = session.trainer().params().to_vec();
        session.finish().expect("finish");
        (losses, params)
    };

    for (cut, batch) in [(2usize, 4u32), (4, 8), (6, 16)] {
        let (loss_n, params_n) = run(BackendKind::Native, cut, batch);
        let (loss_p, params_p) = run(BackendKind::Pjrt, cut, batch);
        for (r, (a, b)) in loss_n.iter().zip(&loss_p).enumerate() {
            assert!(
                (a - b).abs() < 1e-3 + 1e-3 * b.abs(),
                "cut {cut} batch {batch} round {r}: native loss {a} vs pjrt {b}"
            );
        }
        for (i, (pn, pp)) in params_n.iter().zip(&params_p).enumerate() {
            for (t, (tn, tp)) in pn.tensors.iter().zip(&pp.tensors).enumerate() {
                assert_close(
                    &tn.data,
                    &tp.data,
                    5e-4,
                    1e-3,
                    &format!("cut {cut} batch {batch} device {i} tensor {t}"),
                );
            }
        }
    }
}
