//! Buffered-asynchronous round suite (DESIGN.md §16, `docs/ASYNC.md`).
//!
//! Acceptance properties:
//!
//! 1. **Async runs are deterministic**: the completion schedule is a pure
//!    function of the config seed, so the same async run twice — and at
//!    any engine-pool width — produces byte-identical histories and
//!    identical per-round staleness stats.
//! 2. **The sync path is untouched**: a config without an async spec
//!    trains byte-identically to the pinned pre-async snapshot (a
//!    bootstrap golden on the always-available native backend), and its
//!    round reports carry no asynchrony block.
//! 3. **The in-flight buffer survives checkpoint/resume**: resuming an
//!    async run mid-flight replays the remaining flushes bit-identically.
//! 4. **Asynchrony composes with fault injection**: async + chaos is as
//!    deterministic as either alone.
//!
//! Engine-backed tests run on the resolved backend (PJRT with artifacts,
//! native without) and never skip.

use std::path::PathBuf;

use hasfl::asynch::{AsyncRoundStats, AsyncSpec};
use hasfl::backend::BackendKind;
use hasfl::config::{Config, StrategyKind};
use hasfl::experiment::Experiment;
use hasfl::fault::FaultSpec;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hasfl_async_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A small config whose native-engine run finishes in seconds.
fn quick_config(seed: u64, rounds: usize) -> Config {
    let mut cfg = Config::small();
    cfg.fleet.n_devices = 4;
    cfg.seed = seed;
    cfg.train.rounds = rounds;
    cfg.train.agg_interval = 2;
    cfg.train.eval_every = 3;
    cfg.train.train_samples = 256;
    cfg.train.test_samples = 64;
    cfg.train.batch_cap = 16;
    cfg.strategy = StrategyKind::Hasfl;
    cfg.fixed_batch = 8;
    cfg.fixed_cut = 3;
    cfg
}

fn async_config(seed: u64, rounds: usize) -> Config {
    let mut cfg = quick_config(seed, rounds);
    cfg.async_spec = Some(AsyncSpec { buffer_k: 2, max_staleness: 8, decay: 0.5 });
    cfg
}

/// Run `cfg` to completion at the given pool width; returns the history
/// CSV and every round's asynchrony stats.
fn run_collecting(cfg: &Config, pool: usize) -> (String, Vec<Option<AsyncRoundStats>>) {
    let mut session = Experiment::builder()
        .config(cfg.clone())
        .artifacts(artifacts_dir())
        .tune(move |c| c.engine_pool = pool)
        .build()
        .expect("session");
    let mut stats = Vec::new();
    while !session.is_done() {
        let report = session.step().expect("step");
        stats.push(report.asynchrony);
    }
    (session.finish().expect("finish").to_csv_string(), stats)
}

#[test]
fn async_runs_are_deterministic_across_executions_and_pool_widths() {
    let cfg = async_config(41, 6);
    let (csv_a, stats_a) = run_collecting(&cfg, 2);
    let (csv_b, stats_b) = run_collecting(&cfg, 2);
    assert_eq!(csv_a, csv_b, "two executions of the same async run diverged");
    assert_eq!(stats_a, stats_b, "staleness bookkeeping diverged between executions");

    // Pool width is a wall-clock knob, never a numerics knob — the async
    // completion schedule is simulated, not measured.
    let (csv_w1, stats_w1) = run_collecting(&cfg, 1);
    assert_eq!(csv_a, csv_w1, "async run diverged across engine-pool widths");
    assert_eq!(stats_a, stats_w1);

    // The asynchrony actually happened: every round reports a flush, the
    // buffer bound holds, and version lag shows up once the slow devices'
    // round-one dispatches land behind the bumped model version.
    let spec = cfg.async_spec.as_ref().unwrap();
    assert!(stats_a.iter().all(|s| s.is_some()), "async rounds must report stats");
    let flushes: Vec<&AsyncRoundStats> = stats_a.iter().flatten().collect();
    assert!(flushes.iter().all(|s| s.flushed <= spec.buffer_k));
    assert!(flushes.iter().map(|s| s.flushed).sum::<usize>() > 0, "no update ever flushed");
    assert!(
        flushes.iter().any(|s| s.staleness_mean > 0.0),
        "a buffer of {} over {} devices must observe stale updates",
        spec.buffer_k,
        cfg.fleet.n_devices
    );
}

#[test]
fn sync_path_matches_the_pinned_snapshot_and_reports_no_asynchrony() {
    // Pin the backend: goldens are only comparable like-for-like, and
    // native is the backend that exists everywhere.
    let cfg = {
        let mut c = quick_config(59, 5);
        c.backend = BackendKind::Native;
        c
    };
    assert!(cfg.async_spec.is_none());
    let (csv, stats) = run_collecting(&cfg, 2);
    assert!(
        stats.iter().all(|s| s.is_none()),
        "a sync run must not report asynchrony stats"
    );
    // ...and its config JSON carries no "async" key at all (historical
    // byte layout — old configs keep loading, new sync dumps keep diffing
    // clean against old ones).
    assert!(cfg.to_json().get("async").is_none());

    // Bootstrap golden: first run on a machine writes the snapshot; every
    // later run must reproduce it byte-for-byte. Delete the file to
    // re-baseline after an *intentional* numerics change.
    let golden = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/data/sync_history_native_seed59.csv");
    if let Ok(want) = std::fs::read_to_string(&golden) {
        assert_eq!(
            csv, want,
            "sync training history diverged from the pinned pre-async snapshot at {}",
            golden.display()
        );
    } else {
        std::fs::create_dir_all(golden.parent().unwrap()).unwrap();
        std::fs::write(&golden, &csv).unwrap();
        eprintln!("bootstrapped sync golden at {}", golden.display());
    }
}

#[test]
fn async_buffer_survives_checkpoint_and_resume_bit_identically() {
    let dir = temp_dir("resume");
    let cfg = async_config(23, 6);

    // Straight run, checkpointing mid-flight at round 3 (in-flight
    // dispatches from the round-3 flush are still outstanding there).
    let mut session = Experiment::builder()
        .config(cfg.clone())
        .artifacts(artifacts_dir())
        .build()
        .expect("straight session");
    let ckpt = dir.join("mid.hckpt");
    let mut straight_stats = Vec::new();
    while !session.is_done() {
        let report = session.step().expect("step");
        if report.round == 3 {
            session.checkpoint(&ckpt).expect("checkpoint");
        }
        straight_stats.push(report.asynchrony);
    }
    let straight_csv = session.finish().expect("finish").to_csv_string();

    // Resume and replay rounds 4..=6.
    let mut resumed = Experiment::builder()
        .resume_from(&ckpt)
        .artifacts(artifacts_dir())
        .build()
        .expect("resumed session");
    assert_eq!(resumed.round(), 3);
    let mut resumed_stats = Vec::new();
    while !resumed.is_done() {
        resumed_stats.push(resumed.step().expect("step").asynchrony);
    }
    let resumed_csv = resumed.finish().expect("finish").to_csv_string();

    assert_eq!(straight_csv, resumed_csv, "resumed async history diverged");
    assert_eq!(
        &straight_stats[3..],
        &resumed_stats[..],
        "resumed staleness schedule diverged — the in-flight buffer did not survive"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn async_overrides_conflict_with_resume() {
    let dir = temp_dir("conflict");
    let cfg = async_config(31, 2);
    let mut session = Experiment::builder()
        .config(cfg)
        .artifacts(artifacts_dir())
        .build()
        .expect("session");
    session.step().expect("step");
    let ckpt = dir.join("one.hckpt");
    session.checkpoint(&ckpt).expect("checkpoint");
    session.finish().expect("finish");

    let err = Experiment::builder()
        .resume_from(&ckpt)
        .async_buffer(3)
        .artifacts(artifacts_dir())
        .build()
        .expect_err("async override over resume must be rejected");
    assert!(err.to_string().contains("conflicts with resume_from"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn async_composes_deterministically_with_fault_injection() {
    let cfg = async_config(47, 5);
    let spec = FaultSpec {
        name: "async-chaos".into(),
        error_rate: 0.2,
        panic_rate: 0.1,
        max_retries: 2,
        backoff_ms: 0,
        quarantine_after: 3,
        ..FaultSpec::default()
    };
    let run = || {
        let mut session = Experiment::builder()
            .config(cfg.clone())
            .faults(spec.clone())
            .artifacts(artifacts_dir())
            .tune(|c| c.engine_pool = 2)
            .build()
            .expect("faulted async session");
        let mut per_round = Vec::new();
        while !session.is_done() {
            let report = session.step().expect("step");
            per_round.push((report.abandoned.clone(), report.asynchrony.clone()));
        }
        (session.finish().expect("finish").to_csv_string(), per_round)
    };
    let (csv_a, rounds_a) = run();
    let (csv_b, rounds_b) = run();
    assert_eq!(csv_a, csv_b, "async + chaos diverged between executions");
    assert_eq!(rounds_a, rounds_b, "abandonment/staleness bookkeeping diverged");
}
