//! Chaos suite: training and the serve daemon under injected faults
//! (DESIGN.md §13).
//!
//! Three acceptance properties:
//!
//! 1. **Fault runs are deterministic**: with a seeded [`FaultSpec`] the
//!    whole degraded run — retries, abandonments, quarantines, lane
//!    respawns — is a pure function of the config, so two executions
//!    produce byte-identical histories.
//! 2. **Degradation is surgical**: killing a device changes *nothing*
//!    for the survivors — their history is byte-identical to a run whose
//!    spec excludes that device from the start (same roster size, so the
//!    per-device RNG streams line up).
//! 3. **The daemon outlives hostile clients**: slow-loris senders,
//!    mid-body disconnects, and connection floods are shed with timeouts
//!    and `503`s while `/healthz` keeps answering, and churn with
//!    disconnecting clients never loses a run kick.
//!
//! Engine-backed tests run on the resolved backend (PJRT with artifacts,
//! native without) and never skip.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::time::Duration;

use hasfl::checkpoint::CheckpointState;
use hasfl::config::{Config, StrategyKind};
use hasfl::experiment::Experiment;
use hasfl::fault::FaultSpec;
use hasfl::serve::{Daemon, ServeConfig};
use hasfl::util::Json;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hasfl_chaos_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A small config whose native-engine run finishes in seconds.
fn quick_config(seed: u64, rounds: usize) -> Config {
    let mut cfg = Config::small();
    cfg.fleet.n_devices = 4;
    cfg.seed = seed;
    cfg.train.rounds = rounds;
    cfg.train.agg_interval = 2;
    cfg.train.eval_every = 3;
    cfg.train.train_samples = 256;
    cfg.train.test_samples = 64;
    cfg.train.batch_cap = 16;
    cfg.strategy = StrategyKind::Hasfl;
    cfg.fixed_batch = 8;
    cfg.fixed_cut = 3;
    cfg
}

/// Heavy transient noise + one killed device + a lane crash every round:
/// every layer of the degradation ladder fires in one run. The injected
/// stall (5 s) exceeds the device deadline (1 s) so delay faults abandon
/// by arithmetic without sleeping, and `backoff_ms: 0` keeps retries
/// instant — the whole chaos run stays test-suite fast.
fn chaos_spec() -> FaultSpec {
    FaultSpec {
        name: "test-chaos".into(),
        kill: vec![2],
        error_rate: 0.2,
        panic_rate: 0.1,
        delay_rate: 0.1,
        delay_ms: 5_000,
        deadline_ms: 1_000,
        max_retries: 2,
        backoff_ms: 0,
        quarantine_after: 2,
        lane_crash_rate: 1.0,
        ..FaultSpec::default()
    }
}

/// Run `cfg` + `spec` to completion; returns (history csv, per-round
/// (abandoned, quarantined) pairs).
#[allow(clippy::type_complexity)]
fn run_faulted(
    cfg: &Config,
    spec: &FaultSpec,
    concurrent: bool,
) -> (String, Vec<(Vec<usize>, Vec<usize>)>) {
    let mut session = Experiment::builder()
        .config(cfg.clone())
        .faults(spec.clone())
        .artifacts(artifacts_dir())
        .concurrent(concurrent)
        .tune(|c| c.engine_pool = 2)
        .build()
        .expect("faulted session");
    let mut fleet = Vec::new();
    while !session.is_done() {
        let report = session.step().expect("faulted step");
        fleet.push((report.abandoned.clone(), report.quarantined.clone()));
    }
    (session.finish().expect("finish").to_csv_string(), fleet)
}

#[test]
fn chaos_run_is_deterministic_and_surgical_for_survivors() {
    let cfg = quick_config(41, 6);
    let spec = chaos_spec();

    // Property 1: the same chaos twice is byte-identical — in concurrent
    // mode (lane supervision + worker threads) and against the
    // sequential pump (fault handling must not fork the numerics).
    let (csv_a, fleet_a) = run_faulted(&cfg, &spec, true);
    let (csv_b, fleet_b) = run_faulted(&cfg, &spec, true);
    assert_eq!(csv_a, csv_b, "two executions of the same chaos run diverged");
    assert_eq!(fleet_a, fleet_b, "abandonment bookkeeping diverged between executions");
    let (csv_seq, _) = run_faulted(&cfg, &spec, false);
    assert_eq!(csv_a, csv_seq, "concurrent chaos run diverged from the sequential pump");

    // The chaos actually happened: the killed device is abandoned every
    // round it is scheduled, then quarantined for the rest of the run.
    assert_eq!(fleet_a[0].0, vec![2], "round 1 must abandon the killed device");
    assert_eq!(fleet_a[1].0, vec![2], "round 2 must abandon the killed device again");
    let (_, last_quarantined) = fleet_a.last().unwrap();
    assert_eq!(last_quarantined, &vec![2], "two strikes must quarantine the killed device");
    assert!(
        fleet_a.last().unwrap().0.is_empty(),
        "a quarantined device is excluded, not re-abandoned"
    );

    // Property 2: the survivors never noticed. A run whose spec blacks
    // out the same device from round 1 (same roster size, so every
    // sampler stream lines up) produces a byte-identical history.
    let survivors = FaultSpec {
        name: "survivors".into(),
        blackout: vec![2],
        ..FaultSpec::default()
    };
    let (csv_survivors, fleet_survivors) = run_faulted(&cfg, &survivors, true);
    assert_eq!(
        csv_a, csv_survivors,
        "survivor histories diverged from the run without the killed device"
    );
    assert!(
        fleet_survivors.iter().all(|(a, q)| a.is_empty() && q.is_empty()),
        "a blackout is structural exclusion, not a fault"
    );
}

#[test]
fn torn_checkpoints_fail_loud_and_good_ones_resume_bit_identical() {
    let dir = temp_dir("torn");
    let cfg = quick_config(77, 6);
    // Tears every checkpoint written in rounds 1..=3, with transient step
    // noise on top; rounds 4+ write clean.
    let spec = FaultSpec {
        name: "torn".into(),
        error_rate: 0.15,
        max_retries: 2,
        backoff_ms: 0,
        torn_checkpoint_rate: 1.0,
        until_round: 3,
        ..FaultSpec::default()
    };

    let build = || {
        Experiment::builder()
            .config(cfg.clone())
            .faults(spec.clone())
            .artifacts(artifacts_dir())
            .build()
            .expect("session")
    };

    // The straight run, with a torn write at round 2 and a good one at
    // round 4 along the way.
    let mut session = build();
    let torn = dir.join("torn.hckpt");
    let good = dir.join("good.hckpt");
    while !session.is_done() {
        let report = session.step().expect("step");
        if report.round == 2 {
            session.checkpoint(&torn).expect("torn write itself reports success");
        }
        if report.round == 4 {
            session.checkpoint(&good).expect("good write");
        }
    }
    let straight = session.finish().expect("finish").to_csv_string();

    // The torn file is detected as corrupt, not silently half-loaded.
    let err = CheckpointState::load(&torn).expect_err("torn checkpoint must not load");
    assert!(
        err.to_string().contains("corrupt") || err.to_string().contains("truncated"),
        "unexpected torn-load error: {err:#}"
    );

    // The good one resumes — fault state included — to a byte-identical
    // finish.
    let mut resumed = Experiment::builder()
        .resume_from(&good)
        .artifacts(artifacts_dir())
        .build()
        .expect("resume from the good checkpoint");
    assert_eq!(resumed.round(), 4);
    while !resumed.is_done() {
        resumed.step().expect("resumed step");
    }
    let resumed_csv = resumed.finish().expect("finish resumed").to_csv_string();
    assert_eq!(straight, resumed_csv, "resume through chaos diverged from the straight run");
}

// ---------------------------------------------------------------------------
// Daemon-side chaos
// ---------------------------------------------------------------------------

fn start_daemon(state_dir: &std::path::Path, cfg: ServeConfig) -> Daemon {
    Daemon::start(ServeConfig {
        addr: "127.0.0.1:0".into(),
        state_dir: state_dir.to_path_buf(),
        artifacts: artifacts_dir(),
        ..cfg
    })
    .expect("daemon start")
}

/// One-shot HTTP request; returns (status, body).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).expect("send");
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).expect("recv");
    let text = String::from_utf8_lossy(&raw).into_owned();
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("no status line in: {text}"))
        .parse()
        .expect("status code");
    let body_at = text.find("\r\n\r\n").expect("header/body separator") + 4;
    (status, text[body_at..].to_string())
}

fn http_json(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, Json) {
    let (status, text) = http(addr, method, path, body);
    let json = Json::parse(&text).unwrap_or_else(|e| panic!("bad JSON ({e}) in: {text}"));
    (status, json)
}

/// Fire a request and hang up without reading the response (a client
/// that crashed mid-call). The command must still take effect.
fn http_and_drop(addr: SocketAddr, method: &str, path: &str, body: &str) {
    let mut s = TcpStream::connect(addr).expect("connect");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).expect("send");
    // Dropped here: no read, immediate close.
}

fn assert_healthy(addr: SocketAddr) {
    let (status, j) = http_json(addr, "GET", "/healthz", "");
    assert_eq!(status, 200, "healthz failed: {}", j.dump());
    assert_eq!(j.get("status").unwrap().as_str().unwrap(), "ok");
}

#[test]
fn daemon_sheds_hostile_clients_and_stays_responsive() {
    let state = temp_dir("hostile");
    let daemon = start_daemon(
        &state,
        ServeConfig {
            workers: 1,
            max_conns: 2,
            io_timeout: Duration::from_millis(250),
            ..ServeConfig::default()
        },
    );
    let addr = daemon.addr();

    // Mid-body disconnect: the header promises 64 bytes, 9 arrive, then
    // the client vanishes. The read fails server-side; nothing panics.
    {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(b"POST /sessions HTTP/1.1\r\nHost: x\r\nContent-Length: 64\r\n\r\n{\"name\": ")
            .expect("partial send");
    }
    std::thread::sleep(Duration::from_millis(50));
    assert_healthy(addr);

    // Slow-loris: a connection that sends a few bytes and stalls. The
    // read timeout reclaims its thread; meanwhile the remaining slot
    // still serves real traffic.
    let mut loris = TcpStream::connect(addr).expect("loris connect");
    loris.write_all(b"GET /hea").expect("loris trickle");
    assert_healthy(addr);

    // Connection flood: with both slots held (the loris plus one idle
    // connection), the next connection is answered 503 at the door.
    let mut idle = TcpStream::connect(addr).expect("idle connect");
    idle.write_all(b"GET /hea").expect("idle trickle");
    std::thread::sleep(Duration::from_millis(30)); // let both handlers claim slots
    let mut flood = TcpStream::connect(addr).expect("flood connect");
    let mut reply = String::new();
    flood.read_to_string(&mut reply).expect("read 503");
    assert!(reply.starts_with("HTTP/1.1 503"), "expected shed at the door, got: {reply}");

    // Once the stalled connections time out, capacity returns.
    std::thread::sleep(Duration::from_millis(400));
    drop(loris);
    drop(idle);
    assert_healthy(addr);
    let (_, j) = http_json(addr, "GET", "/healthz", "");
    assert_eq!(j.get("max_conns").unwrap().as_usize().unwrap(), 2);
    assert_eq!(j.get("jobs").unwrap().as_usize().unwrap(), 0);

    daemon.stop().expect("stop");
}

#[test]
fn churn_with_disconnecting_clients_never_loses_a_kick() {
    let state = temp_dir("churn");
    let daemon = start_daemon(
        &state,
        ServeConfig {
            workers: 2,
            io_timeout: Duration::from_millis(500),
            ..ServeConfig::default()
        },
    );
    let addr = daemon.addr();

    // Four tenants created concurrently; every run kick arrives from a
    // client that hangs up before reading its response.
    let mut ids = Vec::new();
    for i in 0..4u64 {
        let mut cfg = quick_config(100 + i, 2);
        cfg.fleet.n_devices = 2;
        cfg.train.train_samples = 128;
        let mut body = Json::obj();
        body.set("config", cfg.to_json()).set("engine_pool", Json::Num(1.0));
        let (status, j) = http_json(addr, "POST", "/sessions", &body.dump());
        assert_eq!(status, 201, "create failed: {}", j.dump());
        ids.push(j.get("id").unwrap().as_usize().unwrap() as u64);
    }
    for &id in &ids {
        http_and_drop(addr, "POST", &format!("/sessions/{id}/run"), r#"{"rounds": 2}"#);
    }
    // Interleave hostile noise with the running sessions.
    {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(b"POST /sessions/1/step HTTP/1.1\r\nContent-Length: 32\r\n\r\n{").expect("torn");
    }

    // Every kick landed despite the disconnects: all sessions finish.
    for &id in &ids {
        let (status, j) = http_json(
            addr,
            "GET",
            &format!("/sessions/{id}/wait?round=2&timeout_ms=300000"),
            "",
        );
        assert_eq!(status, 200, "session {id} never finished: {}", j.dump());
        assert_eq!(j.get("round").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("last_error").unwrap(), &Json::Null, "session {id}: {}", j.dump());
    }

    // Churn the registry: delete two sessions from clients that hang up
    // mid-delete. The close still completes and the slots disappear.
    for &id in &ids[..2] {
        http_and_drop(addr, "DELETE", &format!("/sessions/{id}"), "");
    }
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let (_, list) = http_json(addr, "GET", "/sessions", "");
        if list.get("sessions").unwrap().as_arr().unwrap().len() == 2 {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "deletes never landed: {}", list.dump());
        std::thread::sleep(Duration::from_millis(50));
    }

    // The queue drained and the daemon is still healthy.
    let (_, j) = http_json(addr, "GET", "/healthz", "");
    assert_eq!(j.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(j.get("jobs").unwrap().as_usize().unwrap(), 0);
    assert_eq!(j.get("sessions").unwrap().as_usize().unwrap(), 2);
    daemon.stop().expect("stop");
}
