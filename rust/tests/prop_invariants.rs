//! Property-based tests over the coordinator invariants (routing/batching/
//! state in the paper's terms: latency model, convergence bound, optimizer
//! feasibility, partitioner, aggregation).
//!
//! crates.io is unreachable in this environment, so instead of `proptest`
//! we drive the properties with the in-repo PCG32 generator: every property
//! runs across `CASES` randomized instances and failures print the seed.

use hasfl::config::{Config, Device, Partition, StrategyKind};
use hasfl::convergence::{
    drift_term, memory_feasible, rounds_to_epsilon, variance_term, BoundParams,
};
use hasfl::data::{partition, Dataset};
use hasfl::latency::{round_latency, Decisions};
use hasfl::model::ModelProfile;
use hasfl::optimizer::{decide, ms, OptContext, StrategyInputs};
use hasfl::rng::Pcg32;
use hasfl::util::Json;

const CASES: u64 = 24;

fn random_fleet(rng: &mut Pcg32, n: usize) -> Vec<Device> {
    (0..n)
        .map(|_| Device {
            flops: rng.uniform(0.2e12, 4e12),
            up_bps: rng.uniform(10e6, 200e6),
            down_bps: rng.uniform(50e6, 500e6),
            fed_up_bps: rng.uniform(10e6, 200e6),
            fed_down_bps: rng.uniform(50e6, 500e6),
            mem_bytes: rng.uniform(0.5, 8.0) * 1024.0 * 1024.0 * 1024.0,
        })
        .collect()
}

#[test]
fn prop_latency_monotone_in_batch() {
    // For any fleet/cut, every latency component grows with batch size.
    let profile = ModelProfile::vgg16();
    let server = Config::table1().server;
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(seed);
        let n = rng.int_range(2, 12) as usize;
        let devices = random_fleet(&mut rng, n);
        let cut = rng.int_range(1, 15) as usize;
        let b1 = rng.int_range(1, 32);
        let b2 = b1 + rng.int_range(1, 32);
        let l1 = round_latency(&profile, &devices, &server, &Decisions::uniform(n, b1, cut));
        let l2 = round_latency(&profile, &devices, &server, &Decisions::uniform(n, b2, cut));
        assert!(l2.t_split > l1.t_split, "seed {seed}: T_S not monotone");
        // Aggregation latency is batch-independent (sub-model sizes only).
        assert!((l2.t_agg - l1.t_agg).abs() < 1e-12, "seed {seed}: T_A depends on b");
    }
}

#[test]
fn prop_straggler_never_faster() {
    // Degrading any single device's resources can never speed up the round.
    let profile = ModelProfile::vgg16();
    let server = Config::table1().server;
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(1000 + seed);
        let n = rng.int_range(2, 10) as usize;
        let mut devices = random_fleet(&mut rng, n);
        let dec = Decisions::uniform(n, rng.int_range(1, 64), rng.int_range(1, 15) as usize);
        let base = round_latency(&profile, &devices, &server, &dec).t_split;
        let victim = rng.below(n as u32) as usize;
        devices[victim].flops /= rng.uniform(1.5, 20.0);
        devices[victim].up_bps /= rng.uniform(1.5, 20.0);
        let worse = round_latency(&profile, &devices, &server, &dec).t_split;
        assert!(worse >= base - 1e-12, "seed {seed}: straggler sped up the round");
    }
}

#[test]
fn prop_bound_monotonicity() {
    // Variance term: decreasing in every b_i. Drift term: nondecreasing in
    // L_c and zero iff I <= 1.
    let profile = ModelProfile::vgg16();
    let bp = BoundParams::default_for(&profile, 5e-4);
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(2000 + seed);
        let n = rng.int_range(2, 20) as usize;
        let mut b: Vec<u32> = (0..n).map(|_| rng.int_range(1, 63)).collect();
        let v1 = variance_term(&bp, &b);
        let k = rng.below(n as u32) as usize;
        b[k] += rng.int_range(1, 32);
        let v2 = variance_term(&bp, &b);
        assert!(v2 < v1, "seed {seed}: variance not decreasing in b");

        let l1 = rng.int_range(1, 14) as usize;
        let l2 = l1 + 1;
        let i = rng.int_range(2, 30) as usize;
        assert!(drift_term(&bp, l2, i) >= drift_term(&bp, l1, i), "seed {seed}");
        assert_eq!(drift_term(&bp, l2, 1), 0.0);
    }
}

#[test]
fn prop_rounds_to_epsilon_consistency() {
    // If R rounds suffice for eps, they suffice for any larger eps; and
    // the returned R makes Theorem 1's bound <= eps.
    let profile = ModelProfile::vgg16();
    let bp = BoundParams::default_for(&profile, 5e-4);
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(3000 + seed);
        let n = rng.int_range(2, 20) as usize;
        let b: Vec<u32> = (0..n).map(|_| rng.int_range(4, 64)).collect();
        let l_c = rng.int_range(1, 15) as usize;
        let i = rng.int_range(1, 30) as usize;
        let eps = rng.uniform(0.2, 1.5);
        if let Some(r) = rounds_to_epsilon(&bp, &b, l_c, i, eps) {
            let bound = hasfl::convergence::theorem1_bound(&bp, &b, l_c, i, r.ceil() as usize);
            assert!(bound <= eps * 1.01, "seed {seed}: bound {bound} > eps {eps}");
            let r2 = rounds_to_epsilon(&bp, &b, l_c, i, eps * 1.5).unwrap();
            assert!(r2 <= r, "seed {seed}: looser eps needs more rounds");
        }
    }
}

#[test]
fn prop_strategies_always_feasible() {
    // Every strategy's decisions satisfy C2-C5 on random fleets.
    let profile = ModelProfile::vgg16();
    let server = Config::table1().server;
    let bp = BoundParams::default_for(&profile, 5e-4);
    let kinds = [
        StrategyKind::Hasfl,
        StrategyKind::RbsHams,
        StrategyKind::HabsRms,
        StrategyKind::RbsRms,
        StrategyKind::RbsRhams,
        StrategyKind::HabsFixedCut,
        StrategyKind::HamsFixedBatch,
    ];
    for seed in 0..8u64 {
        let mut rng = Pcg32::seeded(4000 + seed);
        let n = rng.int_range(2, 8) as usize;
        let devices = random_fleet(&mut rng, n);
        let ctx = OptContext {
            profile: &profile,
            devices: &devices,
            server: &server,
            bound: &bp,
            interval: 15,
            epsilon: 0.5,
            batch_cap: 64,
        };
        for kind in kinds {
            let dec = decide(kind, &ctx, &mut rng, StrategyInputs::default());
            assert_eq!(dec.n(), n);
            for (&b, &c) in dec.batch.iter().zip(&dec.cut) {
                assert!((1..=64).contains(&b), "{kind:?} seed {seed}: b={b}");
                assert!(profile.valid_cuts.contains(&c), "{kind:?} seed {seed}: c={c}");
            }
            assert!(
                memory_feasible(&profile, &devices, &dec),
                "{kind:?} seed {seed}: C4 violated"
            );
        }
    }
}

#[test]
fn prop_ms_bcd_never_worse_than_greedy_or_uniform() {
    let profile = ModelProfile::vgg16();
    let server = Config::table1().server;
    let bp = BoundParams::default_for(&profile, 5e-4);
    for seed in 0..10u64 {
        let mut rng = Pcg32::seeded(5000 + seed);
        let n = rng.int_range(2, 6) as usize;
        let devices = random_fleet(&mut rng, n);
        let ctx = OptContext {
            profile: &profile,
            devices: &devices,
            server: &server,
            bound: &bp,
            interval: 15,
            epsilon: 0.5,
            batch_cap: 64,
        };
        let batch: Vec<u32> = (0..n).map(|_| rng.int_range(4, 32)).collect();
        let cuts = ms::solve_bcd(&ctx, &batch, &mut rng, 4);
        let solved = ctx.objective(&Decisions { batch: batch.clone(), cut: cuts });
        let Some(solved) = solved else { continue };
        for c in [1usize, 4, 8] {
            let uni = Decisions { batch: batch.clone(), cut: vec![c; n] };
            if let Some(v) = ctx.objective(&uni) {
                assert!(solved <= v * 1.0001, "seed {seed}: uniform cut {c} beats BCD");
            }
        }
    }
}

#[test]
fn prop_partitions_are_exact_covers() {
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(6000 + seed);
        let classes = if rng.below(2) == 0 { 10 } else { 100 };
        let n_dev = rng.int_range(2, 20) as usize;
        let n = (n_dev * 2 * rng.int_range(5, 30) as usize).max(classes);
        let d = Dataset::synthetic(n, classes, seed);
        for scheme in [Partition::Iid, Partition::NonIidShards] {
            let parts = partition(&d, scheme, n_dev, &mut rng);
            assert_eq!(parts.len(), n_dev);
            let mut all: Vec<usize> = parts.concat();
            all.sort_unstable();
            all.dedup();
            assert_eq!(all.len(), n, "seed {seed} {scheme:?}: not a disjoint cover");
            assert!(parts.iter().all(|p| !p.is_empty()), "seed {seed}: empty partition");
        }
    }
}

#[test]
fn prop_json_roundtrip_random_configs() {
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(7000 + seed);
        let mut cfg = Config::table1();
        cfg.seed = rng.next_u64();
        cfg.fleet.n_devices = rng.int_range(1, 64) as usize;
        cfg.fleet.flops = hasfl::config::Range::new(1e11, rng.uniform(2e11, 9e12));
        cfg.train.lr = rng.uniform(1e-5, 0.5);
        cfg.train.rounds = rng.int_range(1, 100_000) as usize;
        cfg.strategy = if rng.below(2) == 0 {
            StrategyKind::Hasfl
        } else {
            StrategyKind::RbsRhams
        };
        let text = cfg.to_json().dump();
        let back = Config::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(cfg, back, "seed {seed}");
    }
}

#[test]
fn prop_aggregation_preserves_mean() {
    // FedAvg invariance: the global average is unchanged by aggregation.
    use hasfl::aggregation::{aggregate_common, aggregate_forged, global_average};
    use hasfl::model::{Params, Tensor};
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(8000 + seed);
        let n_dev = rng.int_range(2, 8) as usize;
        let n_blocks = rng.int_range(2, 8) as usize;
        let sets: Vec<Params> = (0..n_dev)
            .map(|_| Params {
                tensors: (0..2 * n_blocks)
                    .map(|_| Tensor {
                        shape: vec![3],
                        data: (0..3).map(|_| rng.normal() as f32).collect(),
                    })
                    .collect(),
                n_blocks,
                version: 0,
            })
            .collect();
        let before = global_average(&sets);
        let mut after = sets.clone();
        let dec = Decisions::uniform(n_dev, 8, rng.int_range(1, n_blocks as u32 - 1) as usize);
        aggregate_common(&mut after, &dec);
        aggregate_forged(&mut after, &dec);
        let after_avg = global_average(&after);
        for (a, b) in before.tensors.iter().zip(&after_avg.tensors) {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert!((x - y).abs() < 1e-5, "seed {seed}: aggregation moved the mean");
            }
        }
        // And all devices hold identical parameters afterwards.
        for s in &after[1..] {
            for (a, b) in s.tensors.iter().zip(&after[0].tensors) {
                assert_eq!(a.data, b.data, "seed {seed}: devices diverge post-agg");
            }
        }
    }
}
