//! End-to-end suite for the `hasfl serve` daemon (`hasfl::serve`).
//!
//! Talks to a real [`Daemon`] over real TCP with a hand-rolled HTTP/1.1
//! client (one request per connection, `Connection: close`), exactly like
//! curl would. The two acceptance properties of the serve layer:
//!
//! 1. **Multi-tenancy is invisible**: two sessions trained through the
//!    daemon's worker pool produce `history.csv` documents byte-identical
//!    to the same configs run solo through the Experiment API.
//! 2. **Restarts are invisible**: a daemon stopped mid-run checkpoints
//!    every live session; a new daemon on the same `--state-dir` adopts
//!    them, and the finished history is byte-identical to an
//!    uninterrupted run.
//!
//! Engine-backed tests run on the resolved backend (PJRT with artifacts,
//! native without) and never skip (`HASFL_REQUIRE_ENGINE=1` hard-fails
//! any skip path).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;

use hasfl::config::{Config, StrategyKind};
use hasfl::experiment::Experiment;
use hasfl::serve::{Daemon, ServeConfig};
use hasfl::util::Json;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hasfl_serve_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn start_daemon(state_dir: &std::path::Path, workers: usize) -> Daemon {
    Daemon::start(ServeConfig {
        addr: "127.0.0.1:0".into(),
        state_dir: state_dir.to_path_buf(),
        workers,
        artifacts: artifacts_dir(),
        ..ServeConfig::default()
    })
    .expect("daemon start")
}

/// One-shot HTTP request; returns (status, body). The daemon closes the
/// connection after each response, so the body is read to EOF.
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).expect("send");
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).expect("recv");
    let text = String::from_utf8_lossy(&raw).into_owned();
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("no status line in: {text}"))
        .parse()
        .expect("status code");
    let body_at = text.find("\r\n\r\n").expect("header/body separator") + 4;
    (status, text[body_at..].to_string())
}

fn http_json(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, Json) {
    let (status, text) = http(addr, method, path, body);
    let json = Json::parse(&text).unwrap_or_else(|e| panic!("bad JSON ({e}) in: {text}"));
    (status, json)
}

/// A small config whose native-engine run finishes in seconds.
fn quick_config(seed: u64, rounds: usize, strategy: StrategyKind) -> Config {
    let mut cfg = Config::small();
    cfg.fleet.n_devices = 4;
    cfg.seed = seed;
    cfg.train.rounds = rounds;
    cfg.train.agg_interval = 3;
    cfg.train.eval_every = 4;
    cfg.train.train_samples = 256;
    cfg.train.test_samples = 64;
    cfg.train.batch_cap = 16;
    cfg.strategy = strategy;
    cfg.fixed_batch = 8;
    cfg.fixed_cut = 3;
    cfg
}

/// The reference: the same config run solo through the Experiment API.
fn solo_history_csv(cfg: Config) -> String {
    let mut session = Experiment::builder()
        .config(cfg)
        .artifacts(artifacts_dir())
        .build()
        .expect("solo session");
    while !session.is_done() {
        session.step().expect("solo step");
    }
    session.finish().expect("solo finish").to_csv_string()
}

fn create_session(addr: SocketAddr, cfg: &Config, extra: &[(&str, Json)]) -> u64 {
    let mut body = Json::obj();
    body.set("config", cfg.to_json());
    for (k, v) in extra {
        body.set(k, v.clone());
    }
    let (status, j) = http_json(addr, "POST", "/sessions", &body.dump());
    assert_eq!(status, 201, "create failed: {}", j.dump());
    j.get("id").unwrap().as_usize().unwrap() as u64
}

/// Block until the session reaches `round` (or is done/closed/errored).
fn wait_for_round(addr: SocketAddr, id: u64, round: usize) -> Json {
    let (status, j) = http_json(
        addr,
        "GET",
        &format!("/sessions/{id}/wait?round={round}&timeout_ms=300000"),
        "",
    );
    assert_eq!(status, 200, "wait failed: {}", j.dump());
    assert_eq!(j.get("last_error").unwrap(), &Json::Null, "session errored: {}", j.dump());
    j
}

#[test]
fn two_tenants_match_their_solo_runs_byte_for_byte() {
    let state = temp_dir("tenants");
    let daemon = start_daemon(&state, 2);
    let addr = daemon.addr();

    // Two different experiments sharing the worker pool: seeds, budgets,
    // and strategies all differ, so any cross-session state bleed (RNG,
    // engine buffers, history mix-ups) breaks at least one comparison.
    let cfg_a = quick_config(7, 6, StrategyKind::Hasfl);
    let cfg_b = quick_config(99, 5, StrategyKind::RbsRms);

    let id_a = create_session(addr, &cfg_a, &[("run", Json::Num(6.0))]);
    let id_b = create_session(addr, &cfg_b, &[("run", Json::Num(5.0))]);
    assert_ne!(id_a, id_b);

    wait_for_round(addr, id_a, 6);
    wait_for_round(addr, id_b, 5);

    let (status, served_a) = http(addr, "GET", &format!("/sessions/{id_a}/history.csv"), "");
    assert_eq!(status, 200);
    let (status, served_b) = http(addr, "GET", &format!("/sessions/{id_b}/history.csv"), "");
    assert_eq!(status, 200);

    assert_eq!(served_a, solo_history_csv(cfg_a), "session A diverged from its solo run");
    assert_eq!(served_b, solo_history_csv(cfg_b), "session B diverged from its solo run");

    // The registry sees both, done and never errored.
    let (_, list) = http_json(addr, "GET", "/sessions", "");
    let sessions = list.get("sessions").unwrap().as_arr().unwrap();
    assert_eq!(sessions.len(), 2);
    for s in sessions {
        assert!(s.get("done").unwrap().as_bool().unwrap(), "{}", s.dump());
        assert_eq!(s.get("last_error").unwrap(), &Json::Null);
    }

    // Round reports stream with offsets: the tail after round 4 of A.
    let (_, reports) = http_json(addr, "GET", &format!("/sessions/{id_a}/reports?from=4"), "");
    assert_eq!(reports.get("reports").unwrap().as_arr().unwrap().len(), 2);

    daemon.stop().expect("stop");
}

#[test]
fn restart_adoption_resumes_bit_identical_and_prunes_checkpoints() {
    let state = temp_dir("restart");
    let cfg = quick_config(2025, 8, StrategyKind::Hasfl);

    // Phase 1: run 5 of 8 rounds, then stop the daemon mid-experiment.
    // Stopping checkpoints the live session (round 5) into its state dir.
    let daemon = start_daemon(&state, 2);
    let addr = daemon.addr();
    let id = create_session(
        addr,
        &cfg,
        &[("checkpoint_every", Json::Num(4.0)), ("keep_last", Json::Num(2.0))],
    );
    let (status, _) = http_json(addr, "POST", &format!("/sessions/{id}/run"), r#"{"rounds": 5}"#);
    assert_eq!(status, 202);
    wait_for_round(addr, id, 5);
    daemon.stop().expect("stop mid-run");

    let session_dir = state.join(format!("session_{id:06}"));
    let ckpts = |dir: &std::path::Path| -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(dir)
            .unwrap()
            .flatten()
            .filter_map(|e| e.file_name().into_string().ok())
            .filter(|n| n.starts_with("ckpt_round_") && n.ends_with(".hckpt"))
            .collect();
        names.sort();
        names
    };
    // Periodic write at round 4 plus the shutdown checkpoint at round 5.
    assert_eq!(ckpts(&session_dir), vec!["ckpt_round_000004.hckpt", "ckpt_round_000005.hckpt"]);

    // Phase 2: a new daemon on the same state dir adopts the session at
    // round 5 and runs out the remaining budget.
    let daemon = start_daemon(&state, 2);
    let addr = daemon.addr();
    let (_, list) = http_json(addr, "GET", "/sessions", "");
    let sessions = list.get("sessions").unwrap().as_arr().unwrap();
    assert_eq!(sessions.len(), 1, "adopted exactly the one session");
    let adopted = &sessions[0];
    assert_eq!(adopted.get("id").unwrap().as_usize().unwrap() as u64, id);
    assert_eq!(adopted.get("round").unwrap().as_usize().unwrap(), 5);
    assert!(!adopted.get("closed").unwrap().as_bool().unwrap());

    // The report backlog survives the restart: full RoundReports are not
    // checkpointed, so the daemon rebuilds `reports?from=K` entries from
    // the restored history (marked `"restored": true`) instead of
    // serving an empty list for rounds a client already saw.
    let (status, j) = http_json(addr, "GET", &format!("/sessions/{id}/reports"), "");
    assert_eq!(status, 200);
    let restored = j.get("reports").unwrap().as_arr().unwrap().clone();
    assert_eq!(restored.len(), 5, "restored backlog covers rounds 1..=5");
    for (i, r) in restored.iter().enumerate() {
        assert_eq!(r.get("round").unwrap().as_usize().unwrap(), i + 1);
        assert!(r.get("restored").unwrap().as_bool().unwrap(), "{}", r.dump());
    }

    // No body: run defaults to the remaining budget (8 - 5 = 3).
    let (status, j) = http_json(addr, "POST", &format!("/sessions/{id}/run"), "");
    assert_eq!(status, 202);
    assert_eq!(j.get("enqueued_rounds").unwrap().as_usize().unwrap(), 3);
    wait_for_round(addr, id, 8);

    // Restored + live reports stay index-aligned with history.csv: one
    // report per round, `from=K` slices exactly the unseen tail.
    let (_, j) = http_json(addr, "GET", &format!("/sessions/{id}/reports?from=5"), "");
    let live = j.get("reports").unwrap().as_arr().unwrap().clone();
    assert_eq!(live.len(), 3, "live tail covers rounds 6..=8");
    for (i, r) in live.iter().enumerate() {
        assert_eq!(r.get("round").unwrap().as_usize().unwrap(), i + 6);
        assert!(r.get("restored").is_none(), "live reports are full reports: {}", r.dump());
    }

    // The acceptance bar: the interrupted-and-adopted history is
    // byte-identical to the uninterrupted solo run.
    let (status, served) = http(addr, "GET", &format!("/sessions/{id}/history.csv"), "");
    assert_eq!(status, 200);
    assert_eq!(served, solo_history_csv(cfg), "adopted run diverged from the straight run");

    daemon.stop().expect("final stop");
    // Retention across the restart: the observer re-seeded from disk and
    // pruned to keep_last=2 (rounds 4 and 5 give way to newer writes; the
    // final-stop checkpoint at round 8 rewrites the periodic round-8 file).
    assert_eq!(ckpts(&session_dir), vec!["ckpt_round_000005.hckpt", "ckpt_round_000008.hckpt"]);
}

#[test]
fn http_surface_errors_and_introspection() {
    let state = temp_dir("errors");
    let daemon = start_daemon(&state, 1);
    let addr = daemon.addr();

    // /healthz and /info serve the `hasfl info --json` document.
    let (status, health) = http_json(addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    assert_eq!(health.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(health.get("service").unwrap().as_str().unwrap(), "hasfl");
    assert_eq!(health.get("sessions").unwrap().as_usize().unwrap(), 0);
    let (status, info) = http_json(addr, "GET", "/info", "");
    assert_eq!(status, 200);
    assert!(info.get("model").unwrap().get("name").is_some());

    // Config validation failures carry the offending JSON field path.
    let mut bad = quick_config(1, 2, StrategyKind::Hasfl).to_json();
    if let Json::Obj(map) = &mut bad {
        if let Some(Json::Obj(train)) = map.get_mut("train") {
            train.insert("lr".into(), Json::Str("fast".into()));
        }
    }
    let mut body = Json::obj();
    body.set("config", bad);
    let (status, err) = http_json(addr, "POST", "/sessions", &body.dump());
    assert_eq!(status, 400);
    let msg = err.get("error").unwrap().as_str().unwrap().to_string();
    assert!(msg.contains("train.lr"), "error lacks the field path: {msg}");

    // Malformed body, unknown session, unknown route, wrong method.
    let (status, err) = http_json(addr, "POST", "/sessions", "{not json");
    assert_eq!(status, 400);
    assert!(err.get("error").unwrap().as_str().unwrap().contains("JSON"));
    let (status, _) = http_json(addr, "GET", "/sessions/999", "");
    assert_eq!(status, 404);
    let (status, _) = http_json(addr, "GET", "/no/such/route", "");
    assert_eq!(status, 404);
    let (status, _) = http_json(addr, "DELETE", "/healthz", "");
    assert_eq!(status, 405);

    // A live session: step, on-demand checkpoint, NDJSON event log,
    // delete.
    let cfg = quick_config(5, 2, StrategyKind::Hasfl);
    let id = create_session(addr, &cfg, &[]);
    let (status, _) = http_json(addr, "POST", &format!("/sessions/{id}/step"), "");
    assert_eq!(status, 202);
    wait_for_round(addr, id, 1);
    let (status, j) = http_json(addr, "POST", &format!("/sessions/{id}/checkpoint"), "");
    assert_eq!(status, 200, "{}", j.dump());
    let ckpt = j.get("checkpoint").unwrap().as_str().unwrap().to_string();
    assert!(ckpt.ends_with("ckpt_round_000001.hckpt"), "{ckpt}");
    assert!(std::path::Path::new(&ckpt).exists());

    let (status, events) = http(addr, "GET", &format!("/sessions/{id}/events"), "");
    assert_eq!(status, 200);
    let lines: Vec<&str> = events.lines().collect();
    assert!(lines.len() >= 3, "expected round+idle+checkpointed, got: {events}");
    let types: Vec<String> = lines
        .iter()
        .map(|l| {
            Json::parse(l)
                .expect("each event line is JSON")
                .get("type")
                .unwrap()
                .as_str()
                .unwrap()
                .to_string()
        })
        .collect();
    assert!(types.contains(&"round".to_string()), "{types:?}");
    assert!(types.contains(&"checkpointed".to_string()), "{types:?}");

    let (status, j) = http_json(addr, "DELETE", &format!("/sessions/{id}"), "");
    assert_eq!(status, 200, "{}", j.dump());
    assert!(!state.join(format!("session_{id:06}")).exists(), "session dir not removed");
    let (status, _) = http_json(addr, "GET", &format!("/sessions/{id}"), "");
    assert_eq!(status, 404);

    // /shutdown flips the flag the CLI loop polls; the daemon object is
    // still ours to stop.
    let (status, _) = http_json(addr, "POST", "/shutdown", "");
    assert_eq!(status, 200);
    assert!(daemon.shutdown_requested());
    daemon.stop().expect("stop");
}
