//! Scenario-determinism suite: same seed + same `Scenario` spec must give
//! bit-identical per-round fleet snapshots and round histories — on the
//! analytic sim path and on the executable training path, which runs on
//! the resolved backend (PJRT with artifacts, native without) and never
//! skips.
//!
//! Also hosts the mega-fleet smoke: the >= 1000-device preset must
//! complete a 5-round analytic run quickly (the full bench lives in
//! `rust/benches/scenario_fleet.rs`, wired into `make bench-smoke`).

use std::path::PathBuf;

use hasfl::config::{Config, StrategyKind};
use hasfl::experiment::{Experiment, FleetTraceCsv, RoundReport};
use hasfl::scenario::{Scenario, ScenarioEngine, ScenarioPreset, ScenarioSim};

/// Artifacts directory handed to the builder. The session resolves its
/// backend from `HASFL_BACKEND` / auto, and the native backend keeps this
/// suite fully runnable with no artifacts on disk — engine-backed tests
/// never skip (`HASFL_REQUIRE_ENGINE=1` turns any regression of that into
/// a hard failure, see `hasfl::backend::skip_engine_test`).
fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn sim_config(n: usize, seed: u64) -> Config {
    let mut cfg = Config::table1();
    cfg.fleet.n_devices = n;
    cfg.seed = seed;
    cfg.strategy = StrategyKind::Fixed;
    cfg
}

#[test]
fn snapshot_streams_are_bit_identical_for_every_preset() {
    for preset in ScenarioPreset::ALL {
        let cfg = sim_config(16, 4242);
        let base = cfg.sample_fleet();
        let mut a = ScenarioEngine::new(preset.scenario(), base.clone(), cfg.seed).unwrap();
        let mut b = ScenarioEngine::new(preset.scenario(), base, cfg.seed).unwrap();
        for _ in 0..30 {
            assert_eq!(a.advance(), b.advance(), "preset '{}'", preset.as_str());
        }
    }
}

#[test]
fn spec_json_roundtrip_preserves_the_stream() {
    // A spec that survives JSON must drive the exact same evolution: the
    // codec cannot perturb determinism.
    for preset in ScenarioPreset::ALL {
        let spec = preset.scenario();
        let back =
            Scenario::from_json(&hasfl::util::Json::parse(&spec.to_json().dump()).unwrap())
                .unwrap();
        let cfg = sim_config(10, 7);
        let base = cfg.sample_fleet();
        let mut a = ScenarioEngine::new(spec, base.clone(), cfg.seed).unwrap();
        let mut b = ScenarioEngine::new(back, base, cfg.seed).unwrap();
        for _ in 0..20 {
            assert_eq!(a.advance(), b.advance(), "preset '{}'", preset.as_str());
        }
    }
}

#[test]
fn sim_round_histories_are_bit_identical() {
    let presets =
        [ScenarioPreset::DriftingChannels, ScenarioPreset::Diurnal, ScenarioPreset::ChurnHeavy];
    for preset in presets {
        let mut a = ScenarioSim::new(sim_config(12, 99), preset.scenario()).unwrap();
        let mut b = ScenarioSim::new(sim_config(12, 99), preset.scenario()).unwrap();
        a.run(45);
        b.run(45);
        assert_eq!(a.trace(), b.trace(), "preset '{}'", preset.as_str());
        assert_eq!(a.decisions(), b.decisions(), "preset '{}'", preset.as_str());
        assert_eq!(a.sim_time(), b.sim_time(), "preset '{}'", preset.as_str());
    }
}

#[test]
fn mega_fleet_five_round_smoke() {
    // The standing scale scenario: >= 1000 devices through fleet evolution,
    // the heterogeneity-aware BS solver, and the O(N) latency model.
    let mut cfg = sim_config(ScenarioPreset::MegaFleet.suggested_devices().unwrap(), 2025);
    cfg.strategy = ScenarioPreset::MegaFleet.suggested_strategy().unwrap();
    assert!(cfg.fleet.n_devices >= 1000);
    let mut sim = ScenarioSim::new(cfg, ScenarioPreset::MegaFleet.scenario()).unwrap();
    sim.run(5);
    assert_eq!(sim.trace().len(), 5);
    for r in &sim.trace().rounds {
        assert!(r.n_active >= 32, "round {}: active {}", r.round, r.n_active);
        assert!(r.n_active > r.n_dropped, "round {} had no survivors", r.round);
        assert!(r.t_split.is_finite() && r.t_split > 0.0);
    }
    assert!(sim.sim_time().is_finite() && sim.sim_time() > 0.0);
}

// ---- executable path (resolved backend; never skips) ---------------------

fn scenario_session_config() -> Config {
    let mut cfg = Config::small();
    cfg.fleet.n_devices = 4;
    cfg.train.rounds = 8;
    cfg.train.agg_interval = 4;
    cfg.train.eval_every = 4;
    cfg.train.train_samples = 256;
    cfg.train.test_samples = 64;
    cfg.train.batch_cap = 16;
    cfg.strategy = StrategyKind::Fixed;
    cfg.fixed_batch = 8;
    cfg.fixed_cut = 3;
    cfg
}

fn run_scenario_session(
    dir: &std::path::Path,
    spec: Scenario,
) -> (Vec<RoundReport>, hasfl::metrics::History) {
    // Unique trace path per call: tests run concurrently in one process.
    static CALL: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    let call = CALL.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let csv = std::env::temp_dir()
        .join(format!("hasfl_scn_trace_{}_{call}.csv", std::process::id()));
    let mut session = Experiment::builder()
        .config(scenario_session_config())
        .scenario(spec)
        .observe(FleetTraceCsv::new(&csv))
        .artifacts(dir)
        .build()
        .expect("session");
    let mut reports = Vec::new();
    while !session.is_done() {
        reports.push(session.step().expect("step"));
    }
    let history = session.finish().expect("finish");
    // The FleetTraceCsv observer flushed one row per round.
    let text = std::fs::read_to_string(&csv).expect("trace csv");
    assert_eq!(text.lines().count(), reports.len() + 1, "trace rows");
    (reports, history)
}

#[test]
fn executable_scenario_sessions_are_deterministic() {
    let dir = artifacts_dir();
    let spec = ScenarioPreset::ChurnHeavy.scenario();
    let (rep_a, hist_a) = run_scenario_session(&dir, spec.clone());
    let (rep_b, hist_b) = run_scenario_session(&dir, spec);

    assert_eq!(hist_a.records, hist_b.records);
    assert_eq!(rep_a.len(), rep_b.len());
    for (a, b) in rep_a.iter().zip(&rep_b) {
        assert_eq!(a.outcome.mean_loss, b.outcome.mean_loss, "round {}", a.round);
        assert_eq!(a.sim_time, b.sim_time, "round {}", a.round);
        assert_eq!(a.fleet, b.fleet, "round {}", a.round);
    }
    // Scenario sessions surface a snapshot on every round.
    assert!(rep_a.iter().all(|r| r.fleet.is_some()));
}

#[test]
fn executable_scenario_handles_dropouts_and_trains() {
    // Churn-heavy end-to-end through the real engine: dropped devices are
    // skipped, partial aggregation keeps the fleet consistent, and the
    // model still trains (finite losses all the way).
    let dir = artifacts_dir();
    let mut spec = ScenarioPreset::ChurnHeavy.scenario();
    // Crank dropout so a 8-round run reliably sees partial rounds.
    if let Some(churn) = &mut spec.churn {
        churn.dropout_prob = 0.35;
    }
    let (reports, history) = run_scenario_session(&dir, spec);
    assert_eq!(reports.len(), 8);
    for r in &reports {
        assert!(r.outcome.mean_loss.is_finite(), "round {}: loss", r.round);
        let snap = r.fleet.as_ref().unwrap();
        assert!(snap.active.len() > snap.dropped.len(), "round {}: survivors", r.round);
    }
    assert_eq!(history.records.len(), 8);
}

#[test]
fn static_scenario_matches_plain_session() {
    // The `static` preset must reproduce the historical fixed-fleet run
    // bit-for-bit: same per-round losses, same sim clock, same history.
    let dir = artifacts_dir();

    let mut plain = Experiment::builder()
        .config(scenario_session_config())
        .artifacts(&dir)
        .build()
        .expect("plain session");
    let mut plain_reports = Vec::new();
    while !plain.is_done() {
        plain_reports.push(plain.step().expect("step"));
    }
    let plain_hist = plain.finish().expect("finish");

    let (scn_reports, scn_hist) =
        run_scenario_session(&dir, ScenarioPreset::Static.scenario());

    assert_eq!(plain_hist.records, scn_hist.records);
    for (a, b) in plain_reports.iter().zip(&scn_reports) {
        assert_eq!(a.outcome.mean_loss, b.outcome.mean_loss, "round {}", a.round);
        assert_eq!(a.sim_time, b.sim_time, "round {}", a.round);
        assert_eq!(a.decisions.batch, b.decisions.batch, "round {}", a.round);
    }
}
