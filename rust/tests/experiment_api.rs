//! Tests for the `experiment` session API: builder validation, step/driver
//! parity, and observer callback ordering. Engine-backed tests run on the
//! resolved backend (PJRT with artifacts, native without) and never skip.

use std::cell::RefCell;
use std::path::PathBuf;
use std::rc::Rc;

use hasfl::backend::BackendKind;
use hasfl::config::{Config, ModelKind, StrategyKind};
use hasfl::experiment::{Experiment, Observer, RoundReport};
use hasfl::latency::Decisions;

/// Artifacts directory handed to the builder. The session resolves its
/// backend from `HASFL_BACKEND` / auto, and the native backend keeps this
/// suite fully runnable with no artifacts on disk — engine-backed tests
/// never skip (`HASFL_REQUIRE_ENGINE=1` turns any regression of that into
/// a hard failure, see `hasfl::backend::skip_engine_test`).
fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn tiny_config() -> Config {
    let mut cfg = Config::small();
    cfg.fleet.n_devices = 2;
    cfg.train.rounds = 5;
    cfg.train.agg_interval = 2;
    cfg.train.eval_every = 2;
    cfg.train.train_samples = 256;
    cfg.train.test_samples = 64;
    cfg.train.batch_cap = 16;
    cfg.strategy = StrategyKind::Fixed;
    cfg.fixed_batch = 8;
    cfg.fixed_cut = 3;
    cfg
}

// ---------------------------------------------------------------------------
// Builder validation (no artifacts / engine needed)
// ---------------------------------------------------------------------------

#[test]
fn build_rejects_zero_devices() {
    let err = Experiment::builder().devices(0).build().unwrap_err();
    assert!(err.to_string().contains("device"), "{err}");
}

#[test]
fn build_rejects_zero_rounds() {
    assert!(Experiment::builder().rounds(0).build().is_err());
}

#[test]
fn build_rejects_analytic_models() {
    let err = Experiment::builder().config(Config::table1()).build().unwrap_err();
    assert!(err.to_string().contains("analytic"), "{err}");
}

#[test]
fn build_rejects_bad_fixed_batch() {
    assert!(Experiment::builder().fixed_batch(0).build().is_err());
    // small preset: batch_cap = 32
    assert!(Experiment::builder().fixed_batch(64).build().is_err());
}

#[test]
fn pjrt_build_rejects_missing_artifacts() {
    // An explicit PJRT request must fail loudly without artifacts (auto
    // would fall back to the native backend instead).
    let err = Experiment::builder()
        .backend(BackendKind::Pjrt)
        .artifacts("definitely_not_an_artifacts_dir")
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("artifacts"), "{err}");
}

#[test]
fn native_build_needs_no_artifacts() {
    // The native backend synthesizes its manifest: a session builds and
    // trains with no artifacts directory at all.
    let mut session = Experiment::builder()
        .config(tiny_config())
        .rounds(1)
        .backend(BackendKind::Native)
        .artifacts("definitely_not_an_artifacts_dir")
        .build()
        .expect("native session");
    assert_eq!(session.config().backend, BackendKind::Native);
    let report = session.step().expect("step");
    assert!(report.outcome.mean_loss.is_finite());
    session.finish().expect("finish");
}

#[test]
fn auto_resolves_to_native_without_artifacts_and_is_recorded() {
    let session = Experiment::builder()
        .config(tiny_config())
        .backend(BackendKind::Auto)
        .artifacts("definitely_not_an_artifacts_dir")
        .build()
        .expect("auto session");
    // The *resolved* kind lands in the session config (and would be
    // embedded in any checkpoint).
    assert_eq!(session.config().backend, BackendKind::Native);
    session.finish().expect("finish");
}

#[test]
fn native_backend_supports_any_class_count() {
    // No shape-specialized artifacts means no class-count coupling: the
    // native backend trains a 100-way head directly.
    let mut session = Experiment::builder()
        .config(tiny_config())
        .tune(|c| c.train.classes = 100)
        .rounds(1)
        .backend(BackendKind::Native)
        .artifacts(artifacts_dir())
        .build()
        .expect("100-class native session");
    let report = session.step().expect("step");
    // Random init over 100 classes: loss near ln(100) ~ 4.6.
    assert!((3.0..7.0).contains(&report.outcome.mean_loss), "{}", report.outcome.mean_loss);
    session.finish().expect("finish");
}

#[test]
fn build_config_skips_engine_checks() {
    // Analytic configs validate without an artifacts directory.
    let cfg = Experiment::builder()
        .config(Config::table1())
        .devices(40)
        .build_config()
        .unwrap();
    assert_eq!(cfg.model, ModelKind::Vgg16);
    assert_eq!(cfg.fleet.n_devices, 40);
}

// ---------------------------------------------------------------------------
// Engine-backed: artifact-level validation, parity, observers
// ---------------------------------------------------------------------------

#[test]
fn build_rejects_out_of_range_cut() {
    let dir = artifacts_dir();
    let err = Experiment::builder()
        .config(tiny_config())
        .fixed_cut(99)
        .artifacts(&dir)
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("cut"), "{err}");
}

#[test]
fn pjrt_build_rejects_class_mismatch() {
    // Artifact-compatibility check is PJRT-specific: the on-disk manifest
    // is shape-specialized to a class count, the native manifest is not.
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        hasfl::backend::skip_pjrt_only("class-mismatch check needs on-disk AOT artifacts");
        return;
    }
    let err = Experiment::builder()
        .config(tiny_config())
        .tune(|c| c.train.classes = 100)
        .backend(BackendKind::Pjrt)
        .artifacts(&dir)
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("classes"), "{err}");
}

#[test]
fn manual_steps_match_run_to_completion() {
    // Step-driven parity: driving the session by hand produces exactly the
    // history the closed driver produces (same RNG stream, same records).
    let dir = artifacts_dir();

    let mut a = Experiment::builder().config(tiny_config()).artifacts(&dir).build().unwrap();
    let mut reports = Vec::new();
    while !a.is_done() {
        reports.push(a.step().unwrap());
    }
    let ha = a.finish().unwrap();

    let mut b = Experiment::builder().config(tiny_config()).artifacts(&dir).build().unwrap();
    b.run_to_completion().unwrap();
    let hb = b.finish().unwrap();

    assert_eq!(ha.records, hb.records);
    assert_eq!(reports.len(), 5);
    // The report stream mirrors the history records exactly.
    for (rep, rec) in reports.iter().zip(&ha.records) {
        assert_eq!(rep.round, rec.round);
        assert_eq!(rep.outcome.mean_loss, rec.loss);
        assert_eq!(rep.sim_time, rec.sim_time);
        assert_eq!(rep.test_acc, rec.test_acc);
    }
    // agg_interval = 2: rounds 2 and 4 aggregate + re-optimize.
    let agg_rounds: Vec<usize> =
        reports.iter().filter(|r| r.aggregated).map(|r| r.round).collect();
    assert_eq!(agg_rounds, vec![2, 4]);
}

#[derive(Default)]
struct RecordingObserver {
    events: Rc<RefCell<Vec<String>>>,
}

impl Observer for RecordingObserver {
    fn on_round(&mut self, report: &RoundReport) {
        self.events.borrow_mut().push(format!("round:{}", report.round));
    }
    fn on_aggregation(&mut self, report: &RoundReport) {
        self.events.borrow_mut().push(format!("agg:{}", report.round));
    }
    fn on_reoptimize(&mut self, report: &RoundReport, _dec: &Decisions) {
        self.events.borrow_mut().push(format!("reopt:{}", report.round));
    }
    fn on_eval(&mut self, report: &RoundReport, _acc: f64) {
        self.events.borrow_mut().push(format!("eval:{}", report.round));
    }
    fn on_complete(&mut self, _history: &hasfl::metrics::History) -> hasfl::Result<()> {
        self.events.borrow_mut().push("complete".into());
        Ok(())
    }
}

#[test]
fn observer_callbacks_fire_in_order() {
    let dir = artifacts_dir();
    let events = Rc::new(RefCell::new(Vec::new()));
    let obs = RecordingObserver { events: Rc::clone(&events) };
    let mut session = Experiment::builder()
        .config(tiny_config())
        .rounds(4)
        .artifacts(&dir)
        .observe(obs)
        .build()
        .unwrap();
    session.run_to_completion().unwrap();
    session.finish().unwrap();

    // agg_interval = 2, eval_every = 2: per round on_round first, then
    // aggregation -> reoptimize -> eval on the even rounds, and
    // on_complete exactly once at finish().
    let got = events.borrow().clone();
    let want = vec![
        "round:1",
        "round:2",
        "agg:2",
        "reopt:2",
        "eval:2",
        "round:3",
        "round:4",
        "agg:4",
        "reopt:4",
        "eval:4",
        "complete",
    ];
    assert_eq!(got, want);
}

struct StopAfter {
    rounds: usize,
    seen: usize,
}

impl Observer for StopAfter {
    fn on_round(&mut self, _report: &RoundReport) {
        self.seen += 1;
    }
    fn should_stop(&self) -> bool {
        self.seen >= self.rounds
    }
}

#[test]
fn observer_can_stop_the_run_early() {
    let dir = artifacts_dir();
    let mut session = Experiment::builder()
        .config(tiny_config())
        .rounds(50)
        .artifacts(&dir)
        .observe(StopAfter { rounds: 3, seen: 0 })
        .build()
        .unwrap();
    session.run_to_completion().unwrap();
    assert_eq!(session.round(), 3);
    assert!(!session.is_done());
    assert!(session.stop_requested());
    let history = session.finish().unwrap();
    assert_eq!(history.records.len(), 3);
}
