//! Bit-identity guarantee for hierarchical (cell-sharded) aggregation
//! (DESIGN.md §15): a fleet partitioned into cells — each cell training
//! on its own engine-lane slice and producing a weighted partial
//! aggregate that the root merges in fixed cell order — must produce
//! bit-identical `Params`, `RoundReport` streams, and history to the
//! historical flat roster, at every cell count, in sequential and
//! concurrent modes, under churn/dropout scenarios, under fault
//! injection, and across a checkpoint/resume boundary.
//!
//! Runs on the resolved backend (PJRT with artifacts, native without) and
//! never skips.

use std::path::{Path, PathBuf};

use hasfl::checkpoint::CheckpointObserver;
use hasfl::config::{Config, StrategyKind};
use hasfl::experiment::{Experiment, RoundReport};
use hasfl::fault::FaultPreset;
use hasfl::metrics::History;
use hasfl::model::Params;
use hasfl::scenario::{Scenario, ScenarioPreset};
use hasfl::topology::Topology;

/// Artifacts directory handed to the builder. The session resolves its
/// backend from `HASFL_BACKEND` / auto, and the native backend keeps this
/// suite fully runnable with no artifacts on disk — engine-backed tests
/// never skip (`HASFL_REQUIRE_ENGINE=1` turns any regression of that into
/// a hard failure, see `hasfl::backend::skip_engine_test`).
fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hasfl_cells_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Six devices so cell counts 1/3/6/8 exercise multi-device cells,
/// single-device cells, and structurally empty cells (8 cells over 6
/// devices) in one fleet.
fn cells_config(seed: u64) -> Config {
    let mut cfg = Config::small();
    cfg.fleet.n_devices = 6;
    cfg.seed = seed;
    cfg.train.rounds = 6;
    cfg.train.agg_interval = 3;
    cfg.train.eval_every = 3;
    cfg.train.train_samples = 256;
    cfg.train.test_samples = 64;
    cfg.train.batch_cap = 16;
    cfg.strategy = StrategyKind::Fixed;
    cfg.fixed_batch = 8;
    cfg.fixed_cut = 3;
    cfg
}

type RunResult = (Vec<RoundReport>, History, Vec<Params>);

/// Run one (topology, pool, mode) combination to completion.
fn run_with(
    dir: &Path,
    cfg: Config,
    cells: Option<usize>,
    pool: usize,
    concurrent: bool,
    scenario: Option<Scenario>,
    faults: Option<FaultPreset>,
) -> RunResult {
    let mut builder = Experiment::builder()
        .config(cfg)
        .engine_pool(pool)
        .concurrent(concurrent)
        .artifacts(dir);
    if let Some(n) = cells {
        builder = builder.cells(n);
    }
    if let Some(s) = scenario {
        builder = builder.scenario(s);
    }
    if let Some(f) = faults {
        builder = builder.faults_preset(f);
    }
    let mut session = builder.build().expect("session");
    let mut reports = Vec::new();
    while !session.is_done() {
        reports.push(session.step().expect("step"));
    }
    let params = session.trainer().params().to_vec();
    let history = session.finish().expect("finish");
    (reports, history, params)
}

/// Everything except the per-cell stats block must be bit-identical (the
/// cells block legitimately differs across topologies: a flat run has no
/// cells, a 3-cell run has three).
fn assert_reports_identical(a: &[RoundReport], b: &[RoundReport], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: round count");
    for (ra, rb) in a.iter().zip(b) {
        assert_eq!(ra.round, rb.round, "{what}");
        assert_eq!(ra.outcome.mean_loss, rb.outcome.mean_loss, "{what}: round {}", ra.round);
        assert_eq!(ra.outcome.train_acc, rb.outcome.train_acc, "{what}: round {}", ra.round);
        assert_eq!(
            ra.outcome.participants,
            rb.outcome.participants,
            "{what}: round {}",
            ra.round
        );
        assert_eq!(ra.sim_time, rb.sim_time, "{what}: round {}", ra.round);
        assert_eq!(ra.aggregated, rb.aggregated, "{what}: round {}", ra.round);
        assert_eq!(ra.reoptimized, rb.reoptimized, "{what}: round {}", ra.round);
        assert_eq!(ra.test_acc, rb.test_acc, "{what}: round {}", ra.round);
        assert_eq!(ra.decisions.batch, rb.decisions.batch, "{what}: round {}", ra.round);
        assert_eq!(ra.decisions.cut, rb.decisions.cut, "{what}: round {}", ra.round);
        assert_eq!(ra.fleet, rb.fleet, "{what}: round {}", ra.round);
        assert_eq!(ra.abandoned, rb.abandoned, "{what}: round {}", ra.round);
        assert_eq!(ra.quarantined, rb.quarantined, "{what}: round {}", ra.round);
    }
}

#[test]
fn flat_and_sharded_rounds_are_bit_identical() {
    let dir = artifacts_dir();
    let mk = || cells_config(101);

    // The historical flat reference: sequential, single lane, no topology.
    let (rep_flat, hist_flat, params_flat) = run_with(&dir, mk(), None, 1, false, None, None);

    let variants: [(&str, Option<usize>, usize, bool); 5] = [
        // cells=1 is the flat plan by construction.
        ("cells=1 concurrent pool=2", Some(1), 2, true),
        // 3 cells over 6 devices, sequential: streaming-apply path.
        ("cells=3 sequential pool=1", Some(3), 1, false),
        // 3 cells, concurrent over a lane partition: per-cell queues.
        ("cells=3 concurrent pool=2", Some(3), 2, true),
        // One device per cell.
        ("cells=6 concurrent pool=2", Some(6), 2, true),
        // More cells than devices: the trailing cells are structurally
        // empty every round (and more cells than lanes: round-robin wrap).
        ("cells=8 concurrent pool=2", Some(8), 2, true),
    ];
    for (what, cells, pool, concurrent) in variants {
        let (rep, hist, params) = run_with(&dir, mk(), cells, pool, concurrent, None, None);
        assert_reports_identical(&rep_flat, &rep, what);
        assert_eq!(hist_flat.records, hist.records, "{what}: history");
        // Bit-identical final model state on every device (Params derives
        // PartialEq over raw f32 data — no tolerance).
        assert_eq!(params_flat, params, "{what}: params");
    }
}

#[test]
fn sharded_rounds_survive_churn_and_dropout() {
    // Churn + dropout + stragglers: partial aggregation over a moving
    // roster must stay bit-identical however the fleet is sharded, in
    // both execution modes.
    let dir = artifacts_dir();
    let scenario = || Some(ScenarioPreset::ChurnHeavy.scenario());
    let (rep_flat, hist_flat, params_flat) =
        run_with(&dir, cells_config(23), None, 1, false, scenario(), None);
    let (rep_seq, hist_seq, params_seq) =
        run_with(&dir, cells_config(23), Some(3), 1, false, scenario(), None);
    let (rep_conc, hist_conc, params_conc) =
        run_with(&dir, cells_config(23), Some(3), 2, true, scenario(), None);

    assert_reports_identical(&rep_flat, &rep_seq, "churn: flat vs cells=3 sequential");
    assert_reports_identical(&rep_flat, &rep_conc, "churn: flat vs cells=3 concurrent");
    assert_eq!(hist_flat.records, hist_seq.records);
    assert_eq!(hist_flat.records, hist_conc.records);
    assert_eq!(params_flat, params_seq);
    assert_eq!(params_flat, params_conc);
}

#[test]
fn sharded_rounds_survive_fault_injection() {
    // Seeded chaos faults (transient failures, abandonment, quarantine):
    // with one device per cell, an abandoned device empties its whole
    // cell for the round — the all-quarantined/empty-cell path end to end.
    let dir = artifacts_dir();
    let (rep_flat, hist_flat, params_flat) =
        run_with(&dir, cells_config(77), None, 1, false, None, Some(FaultPreset::Chaos));
    let (rep_cells, hist_cells, params_cells) =
        run_with(&dir, cells_config(77), Some(6), 2, true, None, Some(FaultPreset::Chaos));

    assert_reports_identical(&rep_flat, &rep_cells, "chaos: flat vs cells=6 concurrent");
    assert_eq!(hist_flat.records, hist_cells.records);
    assert_eq!(params_flat, params_cells);
}

#[test]
fn per_cell_stats_partition_the_round() {
    let dir = artifacts_dir();
    // Flat runs report no cells block at all.
    let (rep_flat, _, _) = run_with(&dir, cells_config(5), None, 1, false, None, None);
    assert!(rep_flat.iter().all(|r| r.cells.is_empty()));

    // Sharded runs report one entry per cell, in fixed cell order,
    // partitioning the roster and the participant count; sequential and
    // concurrent modes must agree on every field.
    let (rep_seq, _, _) = run_with(&dir, cells_config(5), Some(3), 1, false, None, None);
    let (rep_conc, _, _) = run_with(&dir, cells_config(5), Some(3), 2, true, None, None);
    for (rs, rc) in rep_seq.iter().zip(&rep_conc) {
        assert_eq!(rs.cells, rc.cells, "round {}: cell stats across modes", rs.round);
        assert_eq!(rs.cells.len(), 3, "round {}", rs.round);
        let devices: usize = rs.cells.iter().map(|c| c.devices).sum();
        let participants: usize = rs.cells.iter().map(|c| c.participants).sum();
        assert_eq!(devices, 6, "round {}: cells partition the roster", rs.round);
        assert_eq!(
            participants,
            rs.outcome.participants,
            "round {}: cell participants sum to the round's",
            rs.round
        );
        for (k, c) in rs.cells.iter().enumerate() {
            assert_eq!(c.cell, k, "fixed cell order");
            assert!(c.t_split >= 0.0 && c.t_split.is_finite());
            // Each cell is gated by its own stragglers only, so no cell
            // can be slower than the whole round.
            assert!(c.t_split <= rs.latency.t_split + 1e-12, "round {}", rs.round);
        }
    }
}

#[test]
fn checkpoint_resume_preserves_topology() {
    let dir = artifacts_dir();
    let ckpt_dir = temp_dir("resume");
    let mut cfg = cells_config(42);
    cfg.train.rounds = 8;
    cfg.topology = Some(Topology::with_cells(3));

    // Straight 8-round sharded run, checkpointing at round 4.
    let mut session = Experiment::builder()
        .config(cfg)
        .artifacts(&dir)
        .observe(CheckpointObserver::new(&ckpt_dir, 4))
        .build()
        .expect("straight session");
    let mut straight = Vec::new();
    while !session.is_done() {
        straight.push(session.step().expect("step"));
    }
    let straight_params = session.trainer().params().to_vec();
    let straight_hist = session.finish().expect("finish");

    let ckpt = ckpt_dir.join("ckpt_round_000004.hckpt");
    assert!(ckpt.exists(), "checkpoint at round 4 missing");

    // The embedded topology travels with the checkpoint: the resumed
    // session is sharded without re-stating --cells, and replays rounds
    // 5..=8 bit-identically.
    let mut resumed = Experiment::builder()
        .resume_from(&ckpt)
        .artifacts(&dir)
        .build()
        .expect("resumed session");
    assert_eq!(resumed.config().topology, Some(Topology::with_cells(3)));
    let mut reports = Vec::new();
    while !resumed.is_done() {
        reports.push(resumed.step().expect("step"));
    }
    let resumed_params = resumed.trainer().params().to_vec();
    let resumed_hist = resumed.finish().expect("finish");

    assert_reports_identical(&straight[4..], &reports, "resume");
    for (rs, rr) in straight[4..].iter().zip(&reports) {
        assert_eq!(rs.cells, rr.cells, "round {}: per-cell stats across resume", rs.round);
    }
    assert_eq!(straight_hist.records, resumed_hist.records);
    assert_eq!(straight_params, resumed_params);

    // Reshaping the topology mid-run is rejected loudly: the checkpoint's
    // embedded topology is authoritative.
    let err = Experiment::builder()
        .resume_from(&ckpt)
        .cells(2)
        .artifacts(&dir)
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("conflicts with resume_from"), "{err}");

    let _ = std::fs::remove_dir_all(&ckpt_dir);
}
