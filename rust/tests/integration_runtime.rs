//! Integration tests over the execution runtime: the engine pool, the
//! split/full step contract, bucket padding, and the parameter-buffer
//! cache — on whichever backend the run resolves to (PJRT with AOT
//! artifacts, native without). These tests never skip; the few
//! PJRT-specific assertions (compile counters) adapt to the backend.

use std::path::PathBuf;

use hasfl::backend::BackendKind;
use hasfl::model::{Manifest, Params};
use hasfl::runtime::{
    tensor_to_host, tensor_to_shared, BufKey, EngineHandle, EngineSpec, ExecInput, HostTensor,
    StepArtifacts,
};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The backend this run resolves to: `HASFL_BACKEND` if set, else PJRT
/// when artifacts exist, else native.
fn backend() -> BackendKind {
    BackendKind::from_env().unwrap_or(BackendKind::Auto).resolve(&artifacts_dir())
}

/// Spawn a `width`-lane engine pool on the resolved backend, plus its
/// manifest and whether the PJRT compile counters apply.
fn setup_pool(width: usize) -> (EngineHandle, Manifest, bool) {
    let spec = EngineSpec::resolve(backend(), &artifacts_dir(), 10);
    let pjrt = spec.kind() == BackendKind::Pjrt;
    let manifest = spec.manifest().expect("manifest");
    let engine = EngineHandle::spawn_backend(spec, width).expect("engine");
    (engine, manifest, pjrt)
}

fn setup() -> (EngineHandle, Manifest, bool) {
    setup_pool(1)
}

/// Deterministic pseudo-batch for tests.
fn fake_batch(bucket: usize, classes: usize, true_b: usize) -> (HostTensor, HostTensor, HostTensor) {
    let mut rng = hasfl::rng::Pcg32::seeded(99);
    let px = 32 * 32 * 3;
    let x: Vec<f32> = (0..bucket * px).map(|_| rng.normal() as f32 * 0.5).collect();
    let mut onehot = vec![0.0f32; bucket * classes];
    let mut weights = vec![0.0f32; bucket];
    for r in 0..bucket {
        onehot[r * classes + (r % classes)] = 1.0;
        if r < true_b {
            weights[r] = 1.0;
        }
    }
    (
        HostTensor { shape: vec![bucket, 32, 32, 3], data: x },
        HostTensor { shape: vec![bucket, classes], data: onehot },
        HostTensor { shape: vec![bucket], data: weights },
    )
}

#[test]
fn full_fwd_produces_logits() {
    let (engine, manifest, _) = setup();
    let params = Params::init(&manifest, 1);
    let (x, _, _) = fake_batch(8, manifest.num_classes, 8);
    let name = Manifest::full_name("full_fwd", 8);
    let mut inputs = vec![x];
    inputs.extend(params.tensors.iter().map(tensor_to_host));
    let out = engine.execute_blocking(&name, inputs).expect("exec");
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape, vec![8, manifest.num_classes]);
    assert!(out[0].data.iter().all(|v| v.is_finite()));
    engine.shutdown();
}

#[test]
fn full_step_loss_near_ln10_at_init() {
    // Random init + balanced labels => loss ~ ln(10) ≈ 2.303.
    let (engine, manifest, _) = setup();
    let params = Params::init(&manifest, 2);
    let (x, y, w) = fake_batch(16, manifest.num_classes, 16);
    let name = Manifest::full_name("full_step", 16);
    let mut inputs = vec![x, y, w];
    inputs.extend(params.tensors.iter().map(tensor_to_host));
    let out = engine.execute_blocking(&name, inputs).expect("exec");
    let loss = out[0].data[0];
    assert!((1.5..4.0).contains(&loss), "init loss {loss}");
    // gradients exist for every tensor and are finite
    assert_eq!(out.len(), 2 + params.tensors.len());
    for g in &out[2..] {
        assert!(g.data.iter().all(|v| v.is_finite()));
    }
    engine.shutdown();
}

#[test]
fn split_equals_full_through_the_engine() {
    // The core SFL invariant, across the engine boundary this time:
    // client_fwd -> server_step -> client_bwd == full_step.
    let (engine, manifest, _) = setup();
    let params = Params::init(&manifest, 3);
    let classes = manifest.num_classes;
    let (x, y, w) = fake_batch(8, classes, 8);

    // Full step.
    let name = Manifest::full_name("full_step", 8);
    let mut inputs = vec![x.clone(), y.clone(), w.clone()];
    inputs.extend(params.tensors.iter().map(tensor_to_host));
    let full = engine.execute_blocking(&name, inputs).expect("full");

    for cut in [2usize, 5] {
        let sa = StepArtifacts::resolve(&manifest, cut, 8).unwrap();
        // a1
        let mut cf_in = vec![x.clone()];
        cf_in.extend(params.client_slice(cut).iter().map(tensor_to_host));
        let a = engine.execute_blocking(&sa.client_fwd, cf_in).expect("cf").remove(0);
        // a3
        let mut ss_in = vec![a, y.clone(), w.clone()];
        ss_in.extend(params.server_slice(cut).iter().map(tensor_to_host));
        let mut ss_out = engine.execute_blocking(&sa.server_step, ss_in).expect("ss");
        let loss = ss_out.remove(0).data[0];
        let _correct = ss_out.remove(0);
        let ga = ss_out.remove(0);
        // a5
        let mut cb_in = vec![x.clone(), ga];
        cb_in.extend(params.client_slice(cut).iter().map(tensor_to_host));
        let cb_out = engine.execute_blocking(&sa.client_bwd, cb_in).expect("cb");

        assert!((loss - full[0].data[0]).abs() < 1e-4, "cut {cut} loss");
        let split_grads: Vec<&HostTensor> = cb_out.iter().chain(ss_out.iter()).collect();
        assert_eq!(split_grads.len(), full.len() - 2);
        for (k, (sg, fg)) in split_grads.iter().zip(&full[2..]).enumerate() {
            for (a, b) in sg.data.iter().zip(&fg.data) {
                assert!(
                    (a - b).abs() < 3e-4 + 3e-3 * b.abs(),
                    "cut {cut} grad tensor {k}: {a} vs {b}"
                );
            }
        }
    }
    engine.shutdown();
}

#[test]
fn padded_bucket_matches_unpadded_batch() {
    // Bucket padding with zero weights must be numerically exact: true
    // batch 5 on bucket 8 == batch 5 run with weights all ones on bucket
    // (well, compare loss+grads against an 8-batch where rows 5..8 have
    // zero weight vs the same rows replaced by garbage — results equal).
    let (engine, manifest, _) = setup();
    let params = Params::init(&manifest, 4);
    let classes = manifest.num_classes;
    let (x, y, w) = fake_batch(8, classes, 5);

    let name = Manifest::full_name("full_step", 8);
    let mut inputs = vec![x.clone(), y.clone(), w.clone()];
    inputs.extend(params.tensors.iter().map(tensor_to_host));
    let base = engine.execute_blocking(&name, inputs).expect("base");

    // Scramble the padded rows' pixels; weights stay zero there.
    let mut x2 = x.clone();
    let px = 32 * 32 * 3;
    for v in x2.data[5 * px..].iter_mut() {
        *v = 123.456;
    }
    let mut inputs = vec![x2, y.clone(), w.clone()];
    inputs.extend(params.tensors.iter().map(tensor_to_host));
    let scrambled = engine.execute_blocking(&name, inputs).expect("scrambled");

    assert!((base[0].data[0] - scrambled[0].data[0]).abs() < 1e-5, "loss differs");
    for (a, b) in base[2..].iter().zip(&scrambled[2..]) {
        for (x1, x2) in a.data.iter().zip(&b.data) {
            assert!((x1 - x2).abs() < 1e-4, "padded rows leaked into grads");
        }
    }
    engine.shutdown();
}

#[test]
fn engine_rejects_bad_shapes() {
    let (engine, manifest, _) = setup();
    let name = Manifest::full_name("full_fwd", 8);
    let bad = HostTensor { shape: vec![4, 32, 32, 3], data: vec![0.0; 4 * 32 * 32 * 3] };
    let err = engine.execute_blocking(&name, vec![bad]);
    assert!(err.is_err());
    engine.shutdown();
    let _ = manifest;
}

#[test]
fn engine_stats_accumulate() {
    let (engine, manifest, pjrt) = setup();
    let params = Params::init(&manifest, 5);
    let (x, _, _) = fake_batch(4, manifest.num_classes, 4);
    let name = Manifest::full_name("full_fwd", 4);
    let mut inputs = vec![x];
    inputs.extend(params.tensors.iter().map(tensor_to_host));
    engine.execute_blocking(&name, inputs.clone()).unwrap();
    engine.execute_blocking(&name, inputs).unwrap();
    let stats = engine.stats_blocking().unwrap();
    assert_eq!(stats.executions, 2);
    // PJRT compiles once and caches; native has nothing to compile.
    assert_eq!(stats.compiles, if pjrt { 1 } else { 0 });
    assert_eq!(stats.pool_width, 1);
    assert!(stats.exec_secs > 0.0);
    assert!(stats.upload_bytes > 0);
    assert!(stats.download_bytes > 0);
    // Fresh inputs never touch the buffer cache.
    assert_eq!(stats.buffer_hits + stats.buffer_misses, 0);
    engine.shutdown();
}

/// Build `full_fwd` inputs with the parameters as versioned cached inputs.
fn cached_inputs(params: &Params, x: &HostTensor, version: u64) -> Vec<ExecInput> {
    let mut inputs = vec![ExecInput::Fresh(x.clone())];
    inputs.extend(params.tensors.iter().enumerate().map(|(s, t)| {
        ExecInput::cached(BufKey { set: 0, slot: s as u32 }, version, tensor_to_shared(t))
    }));
    inputs
}

#[test]
fn buffer_cache_serves_stable_versions_and_invalidates_on_bump() {
    let (engine, manifest, _) = setup();
    let params = Params::init(&manifest, 6);
    let (x, _, _) = fake_batch(4, manifest.num_classes, 4);
    let name = Manifest::full_name("full_fwd", 4);
    let n_params = params.tensors.len() as u64;

    // Reference output through the fresh (uncached) path.
    let mut fresh = vec![x.clone()];
    fresh.extend(params.tensors.iter().map(tensor_to_host));
    let want = engine.execute_blocking(&name, fresh).expect("fresh");

    // First cached call packs every parameter (all misses)...
    let got1 = engine
        .execute_inputs_blocking(0, &name, cached_inputs(&params, &x, 1))
        .expect("cached 1");
    // ...the second serves them all from the buffer cache...
    let got2 = engine
        .execute_inputs_blocking(0, &name, cached_inputs(&params, &x, 1))
        .expect("cached 2");
    let stats = engine.stats_blocking().unwrap();
    assert_eq!(stats.buffer_misses, n_params);
    assert_eq!(stats.buffer_hits, n_params);
    assert!(stats.buffer_hit_bytes > 0);

    // ...and a version bump re-packs (no stale literals).
    let got3 = engine
        .execute_inputs_blocking(0, &name, cached_inputs(&params, &x, 2))
        .expect("cached 3");
    let stats = engine.stats_blocking().unwrap();
    assert_eq!(stats.buffer_misses, 2 * n_params);
    assert_eq!(stats.buffer_hits, n_params);

    // Cached execution is bit-identical to the fresh path.
    for got in [&got1, &got2, &got3] {
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.data, w.data, "cached output differs from fresh output");
        }
    }
    engine.shutdown();
}

#[test]
fn engine_pool_lanes_execute_independently() {
    let (engine, manifest, pjrt) = setup_pool(2);
    assert_eq!(engine.width(), 2);
    let params = Params::init(&manifest, 7);
    let (x, _, _) = fake_batch(4, manifest.num_classes, 4);
    let name = Manifest::full_name("full_fwd", 4);

    let run = |lane: usize| {
        engine
            .execute_inputs_blocking(lane, &name, cached_inputs(&params, &x, 1))
            .expect("exec")
    };
    let out0 = run(0);
    let out1 = run(1);
    for (a, b) in out0.iter().zip(&out1) {
        assert_eq!(a.data, b.data, "lanes disagree");
    }
    // Lane routing wraps modulo the width; each lane has its own caches.
    let out2 = run(2); // lane 0 again: params now hit
    assert_eq!(out2[0].data, out0[0].data);
    let stats = engine.stats_blocking().unwrap();
    assert_eq!(stats.pool_width, 2);
    assert_eq!(stats.executions, 3);
    // One compile per PJRT lane; native lanes compile nothing.
    assert_eq!(stats.compiles, if pjrt { 2 } else { 0 });
    let n_params = params.tensors.len() as u64;
    assert_eq!(stats.buffer_misses, 2 * n_params); // one pack per lane
    assert_eq!(stats.buffer_hits, n_params); // the wrapped call hit lane 0
    engine.shutdown();
}
