//! Parity guarantee for the execution modes: sequential, single-engine
//! concurrent, and pooled-concurrent rounds must produce bit-identical
//! `Params` and identical `RoundReport`/history streams for a fixed seed.
//! This is what licenses the engine pool as a pure wall-clock optimisation.
//!
//! Runs on the resolved backend (PJRT with artifacts, native without) and
//! never skips; cross-backend agreement lives in `tests/backend_parity.rs`.

use std::path::PathBuf;

use hasfl::config::{Config, StrategyKind};
use hasfl::experiment::{Experiment, RoundReport};
use hasfl::model::Params;

/// Artifacts directory handed to the builder. The session resolves its
/// backend from `HASFL_BACKEND` / auto, and the native backend keeps this
/// suite fully runnable with no artifacts on disk — engine-backed tests
/// never skip (`HASFL_REQUIRE_ENGINE=1` turns any regression of that into
/// a hard failure, see `hasfl::backend::skip_engine_test`).
fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn parity_config() -> Config {
    let mut cfg = Config::small();
    cfg.fleet.n_devices = 4;
    cfg.train.rounds = 6;
    cfg.train.agg_interval = 3;
    cfg.train.eval_every = 3;
    cfg.train.train_samples = 256;
    cfg.train.test_samples = 64;
    cfg.train.batch_cap = 16;
    cfg.strategy = StrategyKind::Fixed;
    cfg.fixed_batch = 8;
    cfg.fixed_cut = 3;
    cfg
}

/// Run one mode to completion, returning (reports, history, final params).
fn run_mode(
    dir: &std::path::Path,
    pool: usize,
    concurrent: bool,
) -> (Vec<RoundReport>, hasfl::metrics::History, Vec<Params>) {
    let mut session = Experiment::builder()
        .config(parity_config())
        .engine_pool(pool)
        .concurrent(concurrent)
        .artifacts(dir)
        .build()
        .expect("session");
    let mut reports = Vec::new();
    while !session.is_done() {
        reports.push(session.step().expect("step"));
    }
    let params = session.trainer().params().to_vec();
    let history = session.finish().expect("finish");
    (reports, history, params)
}

fn assert_reports_identical(a: &[RoundReport], b: &[RoundReport], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: round count");
    for (ra, rb) in a.iter().zip(b) {
        assert_eq!(ra.round, rb.round, "{what}");
        assert_eq!(ra.outcome.mean_loss, rb.outcome.mean_loss, "{what}: round {}", ra.round);
        assert_eq!(ra.outcome.train_acc, rb.outcome.train_acc, "{what}: round {}", ra.round);
        assert_eq!(ra.sim_time, rb.sim_time, "{what}: round {}", ra.round);
        assert_eq!(ra.aggregated, rb.aggregated, "{what}: round {}", ra.round);
        assert_eq!(ra.reoptimized, rb.reoptimized, "{what}: round {}", ra.round);
        assert_eq!(ra.test_acc, rb.test_acc, "{what}: round {}", ra.round);
        assert_eq!(ra.decisions.batch, rb.decisions.batch, "{what}: round {}", ra.round);
        assert_eq!(ra.decisions.cut, rb.decisions.cut, "{what}: round {}", ra.round);
    }
}

#[test]
fn sequential_single_engine_and_pooled_rounds_are_bit_identical() {
    let dir = artifacts_dir();

    let (rep_seq, hist_seq, params_seq) = run_mode(&dir, 1, false);
    let (rep_c1, hist_c1, params_c1) = run_mode(&dir, 1, true);
    let (rep_pool, hist_pool, params_pool) = run_mode(&dir, 4, true);

    assert_reports_identical(&rep_seq, &rep_c1, "sequential vs concurrent(pool=1)");
    assert_reports_identical(&rep_seq, &rep_pool, "sequential vs concurrent(pool=4)");
    assert_eq!(hist_seq.records, hist_c1.records);
    assert_eq!(hist_seq.records, hist_pool.records);

    // Bit-identical final model state on every device (Params derives
    // PartialEq over raw f32 data — no tolerance).
    assert_eq!(params_seq, params_c1, "params: sequential vs concurrent(pool=1)");
    assert_eq!(params_seq, params_pool, "params: sequential vs concurrent(pool=4)");
}

#[test]
fn pooled_sequential_matches_single_engine_sequential() {
    // Pool width must not leak into *sequential* numerics either (all
    // sequential traffic routes to lane 0).
    let dir = artifacts_dir();
    let (rep_a, hist_a, params_a) = run_mode(&dir, 1, false);
    let (rep_b, hist_b, params_b) = run_mode(&dir, 3, false);
    assert_reports_identical(&rep_a, &rep_b, "sequential pool=1 vs pool=3");
    assert_eq!(hist_a.records, hist_b.records);
    assert_eq!(params_a, params_b);
}
