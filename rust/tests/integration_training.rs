//! End-to-end integration over the full coordinator: real SFL training of
//! SplitCNN-8 through the resolved execution backend (PJRT with artifacts,
//! native without — never skipped), driven by the `experiment` session API.

use std::path::PathBuf;

use hasfl::config::{Config, Partition, StrategyKind};
use hasfl::experiment::{Experiment, Session};

/// Artifacts directory handed to the builder. The session resolves its
/// backend from `HASFL_BACKEND` / auto, and the native backend keeps this
/// suite fully runnable with no artifacts on disk — engine-backed tests
/// never skip (`HASFL_REQUIRE_ENGINE=1` turns any regression of that into
/// a hard failure, see `hasfl::backend::skip_engine_test`).
fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn tiny_config() -> Config {
    let mut cfg = Config::small();
    cfg.fleet.n_devices = 2;
    cfg.train.rounds = 8;
    cfg.train.agg_interval = 4;
    cfg.train.eval_every = 4;
    cfg.train.train_samples = 256;
    cfg.train.test_samples = 64;
    cfg.train.batch_cap = 16;
    cfg.strategy = StrategyKind::Fixed;
    cfg.fixed_batch = 8;
    cfg.fixed_cut = 3;
    cfg
}

fn tiny_session(dir: &std::path::Path) -> Session {
    Experiment::builder()
        .config(tiny_config())
        .artifacts(dir)
        .build()
        .expect("session")
}

#[test]
fn training_reduces_loss() {
    let dir = artifacts_dir();
    let mut session = Experiment::builder()
        .config(tiny_config())
        .rounds(20)
        .artifacts(&dir)
        .build()
        .expect("session");
    session.run_to_completion().expect("run");
    let records = &session.history().records;
    let first: f64 = records[..4].iter().map(|r| r.loss).sum::<f64>() / 4.0;
    let last: f64 = records[16..].iter().map(|r| r.loss).sum::<f64>() / 4.0;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert!(session.sim_time() > 0.0);
    session.finish().expect("finish");
}

#[test]
fn sequential_and_concurrent_rounds_agree() {
    // Same seed => identical sampling => identical histories. The default
    // engine pool (auto width) may genuinely overlap device compute here;
    // results are applied in device order, so numerics must not move (the
    // strict bit-identity version of this lives in tests/parity_modes.rs).
    let dir = artifacts_dir();
    let mut a = tiny_session(&dir);
    a.run_to_completion().expect("run a");
    let mut b = tiny_session(&dir);
    b.run_concurrent().expect("run b");
    assert_eq!(a.history().records.len(), b.history().records.len());
    for (ra, rb) in a.history().records.iter().zip(&b.history().records) {
        assert!((ra.loss - rb.loss).abs() < 1e-6, "round {}: {} vs {}", ra.round, ra.loss, rb.loss);
        assert_eq!(ra.test_acc.is_some(), rb.test_acc.is_some());
    }
    a.finish().expect("finish a");
    b.finish().expect("finish b");
}

#[test]
fn hasfl_strategy_runs_end_to_end() {
    let dir = artifacts_dir();
    let mut session = Experiment::builder()
        .config(tiny_config())
        .strategy(StrategyKind::Hasfl)
        .rounds(6)
        .artifacts(&dir)
        .build()
        .expect("session");
    session.run_to_completion().expect("run");
    // HASFL decisions must be in range and memory-feasible.
    let dec = session.decisions();
    let valid_cuts = session.trainer().manifest().valid_cuts.clone();
    for (&b, &c) in dec.batch.iter().zip(&dec.cut) {
        assert!(b >= 1 && b <= 64);
        assert!(valid_cuts.contains(&c));
    }
    session.finish().expect("finish");
}

#[test]
fn noniid_partition_trains() {
    let dir = artifacts_dir();
    let mut session = Experiment::builder()
        .config(tiny_config())
        .partition(Partition::NonIidShards)
        .rounds(6)
        .artifacts(&dir)
        .build()
        .expect("session");
    session.run_to_completion().expect("run");
    assert_eq!(session.history().records.len(), 6);
    session.finish().expect("finish");
}

#[test]
fn evaluation_accuracy_improves_over_random_guess() {
    let dir = artifacts_dir();
    let mut session = Experiment::builder()
        .config(tiny_config())
        .rounds(60)
        .eval_every(20)
        .fixed_batch(16)
        .artifacts(&dir)
        .build()
        .expect("session");
    session.run_to_completion().expect("run");
    let accs = session.history().eval_points();
    let best = accs.iter().map(|&(_, _, a)| a).fold(0.0f64, f64::max);
    // Random guess = 10%; the synthetic classes are separable so even a
    // short run should clear this comfortably.
    assert!(best > 0.2, "best acc {best} after {} evals", accs.len());
    session.finish().expect("finish");
}

#[test]
fn estimator_picks_up_real_gradient_stats() {
    let dir = artifacts_dir();
    let mut session = Experiment::builder()
        .config(tiny_config())
        .rounds(5)
        .artifacts(&dir)
        .build()
        .expect("session");
    session.run_to_completion().expect("run");
    assert_eq!(session.trainer().estimator().rounds_seen(), 5);
    assert!(session.trainer().estimator().gsq().iter().any(|&g| g > 0.0));
    let bp = session.trainer().bound_params();
    assert!(bp.sigma_sq.iter().all(|&s| s >= 0.0));
    session.finish().expect("finish");
}
