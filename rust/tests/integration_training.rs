//! End-to-end integration over the full coordinator: real SFL training of
//! SplitCNN-8 through the PJRT runtime (skipped without artifacts).

use std::path::PathBuf;

use hasfl::config::{Config, Partition, StrategyKind};
use hasfl::coordinator::Trainer;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        None
    }
}

fn tiny_config() -> Config {
    let mut cfg = Config::small();
    cfg.fleet.n_devices = 2;
    cfg.train.rounds = 8;
    cfg.train.agg_interval = 4;
    cfg.train.eval_every = 4;
    cfg.train.train_samples = 256;
    cfg.train.test_samples = 64;
    cfg.train.batch_cap = 16;
    cfg.strategy = StrategyKind::Fixed;
    cfg.fixed_batch = 8;
    cfg.fixed_cut = 3;
    cfg
}

#[test]
fn training_reduces_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = tiny_config();
    cfg.train.rounds = 20;
    let mut trainer = Trainer::new(cfg, &dir).expect("trainer");
    trainer.run().expect("run");
    let first: f64 = trainer.history.records[..4].iter().map(|r| r.loss).sum::<f64>() / 4.0;
    let last: f64 = trainer.history.records[16..].iter().map(|r| r.loss).sum::<f64>() / 4.0;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert!(trainer.sim_time > 0.0);
    trainer.engine.shutdown();
}

#[test]
fn sequential_and_concurrent_rounds_agree() {
    // Same seed => identical sampling; the engine serializes compute, so
    // the concurrent actor topology must produce the same histories.
    let Some(dir) = artifacts_dir() else { return };
    let mut a = Trainer::new(tiny_config(), &dir).expect("trainer a");
    a.run().expect("run a");
    let mut b = Trainer::new(tiny_config(), &dir).expect("trainer b");
    b.run_concurrent().expect("run b");
    assert_eq!(a.history.records.len(), b.history.records.len());
    for (ra, rb) in a.history.records.iter().zip(&b.history.records) {
        assert!((ra.loss - rb.loss).abs() < 1e-6, "round {}: {} vs {}", ra.round, ra.loss, rb.loss);
        assert_eq!(ra.test_acc.is_some(), rb.test_acc.is_some());
    }
    a.engine.shutdown();
    b.engine.shutdown();
}

#[test]
fn hasfl_strategy_runs_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = tiny_config();
    cfg.strategy = StrategyKind::Hasfl;
    cfg.train.rounds = 6;
    let mut trainer = Trainer::new(cfg, &dir).expect("trainer");
    trainer.run().expect("run");
    // HASFL decisions must be in range and memory-feasible.
    for (&b, &c) in trainer.dec.batch.iter().zip(&trainer.dec.cut) {
        assert!(b >= 1 && b <= 64);
        assert!(trainer.manifest.valid_cuts.contains(&c));
    }
    trainer.engine.shutdown();
}

#[test]
fn noniid_partition_trains() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = tiny_config();
    cfg.partition = Partition::NonIidShards;
    cfg.train.rounds = 6;
    let mut trainer = Trainer::new(cfg, &dir).expect("trainer");
    trainer.run().expect("run");
    assert_eq!(trainer.history.records.len(), 6);
    trainer.engine.shutdown();
}

#[test]
fn evaluation_accuracy_improves_over_random_guess() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = tiny_config();
    cfg.train.rounds = 60;
    cfg.train.eval_every = 20;
    cfg.fixed_batch = 16;
    let mut trainer = Trainer::new(cfg, &dir).expect("trainer");
    trainer.run().expect("run");
    let accs = trainer.history.eval_points();
    let best = accs.iter().map(|&(_, _, a)| a).fold(0.0f64, f64::max);
    // Random guess = 10%; the synthetic classes are separable so even a
    // short run should clear this comfortably.
    assert!(best > 0.2, "best acc {best} after {} evals", accs.len());
    trainer.engine.shutdown();
}

#[test]
fn estimator_picks_up_real_gradient_stats() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = tiny_config();
    cfg.train.rounds = 5;
    let mut trainer = Trainer::new(cfg, &dir).expect("trainer");
    trainer.run().expect("run");
    assert_eq!(trainer.estimator.rounds_seen(), 5);
    assert!(trainer.estimator.gsq().iter().any(|&g| g > 0.0));
    let bp = trainer.bound_params();
    assert!(bp.sigma_sq.iter().all(|&s| s >= 0.0));
    trainer.engine.shutdown();
}
