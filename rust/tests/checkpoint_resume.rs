//! Interrupted-vs-uninterrupted resume determinism suite.
//!
//! A run checkpointed at round k and resumed must produce bit-identical
//! `Params`, `RoundReport` history, and fleet traces to the uninterrupted
//! run — under a static fleet (with a random strategy, so the strategy RNG
//! stream is exercised) and under the churn-heavy and mega-fleet scenario
//! presets (sampler + scenario RNG streams, partial aggregation, drift
//! state). Engine-backed tests run on the resolved backend (PJRT with
//! artifacts, native without) and never skip; the file-format error paths
//! (truncation, corruption, version skew) need no engine at all.

use std::path::{Path, PathBuf};

use hasfl::checkpoint::{CheckpointObserver, CheckpointState, FORMAT_VERSION, MAGIC};
use hasfl::config::{Config, Device, StrategyKind};
use hasfl::convergence::EstimatorState;
use hasfl::experiment::{Experiment, RoundReport};
use hasfl::fault::FaultState;
use hasfl::latency::Decisions;
use hasfl::metrics::{History, Record};
use hasfl::model::{Params, Tensor};
use hasfl::scenario::{DeviceEvoState, Scenario, ScenarioEngineState, ScenarioPreset};

/// Artifacts directory handed to the builder. The session resolves its
/// backend from `HASFL_BACKEND` / auto, and the native backend keeps this
/// suite fully runnable with no artifacts on disk — engine-backed tests
/// never skip (`HASFL_REQUIRE_ENGINE=1` turns any regression of that into
/// a hard failure, see `hasfl::backend::skip_engine_test`).
fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hasfl_ckpt_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn session_config(seed: u64, strategy: StrategyKind) -> Config {
    let mut cfg = Config::small();
    cfg.fleet.n_devices = 4;
    cfg.seed = seed;
    cfg.train.rounds = 8;
    cfg.train.agg_interval = 3;
    cfg.train.eval_every = 4;
    cfg.train.train_samples = 256;
    cfg.train.test_samples = 64;
    cfg.train.batch_cap = 16;
    cfg.strategy = strategy;
    cfg.fixed_batch = 8;
    cfg.fixed_cut = 3;
    cfg
}

type RunResult = (Vec<RoundReport>, History, Vec<Params>);

/// Straight 8-round run that also checkpoints every 4 rounds into
/// `ckpt_dir` — both the uninterrupted reference and the checkpoint
/// producer.
fn run_straight(
    dir: &Path,
    cfg: Config,
    spec: Option<Scenario>,
    ckpt_dir: &Path,
) -> RunResult {
    let mut builder = Experiment::builder()
        .config(cfg)
        .artifacts(dir)
        .observe(CheckpointObserver::new(ckpt_dir, 4));
    if let Some(s) = spec {
        builder = builder.scenario(s);
    }
    let mut session = builder.build().expect("straight session");
    let mut reports = Vec::new();
    while !session.is_done() {
        reports.push(session.step().expect("step"));
    }
    let params = session.trainer().params().to_vec();
    let history = session.finish().expect("finish");
    (reports, history, params)
}

/// Resume from `ckpt` and run to completion.
fn run_resumed(dir: &Path, ckpt: &Path) -> RunResult {
    let mut session = Experiment::builder()
        .resume_from(ckpt)
        .artifacts(dir)
        .build()
        .expect("resumed session");
    assert_eq!(session.round(), 4, "resume restores the round counter");
    let mut reports = Vec::new();
    while !session.is_done() {
        reports.push(session.step().expect("step"));
    }
    let params = session.trainer().params().to_vec();
    let history = session.finish().expect("finish");
    (reports, history, params)
}

fn assert_reports_identical(a: &[RoundReport], b: &[RoundReport], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: round count");
    for (ra, rb) in a.iter().zip(b) {
        assert_eq!(ra.round, rb.round, "{what}");
        assert_eq!(ra.outcome.mean_loss, rb.outcome.mean_loss, "{what}: round {}", ra.round);
        assert_eq!(ra.outcome.train_acc, rb.outcome.train_acc, "{what}: round {}", ra.round);
        assert_eq!(
            ra.outcome.participants,
            rb.outcome.participants,
            "{what}: round {}",
            ra.round
        );
        assert_eq!(ra.sim_time, rb.sim_time, "{what}: round {}", ra.round);
        assert_eq!(ra.aggregated, rb.aggregated, "{what}: round {}", ra.round);
        assert_eq!(ra.reoptimized, rb.reoptimized, "{what}: round {}", ra.round);
        assert_eq!(ra.test_acc, rb.test_acc, "{what}: round {}", ra.round);
        assert_eq!(ra.decisions.batch, rb.decisions.batch, "{what}: round {}", ra.round);
        assert_eq!(ra.decisions.cut, rb.decisions.cut, "{what}: round {}", ra.round);
        // The fleet trace: bit-exact snapshot equality (rates, membership,
        // dropouts, drift).
        assert_eq!(ra.fleet, rb.fleet, "{what}: round {}", ra.round);
    }
}

/// The core acceptance check: interrupted-at-4 + resumed == uninterrupted,
/// bit for bit.
fn assert_resume_is_bit_identical(tag: &str, cfg: Config, spec: Option<Scenario>) {
    let dir = artifacts_dir();
    let ckpt_dir = temp_dir(tag);

    let (straight_reports, straight_hist, straight_params) =
        run_straight(&dir, cfg, spec, &ckpt_dir);
    let ckpt = ckpt_dir.join("ckpt_round_000004.hckpt");
    assert!(ckpt.exists(), "{tag}: checkpoint at round 4 missing");

    let (resumed_reports, resumed_hist, resumed_params) = run_resumed(&dir, &ckpt);

    // Rounds 5..=8 replay identically...
    assert_reports_identical(&straight_reports[4..], &resumed_reports, tag);
    // ...the restored+appended history equals the uninterrupted one...
    assert_eq!(straight_hist.records, resumed_hist.records, "{tag}: history");
    // ...and the final model state matches bit-for-bit on every device
    // (Params derives PartialEq over raw f32 data — no tolerance).
    assert_eq!(straight_params, resumed_params, "{tag}: params");

    let _ = std::fs::remove_dir_all(&ckpt_dir);
}

#[test]
fn static_fleet_resume_is_bit_identical() {
    // Random BS + random MS exercises the strategy RNG stream across the
    // checkpoint boundary (a lost cursor would diverge at the round-6
    // re-solve).
    assert_resume_is_bit_identical(
        "static",
        session_config(11, StrategyKind::RbsRms),
        None,
    );
}

#[test]
fn churn_heavy_resume_is_bit_identical() {
    // Churn + dropout + stragglers: scenario RNG, partial aggregation,
    // and participation masks all cross the checkpoint boundary.
    assert_resume_is_bit_identical(
        "churn",
        session_config(23, StrategyKind::Fixed),
        Some(ScenarioPreset::ChurnHeavy.scenario()),
    );
}

#[test]
fn mega_fleet_resume_is_bit_identical() {
    // The mega-fleet preset spec at a test-sized fleet (min_active clamps
    // to the roster): gentle drift + churn + aggressive stragglers. The
    // aggregation window is aligned with the checkpoint cadence so the
    // checkpoint lands on a forged-sync round and the `fleet_synced`
    // restore path (shared buffer-set keying) is exercised.
    let mut cfg = session_config(37, StrategyKind::Fixed);
    cfg.train.agg_interval = 4;
    assert_resume_is_bit_identical("mega", cfg, Some(ScenarioPreset::MegaFleet.scenario()));
}

#[test]
fn resume_can_extend_the_round_budget() {
    let dir = artifacts_dir();
    let ckpt_dir = temp_dir("extend");
    let cfg = session_config(5, StrategyKind::Fixed);
    run_straight(&dir, cfg, None, &ckpt_dir);
    let ckpt = ckpt_dir.join("ckpt_round_000004.hckpt");

    // Shrinking the budget to the checkpointed round makes the session
    // immediately done; the override reaches the resumed config.
    let session = Experiment::builder()
        .resume_from(&ckpt)
        .rounds(4)
        .artifacts(&dir)
        .build()
        .expect("resumed session");
    assert_eq!(session.config().train.rounds, 4);
    assert_eq!(session.round(), 4);
    assert!(session.is_done());
    session.finish().expect("finish");
    let _ = std::fs::remove_dir_all(&ckpt_dir);
}

#[test]
fn resume_keeps_the_embedded_backend_and_rejects_overrides() {
    let dir = artifacts_dir();
    let ckpt_dir = temp_dir("backend");
    let cfg = session_config(9, StrategyKind::Fixed);
    run_straight(&dir, cfg, None, &ckpt_dir);
    let ckpt = ckpt_dir.join("ckpt_round_000004.hckpt");

    // The checkpoint embeds the *resolved* backend of the producing run;
    // a plain resume comes back on exactly that backend.
    let expected = hasfl::backend::BackendKind::from_env()
        .unwrap_or(hasfl::backend::BackendKind::Auto)
        .resolve(&dir);
    let session =
        Experiment::builder().resume_from(&ckpt).artifacts(&dir).build().expect("resume");
    assert_eq!(session.config().backend, expected);
    session.finish().expect("finish");

    // Backends agree within float tolerance only, so switching one on
    // resume would silently break bit-identical warm restarts: rejected.
    let err = Experiment::builder()
        .resume_from(&ckpt)
        .backend(hasfl::backend::BackendKind::Native)
        .artifacts(&dir)
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("backend"), "{err}");
    let _ = std::fs::remove_dir_all(&ckpt_dir);
}

#[test]
fn scenario_mismatch_is_rejected_on_resume() {
    let dir = artifacts_dir();
    let ckpt_dir = temp_dir("mismatch");
    let cfg = session_config(7, StrategyKind::Fixed);
    run_straight(&dir, cfg, Some(ScenarioPreset::ChurnHeavy.scenario()), &ckpt_dir);
    let ckpt = ckpt_dir.join("ckpt_round_000004.hckpt");

    // Strip the engine state but keep the scenario in the embedded config:
    // the restore must refuse instead of silently replaying a fresh fleet.
    let mut state = CheckpointState::load(&ckpt).unwrap();
    assert!(state.scenario.is_some());
    state.scenario = None;
    let tampered = ckpt_dir.join("tampered.hckpt");
    state.save(&tampered).unwrap();
    let err = Experiment::builder()
        .resume_from(&tampered)
        .artifacts(&dir)
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("no engine state"), "{err}");
    let _ = std::fs::remove_dir_all(&ckpt_dir);
}

// ---- file-format error paths (no engine needed) --------------------------

fn device() -> Device {
    Device {
        flops: 1e12,
        up_bps: 7.5e7,
        down_bps: 3.6e8,
        fed_up_bps: 7.5e7,
        fed_down_bps: 3.6e8,
        mem_bytes: 1e9,
    }
}

fn synthetic_state() -> CheckpointState {
    let tensor = Tensor { shape: vec![2, 2], data: vec![0.5, -1.0, 3.25, 0.0] };
    let params = Params { tensors: vec![tensor], n_blocks: 1, version: 7 };
    CheckpointState {
        config_json: Config::small().to_json().dump(),
        round: 3,
        rounds_run: 3,
        eval_epoch: 1,
        common_version: 3,
        sync_version: 1,
        fleet_synced: false,
        sim_time: 12.5,
        params: vec![params.clone(), params],
        dec: Decisions { batch: vec![8, 4], cut: vec![2, 3] },
        history: vec![
            Record { round: 1, sim_time: 1.0, loss: 2.25, test_acc: Some(0.5) },
            Record { round: 2, sim_time: 2.0, loss: 2.0, test_acc: None },
        ],
        estimator: EstimatorState {
            n_blocks: 1,
            alpha: 0.2,
            gsq: vec![1.5],
            sigma_sq: vec![0.25],
            beta: 0.0,
            rounds_seen: 2,
            prev_flat_grad: None,
            prev_flat_param: Some(vec![1.0, 2.0]),
        },
        strategy_rng: (0x1234_5678_9abc_def0, 0x1111),
        sampler_rngs: vec![(1, 3), (2, 5)],
        scenario: Some(ScenarioEngineState {
            rng: (9, 11),
            round: 3,
            roster: vec![DeviceEvoState {
                base: device(),
                channel_mult: 1.1,
                compute_mult: 0.9,
                active: true,
                phase: 0.25,
            }],
            effective: vec![device()],
            reference: vec![device()],
            reference_active: vec![true],
        }),
        fault: Some(FaultState { strikes: vec![0, 2], quarantined: vec![false, true] }),
        async_state: None,
    }
}

fn synthetic_async() -> hasfl::asynch::AsyncState {
    hasfl::asynch::AsyncState {
        model_version: 4,
        now: 9.25,
        dispatch_version: vec![4, 3],
        dispatch_at: vec![8.0, 6.5],
        ready_at: vec![10.0, 11.5],
        in_flight: vec![true, false],
        dispatch_seq: vec![5, 4],
        ema_latency: vec![1.5, 0.0],
        ema_seen: vec![true, false],
    }
}

#[test]
fn async_state_roundtrips_through_bytes() {
    // Fault and async trailers together (the full trailing layout)...
    let mut state = synthetic_state();
    state.async_state = Some(synthetic_async());
    assert_eq!(CheckpointState::from_bytes(&state.to_bytes()).unwrap(), state);

    // ...and async without a fault spec, which exercises the
    // absent-fault marker byte before the async trailer.
    state.fault = None;
    assert_eq!(CheckpointState::from_bytes(&state.to_bytes()).unwrap(), state);
}

#[test]
fn sync_state_omits_the_async_trailer() {
    // A synchronous-barrier run serializes byte-identically to the
    // pre-async format: the async trailer only costs bytes when present.
    let state = synthetic_state();
    let with = {
        let mut s = state.clone();
        s.async_state = Some(synthetic_async());
        s.to_bytes()
    };
    let without = state.to_bytes();
    assert!(without.len() < with.len());
    let back = CheckpointState::from_bytes(&without).unwrap();
    assert!(back.async_state.is_none());
    assert_eq!(back, state);
}

#[test]
fn faultless_state_omits_the_trailing_fault_field() {
    // A run without a fault spec must serialize byte-identically to the
    // pre-fault format: no trailing marker, and the roundtrip restores
    // `fault: None`.
    let mut state = synthetic_state();
    state.fault = None;
    let with = {
        let mut s = state.clone();
        s.fault = Some(FaultState::new(2));
        s.to_bytes()
    };
    let without = state.to_bytes();
    assert!(without.len() < with.len());
    let back = CheckpointState::from_bytes(&without).unwrap();
    assert!(back.fault.is_none());
    assert_eq!(back, state);
}

#[test]
fn state_roundtrips_through_bytes_and_files() {
    let state = synthetic_state();
    let bytes = state.to_bytes();
    assert_eq!(&bytes[..8], MAGIC.as_slice());
    assert_eq!(CheckpointState::from_bytes(&bytes).unwrap(), state);

    let dir = temp_dir("roundtrip");
    let path = dir.join("state.hckpt");
    state.save(&path).unwrap();
    assert_eq!(CheckpointState::load(&path).unwrap(), state);
    // The atomic-write temp sibling is gone after a successful save.
    let leftovers: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
    assert_eq!(leftovers.len(), 1, "temp file left behind: {leftovers:?}");
    // Overwriting an existing checkpoint also succeeds (rename semantics).
    state.save(&path).unwrap();
    assert_eq!(CheckpointState::load(&path).unwrap(), state);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_files_are_rejected() {
    let bytes = synthetic_state().to_bytes();
    for cut in [0, 5, 19, bytes.len() / 2, bytes.len() - 1] {
        let err = CheckpointState::from_bytes(&bytes[..cut]).unwrap_err();
        assert!(err.to_string().contains("truncated"), "cut {cut}: {err}");
    }
    // Trailing garbage is a length mismatch, not silently ignored.
    let mut long = bytes.clone();
    long.extend_from_slice(b"junk");
    assert!(CheckpointState::from_bytes(&long).is_err());
}

#[test]
fn corrupted_payload_fails_the_checksum() {
    let mut bytes = synthetic_state().to_bytes();
    let mid = 20 + (bytes.len() - 28) / 2; // somewhere inside the payload
    bytes[mid] ^= 0x40;
    let err = CheckpointState::from_bytes(&bytes).unwrap_err();
    assert!(err.to_string().contains("checksum"), "{err}");
}

#[test]
fn version_mismatch_is_a_clear_error() {
    let mut bytes = synthetic_state().to_bytes();
    // The format version lives at bytes 8..12 (after the 8-byte magic).
    bytes[8] = (FORMAT_VERSION + 1) as u8;
    let err = CheckpointState::from_bytes(&bytes).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("version"), "{msg}");
    assert!(msg.contains(&format!("{}", FORMAT_VERSION + 1)), "{msg}");
}

#[test]
fn foreign_files_are_rejected_by_magic() {
    let mut bytes = synthetic_state().to_bytes();
    bytes[0] = b'X';
    let err = CheckpointState::from_bytes(&bytes).unwrap_err();
    assert!(err.to_string().contains("not a HASFL checkpoint"), "{err}");

    let err = CheckpointState::from_bytes(b"round,sim_time,loss\n1,0.5,2.3\n").unwrap_err();
    assert!(err.to_string().contains("not a HASFL checkpoint"), "{err}");
}
