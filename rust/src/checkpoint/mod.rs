//! Crash-safe checkpoint/resume subsystem (DESIGN.md §10).
//!
//! A checkpoint is a single versioned file capturing the *complete*
//! training state between two rounds: every per-device [`Params`] tensor
//! (+ version counters), every PCG RNG stream (strategy, per-device
//! samplers, scenario engine), the Assumption-2 estimator, the scenario
//! engine's fleet roster/drift/churn state, the incumbent [`Decisions`],
//! the run history, the simulated clock, and the buffer-cache version
//! counters. The experiment [`Config`](crate::config::Config) is embedded
//! as canonical JSON so a resume rebuilds the deterministic substrate
//! (datasets, partitions, artifacts) from it and then overlays the
//! evolving state — a resumed run is **bit-identical** to the
//! uninterrupted one (`rust/tests/checkpoint_resume.rs`, plus the ci.sh
//! resume smoke).
//!
//! Crash safety: [`CheckpointState::save`] writes to a temp sibling,
//! fsyncs, then atomically renames into place, so a crash mid-write never
//! clobbers the previous checkpoint. Files carry a magic tag, a format
//! version, a payload length, and an FNV-1a checksum; truncation,
//! corruption, and version skew all fail loudly on load.
//!
//! Entry points:
//! - [`crate::experiment::Session::checkpoint`] — write one now.
//! - [`CheckpointObserver`] — periodic write-every-N-rounds observer with
//!   keep-last-K retention.
//! - [`crate::experiment::ExperimentBuilder::resume_from`] — rebuild a
//!   session from a checkpoint file.
//! - CLI: `hasfl train --checkpoint-every N --checkpoint-dir D` and
//!   `hasfl train --resume PATH`.

mod codec;

use std::path::{Path, PathBuf};

use crate::config::Device;
use crate::convergence::EstimatorState;
use crate::experiment::{Observer, RoundReport};
use crate::latency::Decisions;
use crate::metrics::Record;
use crate::model::{Params, Tensor};
use crate::scenario::{DeviceEvoState, ScenarioEngineState};

use codec::{fnv1a64, ByteReader, ByteWriter};

/// File magic: the first 8 bytes of every HASFL checkpoint.
pub const MAGIC: [u8; 8] = *b"HASFLCKP";

/// On-disk format version this build reads and writes.
pub const FORMAT_VERSION: u32 = 1;

/// Header bytes before the payload: magic (8) + version (4) + payload
/// length (8).
const HEADER_LEN: usize = 20;

/// The complete training state of a session between two rounds. Plain
/// data: captured by the coordinator, serialized here, restorable onto a
/// freshly-built trainer with the same config.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointState {
    /// The experiment configuration as its canonical JSON dump — the
    /// resume path's authoritative config and the compatibility anchor.
    pub config_json: String,
    /// Rounds completed when the checkpoint was taken (the session's
    /// round counter).
    pub round: u64,
    /// Trainer round counter (versions the per-round input buffers).
    pub rounds_run: u64,
    /// Evaluations run so far (versions the eval-time buffers).
    pub eval_epoch: u64,
    /// Version of the fleet-common server sub-model.
    pub common_version: u64,
    /// Version of the last full fleet synchronisation.
    pub sync_version: u64,
    /// Whether every device provably holds identical parameters.
    pub fleet_synced: bool,
    /// Simulated wall-clock so far (seconds).
    pub sim_time: f64,
    /// Per-device full-model parameters (bit-exact f32 payloads).
    pub params: Vec<Params>,
    /// The decisions in force.
    pub dec: Decisions,
    /// Run history records accumulated so far.
    pub history: Vec<Record>,
    /// Assumption-2 gradient-statistics estimator state.
    pub estimator: EstimatorState,
    /// Strategy RNG stream `(state, inc)`.
    pub strategy_rng: (u64, u64),
    /// Per-device batch-sampler RNG streams `(state, inc)`.
    pub sampler_rngs: Vec<(u64, u64)>,
    /// Scenario-engine state (`None` on static-fleet runs).
    pub scenario: Option<ScenarioEngineState>,
    /// Fault-layer state — strike counts and the quarantine roster
    /// (`None` when the run has no fault spec). Serialized as a trailing
    /// optional field, so fault-less checkpoints stay byte-identical to
    /// the pre-fault format and still load.
    pub fault: Option<crate::fault::FaultState>,
    /// Buffered-asynchronous scheduler state — the in-flight buffer,
    /// per-device version lags, and the EMA latency model (`None` on
    /// synchronous-barrier runs; DESIGN.md §16). Serialized after the
    /// fault trailer, so sync checkpoints stay byte-identical to the
    /// pre-async format and legacy fault-only files still load.
    pub async_state: Option<crate::asynch::AsyncState>,
}

fn write_device(w: &mut ByteWriter, d: &Device) {
    w.f64(d.flops);
    w.f64(d.up_bps);
    w.f64(d.down_bps);
    w.f64(d.fed_up_bps);
    w.f64(d.fed_down_bps);
    w.f64(d.mem_bytes);
}

fn read_device(r: &mut ByteReader) -> crate::Result<Device> {
    Ok(Device {
        flops: r.f64()?,
        up_bps: r.f64()?,
        down_bps: r.f64()?,
        fed_up_bps: r.f64()?,
        fed_down_bps: r.f64()?,
        mem_bytes: r.f64()?,
    })
}

fn write_devices(w: &mut ByteWriter, ds: &[Device]) {
    w.usize(ds.len());
    for d in ds {
        write_device(w, d);
    }
}

fn read_devices(r: &mut ByteReader) -> crate::Result<Vec<Device>> {
    let n = r.usize()?;
    (0..n).map(|_| read_device(r)).collect()
}

fn write_tensor(w: &mut ByteWriter, t: &Tensor) {
    w.usizes(&t.shape);
    w.f32s(&t.data);
}

fn read_tensor(r: &mut ByteReader) -> crate::Result<Tensor> {
    Ok(Tensor { shape: r.usizes()?, data: r.f32s()? })
}

fn write_params(w: &mut ByteWriter, p: &Params) {
    w.usize(p.n_blocks);
    w.u64(p.version);
    w.usize(p.tensors.len());
    for t in &p.tensors {
        write_tensor(w, t);
    }
}

fn read_params(r: &mut ByteReader) -> crate::Result<Params> {
    let n_blocks = r.usize()?;
    let version = r.u64()?;
    let n = r.usize()?;
    let tensors = (0..n).map(|_| read_tensor(r)).collect::<crate::Result<Vec<_>>>()?;
    Ok(Params { tensors, n_blocks, version })
}

fn write_record(w: &mut ByteWriter, rec: &Record) {
    w.usize(rec.round);
    w.f64(rec.sim_time);
    w.f64(rec.loss);
    match rec.test_acc {
        Some(a) => {
            w.bool(true);
            w.f64(a);
        }
        None => w.bool(false),
    }
}

fn read_record(r: &mut ByteReader) -> crate::Result<Record> {
    Ok(Record {
        round: r.usize()?,
        sim_time: r.f64()?,
        loss: r.f64()?,
        test_acc: if r.bool()? { Some(r.f64()?) } else { None },
    })
}

fn write_estimator(w: &mut ByteWriter, e: &EstimatorState) {
    w.usize(e.n_blocks);
    w.f64(e.alpha);
    w.f64s(&e.gsq);
    w.f64s(&e.sigma_sq);
    w.f64(e.beta);
    w.usize(e.rounds_seen);
    w.opt_f64s(&e.prev_flat_grad);
    w.opt_f64s(&e.prev_flat_param);
}

fn read_estimator(r: &mut ByteReader) -> crate::Result<EstimatorState> {
    Ok(EstimatorState {
        n_blocks: r.usize()?,
        alpha: r.f64()?,
        gsq: r.f64s()?,
        sigma_sq: r.f64s()?,
        beta: r.f64()?,
        rounds_seen: r.usize()?,
        prev_flat_grad: r.opt_f64s()?,
        prev_flat_param: r.opt_f64s()?,
    })
}

fn write_scenario(w: &mut ByteWriter, s: &ScenarioEngineState) {
    w.u64(s.rng.0);
    w.u64(s.rng.1);
    w.usize(s.round);
    w.usize(s.roster.len());
    for evo in &s.roster {
        write_device(w, &evo.base);
        w.f64(evo.channel_mult);
        w.f64(evo.compute_mult);
        w.bool(evo.active);
        w.f64(evo.phase);
    }
    write_devices(w, &s.effective);
    write_devices(w, &s.reference);
    w.bools(&s.reference_active);
}

fn read_scenario(r: &mut ByteReader) -> crate::Result<ScenarioEngineState> {
    let rng = (r.u64()?, r.u64()?);
    let round = r.usize()?;
    let n = r.usize()?;
    let mut roster = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        roster.push(DeviceEvoState {
            base: read_device(r)?,
            channel_mult: r.f64()?,
            compute_mult: r.f64()?,
            active: r.bool()?,
            phase: r.f64()?,
        });
    }
    Ok(ScenarioEngineState {
        rng,
        round,
        roster,
        effective: read_devices(r)?,
        reference: read_devices(r)?,
        reference_active: r.bools()?,
    })
}

fn write_u64s(w: &mut ByteWriter, vs: &[u64]) {
    w.usize(vs.len());
    for &v in vs {
        w.u64(v);
    }
}

fn read_u64s(r: &mut ByteReader) -> crate::Result<Vec<u64>> {
    let n = r.usize()?;
    let mut out = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        out.push(r.u64()?);
    }
    Ok(out)
}

fn write_async(w: &mut ByteWriter, a: &crate::asynch::AsyncState) {
    w.u64(a.model_version);
    w.f64(a.now);
    write_u64s(w, &a.dispatch_version);
    w.f64s(&a.dispatch_at);
    w.f64s(&a.ready_at);
    w.bools(&a.in_flight);
    write_u64s(w, &a.dispatch_seq);
    w.f64s(&a.ema_latency);
    w.bools(&a.ema_seen);
}

fn read_async(r: &mut ByteReader) -> crate::Result<crate::asynch::AsyncState> {
    Ok(crate::asynch::AsyncState {
        model_version: r.u64()?,
        now: r.f64()?,
        dispatch_version: read_u64s(r)?,
        dispatch_at: r.f64s()?,
        ready_at: r.f64s()?,
        in_flight: r.bools()?,
        dispatch_seq: read_u64s(r)?,
        ema_latency: r.f64s()?,
        ema_seen: r.bools()?,
    })
}

fn write_state(w: &mut ByteWriter, s: &CheckpointState) {
    w.str(&s.config_json);
    w.u64(s.round);
    w.u64(s.rounds_run);
    w.u64(s.eval_epoch);
    w.u64(s.common_version);
    w.u64(s.sync_version);
    w.bool(s.fleet_synced);
    w.f64(s.sim_time);
    w.usize(s.params.len());
    for p in &s.params {
        write_params(w, p);
    }
    w.u32s(&s.dec.batch);
    w.usizes(&s.dec.cut);
    w.usize(s.history.len());
    for rec in &s.history {
        write_record(w, rec);
    }
    write_estimator(w, &s.estimator);
    w.u64(s.strategy_rng.0);
    w.u64(s.strategy_rng.1);
    w.usize(s.sampler_rngs.len());
    for &(st, inc) in &s.sampler_rngs {
        w.u64(st);
        w.u64(inc);
    }
    match &s.scenario {
        Some(sc) => {
            w.bool(true);
            write_scenario(w, sc);
        }
        None => w.bool(false),
    }
    // Trailing optional fields, written only when at least one is
    // present: readers consume them iff payload bytes remain, so plain
    // sync checkpoints (and ones written before the fault/async layers
    // existed) parse unchanged under the same FORMAT_VERSION. A run with
    // only a fault spec emits exactly the legacy fault-only byte layout
    // (true marker + payload, nothing after); a run with only an async
    // spec emits a false fault marker followed by the async trailer.
    if s.fault.is_some() || s.async_state.is_some() {
        match &s.fault {
            Some(f) => {
                w.bool(true);
                w.u32s(&f.strikes);
                w.bools(&f.quarantined);
            }
            None => w.bool(false),
        }
        if let Some(a) = &s.async_state {
            w.bool(true);
            write_async(w, a);
        }
    }
}

fn read_state(r: &mut ByteReader) -> crate::Result<CheckpointState> {
    let config_json = r.str()?;
    let round = r.u64()?;
    let rounds_run = r.u64()?;
    let eval_epoch = r.u64()?;
    let common_version = r.u64()?;
    let sync_version = r.u64()?;
    let fleet_synced = r.bool()?;
    let sim_time = r.f64()?;
    let n_params = r.usize()?;
    let params = (0..n_params).map(|_| read_params(r)).collect::<crate::Result<Vec<_>>>()?;
    let dec = Decisions { batch: r.u32s()?, cut: r.usizes()? };
    let n_hist = r.usize()?;
    let history = (0..n_hist).map(|_| read_record(r)).collect::<crate::Result<Vec<_>>>()?;
    let estimator = read_estimator(r)?;
    let strategy_rng = (r.u64()?, r.u64()?);
    let n_samplers = r.usize()?;
    let sampler_rngs = (0..n_samplers)
        .map(|_| -> crate::Result<(u64, u64)> { Ok((r.u64()?, r.u64()?)) })
        .collect::<crate::Result<Vec<_>>>()?;
    let scenario = if r.bool()? { Some(read_scenario(r)?) } else { None };
    // Trailing optional fields in fixed order: fault, then async. Legacy
    // fault-only files end right after the fault payload; legacy
    // fault-less files end at the scenario marker; both parse here.
    let fault = if r.remaining() > 0 {
        if r.bool()? {
            Some(crate::fault::FaultState { strikes: r.u32s()?, quarantined: r.bools()? })
        } else {
            None
        }
    } else {
        None
    };
    let async_state = if r.remaining() > 0 {
        anyhow::ensure!(
            r.bool()?,
            "corrupt checkpoint: unexpected trailing field marker"
        );
        Some(read_async(r)?)
    } else {
        None
    };
    Ok(CheckpointState {
        config_json,
        round,
        rounds_run,
        eval_epoch,
        common_version,
        sync_version,
        fleet_synced,
        sim_time,
        params,
        dec,
        history,
        estimator,
        strategy_rng,
        sampler_rngs,
        scenario,
        fault,
        async_state,
    })
}

impl CheckpointState {
    /// Serialize to the on-disk byte layout:
    /// `MAGIC | FORMAT_VERSION | payload_len | payload | fnv1a64(payload)`.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        write_state(&mut w, self);
        let payload = w.into_bytes();
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + 8);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        let sum = fnv1a64(&payload);
        out.extend_from_slice(&payload);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Parse and verify the on-disk byte layout. Distinct, descriptive
    /// errors for bad magic, version skew, truncation, and corruption.
    pub fn from_bytes(bytes: &[u8]) -> crate::Result<CheckpointState> {
        anyhow::ensure!(
            bytes.len() >= HEADER_LEN,
            "truncated checkpoint: {} bytes is smaller than the {HEADER_LEN}-byte header",
            bytes.len()
        );
        anyhow::ensure!(
            bytes[..8] == MAGIC,
            "not a HASFL checkpoint (bad magic; expected {:?})",
            std::str::from_utf8(&MAGIC).unwrap()
        );
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        anyhow::ensure!(
            version == FORMAT_VERSION,
            "checkpoint format version {version} is unsupported \
             (this build reads version {FORMAT_VERSION})"
        );
        let payload_len = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
        let payload_len = usize::try_from(payload_len)
            .map_err(|_| anyhow::anyhow!("corrupt checkpoint: payload length overflows"))?;
        let want = HEADER_LEN
            .checked_add(payload_len)
            .and_then(|n| n.checked_add(8))
            .ok_or_else(|| anyhow::anyhow!("corrupt checkpoint: payload length overflows"))?;
        anyhow::ensure!(
            bytes.len() == want,
            "truncated checkpoint: header claims {want} bytes, file has {}",
            bytes.len()
        );
        let payload = &bytes[HEADER_LEN..HEADER_LEN + payload_len];
        let sum = u64::from_le_bytes(bytes[HEADER_LEN + payload_len..].try_into().unwrap());
        anyhow::ensure!(
            fnv1a64(payload) == sum,
            "corrupt checkpoint: payload checksum mismatch"
        );
        let mut r = ByteReader::new(payload);
        let state = read_state(&mut r)?;
        anyhow::ensure!(
            r.remaining() == 0,
            "corrupt checkpoint: {} unparsed trailing payload bytes",
            r.remaining()
        );
        Ok(state)
    }

    /// Crash-safe write: serialize into a temp sibling, fsync it, then
    /// atomically rename into place. A crash mid-write leaves the previous
    /// checkpoint (if any) untouched.
    pub fn save(&self, path: &Path) -> crate::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let file_name = match path.file_name() {
            Some(name) => name.to_string_lossy().into_owned(),
            None => anyhow::bail!("checkpoint path '{}' has no file name", path.display()),
        };
        let tmp = path.with_file_name(format!("{file_name}.tmp-{}", std::process::id()));
        {
            use std::io::Write as _;
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&self.to_bytes())?;
            // Durable before the rename makes it visible.
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        // The file's fsync does not cover the directory entry: sync the
        // parent too, so the rename itself survives power loss (without
        // it, a later retention unlink could be journaled first and a
        // crash would leave zero checkpoints on disk). Best-effort: not
        // every filesystem lets a directory be opened for sync.
        #[cfg(unix)]
        if let Some(dir) = path.parent() {
            let dir = if dir.as_os_str().is_empty() { Path::new(".") } else { dir };
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    }

    /// Load and verify a checkpoint file.
    pub fn load(path: &Path) -> crate::Result<CheckpointState> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("cannot read checkpoint '{}': {e}", path.display()))?;
        Self::from_bytes(&bytes)
            .map_err(|e| anyhow::anyhow!("checkpoint '{}': {e}", path.display()))
    }
}

/// Periodic checkpointer: every `every` rounds it asks the session to
/// write `ckpt_round_NNNNNN.hckpt` into `dir`, keeping only the newest
/// `keep_last` files (write-to-temp + atomic rename happens inside
/// [`CheckpointState::save`], so an interrupted write never corrupts an
/// older checkpoint).
pub struct CheckpointObserver {
    dir: PathBuf,
    every: usize,
    keep_last: usize,
    written: Vec<PathBuf>,
    /// Whether `written` has been seeded from the files already on disk
    /// (checkpoints surviving a crash must count against `keep_last` too,
    /// or a resumed run would accumulate them forever).
    seeded: bool,
}

impl CheckpointObserver {
    /// Checkpoint every `every` rounds into `dir` (keep-last-3 default).
    pub fn new(dir: impl Into<PathBuf>, every: usize) -> CheckpointObserver {
        CheckpointObserver {
            dir: dir.into(),
            every: every.max(1),
            keep_last: 3,
            written: Vec::new(),
            seeded: false,
        }
    }

    /// Retain only the newest `k` checkpoints (older ones are deleted
    /// after each successful write).
    pub fn keep_last(mut self, k: usize) -> CheckpointObserver {
        self.keep_last = k.max(1);
        self
    }

    /// The file this observer writes for a given round.
    pub fn path_for(&self, round: usize) -> PathBuf {
        self.dir.join(format!("ckpt_round_{round:06}.hckpt"))
    }

    /// Paths written so far (oldest first, pre-existing on-disk
    /// checkpoints included once seeded), after retention pruning.
    pub fn written(&self) -> &[PathBuf] {
        &self.written
    }

    /// Fold checkpoints already on disk (e.g. survivors of a crashed
    /// run) into the retention window, oldest first (name order is round
    /// order — zero-padded), and sweep atomic-write temp leftovers whose
    /// rename never happened (retention would otherwise never touch
    /// them, and each crashed run orphans a fresh pid-suffixed file).
    fn seed_from_disk(&mut self, just_written: &Path) {
        let Ok(entries) = std::fs::read_dir(&self.dir) else { return };
        let mut old: Vec<PathBuf> = Vec::new();
        for path in entries.filter_map(|e| e.ok().map(|e| e.path())) {
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
            if !name.starts_with("ckpt_round_") || path == just_written {
                continue;
            }
            if name.ends_with(".hckpt") {
                old.push(path);
            } else if name.contains(".hckpt.tmp-") {
                // Best-effort sweep of a crashed save's temp file.
                let _ = std::fs::remove_file(&path);
            }
        }
        old.sort();
        self.written.splice(0..0, old);
    }
}

impl Observer for CheckpointObserver {
    fn checkpoint_request(&mut self, report: &RoundReport) -> Option<PathBuf> {
        (report.round % self.every == 0).then(|| self.path_for(report.round))
    }

    fn on_checkpoint(&mut self, _report: &RoundReport, path: &Path) {
        // Checkpoint writes are announced to every observer; this one only
        // manages retention for its own directory, so announcements of
        // writes elsewhere (another observer's request, an explicit
        // `Session::checkpoint` path) must not enter the pruning window.
        if path.parent() != Some(self.dir.as_path()) {
            return;
        }
        if !self.seeded {
            self.seeded = true;
            self.seed_from_disk(path);
        }
        // A rewrite of a round already in the window (a resumed run
        // replaying past a crash survivor) moves that path to the newest
        // slot instead of duplicating it — a duplicate would make the
        // age-ordered pruning below unlink one of the newest K files.
        self.written.retain(|p| p != path);
        self.written.push(path.to_path_buf());
        while self.written.len() > self.keep_last {
            // Best-effort retention: a missing file is not an error.
            let old = self.written.remove(0);
            let _ = std::fs::remove_file(old);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RoundOutcome;
    use crate::latency::RoundLatency;

    fn fake_report(round: usize) -> RoundReport {
        RoundReport {
            round,
            sim_time: round as f64,
            outcome: RoundOutcome { mean_loss: 1.0, train_acc: 0.5, participants: 1 },
            latency: RoundLatency {
                per_device: vec![],
                server_fwd: 0.0,
                server_bwd: 0.0,
                t_split: 1.0,
                t_agg: 0.0,
            },
            aggregated: false,
            reoptimized: false,
            decisions: Decisions::uniform(1, 8, 4),
            test_acc: None,
            fleet: None,
            abandoned: vec![],
            quarantined: vec![],
            cells: vec![],
            asynchrony: None,
        }
    }

    #[test]
    fn observer_requests_on_schedule() {
        let mut obs = CheckpointObserver::new("ckdir", 3);
        assert!(obs.checkpoint_request(&fake_report(1)).is_none());
        assert!(obs.checkpoint_request(&fake_report(2)).is_none());
        let p = obs.checkpoint_request(&fake_report(3)).unwrap();
        assert_eq!(p, PathBuf::from("ckdir/ckpt_round_000003.hckpt"));
        assert!(obs.checkpoint_request(&fake_report(6)).is_some());
    }

    #[test]
    fn observer_retention_counts_crash_survivors() {
        // Checkpoints left on disk by a crashed run must count against
        // keep_last on resume, not accumulate forever.
        let dir = std::env::temp_dir()
            .join(format!("hasfl_ckpt_survivors_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut obs = CheckpointObserver::new(&dir, 1).keep_last(2);
        // Survivors of the "previous" run, plus an atomic-write temp file
        // orphaned by a crash mid-save.
        for round in [3usize, 6] {
            std::fs::write(obs.path_for(round), b"stale").unwrap();
        }
        let orphan = dir.join("ckpt_round_000007.hckpt.tmp-12345");
        std::fs::write(&orphan, b"partial").unwrap();
        // The resumed run writes rounds 9 and 12.
        for round in [9usize, 12] {
            let path = obs.path_for(round);
            std::fs::write(&path, b"fresh").unwrap();
            obs.on_checkpoint(&fake_report(round), &path);
        }
        assert!(!obs.path_for(3).exists());
        assert!(!obs.path_for(6).exists());
        assert!(obs.path_for(9).exists());
        assert!(obs.path_for(12).exists());
        assert!(!orphan.exists(), "crashed-save temp file must be swept");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn observer_retention_handles_rewritten_rounds() {
        // Resuming from a non-newest checkpoint rewrites round numbers
        // that already exist on disk; the rewrite must not duplicate
        // window entries (a duplicate would make the age-ordered pruning
        // unlink one of the newest K files).
        let dir = std::env::temp_dir()
            .join(format!("hasfl_ckpt_rewrite_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut obs = CheckpointObserver::new(&dir, 4).keep_last(3);
        // Survivors of the previous run...
        for round in [4usize, 8, 12] {
            std::fs::write(obs.path_for(round), b"stale").unwrap();
        }
        // ...then a run resumed from round 4 replays rounds 8/12 and
        // continues to 16.
        for round in [8usize, 12, 16] {
            let path = obs.path_for(round);
            std::fs::write(&path, b"fresh").unwrap();
            obs.on_checkpoint(&fake_report(round), &path);
        }
        assert!(!obs.path_for(4).exists());
        assert!(obs.path_for(8).exists());
        assert!(obs.path_for(12).exists());
        assert!(obs.path_for(16).exists());
        assert_eq!(obs.written().len(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn observer_retention_keeps_last_k() {
        let dir = std::env::temp_dir()
            .join(format!("hasfl_ckpt_retention_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut obs = CheckpointObserver::new(&dir, 1).keep_last(2);
        for round in 1..=4 {
            let path = obs.path_for(round);
            std::fs::write(&path, b"stub").unwrap();
            obs.on_checkpoint(&fake_report(round), &path);
        }
        assert_eq!(obs.written().len(), 2);
        assert!(!obs.path_for(1).exists());
        assert!(!obs.path_for(2).exists());
        assert!(obs.path_for(3).exists());
        assert!(obs.path_for(4).exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
