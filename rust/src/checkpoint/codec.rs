//! Byte-level codec for the checkpoint format: little-endian fixed-width
//! primitives, length-prefixed containers, and an FNV-1a payload checksum.
//!
//! Floats travel as raw IEEE-754 bit patterns (`to_bits`/`from_bits`), so
//! a save/load round-trip is bit-exact by construction — the foundation of
//! the resume-determinism contract (`rust/tests/checkpoint_resume.rs`).
//! Every read is bounds-checked against the remaining buffer, so a
//! truncated or corrupted file fails with a clear error instead of a
//! panic.

/// FNV-1a 64-bit hash of a byte slice (payload integrity check).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Incremental little-endian writer.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    /// Empty writer.
    pub fn new() -> ByteWriter {
        ByteWriter::default()
    }

    /// Consume the writer, yielding the encoded bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Append one byte.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a bool as one byte (0/1).
    pub fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    /// Append a `u32`, little-endian.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64`, little-endian.
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `usize` as a `u64`.
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// Append an `f32` as its raw bit pattern.
    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    /// Append an `f64` as its raw bit pattern.
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Append a length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Append a length-prefixed `f32` slice.
    pub fn f32s(&mut self, vs: &[f32]) {
        self.usize(vs.len());
        for &v in vs {
            self.f32(v);
        }
    }

    /// Append a length-prefixed `f64` slice.
    pub fn f64s(&mut self, vs: &[f64]) {
        self.usize(vs.len());
        for &v in vs {
            self.f64(v);
        }
    }

    /// Append a length-prefixed `u32` slice.
    pub fn u32s(&mut self, vs: &[u32]) {
        self.usize(vs.len());
        for &v in vs {
            self.u32(v);
        }
    }

    /// Append a length-prefixed `usize` slice.
    pub fn usizes(&mut self, vs: &[usize]) {
        self.usize(vs.len());
        for &v in vs {
            self.usize(v);
        }
    }

    /// Append a length-prefixed bool slice.
    pub fn bools(&mut self, vs: &[bool]) {
        self.usize(vs.len());
        for &v in vs {
            self.bool(v);
        }
    }

    /// Append a presence byte, then the `f64` slice if present.
    pub fn opt_f64s(&mut self, vs: &Option<Vec<f64>>) {
        match vs {
            Some(v) => {
                self.bool(true);
                self.f64s(v);
            }
            None => self.bool(false),
        }
    }
}

/// Bounds-checked little-endian reader over a borrowed buffer.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    /// Reader positioned at the start of `buf`.
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> crate::Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| anyhow::anyhow!("corrupt checkpoint: length overflow"))?;
        anyhow::ensure!(
            end <= self.buf.len(),
            "truncated checkpoint: wanted {n} bytes at offset {}, only {} remain",
            self.pos,
            self.remaining()
        );
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    /// Read one byte.
    pub fn u8(&mut self) -> crate::Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read a bool byte (rejects anything but 0/1).
    pub fn bool(&mut self) -> crate::Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => anyhow::bail!("corrupt checkpoint: bad bool byte {v}"),
        }
    }

    /// Read a little-endian `u32`.
    pub fn u32(&mut self) -> crate::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read a little-endian `u64`.
    pub fn u64(&mut self) -> crate::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a `u64` and narrow it to `usize`.
    pub fn usize(&mut self) -> crate::Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| anyhow::anyhow!("corrupt checkpoint: count {v} overflows"))
    }

    /// A container length, sanity-bounded by the bytes that remain (each
    /// element needs at least `min_elem_bytes`), so a corrupted length
    /// cannot trigger a huge allocation.
    fn len(&mut self, min_elem_bytes: usize) -> crate::Result<usize> {
        let n = self.usize()?;
        anyhow::ensure!(
            n.checked_mul(min_elem_bytes.max(1))
                .is_some_and(|need| need <= self.remaining()),
            "corrupt checkpoint: container of {n} elements exceeds the remaining {} bytes",
            self.remaining()
        );
        Ok(n)
    }

    /// Read an `f32` from its raw bit pattern.
    pub fn f32(&mut self) -> crate::Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    /// Read an `f64` from its raw bit pattern.
    pub fn f64(&mut self) -> crate::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn str(&mut self) -> crate::Result<String> {
        let n = self.len(1)?;
        let bytes = self.take(n)?;
        Ok(std::str::from_utf8(bytes)
            .map_err(|_| anyhow::anyhow!("corrupt checkpoint: non-UTF-8 string"))?
            .to_string())
    }

    /// Read a length-prefixed `f32` vector.
    pub fn f32s(&mut self) -> crate::Result<Vec<f32>> {
        let n = self.len(4)?;
        (0..n).map(|_| self.f32()).collect()
    }

    /// Read a length-prefixed `f64` vector.
    pub fn f64s(&mut self) -> crate::Result<Vec<f64>> {
        let n = self.len(8)?;
        (0..n).map(|_| self.f64()).collect()
    }

    /// Read a length-prefixed `u32` vector.
    pub fn u32s(&mut self) -> crate::Result<Vec<u32>> {
        let n = self.len(4)?;
        (0..n).map(|_| self.u32()).collect()
    }

    /// Read a length-prefixed `usize` vector.
    pub fn usizes(&mut self) -> crate::Result<Vec<usize>> {
        let n = self.len(8)?;
        (0..n).map(|_| self.usize()).collect()
    }

    /// Read a length-prefixed bool vector.
    pub fn bools(&mut self) -> crate::Result<Vec<bool>> {
        let n = self.len(1)?;
        (0..n).map(|_| self.bool()).collect()
    }

    /// Read a presence byte, then the `f64` vector if present.
    pub fn opt_f64s(&mut self) -> crate::Result<Option<Vec<f64>>> {
        Ok(if self.bool()? { Some(self.f64s()?) } else { None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_roundtrip_bit_exactly() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.bool(true);
        w.u32(u32::MAX - 3);
        w.u64(u64::MAX - 5);
        w.usize(12345);
        w.f32(-0.0);
        w.f64(f64::NAN);
        w.str("héllo");
        w.f32s(&[1.5, -2.25]);
        w.f64s(&[3.5]);
        w.u32s(&[9, 8]);
        w.usizes(&[1, 2, 3]);
        w.bools(&[true, false]);
        w.opt_f64s(&Some(vec![4.0]));
        w.opt_f64s(&None);
        let bytes = w.into_bytes();

        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert!(r.bool().unwrap());
        assert_eq!(r.u32().unwrap(), u32::MAX - 3);
        assert_eq!(r.u64().unwrap(), u64::MAX - 5);
        assert_eq!(r.usize().unwrap(), 12345);
        assert_eq!(r.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        // NaN survives as its exact bit pattern.
        assert_eq!(r.f64().unwrap().to_bits(), f64::NAN.to_bits());
        assert_eq!(r.str().unwrap(), "héllo");
        assert_eq!(r.f32s().unwrap(), vec![1.5, -2.25]);
        assert_eq!(r.f64s().unwrap(), vec![3.5]);
        assert_eq!(r.u32s().unwrap(), vec![9, 8]);
        assert_eq!(r.usizes().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.bools().unwrap(), vec![true, false]);
        assert_eq!(r.opt_f64s().unwrap(), Some(vec![4.0]));
        assert_eq!(r.opt_f64s().unwrap(), None);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn truncated_reads_error_instead_of_panicking() {
        let mut w = ByteWriter::new();
        w.u64(42);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes[..5]);
        let err = r.u64().unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    #[test]
    fn absurd_container_length_is_rejected() {
        // A corrupted length prefix must not trigger a huge allocation.
        let mut w = ByteWriter::new();
        w.usize(usize::MAX / 2);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert!(r.f64s().is_err());
    }

    #[test]
    fn bad_bool_is_rejected() {
        let mut r = ByteReader::new(&[2]);
        assert!(r.bool().is_err());
    }

    #[test]
    fn fnv_is_stable_and_sensitive() {
        let h = fnv1a64(b"hasfl");
        assert_eq!(h, fnv1a64(b"hasfl"));
        assert_ne!(h, fnv1a64(b"hasfm"));
        assert_ne!(fnv1a64(b""), 0);
    }
}
