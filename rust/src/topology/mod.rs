//! Fleet topology: deterministic partition of the roster into cells.
//!
//! A [`Topology`] splits the device roster into `cells` contiguous,
//! balanced id ranges. Each cell owns a coordinator shard (see
//! `crate::coordinator`): its devices run on a dedicated slice of the
//! engine-lane/worker pool and produce one weighted partial aggregate,
//! which the root coordinator merges in fixed cell order. Because the
//! ranges are contiguous and ascending, concatenating the per-cell
//! participant lists in cell order reproduces the flat path's globally
//! ascending participant order exactly — the merged parameters are
//! bit-identical to the single-roster path at any cell count
//! (`rust/tests/cells_parity.rs`, DESIGN.md §15).
//!
//! The partition is a pure function of `(cells, n_devices)`: no RNG, no
//! host state. `cells = 0` means auto — one cell per engine-pool lane,
//! so the sharding matches the execution parallelism actually available.

use crate::util::Json;

/// How device ids map to cells. Only contiguous assignment exists today;
/// the enum keeps the config format open for hashed/affinity assignments
/// without a format break.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Assignment {
    /// Balanced contiguous id ranges: cell `k` of `C` over `N` devices
    /// holds `N/C` devices, the first `N mod C` cells one extra.
    #[default]
    Contiguous,
}

impl Assignment {
    /// Canonical lowercase name — the inverse of [`Assignment::parse`].
    pub fn as_str(&self) -> &'static str {
        match self {
            Assignment::Contiguous => "contiguous",
        }
    }

    /// Parse an assignment name (contiguous).
    pub fn parse(s: &str) -> crate::Result<Assignment> {
        match s {
            "contiguous" => Ok(Assignment::Contiguous),
            _ => anyhow::bail!("unknown cell assignment '{s}'"),
        }
    }
}

/// Hierarchical-aggregation topology carried by
/// [`crate::config::Config::topology`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    /// Number of cells. `0` = auto: one cell per engine-pool lane
    /// (resolved against the pool width at session build time).
    pub cells: usize,
    /// Device-id → cell mapping scheme.
    pub assignment: Assignment,
}

impl Topology {
    /// A fixed cell count under contiguous assignment.
    pub fn with_cells(cells: usize) -> Topology {
        Topology { cells, assignment: Assignment::Contiguous }
    }

    /// Auto-sized topology: cell count tracks the engine-pool width.
    pub fn auto() -> Topology {
        Topology::with_cells(0)
    }

    /// Resolve the configured cell count against the engine pool.
    /// `0` (auto) becomes one cell per pool lane; explicit counts pass
    /// through unclamped (cells beyond the roster are simply empty — the
    /// merge handles them, `crate::aggregation::merge_cell_aggregates`).
    pub fn resolve_cells(&self, pool_width: usize) -> usize {
        if self.cells > 0 {
            self.cells
        } else {
            pool_width.max(1)
        }
    }

    /// Contiguous device-id ranges of each cell, in cell order.
    pub fn cell_ranges(cells: usize, n_devices: usize) -> Vec<std::ops::Range<usize>> {
        balanced_ranges(n_devices, cells)
    }

    /// The cell owning device `i` under `cells` cells over `n_devices`.
    pub fn cell_of(i: usize, cells: usize, n_devices: usize) -> usize {
        debug_assert!(i < n_devices);
        let c = cells.max(1);
        let base = n_devices / c;
        let rem = n_devices % c;
        let boundary = rem * (base + 1);
        if i < boundary {
            i / (base + 1)
        } else {
            rem + (i - boundary) / base.max(1)
        }
    }

    /// Serialize to the JSON form accepted by [`Topology::from_json`].
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("cells", Json::Num(self.cells as f64))
            .set("assignment", Json::Str(self.assignment.as_str().into()));
        j
    }

    /// Decode a topology. `assignment` is optional (defaults to
    /// contiguous) so hand-written configs can say just `{"cells": 8}`.
    pub fn from_json(j: &Json) -> crate::Result<Topology> {
        let cells = j.req("cells").and_then(|v| v.as_usize())?;
        let assignment = match j.get("assignment") {
            Some(v) => Assignment::parse(v.as_str()?)?,
            None => Assignment::Contiguous,
        };
        Ok(Topology { cells, assignment })
    }
}

/// Split `0..n` into `k` balanced contiguous ranges (the first `n mod k`
/// ranges get one extra element; ranges beyond `n` come out empty). The
/// shared partition primitive for device→cell and lane→cell slicing.
pub fn balanced_ranges(n: usize, k: usize) -> Vec<std::ops::Range<usize>> {
    let k = k.max(1);
    let base = n / k;
    let rem = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0usize;
    for i in 0..k {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_ranges_cover_and_are_contiguous() {
        for n in [0usize, 1, 4, 7, 10, 100] {
            for k in [1usize, 2, 3, 8, 17] {
                let ranges = balanced_ranges(n, k);
                assert_eq!(ranges.len(), k);
                let mut next = 0usize;
                for r in &ranges {
                    assert_eq!(r.start, next, "contiguous over n={n} k={k}");
                    next = r.end;
                }
                assert_eq!(next, n, "covering over n={n} k={k}");
                // Balanced: sizes differ by at most one.
                let sizes: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
                let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(hi - lo <= 1, "balanced over n={n} k={k}: {sizes:?}");
            }
        }
    }

    #[test]
    fn cell_of_matches_cell_ranges() {
        for n in [1usize, 5, 12, 37] {
            for c in [1usize, 2, 3, 5, 40] {
                let ranges = Topology::cell_ranges(c, n);
                for i in 0..n {
                    let k = Topology::cell_of(i, c, n);
                    assert!(ranges[k].contains(&i), "device {i} n={n} c={c} -> cell {k}");
                }
            }
        }
    }

    #[test]
    fn auto_resolves_to_pool_width() {
        assert_eq!(Topology::auto().resolve_cells(4), 4);
        assert_eq!(Topology::auto().resolve_cells(0), 1);
        assert_eq!(Topology::with_cells(3).resolve_cells(8), 3);
        // Explicit counts beyond the pool pass through unclamped.
        assert_eq!(Topology::with_cells(12).resolve_cells(2), 12);
    }

    #[test]
    fn topology_roundtrips_through_json() {
        for t in [Topology::auto(), Topology::with_cells(1), Topology::with_cells(8)] {
            let back = Topology::from_json(&Json::parse(&t.to_json().dump()).unwrap()).unwrap();
            assert_eq!(back, t);
        }
    }

    #[test]
    fn assignment_defaults_to_contiguous() {
        let j = Json::parse("{\"cells\": 4}").unwrap();
        let t = Topology::from_json(&j).unwrap();
        assert_eq!(t, Topology::with_cells(4));
        assert!(Assignment::parse("ring").is_err());
        assert_eq!(Assignment::parse("contiguous").unwrap(), Assignment::Contiguous);
    }
}
