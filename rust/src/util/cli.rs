//! Minimal CLI argument substrate (no network access for `clap`):
//! `binary <subcommand> [--key value | --flag]...`

use std::collections::BTreeMap;
use std::str::FromStr;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional argument (the subcommand), if any.
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.opts.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                anyhow::bail!("unexpected positional argument '{arg}'");
            }
        }
        Ok(out)
    }

    /// Parse the process's own arguments (skipping argv[0]).
    pub fn from_env() -> anyhow::Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// Raw string value of `--key value` / `--key=value`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// Whether the bare flag `--key` was given.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Typed option with default.
    pub fn get_or<T: FromStr>(&self, key: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("--{key} '{s}': {e}")),
        }
    }

    /// Typed optional option.
    pub fn get_opt<T: FromStr>(&self, key: &str) -> anyhow::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key} '{s}': {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["train", "--rounds", "100", "--seed=7", "--non-iid"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("rounds"), Some("100"));
        assert_eq!(a.get("seed"), Some("7"));
        assert!(a.flag("non-iid"));
        assert!(!a.flag("async"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["x", "--n", "42"]);
        assert_eq!(a.get_or("n", 0usize).unwrap(), 42);
        assert_eq!(a.get_or("m", 7usize).unwrap(), 7);
        assert_eq!(a.get_opt::<u64>("n").unwrap(), Some(42));
        assert_eq!(a.get_opt::<u64>("m").unwrap(), None);
        assert!(a.get_or("n", 0.0f64).is_ok());
    }

    #[test]
    fn bad_typed_value_errors() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.get_or("n", 0usize).is_err());
    }

    #[test]
    fn rejects_extra_positional() {
        assert!(Args::parse(["a".to_string(), "b".to_string()]).is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--fast", "--out", "file.csv"]);
        assert!(a.flag("fast"));
        assert_eq!(a.get("out"), Some("file.csv"));
    }
}
