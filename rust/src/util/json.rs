//! Minimal JSON substrate (parser + writer).
//!
//! The artifact manifest and the config files are JSON; with no network
//! access to pull `serde_json`, we implement the subset we need ourselves:
//! full JSON parsing (objects, arrays, strings with escapes, numbers,
//! bools, null) and deterministic serialization. ~300 lines, fully tested.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (all numbers are f64, as in JavaScript).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Json>),
    /// JSON object (sorted keys, so serialization is deterministic).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors -----------------------------------------------------

    /// Empty JSON object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert `key: val` (panics on non-objects); chainable.
    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("set on non-object");
        }
        self
    }

    /// Numeric array from a slice of f64s.
    pub fn from_f64s(vals: &[f64]) -> Json {
        Json::Arr(vals.iter().map(|&v| Json::Num(v)).collect())
    }

    /// Numeric array from a slice of usizes.
    pub fn from_usizes(vals: &[usize]) -> Json {
        Json::Arr(vals.iter().map(|&v| Json::Num(v as f64)).collect())
    }

    // ---- accessors --------------------------------------------------------

    /// Object field lookup (`None` on non-objects or missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panicking accessor for required fields (manifest/config are
    /// machine-generated; a missing field is a build error, not user input).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON field '{key}'"))
    }

    /// The value as a number.
    pub fn as_f64(&self) -> anyhow::Result<f64> {
        match self {
            Json::Num(v) => Ok(*v),
            _ => anyhow::bail!("not a number: {self:?}"),
        }
    }

    /// The value as a number, truncated to usize.
    pub fn as_usize(&self) -> anyhow::Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    /// The value as a number, truncated to u32.
    pub fn as_u32(&self) -> anyhow::Result<u32> {
        Ok(self.as_f64()? as u32)
    }

    /// The value as a number, truncated to u64.
    pub fn as_u64(&self) -> anyhow::Result<u64> {
        Ok(self.as_f64()? as u64)
    }

    /// The value as a bool.
    pub fn as_bool(&self) -> anyhow::Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => anyhow::bail!("not a bool: {self:?}"),
        }
    }

    /// The value as a string slice.
    pub fn as_str(&self) -> anyhow::Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => anyhow::bail!("not a string: {self:?}"),
        }
    }

    /// The value as an array slice.
    pub fn as_arr(&self) -> anyhow::Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => anyhow::bail!("not an array: {self:?}"),
        }
    }

    /// A numeric array as a `Vec<usize>`.
    pub fn usize_vec(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    /// A numeric array as a `Vec<u32>`.
    pub fn u32_vec(&self) -> anyhow::Result<Vec<u32>> {
        self.as_arr()?.iter().map(|j| j.as_u32()).collect()
    }

    /// A numeric array as a `Vec<f64>`.
    pub fn f64_vec(&self) -> anyhow::Result<Vec<f64>> {
        self.as_arr()?.iter().map(|j| j.as_f64()).collect()
    }

    // ---- serialization ----------------------------------------------------

    /// Serialize to compact JSON text (deterministic: object keys sorted).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    let _ = write!(out, "{}", *v as i64);
                } else {
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- parsing ----------------------------------------------------------

    /// Parse a complete JSON document (trailing bytes are an error).
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let val = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        anyhow::ensure!(pos == bytes.len(), "trailing JSON at byte {pos}");
        Ok(val)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    skip_ws(b, pos);
    anyhow::ensure!(*pos < b.len(), "unexpected end of JSON");
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, val: Json) -> anyhow::Result<Json> {
    anyhow::ensure!(
        b[*pos..].starts_with(lit.as_bytes()),
        "bad literal at byte {pos}",
        pos = *pos
    );
    *pos += lit.len();
    Ok(val)
}

fn parse_num(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(s.parse::<f64>().map_err(|e| {
        anyhow::anyhow!("bad number '{s}' at byte {start}: {e}")
    })?))
}

fn parse_string(b: &[u8], pos: &mut usize) -> anyhow::Result<String> {
    anyhow::ensure!(b[*pos] == b'"', "expected string at byte {pos}", pos = *pos);
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                anyhow::ensure!(*pos < b.len(), "dangling escape");
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000C}'),
                    b'u' => {
                        anyhow::ensure!(*pos + 4 < b.len(), "short \\u escape");
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    c => anyhow::bail!("bad escape \\{}", c as char),
                }
                *pos += 1;
            }
            _ => {
                // copy a full UTF-8 sequence
                let s = &b[*pos..];
                let ch_len = utf8_len(s[0]);
                let chunk = std::str::from_utf8(&s[..ch_len.min(s.len())])?;
                out.push_str(chunk);
                *pos += chunk.len();
            }
        }
    }
    anyhow::bail!("unterminated string")
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    *pos += 1; // [
    let mut items = Vec::new();
    loop {
        skip_ws(b, pos);
        anyhow::ensure!(*pos < b.len(), "unterminated array");
        if b[*pos] == b']' {
            *pos += 1;
            return Ok(Json::Arr(items));
        }
        if !items.is_empty() {
            anyhow::ensure!(b[*pos] == b',', "expected ',' in array at byte {}", *pos);
            *pos += 1;
        }
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == b',' {
            continue;
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    *pos += 1; // {
    let mut map = BTreeMap::new();
    loop {
        skip_ws(b, pos);
        anyhow::ensure!(*pos < b.len(), "unterminated object");
        if b[*pos] == b'}' {
            *pos += 1;
            return Ok(Json::Obj(map));
        }
        if !map.is_empty() {
            anyhow::ensure!(b[*pos] == b',', "expected ',' in object at byte {}", *pos);
            *pos += 1;
            skip_ws(b, pos);
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        anyhow::ensure!(*pos < b.len() && b[*pos] == b':', "expected ':' at byte {}", *pos);
        *pos += 1;
        let val = parse_value(b, pos)?;
        map.insert(key, val);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "c"
        );
    }

    #[test]
    fn escapes_roundtrip() {
        let original = Json::Str("line\n\"quote\"\t\\back".into());
        let text = original.dump();
        assert_eq!(Json::parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn dump_parse_roundtrip_object() {
        let mut j = Json::obj();
        j.set("x", Json::Num(1.5))
            .set("name", Json::Str("hasfl".into()))
            .set("flags", Json::Arr(vec![Json::Bool(true), Json::Null]));
        let back = Json::parse(&j.dump()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn integer_formatting_is_stable() {
        assert_eq!(Json::Num(64.0).dump(), "64");
        assert_eq!(Json::Num(0.5).dump(), "0.5");
    }

    #[test]
    fn vec_helpers() {
        let j = Json::parse("[1,2,3]").unwrap();
        assert_eq!(j.usize_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(j.u32_vec().unwrap(), vec![1, 2, 3]);
    }
}
