//! Utility substrates built in-repo (the build environment has no network
//! access to crates.io, so JSON/CLI layers are implemented here and tested
//! like everything else).

pub mod cli;
pub mod json;

pub use cli::Args;
pub use json::Json;

/// Logical CPU cores visible to this process (1 when the platform cannot
/// say). Recorded in bench metadata (`BENCH_*.json: meta.host_cores`) and
/// `info --json` so `hasfl bench-diff` can flag cross-machine comparisons
/// as environment skew rather than code regressions.
pub fn host_cores() -> usize {
    std::thread::available_parallelism().map_or(1, |n| n.get())
}
