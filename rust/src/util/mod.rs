//! Utility substrates built in-repo (the build environment has no network
//! access to crates.io, so JSON/CLI layers are implemented here and tested
//! like everything else).

pub mod cli;
pub mod json;

pub use cli::Args;
pub use json::Json;
