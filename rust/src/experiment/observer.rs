//! Observer hooks + the built-in observers (CSV history, progress
//! logging, early stop on convergence).

use std::path::PathBuf;

use crate::latency::Decisions;
use crate::metrics::{
    FleetRound, FleetTrace, History, CONVERGENCE_ACC_THRESHOLD, CONVERGENCE_WINDOW,
};
use crate::scenario::FleetSnapshot;

use super::RoundReport;

/// Callbacks fired by [`super::Session::step`], in this order per round:
/// `on_round`, then `on_fleet` (scenario sessions only), then
/// `on_aggregation` (aggregation rounds), then `on_reoptimize` (after
/// fresh decisions land), then `on_eval` (evaluation rounds), then
/// `checkpoint_request`/`on_checkpoint` (so checkpoints capture the fully
/// booked round). `on_complete` fires once from
/// [`super::Session::finish`].
pub trait Observer {
    /// A training round completed (fires every round).
    fn on_round(&mut self, _report: &RoundReport) {}
    /// The round's fleet snapshot; fires only when the session runs under
    /// a dynamic scenario.
    fn on_fleet(&mut self, _report: &RoundReport, _snapshot: &FleetSnapshot) {}
    /// The round ended in a client-model aggregation event.
    fn on_aggregation(&mut self, _report: &RoundReport) {}
    /// Fresh BS/MS decisions were solved and took effect.
    fn on_reoptimize(&mut self, _report: &RoundReport, _decisions: &Decisions) {}
    /// The round included a test-set evaluation.
    fn on_eval(&mut self, _report: &RoundReport, _test_acc: f64) {}
    /// Ask the session to checkpoint the just-completed round: return the
    /// file to write. The session captures the complete training state and
    /// saves it crash-safely (write-to-temp + atomic rename, see
    /// [`crate::checkpoint`]), then fires [`Observer::on_checkpoint`].
    /// Fired after every per-round event above, so the captured state
    /// includes the round's full bookkeeping.
    fn checkpoint_request(&mut self, _report: &RoundReport) -> Option<std::path::PathBuf> {
        None
    }
    /// A checkpoint of `report`'s round was written to `path` (retention
    /// pruning hooks here).
    fn on_checkpoint(&mut self, _report: &RoundReport, _path: &std::path::Path) {}
    /// The session was rebuilt from a checkpoint: `history` holds the
    /// restored records for rounds `1..=k`. Observers carrying
    /// cross-round state (convergence windows, running maxima) rebuild
    /// it here so a resumed run behaves like the uninterrupted one
    /// ([`EarlyStop`] does); pure per-round sinks ignore it and simply
    /// continue from round k+1.
    fn on_resume(&mut self, _history: &History) {}
    /// Flush side effects at the end of the run.
    fn on_complete(&mut self, _history: &History) -> crate::Result<()> {
        Ok(())
    }
    /// Ask the driving loop to stop after the current round.
    fn should_stop(&self) -> bool {
        false
    }
}

/// Writes the run history as `round,sim_time,loss,test_acc` CSV when the
/// session finishes.
pub struct CsvHistory {
    path: PathBuf,
}

impl CsvHistory {
    /// Write the history CSV to `path` on completion.
    pub fn new(path: impl Into<PathBuf>) -> CsvHistory {
        CsvHistory { path: path.into() }
    }

    /// Destination path of the CSV.
    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

impl Observer for CsvHistory {
    fn on_complete(&mut self, history: &History) -> crate::Result<()> {
        history.write_csv(&self.path)
    }
}

/// Collects the per-round fleet trace of a scenario session (membership,
/// drift, latency — see [`FleetTrace`]) and writes it as CSV when the
/// session finishes. Produces a header-only file on static-fleet sessions
/// (no snapshots ever fire). On a resumed session the trace holds only
/// the post-resume rounds (snapshots are per-round events, not part of
/// the restored history); the replayed rounds themselves are still
/// bit-identical to the uninterrupted run's
/// (`rust/tests/checkpoint_resume.rs`).
pub struct FleetTraceCsv {
    path: PathBuf,
    trace: FleetTrace,
}

impl FleetTraceCsv {
    /// Write the fleet-trace CSV to `path` on completion.
    pub fn new(path: impl Into<PathBuf>) -> FleetTraceCsv {
        FleetTraceCsv { path: path.into(), trace: FleetTrace::default() }
    }

    /// Trace collected so far.
    pub fn trace(&self) -> &FleetTrace {
        &self.trace
    }
}

impl Observer for FleetTraceCsv {
    fn on_fleet(&mut self, report: &RoundReport, snapshot: &FleetSnapshot) {
        self.trace.push(FleetRound {
            round: report.round,
            n_active: snapshot.active.len(),
            n_dropped: snapshot.dropped.len(),
            n_joined: snapshot.joined.len(),
            n_left: snapshot.left.len(),
            drift: snapshot.drift,
            resolved: report.reoptimized,
            t_split: report.latency.t_split,
            t_agg: if report.aggregated { report.latency.t_agg } else { 0.0 },
            sim_time: report.sim_time,
            flushed: report.asynchrony.as_ref().map_or(0, |a| a.flushed),
            stale_drops: report.asynchrony.as_ref().map_or(0, |a| a.dropped_stale),
            staleness_mean: report.asynchrony.as_ref().map_or(0.0, |a| a.staleness_mean),
        });
    }

    fn on_complete(&mut self, _history: &History) -> crate::Result<()> {
        self.trace.write_csv(&self.path)
    }
}

/// Logs re-optimizations and evaluation points to stderr.
pub struct ProgressLogger;

impl Observer for ProgressLogger {
    fn on_reoptimize(&mut self, report: &RoundReport, decisions: &Decisions) {
        eprintln!(
            "[round {:>4}] re-optimized: b={:?} cut={:?}",
            report.round, decisions.batch, decisions.cut
        );
    }

    fn on_eval(&mut self, report: &RoundReport, test_acc: f64) {
        eprintln!(
            "[round {:>4}] sim_time {:>9.2}s  loss {:.4}  test_acc {:.2}%",
            report.round,
            report.sim_time,
            report.outcome.mean_loss,
            test_acc * 100.0
        );
    }
}

/// Early stop on the paper's convergence rule: test accuracy improves by
/// less than `threshold` across `window` consecutive evaluation rounds
/// (stateful mirror of [`History::converged`]).
pub struct EarlyStop {
    threshold: f64,
    window: usize,
    running_max: Option<f64>,
    stagnant: usize,
    triggered_at: Option<(usize, f64, f64)>,
}

impl EarlyStop {
    /// Stop once accuracy improves by less than `threshold` for `window`
    /// consecutive evaluation rounds.
    pub fn new(threshold: f64, window: usize) -> EarlyStop {
        EarlyStop { threshold, window, running_max: None, stagnant: 0, triggered_at: None }
    }

    /// The paper's defaults (0.02% over five evaluation rounds).
    pub fn paper_default() -> EarlyStop {
        EarlyStop::new(CONVERGENCE_ACC_THRESHOLD, CONVERGENCE_WINDOW)
    }

    /// `(round, sim_time, accuracy)` of the convergence point, if reached.
    pub fn triggered(&self) -> Option<(usize, f64, f64)> {
        self.triggered_at
    }

    fn observe(&mut self, round: usize, sim_time: f64, test_acc: f64) {
        match self.running_max {
            None => self.running_max = Some(test_acc),
            Some(m) => {
                if (test_acc - m).max(0.0) < self.threshold {
                    self.stagnant += 1;
                    if self.stagnant >= self.window && self.triggered_at.is_none() {
                        self.triggered_at = Some((round, sim_time, test_acc));
                    }
                } else {
                    self.stagnant = 0;
                }
                self.running_max = Some(m.max(test_acc));
            }
        }
    }
}

impl Observer for EarlyStop {
    fn on_eval(&mut self, report: &RoundReport, test_acc: f64) {
        self.observe(report.round, report.sim_time, test_acc);
    }

    fn on_resume(&mut self, history: &History) {
        // Replay the restored evaluation points so the stagnation window
        // and running maximum match the uninterrupted run's state.
        for (round, sim_time, acc) in history.eval_points() {
            self.observe(round, sim_time, acc);
        }
    }

    fn should_stop(&self) -> bool {
        self.triggered_at.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RoundOutcome;
    use crate::latency::RoundLatency;

    fn fake_report(round: usize, test_acc: Option<f64>) -> RoundReport {
        RoundReport {
            round,
            sim_time: round as f64,
            outcome: RoundOutcome { mean_loss: 1.0, train_acc: 0.5, participants: 1 },
            latency: RoundLatency {
                per_device: vec![],
                server_fwd: 0.0,
                server_bwd: 0.0,
                t_split: 1.0,
                t_agg: 0.0,
            },
            aggregated: false,
            reoptimized: false,
            decisions: Decisions::uniform(1, 8, 4),
            test_acc,
            fleet: None,
            abandoned: vec![],
            quarantined: vec![],
            cells: vec![],
            asynchrony: None,
        }
    }

    fn feed(stop: &mut EarlyStop, accs: &[f64]) {
        for (i, &a) in accs.iter().enumerate() {
            let r = fake_report(i + 1, Some(a));
            stop.on_eval(&r, a);
        }
    }

    #[test]
    fn early_stop_matches_history_converged() {
        // Same sequence as metrics::tests::converged_detects_stagnation.
        let accs = [0.1, 0.3, 0.5, 0.6, 0.6, 0.6, 0.6, 0.6, 0.6];
        let mut stop = EarlyStop::new(0.0002, 5);
        feed(&mut stop, &accs);
        let (round, _, acc) = stop.triggered().unwrap();
        assert_eq!(round, 9); // 1-based round of the 9th eval
        assert!((acc - 0.6).abs() < 1e-12);
        assert!(stop.should_stop());
    }

    #[test]
    fn early_stop_rebuilds_its_window_on_resume() {
        // A resumed run replays the restored eval points through
        // on_resume, so the stagnation window matches the uninterrupted
        // run: 4 stagnant restored evals + 1 live eval => trigger.
        let mut h = History::default();
        for (i, &a) in [0.1, 0.5, 0.5, 0.5, 0.5, 0.5].iter().enumerate() {
            h.push(crate::metrics::Record {
                round: i + 1,
                sim_time: i as f64,
                loss: 1.0,
                test_acc: Some(a),
            });
        }
        let mut stop = EarlyStop::new(0.0002, 5);
        stop.on_resume(&h);
        assert!(!stop.should_stop(), "4 stagnant evals must not trigger a 5-window");
        let r = fake_report(7, Some(0.5));
        stop.on_eval(&r, 0.5);
        assert!(stop.should_stop());
        assert_eq!(stop.triggered().unwrap().0, 7);
    }

    #[test]
    fn early_stop_resets_on_improvement() {
        let mut stop = EarlyStop::new(0.0002, 5);
        feed(&mut stop, &[0.1, 0.1, 0.1, 0.1, 0.5, 0.5, 0.5, 0.5]);
        assert!(stop.triggered().is_none());
        assert!(!stop.should_stop());
    }

    #[test]
    fn fleet_trace_csv_collects_snapshots() {
        let path = std::env::temp_dir().join("hasfl_fleet_obs_test.csv");
        let mut obs = FleetTraceCsv::new(&path);
        let report = fake_report(1, None);
        let snap = FleetSnapshot {
            round: 1,
            active: vec![0, 1, 2],
            devices: vec![],
            dropped: vec![2],
            joined: vec![],
            left: vec![],
            drift: 0.1,
        };
        obs.on_fleet(&report, &snap);
        assert_eq!(obs.trace().len(), 1);
        assert_eq!(obs.trace().rounds[0].n_active, 3);
        assert_eq!(obs.trace().rounds[0].n_dropped, 1);
        obs.on_complete(&History::default()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
    }

    #[test]
    fn csv_history_writes_on_complete() {
        let mut h = History::default();
        h.push(crate::metrics::Record { round: 1, sim_time: 1.0, loss: 2.0, test_acc: Some(0.1) });
        let path = std::env::temp_dir().join("hasfl_observer_csv_test.csv");
        let mut obs = CsvHistory::new(&path);
        obs.on_complete(&h).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("round,sim_time,loss,test_acc"));
        assert_eq!(text.lines().count(), 2);
    }
}
