//! The ownable session driver: a non-blocking command loop over a
//! [`Session`], built for callers that multiplex many sessions through a
//! bounded worker pool (the `hasfl serve` daemon, `crate::serve`).
//!
//! A [`SessionDriver`] owns its [`Session`] and pulls [`DriverCommand`]s
//! from a caller-supplied source *between* rounds: [`SessionDriver::pump`]
//! drains every queued command, then advances at most one training round,
//! so control traffic (checkpoint now, pause, close) interleaves with a
//! long `Run` without waiting for it to finish. Everything the driver does
//! is announced through a [`SessionEvent`] sink — the same sink an
//! [`EventBridge`] observer feeds from inside the session, so periodic
//! [`crate::checkpoint::CheckpointObserver`] writes surface as
//! [`SessionEvent::Checkpointed`] events too.
//!
//! The driver never blocks waiting for commands: an idle driver simply
//! returns [`Pump::Idle`] and the caller decides when to poll again (the
//! serve worker pool re-schedules a driver only when new commands arrive).

use std::path::PathBuf;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use crate::metrics::Record;

use super::{Observer, RoundReport, Session};

/// A control message for a [`SessionDriver`].
#[derive(Debug, Clone, PartialEq)]
pub enum DriverCommand {
    /// Run `n` more rounds (additive with rounds still pending).
    Run(usize),
    /// Drop all pending rounds; the driver goes idle after the current one.
    Pause,
    /// Checkpoint now. `None` writes `ckpt_round_NNNNNN.hckpt` into the
    /// driver's checkpoint directory ([`SessionDriver::checkpoint_dir`]);
    /// `Some(path)` writes exactly there.
    Checkpoint(Option<PathBuf>),
    /// Finish the session: optionally checkpoint first, flush observers,
    /// shut the engine down. The driver is closed afterwards.
    Close { checkpoint: bool },
}

/// Everything a driver announces through its event sink.
#[derive(Debug, Clone)]
pub enum SessionEvent {
    /// A training round completed.
    Round(Box<RoundReport>),
    /// A checkpoint was written (on-demand or by a periodic
    /// [`crate::checkpoint::CheckpointObserver`] through [`EventBridge`]).
    Checkpointed { round: usize, path: PathBuf },
    /// The command queue and pending rounds are drained. `done` is true
    /// when the session's round budget is exhausted (or an observer
    /// requested an early stop).
    Idle { round: usize, done: bool },
    /// A command failed; the driver stays alive, pending rounds are
    /// dropped.
    Error { round: usize, message: String },
    /// The session finished and the engine shut down; terminal.
    Closed { round: usize },
}

/// What a single [`SessionDriver::pump`] call did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pump {
    /// A round was stepped (or a command executed); call again.
    Worked,
    /// Nothing to do until more commands arrive.
    Idle,
    /// The session is closed; the driver is spent.
    Closed,
}

/// Shared event sink: both the driver and any [`EventBridge`] observer
/// inside the session publish through it.
pub type EventSink = Arc<dyn Fn(SessionEvent) + Send + Sync>;

/// Bridges [`Observer`] callbacks out of the session into a driver's
/// event sink. Attach it alongside a
/// [`crate::checkpoint::CheckpointObserver`] so its periodic writes are
/// announced as [`SessionEvent::Checkpointed`] — the driver only sees its
/// own on-demand checkpoints otherwise.
pub struct EventBridge {
    sink: EventSink,
}

impl EventBridge {
    /// Wrap an event sink as an observer.
    pub fn new(sink: EventSink) -> EventBridge {
        EventBridge { sink }
    }
}

impl Observer for EventBridge {
    fn on_checkpoint(&mut self, report: &RoundReport, path: &std::path::Path) {
        (self.sink)(SessionEvent::Checkpointed {
            round: report.round,
            path: path.to_path_buf(),
        });
    }
}

/// Owns a [`Session`] and drives it one round at a time under external
/// command flow. See the [module docs](self).
pub struct SessionDriver {
    /// `None` after [`DriverCommand::Close`] consumed the session.
    session: Option<Session>,
    commands: Receiver<DriverCommand>,
    sink: EventSink,
    /// Where parameterless [`DriverCommand::Checkpoint`] requests (and
    /// close-time checkpoints) land.
    checkpoint_dir: Option<PathBuf>,
    /// Rounds still to run.
    pending: usize,
    /// Suppresses repeated `Idle` events while nothing changes.
    announced_idle: bool,
}

impl SessionDriver {
    /// Wrap `session`; returns the driver and the command sender feeding
    /// it. Events go to `sink`.
    pub fn new(session: Session, sink: EventSink) -> (SessionDriver, Sender<DriverCommand>) {
        let (tx, rx) = std::sync::mpsc::channel();
        (
            SessionDriver {
                session: Some(session),
                commands: rx,
                sink,
                checkpoint_dir: None,
                pending: 0,
                announced_idle: false,
            },
            tx,
        )
    }

    /// Directory for parameterless checkpoint commands; files are named
    /// `ckpt_round_NNNNNN.hckpt` (the
    /// [`crate::checkpoint::CheckpointObserver`] naming, so retention and
    /// adoption treat both kinds uniformly).
    pub fn checkpoint_dir(mut self, dir: impl Into<PathBuf>) -> SessionDriver {
        self.checkpoint_dir = Some(dir.into());
        self
    }

    /// The wrapped session, while it lives.
    pub fn session(&self) -> Option<&Session> {
        self.session.as_ref()
    }

    /// Rounds queued but not yet run.
    pub fn pending(&self) -> usize {
        self.pending
    }

    /// Whether [`DriverCommand::Close`] already consumed the session.
    pub fn is_closed(&self) -> bool {
        self.session.is_none()
    }

    fn emit(&self, event: SessionEvent) {
        (self.sink)(event);
    }

    fn checkpoint_path(&self, round: usize, explicit: Option<PathBuf>) -> crate::Result<PathBuf> {
        match explicit {
            Some(p) => Ok(p),
            None => match &self.checkpoint_dir {
                Some(dir) => Ok(dir.join(format!("ckpt_round_{round:06}.hckpt"))),
                None => anyhow::bail!(
                    "checkpoint command without a path, and the driver has no checkpoint_dir"
                ),
            },
        }
    }

    fn do_checkpoint(&mut self, explicit: Option<PathBuf>) {
        let round = self.round();
        match self
            .checkpoint_path(round, explicit)
            .and_then(|path| match &self.session {
                Some(s) => s.checkpoint(&path).map(|()| path),
                None => anyhow::bail!("session already closed"),
            }) {
            Ok(path) => self.emit(SessionEvent::Checkpointed { round, path }),
            Err(e) => {
                self.pending = 0;
                self.emit(SessionEvent::Error { round, message: format!("checkpoint: {e}") });
            }
        }
    }

    fn round(&self) -> usize {
        self.session.as_ref().map_or(0, |s| s.round())
    }

    /// Drain queued commands (non-blocking), then advance at most one
    /// round. Call repeatedly while it returns [`Pump::Worked`].
    pub fn pump(&mut self) -> Pump {
        if self.session.is_none() {
            return Pump::Closed;
        }
        // Absorb every queued command first: a `Checkpoint` or `Close`
        // issued mid-`Run` executes before the next round, not after the
        // whole run.
        while let Ok(cmd) = self.commands.try_recv() {
            match cmd {
                DriverCommand::Run(n) => {
                    self.pending = self.pending.saturating_add(n);
                    self.announced_idle = false;
                }
                DriverCommand::Pause => self.pending = 0,
                DriverCommand::Checkpoint(path) => self.do_checkpoint(path),
                DriverCommand::Close { checkpoint } => {
                    if checkpoint {
                        self.do_checkpoint(None);
                    }
                    let round = self.round();
                    let session = self.session.take().expect("checked non-closed above");
                    if let Err(e) = session.finish() {
                        self.emit(SessionEvent::Error {
                            round,
                            message: format!("finish: {e}"),
                        });
                    }
                    self.emit(SessionEvent::Closed { round });
                    return Pump::Closed;
                }
            }
        }
        let session = self.session.as_mut().expect("checked non-closed above");
        if self.pending > 0 {
            if session.is_done() || session.stop_requested() {
                self.pending = 0;
            } else {
                match session.step() {
                    Ok(report) => {
                        self.pending -= 1;
                        self.emit(SessionEvent::Round(Box::new(report)));
                    }
                    Err(e) => {
                        self.pending = 0;
                        let round = self.round();
                        self.emit(SessionEvent::Error {
                            round,
                            message: format!("step: {e}"),
                        });
                    }
                }
                return Pump::Worked;
            }
        }
        if !self.announced_idle {
            self.announced_idle = true;
            let session = self.session.as_ref().expect("checked non-closed above");
            self.emit(SessionEvent::Idle {
                round: session.round(),
                done: session.is_done() || session.stop_requested(),
            });
        }
        Pump::Idle
    }

    /// Pump until idle or closed (the standalone, single-session way to
    /// use a driver; the serve worker pool calls [`SessionDriver::pump`]
    /// directly so it can interleave other sessions).
    pub fn run_until_idle(&mut self) -> Pump {
        loop {
            match self.pump() {
                Pump::Worked => continue,
                outcome => return outcome,
            }
        }
    }

    /// Per-round history records of the live session (restored rounds
    /// included on resumed sessions).
    pub fn records(&self) -> Vec<Record> {
        self.session.as_ref().map_or_else(Vec::new, |s| s.history().records.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    fn collecting_sink() -> (EventSink, Arc<Mutex<Vec<SessionEvent>>>) {
        let log: Arc<Mutex<Vec<SessionEvent>>> = Arc::new(Mutex::new(Vec::new()));
        let log2 = log.clone();
        (Arc::new(move |e| log2.lock().unwrap().push(e)), log)
    }

    fn fake_report(round: usize) -> RoundReport {
        RoundReport {
            round,
            sim_time: round as f64,
            outcome: crate::coordinator::RoundOutcome {
                mean_loss: 1.0,
                train_acc: 0.5,
                participants: 1,
            },
            latency: crate::latency::RoundLatency {
                per_device: vec![],
                server_fwd: 0.0,
                server_bwd: 0.0,
                t_split: 1.0,
                t_agg: 0.0,
            },
            aggregated: false,
            reoptimized: false,
            decisions: crate::latency::Decisions::uniform(1, 8, 4),
            test_acc: None,
            fleet: None,
            abandoned: vec![],
            quarantined: vec![],
            cells: vec![],
            asynchrony: None,
        }
    }

    #[test]
    fn event_bridge_forwards_checkpoints() {
        let (sink, log) = collecting_sink();
        let mut bridge = EventBridge::new(sink);
        let report = fake_report(7);
        bridge.on_checkpoint(&report, std::path::Path::new("ck/x.hckpt"));
        let log = log.lock().unwrap();
        assert!(matches!(
            &log[..],
            [SessionEvent::Checkpointed { round: 7, path }] if path.ends_with("x.hckpt")
        ));
    }
}
