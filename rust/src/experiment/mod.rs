//! The public experiment API: one obvious way to drive HASFL.
//!
//! Every scenario — CLI runs, figure regeneration, the examples, the
//! benches — goes through the same three pieces:
//!
//! - [`ExperimentBuilder`] (via [`Experiment::builder`]) assembles and
//!   *validates* a configuration up front: preset selection, fleet size,
//!   strategy, seed, artifact compatibility, cut/bucket bounds. No more
//!   ad-hoc `Config` field pokes scattered across drivers.
//! - [`Session`] is the step-driven training loop: [`Session::step`]
//!   advances one round and returns a [`RoundReport`] (loss, latency
//!   breakdown, current decisions, optional eval).
//!   [`Session::run_to_completion`] / [`Session::run_concurrent`] are thin
//!   drivers on top.
//! - [`Observer`]s hook round/aggregation/re-optimization/eval events;
//!   built-ins cover CSV history ([`CsvHistory`]), progress logging
//!   ([`ProgressLogger`]), and early stop on convergence ([`EarlyStop`]).
//!
//! ```no_run
//! use hasfl::experiment::{CsvHistory, Experiment, Preset};
//! use hasfl::config::StrategyKind;
//!
//! let mut session = Experiment::builder()
//!     .preset(Preset::Small)
//!     .devices(4)
//!     .strategy(StrategyKind::Hasfl)
//!     .seed(7)
//!     .artifacts("artifacts")
//!     .observe(CsvHistory::new("results/run.csv"))
//!     .build()?;
//! while !session.is_done() {
//!     let report = session.step()?;
//!     if let Some(acc) = report.test_acc {
//!         println!("round {}: {:.2}%", report.round, acc * 100.0);
//!     }
//! }
//! session.finish()?; // flush observers, shut the engine down
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! The step-driven path is numerics-identical to the historical closed
//! `Trainer::run()` loop: same RNG stream order, same history records
//! (verified by `rust/tests/experiment_api.rs`).

mod driver;
mod observer;
mod session;

pub use driver::{DriverCommand, EventBridge, EventSink, Pump, SessionDriver, SessionEvent};
pub use observer::{CsvHistory, EarlyStop, FleetTraceCsv, Observer, ProgressLogger};
pub use session::{RoundReport, Session};

use std::path::{Path, PathBuf};

use crate::asynch::AsyncSpec;
use crate::backend::{BackendKind, ModelSpec};
use crate::config::{Config, ModelKind, Partition, StrategyKind};
use crate::coordinator::Trainer;
use crate::fault::{FaultPreset, FaultSpec};
use crate::model::Manifest;
use crate::scenario::{Scenario, ScenarioPreset};
use crate::topology::Topology;

/// Named experiment presets (the validated entry points into [`Config`]).
///
/// Presets configure *executable* sessions: [`Preset::Table1`] applies the
/// paper's Table I fleet/network but selects the executable SplitCNN-8
/// model (the analytic VGG-16 variant of Table I remains available as
/// [`Config::table1`] for latency-model studies via
/// [`ExperimentBuilder::build_config`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preset {
    /// N=4 executable CPU-testbed preset ([`Config::small`]).
    Small,
    /// N=8 figure-harness preset ([`Config::figure_small`]).
    Figure,
    /// Table I fleet at N=20 with the executable model.
    Table1,
}

impl Preset {
    /// Canonical name — the inverse of [`Preset::parse`].
    pub fn as_str(&self) -> &'static str {
        match self {
            Preset::Small => "small",
            Preset::Figure => "figure",
            Preset::Table1 => "table1",
        }
    }

    /// Parse a preset name (small|figure|table1).
    pub fn parse(s: &str) -> crate::Result<Preset> {
        Ok(match s {
            "small" => Preset::Small,
            "figure" | "figure_small" => Preset::Figure,
            "table1" => Preset::Table1,
            _ => anyhow::bail!("unknown preset '{s}' (expected small|figure|table1)"),
        })
    }

    /// The preset's base configuration.
    pub fn config(&self) -> Config {
        match self {
            Preset::Small => Config::small(),
            Preset::Figure => Config::figure_small(),
            Preset::Table1 => {
                let mut cfg = Config::table1();
                cfg.model = ModelKind::Splitcnn8;
                cfg
            }
        }
    }
}

/// Entry point to the experiment API. See the [module docs](self).
pub struct Experiment;

impl Experiment {
    /// Start building an experiment (defaults to [`Preset::Small`]).
    pub fn builder() -> ExperimentBuilder {
        ExperimentBuilder {
            cfg: Preset::Small.config(),
            artifacts: PathBuf::from("artifacts"),
            concurrent: false,
            observers: Vec::new(),
            resume: None,
            rounds_override: None,
            pool_override: None,
            backend_override: None,
            topology_override: None,
            async_override: None,
        }
    }
}

/// Fluent builder for a training [`Session`].
pub struct ExperimentBuilder {
    cfg: Config,
    artifacts: PathBuf,
    concurrent: bool,
    observers: Vec<Box<dyn Observer + Send>>,
    /// Checkpoint file to resume from; its embedded config is then
    /// authoritative (only the round budget may be overridden on top).
    resume: Option<PathBuf>,
    /// Explicit `.rounds(..)` value, applied over a resumed config too so
    /// a resumed run can extend its round budget.
    rounds_override: Option<usize>,
    /// Explicit `.engine_pool(..)` value, applied over a resumed config
    /// too: pool width is a pure wall-clock knob (numerics are identical
    /// at any width, `rust/tests/parity_modes.rs`), so resuming on a
    /// differently-sized machine may retune it.
    pool_override: Option<usize>,
    /// Explicit `.backend(..)` value. Unlike pool width this is a
    /// numerics-affecting knob (backends agree within float tolerance
    /// only), so it conflicts with [`ExperimentBuilder::resume_from`] —
    /// the checkpoint's embedded backend is authoritative there.
    backend_override: Option<BackendKind>,
    /// Explicit `.topology(..)` / `.cells(..)` value. Topology is
    /// bit-neutral (`rust/tests/cells_parity.rs`), but it reshapes
    /// per-cell reporting and lane affinity mid-run, so it conflicts with
    /// [`ExperimentBuilder::resume_from`] — the checkpoint's embedded
    /// topology is authoritative there.
    topology_override: Option<Topology>,
    /// Explicit `.async_buffer(..)` / `.async_spec(..)` value. The async
    /// schedule reshapes the whole round structure, so it conflicts with
    /// [`ExperimentBuilder::resume_from`] — the checkpoint's embedded
    /// async spec (and its restored in-flight buffer) is authoritative
    /// there.
    async_override: Option<AsyncSpec>,
}

impl ExperimentBuilder {
    /// Replace the whole configuration with a preset.
    pub fn preset(mut self, preset: Preset) -> Self {
        self.cfg = preset.config();
        self
    }

    /// Replace the whole configuration with an explicit [`Config`]
    /// (e.g. loaded from JSON).
    pub fn config(mut self, cfg: Config) -> Self {
        self.cfg = cfg;
        self
    }

    /// Fleet size override.
    pub fn devices(mut self, n: usize) -> Self {
        self.cfg.fleet.n_devices = n;
        self
    }

    /// Round-budget override. With [`ExperimentBuilder::resume_from`],
    /// this overrides the checkpointed budget too (extend a finished run
    /// by resuming it with a larger budget).
    pub fn rounds(mut self, rounds: usize) -> Self {
        self.cfg.train.rounds = rounds;
        self.rounds_override = Some(rounds);
        self
    }

    /// Resume a session from a checkpoint file written by
    /// [`Session::checkpoint`] or [`crate::checkpoint::CheckpointObserver`].
    /// The checkpoint's embedded config becomes the session config
    /// (validated against the artifacts as usual); the complete training
    /// state — params, RNG streams, sampler cursors, estimator, scenario
    /// engine, decisions, history, clocks — is restored so the resumed run
    /// is bit-identical to the uninterrupted one
    /// (`rust/tests/checkpoint_resume.rs`).
    pub fn resume_from(mut self, path: impl Into<PathBuf>) -> Self {
        self.resume = Some(path.into());
        self
    }

    /// RNG seed override (fleet sampling, partitioning, init, strategies).
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// BS/MS control strategy.
    pub fn strategy(mut self, strategy: StrategyKind) -> Self {
        self.cfg.strategy = strategy;
        self
    }

    /// Data partition across devices.
    pub fn partition(mut self, partition: Partition) -> Self {
        self.cfg.partition = partition;
        self
    }

    /// Shorthand for the paper's non-IID shard partition.
    pub fn non_iid(self) -> Self {
        self.partition(Partition::NonIidShards)
    }

    /// Model kind (the default presets already pick the executable model).
    pub fn model(mut self, model: ModelKind) -> Self {
        self.cfg.model = model;
        self
    }

    /// Fixed per-device batch size used by the fixed-BS strategies.
    pub fn fixed_batch(mut self, batch: u32) -> Self {
        self.cfg.fixed_batch = batch;
        self
    }

    /// Fixed cut layer used by the fixed-MS strategies.
    pub fn fixed_cut(mut self, cut: usize) -> Self {
        self.cfg.fixed_cut = cut;
        self
    }

    /// Evaluate test accuracy every `n` rounds.
    pub fn eval_every(mut self, n: usize) -> Self {
        self.cfg.train.eval_every = n;
        self
    }

    /// Client-side aggregation interval I.
    pub fn agg_interval(mut self, n: usize) -> Self {
        self.cfg.train.agg_interval = n;
        self
    }

    /// Escape hatch for config fields without a dedicated setter.
    pub fn tune(mut self, f: impl FnOnce(&mut Config)) -> Self {
        f(&mut self.cfg);
        self
    }

    /// AOT-artifacts directory (default `artifacts`).
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = dir.into();
        self
    }

    /// Run rounds in concurrent-actor mode (one thread per device;
    /// numerics identical to sequential mode).
    pub fn concurrent(mut self, on: bool) -> Self {
        self.concurrent = on;
        self
    }

    /// PJRT engine-pool width: 0 = auto (fleet size capped by host
    /// parallelism), n = exactly n lanes. Width changes wall-clock only,
    /// never numerics (`rust/tests/parity_modes.rs`), so with
    /// [`ExperimentBuilder::resume_from`] it also overrides the
    /// checkpointed width.
    pub fn engine_pool(mut self, width: usize) -> Self {
        self.cfg.engine_pool = width;
        self.pool_override = Some(width);
        self
    }

    /// Execution backend (DESIGN.md §11): [`BackendKind::Native`] (pure
    /// Rust, runs anywhere), [`BackendKind::Pjrt`] (AOT artifacts through
    /// XLA), or [`BackendKind::Auto`] (PJRT when artifacts exist, native
    /// otherwise). Without an explicit choice the builder honours the
    /// `HASFL_BACKEND` environment variable, then falls back to auto. The
    /// *resolved* kind is stored in the session config, so checkpoints
    /// embed it and resumes stay on the producing backend.
    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.cfg.backend = kind;
        self.backend_override = Some(kind);
        self
    }

    /// Hierarchical aggregation topology (DESIGN.md §15): partition the
    /// fleet into cells, each running on its own engine-lane slice and
    /// producing a weighted partial aggregate that the root merges in
    /// fixed cell order. Numerics are bit-identical to the flat roster at
    /// any cell count (`rust/tests/cells_parity.rs`); cells change
    /// wall-clock shape and per-cell reporting only.
    pub fn topology(mut self, t: Topology) -> Self {
        self.cfg.topology = Some(t);
        self.topology_override = Some(t);
        self
    }

    /// [`ExperimentBuilder::topology`] shorthand: `n` contiguous cells
    /// (0 = auto: one cell per engine lane).
    pub fn cells(self, n: usize) -> Self {
        if n == 0 {
            return self.topology(Topology::auto());
        }
        self.topology(Topology::with_cells(n))
    }

    /// Attach a dynamic-fleet scenario (channel drift, churn, stragglers;
    /// see [`crate::scenario`]). Rounds then run over the evolving fleet:
    /// dropped devices are skipped with partial aggregation, and drift can
    /// trigger early BS/MS re-solves.
    pub fn scenario(mut self, scenario: Scenario) -> Self {
        self.cfg.scenario = Some(scenario);
        self
    }

    /// [`ExperimentBuilder::scenario`] from a named preset.
    pub fn scenario_preset(self, preset: ScenarioPreset) -> Self {
        self.scenario(preset.scenario())
    }

    /// Arm seeded fault injection + graceful degradation (see
    /// [`crate::fault`] and DESIGN.md §13). Devices that exhaust their
    /// retries are abandoned for the round (Eqn-39 partial aggregation
    /// over the survivors) instead of failing the run; crashed engine
    /// lanes are respawned and their in-flight job replayed.
    pub fn faults(mut self, spec: FaultSpec) -> Self {
        self.cfg.faults = Some(spec);
        self
    }

    /// [`ExperimentBuilder::faults`] from a named preset.
    pub fn faults_preset(self, preset: FaultPreset) -> Self {
        self.faults(preset.spec())
    }

    /// Buffered-asynchronous training (DESIGN.md §16, `docs/ASYNC.md`):
    /// devices submit split-training updates as they finish, and each
    /// "round" aggregates a staleness-weighted buffer of `k` updates
    /// instead of waiting for the synchronous barrier. The remaining
    /// knobs (`max_staleness`, `decay`) keep their defaults; use
    /// [`ExperimentBuilder::async_spec`] to set everything.
    pub fn async_buffer(self, k: usize) -> Self {
        self.async_spec(AsyncSpec { buffer_k: k, ..AsyncSpec::default() })
    }

    /// Full buffered-asynchrony spec: buffer size, staleness cap, and the
    /// polynomial staleness-decay exponent.
    pub fn async_spec(mut self, spec: AsyncSpec) -> Self {
        self.cfg.async_spec = Some(spec.clone());
        self.async_override = Some(spec);
        self
    }

    /// Attach a boxed observer. Observers are `Send` so a built
    /// [`Session`] can move into a worker thread (the serve daemon's
    /// session-worker pool does exactly that).
    pub fn observer(mut self, obs: Box<dyn Observer + Send>) -> Self {
        self.observers.push(obs);
        self
    }

    /// Attach an observer by value.
    pub fn observe(self, obs: impl Observer + Send + 'static) -> Self {
        self.observer(Box::new(obs))
    }

    /// Pure configuration checks that need no filesystem access.
    ///
    /// Error messages name the offending JSON config path
    /// (`fleet.n_devices`, `train.lr`, ...) so machine clients — the
    /// serve daemon turns these into HTTP 400 bodies — get an actionable
    /// pointer instead of a bare validation string.
    fn validate_config(cfg: &Config) -> crate::Result<()> {
        anyhow::ensure!(
            cfg.fleet.n_devices >= 1,
            "config field 'fleet.n_devices': fleet needs at least 1 device"
        );
        anyhow::ensure!(
            (cfg.fleet.n_devices as u64) < crate::runtime::BufKey::RESERVED_FLOOR,
            "config field 'fleet.n_devices': fleet of {} devices collides with the \
             reserved buffer-set ids (device indices must stay below {})",
            cfg.fleet.n_devices,
            crate::runtime::BufKey::RESERVED_FLOOR
        );
        cfg.fleet.validate().map_err(|e| anyhow::anyhow!("config section 'fleet': {e}"))?;
        cfg.server.validate().map_err(|e| anyhow::anyhow!("config section 'server': {e}"))?;
        anyhow::ensure!(
            cfg.train.rounds >= 1,
            "config field 'train.rounds': round budget must be >= 1"
        );
        anyhow::ensure!(
            cfg.train.eval_every >= 1,
            "config field 'train.eval_every': must be >= 1"
        );
        anyhow::ensure!(
            cfg.train.agg_interval >= 1,
            "config field 'train.agg_interval': must be >= 1"
        );
        anyhow::ensure!(cfg.train.batch_cap >= 1, "config field 'train.batch_cap': must be >= 1");
        anyhow::ensure!(
            cfg.train.lr.is_finite() && cfg.train.lr > 0.0,
            "config field 'train.lr': learning rate must be positive, got {}",
            cfg.train.lr
        );
        anyhow::ensure!(
            cfg.train.epsilon > 0.0,
            "config field 'train.epsilon': target epsilon must be positive, got {}",
            cfg.train.epsilon
        );
        anyhow::ensure!(
            cfg.train.train_samples >= cfg.fleet.n_devices,
            "config field 'train.train_samples': {} train samples cannot cover {} devices",
            cfg.train.train_samples,
            cfg.fleet.n_devices
        );
        anyhow::ensure!(
            cfg.fixed_cut >= 1,
            "config field 'fixed_cut': must be >= 1 (1-based layer index)"
        );
        anyhow::ensure!(
            cfg.fixed_batch >= 1 && cfg.fixed_batch <= cfg.train.batch_cap,
            "config field 'fixed_batch': {} outside 1..={}",
            cfg.fixed_batch,
            cfg.train.batch_cap
        );
        if let Some(s) = &cfg.scenario {
            s.validate(cfg.fleet.n_devices)
                .map_err(|e| anyhow::anyhow!("config section 'scenario': {e}"))?;
        }
        if let Some(f) = &cfg.faults {
            f.validate(cfg.fleet.n_devices)
                .map_err(|e| anyhow::anyhow!("config section 'faults': {e}"))?;
        }
        if let Some(a) = &cfg.async_spec {
            a.validate(cfg.fleet.n_devices)
                .map_err(|e| anyhow::anyhow!("config section 'async': {e}"))?;
        }
        Ok(())
    }

    /// Validate the configuration and return it *without* building a
    /// session. This is the entry point for analytic (latency-model /
    /// convergence-bound) studies that never execute the model.
    pub fn build_config(self) -> crate::Result<Config> {
        Self::validate_config(&self.cfg)?;
        Ok(self.cfg)
    }

    /// Resolve the effective backend for `cfg`: an explicit
    /// [`ExperimentBuilder::backend`] choice wins, then a concrete
    /// `cfg.backend` (e.g. from a loaded config file), then the
    /// `HASFL_BACKEND` environment variable, then auto — and `Auto`
    /// resolves against the artifacts directory.
    fn resolve_backend(&self, cfg: &Config) -> BackendKind {
        self.backend_override
            .or((cfg.backend != BackendKind::Auto).then_some(cfg.backend))
            .or_else(BackendKind::from_env)
            .unwrap_or(BackendKind::Auto)
            .resolve(&self.artifacts)
    }

    /// Checks against the manifest of the resolved backend (artifact
    /// compatibility + cut/bucket bounds). The native backend synthesizes
    /// its manifest in-process; PJRT loads `manifest.json` from disk.
    fn validate_against_manifest(cfg: &Config, artifacts: &Path) -> crate::Result<Manifest> {
        let manifest = match cfg.backend {
            BackendKind::Native => ModelSpec::splitcnn8(cfg.train.classes).manifest(),
            _ => {
                anyhow::ensure!(
                    artifacts.join("manifest.json").exists(),
                    "no AOT artifacts at '{}' (run `make artifacts`, or use the \
                     artifact-free native backend: --backend native)",
                    artifacts.display()
                );
                Manifest::load(artifacts)?
            }
        };
        anyhow::ensure!(
            manifest.num_classes == cfg.train.classes,
            "artifacts built for {} classes, config wants {}",
            manifest.num_classes,
            cfg.train.classes
        );
        anyhow::ensure!(
            manifest.valid_cuts.contains(&cfg.fixed_cut),
            "fixed_cut {} not an exported cut (valid: {:?})",
            cfg.fixed_cut,
            manifest.valid_cuts
        );
        anyhow::ensure!(
            cfg.fixed_batch <= manifest.max_bucket(),
            "fixed_batch {} exceeds max exported bucket {}",
            cfg.fixed_batch,
            manifest.max_bucket()
        );
        anyhow::ensure!(
            cfg.train.batch_cap <= manifest.max_bucket(),
            "batch_cap {} exceeds max exported bucket {}",
            cfg.train.batch_cap,
            manifest.max_bucket()
        );
        Ok(manifest)
    }

    /// Validate everything and build the training [`Session`].
    ///
    /// With [`ExperimentBuilder::resume_from`], the checkpoint is loaded
    /// and verified first (magic/version/checksum), its embedded config
    /// becomes the session config (round budget overridable via
    /// [`ExperimentBuilder::rounds`]), and the full training state is
    /// restored onto the freshly-built trainer.
    pub fn build(mut self) -> crate::Result<Session> {
        if let Some(path) = self.resume.take() {
            let state = crate::checkpoint::CheckpointState::load(&path)?;
            let json = crate::util::Json::parse(&state.config_json)?;
            let mut cfg = Config::from_json(&json).map_err(|e| {
                anyhow::anyhow!("checkpoint '{}': bad embedded config: {e}", path.display())
            })?;
            if let Some(rounds) = self.rounds_override {
                cfg.train.rounds = rounds;
            }
            if let Some(pool) = self.pool_override {
                cfg.engine_pool = pool;
            }
            // The embedded backend is authoritative: switching backends
            // changes numerics, which would silently break the
            // bit-identical-resume contract.
            anyhow::ensure!(
                self.backend_override.is_none(),
                "backend() conflicts with resume_from() (the checkpoint's embedded \
                 backend '{}' is authoritative; numerics differ across backends)",
                cfg.backend.as_str()
            );
            // Likewise the embedded topology: mid-run cell reshapes would
            // change per-cell reporting and lane affinity under the same
            // session id, so a resume keeps the producing topology.
            anyhow::ensure!(
                self.topology_override.is_none(),
                "topology()/cells() conflicts with resume_from() (the checkpoint's \
                 embedded topology is authoritative; resume, then reshape in a fresh run)"
            );
            // And the embedded async spec: the restored in-flight buffer
            // only replays bit-identically under the producing schedule.
            anyhow::ensure!(
                self.async_override.is_none(),
                "async_buffer()/async_spec() conflicts with resume_from() (the \
                 checkpoint's embedded async spec is authoritative; its in-flight \
                 buffer only replays under the producing schedule)"
            );
            // New checkpoints embed a concrete backend. Pre-backend
            // checkpoints load as `Auto` and all ran PJRT, so pin them to
            // PJRT outright — auto-resolving by artifact presence could
            // silently resume a PJRT run on native numerics.
            if cfg.backend == BackendKind::Auto {
                cfg.backend = BackendKind::Pjrt;
            }
            Self::validate_config(&cfg)?;
            anyhow::ensure!(
                cfg.model == ModelKind::Splitcnn8,
                "checkpointed model '{}' is analytic-only and cannot resume training",
                cfg.model.as_str()
            );
            Self::validate_against_manifest(&cfg, &self.artifacts)?;
            let mut trainer = Trainer::new(cfg, &self.artifacts)?;
            let round = state.round as usize;
            trainer
                .restore(state)
                .map_err(|e| anyhow::anyhow!("checkpoint '{}': {e}", path.display()))?;
            let mut session = Session::new(trainer, self.observers, self.concurrent);
            session.set_completed_rounds(round);
            session.notify_resumed();
            return Ok(session);
        }
        Self::validate_config(&self.cfg)?;
        anyhow::ensure!(
            self.cfg.model == ModelKind::Splitcnn8,
            "model '{}' is analytic-only; executable sessions train splitcnn8 \
             (use build_config() for latency-model studies)",
            self.cfg.model.as_str()
        );
        self.cfg.backend = self.resolve_backend(&self.cfg);
        Self::validate_against_manifest(&self.cfg, &self.artifacts)?;
        let trainer = Trainer::new(self.cfg, &self.artifacts)?;
        Ok(Session::new(trainer, self.observers, self.concurrent))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_parse_roundtrip() {
        for p in [Preset::Small, Preset::Figure, Preset::Table1] {
            assert_eq!(Preset::parse(p.as_str()).unwrap(), p);
        }
        assert!(Preset::parse("bogus").is_err());
    }

    #[test]
    fn table1_preset_is_executable() {
        assert_eq!(Preset::Table1.config().model, ModelKind::Splitcnn8);
        assert_eq!(Preset::Table1.config().fleet.n_devices, 20);
    }

    #[test]
    fn build_config_validates_without_artifacts() {
        // Analytic config path: no artifacts needed, model kind free.
        let cfg = Experiment::builder().config(Config::table1()).build_config().unwrap();
        assert_eq!(cfg.model, ModelKind::Vgg16);

        assert!(Experiment::builder().devices(0).build_config().is_err());
        assert!(Experiment::builder().rounds(0).build_config().is_err());
        assert!(Experiment::builder().fixed_batch(0).build_config().is_err());
        assert!(Experiment::builder()
            .tune(|c| c.train.lr = f64::NAN)
            .build_config()
            .is_err());
    }

    #[test]
    fn zero_rate_configs_are_rejected_up_front() {
        // Regression for the latency-kernel division guard (see
        // `config::FleetConfig::validate`).
        assert!(Experiment::builder()
            .tune(|c| c.fleet.up_bps = crate::config::Range::new(0.0, 1e6))
            .build_config()
            .is_err());
        assert!(Experiment::builder()
            .tune(|c| c.fleet.flops = crate::config::Range::new(1e9, f64::INFINITY))
            .build_config()
            .is_err());
        assert!(Experiment::builder()
            .tune(|c| c.server.to_fed_bps = 0.0)
            .build_config()
            .is_err());
    }

    #[cfg(target_pointer_width = "64")]
    #[test]
    fn astronomical_fleets_cannot_reach_reserved_buffer_sets() {
        // Device buffer-set ids are the device indices; the validator
        // refuses fleets that could collide with the reserved shared sets.
        let err = Experiment::builder().devices(usize::MAX).build_config().unwrap_err();
        assert!(err.to_string().contains("reserved buffer-set"), "{err}");
    }

    #[test]
    fn resume_from_missing_file_fails_fast() {
        let err = Experiment::builder()
            .resume_from("/nonexistent/dir/ckpt.hckpt")
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("cannot read checkpoint"), "{err}");
    }

    #[test]
    fn builder_accepts_and_validates_fault_specs() {
        let cfg = Experiment::builder()
            .faults_preset(FaultPreset::Flaky)
            .build_config()
            .unwrap();
        assert_eq!(cfg.faults.as_ref().unwrap().name, "flaky");

        // Out-of-roster device ids are rejected up front.
        let mut bad = FaultPreset::Chaos.spec();
        bad.kill = vec![999];
        let err = Experiment::builder().faults(bad).build_config().unwrap_err();
        assert!(err.to_string().contains("config section 'faults'"), "{err}");
    }

    #[test]
    fn builder_accepts_and_validates_scenarios() {
        let cfg = Experiment::builder()
            .scenario_preset(ScenarioPreset::ChurnHeavy)
            .build_config()
            .unwrap();
        assert_eq!(cfg.scenario.as_ref().unwrap().name, "churn-heavy");

        // Invalid scenario specs are rejected up front.
        let mut bad = ScenarioPreset::ChurnHeavy.scenario();
        bad.resolve_drift = Some(f64::NAN);
        assert!(Experiment::builder().scenario(bad).build_config().is_err());
    }

    #[test]
    fn builder_accepts_and_validates_async_specs() {
        let cfg = Experiment::builder().async_buffer(3).build_config().unwrap();
        let spec = cfg.async_spec.as_ref().unwrap();
        assert_eq!(spec.buffer_k, 3);
        assert_eq!(spec.max_staleness, AsyncSpec::default().max_staleness);

        // A buffer wider than the fleet can never fill: rejected up front
        // with the config-section pointer machine clients rely on.
        let err = Experiment::builder().devices(4).async_buffer(5).build_config().unwrap_err();
        assert!(err.to_string().contains("config section 'async'"), "{err}");
        assert!(Experiment::builder().async_buffer(0).build_config().is_err());
    }

    #[test]
    fn builder_setters_compose() {
        let cfg = Experiment::builder()
            .preset(Preset::Table1)
            .devices(6)
            .rounds(42)
            .seed(7)
            .strategy(StrategyKind::RbsRms)
            .non_iid()
            .fixed_batch(8)
            .fixed_cut(3)
            .eval_every(2)
            .agg_interval(3)
            .engine_pool(2)
            .cells(3)
            .tune(|c| c.train.epsilon = 0.4)
            .build_config()
            .unwrap();
        assert_eq!(cfg.fleet.n_devices, 6);
        assert_eq!(cfg.train.rounds, 42);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.strategy, StrategyKind::RbsRms);
        assert_eq!(cfg.partition, Partition::NonIidShards);
        assert_eq!(cfg.fixed_batch, 8);
        assert_eq!(cfg.fixed_cut, 3);
        assert_eq!(cfg.train.eval_every, 2);
        assert_eq!(cfg.train.agg_interval, 3);
        assert_eq!(cfg.engine_pool, 2);
        assert_eq!(cfg.topology, Some(Topology::with_cells(3)));
        assert!((cfg.train.epsilon - 0.4).abs() < 1e-12);
    }

    #[test]
    fn cells_zero_is_auto_topology() {
        let cfg = Experiment::builder().cells(0).build_config().unwrap();
        assert_eq!(cfg.topology, Some(Topology::auto()));
        // resolve_cells then tracks the pool width at session build time.
        assert_eq!(cfg.topology.unwrap().resolve_cells(4), 4);
    }
}
