//! The step-driven training session and its per-round report.

use std::path::{Path, PathBuf};

use crate::coordinator::{RoundOutcome, Trainer};
use crate::latency::{Decisions, RoundLatency};
use crate::metrics::{CellStats, History, Record};
use crate::runtime::EngineStats;
use crate::scenario::FleetSnapshot;

use super::Observer;

/// Everything that happened in one training round, in callback/driver
/// friendly form.
#[derive(Debug, Clone)]
pub struct RoundReport {
    /// 1-based round index.
    pub round: usize,
    /// Simulated wall-clock after this round (latency model).
    pub sim_time: f64,
    /// The round's training outcome (mean loss + train accuracy).
    pub outcome: RoundOutcome,
    /// Latency breakdown of this round (Eqns 28–39).
    pub latency: RoundLatency,
    /// Whether this was a client-side aggregation round (every I rounds).
    pub aggregated: bool,
    /// Whether BS/MS were re-optimized this round (Alg 1 line 24).
    pub reoptimized: bool,
    /// The decisions in force *after* this round (fresh ones when
    /// `reoptimized`, the current window's otherwise).
    pub decisions: Decisions,
    /// Test accuracy, present on evaluation rounds.
    pub test_acc: Option<f64>,
    /// The round's fleet snapshot (membership, effective rates, drift).
    /// Present only when the session runs under a dynamic scenario.
    pub fleet: Option<FleetSnapshot>,
    /// Devices the fault layer abandoned this round — every retry failed,
    /// the round carried on without them (empty without fault injection).
    pub abandoned: Vec<usize>,
    /// Devices quarantined by the fault layer as of this round
    /// (cumulative; empty without fault injection).
    pub quarantined: Vec<usize>,
    /// Per-cell round stats under a hierarchical topology, in fixed cell
    /// order (DESIGN.md §15). Empty on flat-roster runs.
    pub cells: Vec<CellStats>,
    /// Buffer/staleness stats of this flush under buffered-asynchronous
    /// mode (DESIGN.md §16). `None` on every synchronous run, so sync
    /// reports keep their historical byte layout.
    pub asynchrony: Option<crate::asynch::AsyncRoundStats>,
}

impl RoundReport {
    /// Machine-readable form of the report (the serve daemon's
    /// `/sessions/:id/reports` and NDJSON event-stream payload). Floats
    /// print in Rust's shortest round-trip form, so two bit-identical
    /// runs serialize to byte-identical JSON.
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        // Empty scenario rounds are NaN-marked (no fake 0.0 loss); JSON
        // has no NaN, so non-finite metrics serialize as null.
        fn num(v: f64) -> Json {
            if v.is_finite() {
                Json::Num(v)
            } else {
                Json::Null
            }
        }
        let mut dec = Json::obj();
        dec.set("batch", Json::Arr(self.decisions.batch.iter().map(|&b| Json::Num(b as f64)).collect()))
            .set("cut", Json::from_usizes(&self.decisions.cut));
        let mut j = Json::obj();
        j.set("round", Json::Num(self.round as f64))
            .set("sim_time", num(self.sim_time))
            .set("loss", num(self.outcome.mean_loss))
            .set("train_acc", num(self.outcome.train_acc))
            .set("participants", Json::Num(self.outcome.participants as f64))
            .set("t_split", Json::Num(self.latency.t_split))
            .set("t_agg", Json::Num(self.latency.t_agg))
            .set("aggregated", Json::Bool(self.aggregated))
            .set("reoptimized", Json::Bool(self.reoptimized))
            .set("decisions", dec);
        match self.test_acc {
            Some(a) => j.set("test_acc", Json::Num(a)),
            None => j.set("test_acc", Json::Null),
        };
        // The fleet block carries both the scenario snapshot and the
        // fault layer's casualty lists. Fault keys appear only when
        // non-empty, so scenario-only and fault-less reports keep their
        // historical byte layout.
        let faulted = !self.abandoned.is_empty() || !self.quarantined.is_empty();
        if self.fleet.is_some() || faulted {
            let mut f = Json::obj();
            if let Some(fleet) = &self.fleet {
                f.set("n_active", Json::Num(fleet.active.len() as f64))
                    .set("n_dropped", Json::Num(fleet.dropped.len() as f64))
                    .set("n_joined", Json::Num(fleet.joined.len() as f64))
                    .set("n_left", Json::Num(fleet.left.len() as f64))
                    .set("drift", Json::Num(fleet.drift));
            }
            if faulted {
                f.set("abandoned", Json::from_usizes(&self.abandoned))
                    .set("quarantined", Json::from_usizes(&self.quarantined));
            }
            j.set("fleet", f);
        }
        // The cells block appears only under a hierarchical topology, so
        // flat-roster reports keep their historical byte layout.
        if !self.cells.is_empty() {
            j.set("cells", Json::Arr(self.cells.iter().map(CellStats::to_json).collect()));
        }
        // The async block appears only under buffered-asynchronous mode,
        // so synchronous reports keep their historical byte layout.
        if let Some(a) = &self.asynchrony {
            j.set("async", a.to_json());
        }
        j
    }
}

/// A live training session over the execution engine (PJRT or native —
/// DESIGN.md §11).
///
/// Created by [`super::ExperimentBuilder::build`]. Call [`Session::step`]
/// until [`Session::is_done`] (or use the [`Session::run_to_completion`] /
/// [`Session::run_concurrent`] drivers), then [`Session::finish`] to flush
/// observers and shut the engine down.
pub struct Session {
    trainer: Trainer,
    observers: Vec<Box<dyn Observer + Send>>,
    round: usize,
    concurrent: bool,
}

impl Session {
    pub(super) fn new(
        trainer: Trainer,
        observers: Vec<Box<dyn Observer + Send>>,
        concurrent: bool,
    ) -> Session {
        Session { trainer, observers, round: 0, concurrent }
    }

    /// Start the round counter at `round` (the resume path: the restored
    /// trainer already holds that many completed rounds of state).
    pub(super) fn set_completed_rounds(&mut self, round: usize) {
        self.round = round;
    }

    /// Fire [`Observer::on_resume`] with the restored history so
    /// stateful observers (convergence windows, running maxima) rebuild
    /// their cross-round state.
    pub(super) fn notify_resumed(&mut self) {
        let history = self.trainer.history().clone();
        for obs in &mut self.observers {
            obs.on_resume(&history);
        }
    }

    /// Write a crash-safe checkpoint of the complete training state to
    /// `path` (serialize to a temp sibling, fsync, atomic rename — see
    /// [`crate::checkpoint`]). The file embeds the session config; resume
    /// with [`super::ExperimentBuilder::resume_from`], which reproduces
    /// the uninterrupted run bit-for-bit.
    pub fn checkpoint(&self, path: impl AsRef<Path>) -> crate::Result<()> {
        let state = self.trainer.capture(self.round);
        if self.trainer.tear_checkpoint(self.round) {
            // Injected torn write (`crate::fault`): land a truncated file
            // at the final path, bypassing the temp+rename dance — models
            // a machine that died mid-write or a partial copy. Loaders
            // must reject it loudly (`CheckpointState::from_bytes`).
            let bytes = state.to_bytes();
            std::fs::write(path.as_ref(), &bytes[..bytes.len() * 2 / 3])?;
            return Ok(());
        }
        state.save(path.as_ref())
    }

    /// Rounds completed so far.
    pub fn round(&self) -> usize {
        self.round
    }

    /// Whether the configured round budget is exhausted.
    pub fn is_done(&self) -> bool {
        self.round >= self.trainer.cfg().train.rounds
    }

    /// Toggle concurrent-actor rounds (numerics identical either way).
    pub fn set_concurrent(&mut self, on: bool) {
        self.concurrent = on;
    }

    /// The experiment configuration.
    pub fn config(&self) -> &crate::config::Config {
        self.trainer.cfg()
    }

    /// Accumulated run history.
    pub fn history(&self) -> &History {
        self.trainer.history()
    }

    /// The decisions currently in force.
    pub fn decisions(&self) -> &Decisions {
        self.trainer.decisions()
    }

    /// Simulated wall-clock so far.
    pub fn sim_time(&self) -> f64 {
        self.trainer.sim_time()
    }

    /// Latency breakdown of a round under the current decisions.
    pub fn current_latency(&self) -> RoundLatency {
        self.trainer.current_round_latency()
    }

    /// Read access to the underlying trainer (estimator, manifest,
    /// bound parameters, ...).
    pub fn trainer(&self) -> &Trainer {
        &self.trainer
    }

    /// Evaluate test accuracy of the averaged global model right now
    /// (off-schedule; scheduled evals happen inside [`Session::step`]).
    pub fn evaluate_now(&mut self) -> crate::Result<f64> {
        self.trainer.evaluate()
    }

    /// Engine-side execution statistics (merged across pool lanes).
    pub fn engine_stats(&self) -> crate::Result<EngineStats> {
        self.trainer.engine().stats_blocking()
    }

    /// Width of the engine pool backing this session.
    pub fn engine_width(&self) -> usize {
        self.trainer.engine().width()
    }

    /// Advance one training round: steps a1–a5 on every device, post-round
    /// aggregation/re-optimization bookkeeping, scheduled evaluation, and
    /// history record — exactly the historical `Trainer::run()` body, one
    /// iteration at a time.
    pub fn step(&mut self) -> crate::Result<RoundReport> {
        let t = self.round + 1;
        // Buffered-asynchronous sessions step one buffer *flush* per
        // round (DESIGN.md §16); the flush executes devices sequentially
        // in seeded completion order, so `concurrent` changes nothing —
        // pool-width invariance is part of the determinism contract.
        let (outcome, asynchrony) = if self.trainer.cfg().async_spec.is_some() {
            let (outcome, stats) = self.trainer.run_round_async()?;
            (outcome, Some(stats))
        } else if self.concurrent {
            (self.trainer.run_round_concurrent()?, None)
        } else {
            (self.trainer.run_round()?, None)
        };
        let post = match &asynchrony {
            Some(stats) => self.trainer.post_round_async(t, stats)?,
            None => self.trainer.post_round(t)?,
        };
        let test_acc = if t % self.trainer.cfg().train.eval_every == 0 {
            Some(self.trainer.evaluate()?)
        } else {
            None
        };
        self.trainer.push_record(Record {
            round: t,
            sim_time: self.trainer.sim_time(),
            loss: outcome.mean_loss,
            test_acc,
        });
        self.round = t;

        let report = RoundReport {
            round: t,
            sim_time: self.trainer.sim_time(),
            outcome,
            latency: post.latency,
            aggregated: post.aggregated,
            reoptimized: post.reoptimized,
            decisions: self.trainer.decisions().clone(),
            test_acc,
            fleet: self.trainer.take_snapshot(),
            abandoned: self.trainer.last_abandoned().to_vec(),
            quarantined: self.trainer.quarantined_devices(),
            cells: post.cells,
            asynchrony,
        };
        for obs in &mut self.observers {
            obs.on_round(&report);
            if let Some(snapshot) = &report.fleet {
                obs.on_fleet(&report, snapshot);
            }
            if report.aggregated {
                obs.on_aggregation(&report);
            }
            if report.reoptimized {
                obs.on_reoptimize(&report, &report.decisions);
            }
            if let Some(acc) = report.test_acc {
                obs.on_eval(&report, acc);
            }
        }

        // Checkpoint requests fire last, after every observer booked the
        // round, so the captured state is the complete between-rounds
        // state (collect first: writing borrows the trainer).
        let mut requests: Vec<PathBuf> = Vec::new();
        for obs in self.observers.iter_mut() {
            if let Some(path) = obs.checkpoint_request(&report) {
                requests.push(path);
            }
        }
        for path in requests {
            self.checkpoint(&path)?;
            // Every observer hears about every write, not just the one
            // that asked: event bridges forward checkpoint announcements
            // without being the retention manager themselves.
            for obs in self.observers.iter_mut() {
                obs.on_checkpoint(&report, &path);
            }
        }
        Ok(report)
    }

    /// Whether any observer requested an early stop.
    pub fn stop_requested(&self) -> bool {
        self.observers.iter().any(|o| o.should_stop())
    }

    /// Run sequential rounds until the budget is exhausted or an observer
    /// requests a stop.
    pub fn run_to_completion(&mut self) -> crate::Result<()> {
        while !self.is_done() {
            self.step()?;
            if self.stop_requested() {
                break;
            }
        }
        Ok(())
    }

    /// [`Session::run_to_completion`] in concurrent-actor mode.
    pub fn run_concurrent(&mut self) -> crate::Result<()> {
        self.set_concurrent(true);
        self.run_to_completion()
    }

    /// Flush observers (`on_complete`), shut the engine down, and return
    /// the run history. Every observer gets to flush and the engine is
    /// stopped even when an earlier observer errors; the first error is
    /// reported.
    pub fn finish(mut self) -> crate::Result<History> {
        let history = self.trainer.take_history();
        let mut first_err = None;
        for obs in &mut self.observers {
            if let Err(e) = obs.on_complete(&history) {
                first_err.get_or_insert(e);
            }
        }
        self.trainer.engine().shutdown();
        match first_err {
            Some(e) => Err(e),
            None => Ok(history),
        }
    }
}

#[cfg(test)]
mod tests {
    /// The serve daemon moves sessions between worker-pool threads; this
    /// pins the `Send` bound at compile time (observers are
    /// `Box<dyn Observer + Send>`, every other field is owned data or
    /// channel senders).
    #[test]
    fn session_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<super::Session>();
        assert_send::<super::super::SessionDriver>();
    }
}
