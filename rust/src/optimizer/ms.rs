//! The MS sub-problem P2 (Eqn 53): choose per-device cut layers μ given
//! fixed batch sizes.
//!
//! The paper solves P2 as a mixed-integer linear fractional program with the
//! Dinkelbach algorithm. We provide three solvers over the *exact* Θ′
//! objective (latency model + convergence bound evaluated directly, which
//! subsumes the auxiliary T variables — they are tight at the optimum):
//!
//! - [`solve_exhaustive`]: full L^N enumeration, exact; used for small N and
//!   as the test oracle that certifies the other two.
//! - [`solve_bcd`]: multi-start block-coordinate descent over devices; each
//!   device picks the argmin cut given the others. Scales to N=20+.
//! - [`solve_dinkelbach`]: the paper's parametric-fractional iteration with
//!   a BCD inner solver on F(q) = min_μ [Num(μ) − q·Den(μ)].

use super::OptContext;
use crate::latency::{round_latency, Decisions};
use crate::rng::Pcg32;

/// Exact exhaustive enumeration over all cut assignments (L^N). Panics if
/// the search space exceeds `max_space` to protect callers.
pub fn solve_exhaustive(ctx: &OptContext, batch: &[u32], max_space: u64) -> Option<Vec<usize>> {
    let n = ctx.n();
    let cuts = &ctx.profile.valid_cuts;
    let space = (cuts.len() as u64).checked_pow(n as u32)?;
    assert!(space <= max_space, "exhaustive MS space {space} > {max_space}");

    let mut idx = vec![0usize; n];
    let mut best: Option<(f64, Vec<usize>)> = None;
    loop {
        let assignment: Vec<usize> = idx.iter().map(|&k| cuts[k]).collect();
        let dec = Decisions { batch: batch.to_vec(), cut: assignment.clone() };
        if let Some(v) = ctx.objective(&dec) {
            if best.as_ref().map_or(true, |(bv, _)| v < *bv) {
                best = Some((v, assignment));
            }
        }
        let mut carry = true;
        for slot in idx.iter_mut() {
            if carry {
                *slot += 1;
                if *slot == cuts.len() {
                    *slot = 0;
                } else {
                    carry = false;
                }
            }
        }
        if carry {
            break;
        }
    }
    best.map(|(_, a)| a)
}

/// One BCD pass: every device greedily re-picks its cut. Returns whether
/// anything changed.
fn bcd_sweep(ctx: &OptContext, batch: &[u32], cut: &mut Vec<usize>) -> bool {
    let mut changed = false;
    for i in 0..ctx.n() {
        let mut best_cut = cut[i];
        let mut best_val = {
            let dec = Decisions { batch: batch.to_vec(), cut: cut.clone() };
            ctx.objective(&dec).unwrap_or(f64::INFINITY)
        };
        for &c in &ctx.profile.valid_cuts {
            if c == cut[i] {
                continue;
            }
            let mut trial = cut.clone();
            trial[i] = c;
            let dec = Decisions { batch: batch.to_vec(), cut: trial };
            if let Some(v) = ctx.objective(&dec) {
                if v < best_val {
                    best_val = v;
                    best_cut = c;
                }
            }
        }
        if best_cut != cut[i] {
            cut[i] = best_cut;
            changed = true;
        }
    }
    changed
}

/// Multi-start BCD over the exact objective.
///
/// The objective couples devices through phase *maxima* (T3/T4 in P″) and
/// through L_c = max_i c_i, so single-device moves from a uniform
/// assignment often sit on a plateau (changing one device does not move
/// the max). Multi-start handles this: every *uniform* cut assignment is
/// used as a start (coordinated moves come for free), plus a
/// latency-greedy start and `n_starts` random restarts.
pub fn solve_bcd(
    ctx: &OptContext,
    batch: &[u32],
    rng: &mut Pcg32,
    n_starts: usize,
) -> Vec<usize> {
    let n = ctx.n();
    let cuts = &ctx.profile.valid_cuts;
    let mut global_best: Option<(f64, Vec<usize>)> = None;

    let mut starts: Vec<Vec<usize>> = cuts.iter().map(|&c| vec![c; n]).collect();
    starts.push((0..n).map(|i| greedy_latency_cut(ctx, i, batch[i])).collect());
    for _ in 0..n_starts {
        starts.push(
            (0..n)
                .map(|_| cuts[rng.below(cuts.len() as u32) as usize])
                .collect(),
        );
    }

    for mut cut in starts {
        for _ in 0..64 {
            if !bcd_sweep(ctx, batch, &mut cut) {
                break;
            }
        }
        let dec = Decisions { batch: batch.to_vec(), cut: cut.clone() };
        if let Some(v) = ctx.objective(&dec) {
            if global_best.as_ref().map_or(true, |(bv, _)| v < *bv) {
                global_best = Some((v, cut));
            }
        }
    }
    global_best
        .map(|(_, c)| c)
        .unwrap_or_else(|| vec![ctx.profile.valid_cuts[0]; n])
}

/// Per-device latency-greedy cut (ignores convergence): minimizes
/// b_i(rho_c/f_i + 8psi_c/r_up + 8chi_c/r_down + varpi_c/f_i). This is also
/// the RBS+RHAMS benchmark's MS rule [55].
pub fn greedy_latency_cut(ctx: &OptContext, i: usize, b: u32) -> usize {
    let p = ctx.profile;
    let d = &ctx.devices[i];
    let feasible = ctx.feasible_cuts(i, b);
    let candidates = if feasible.is_empty() { p.valid_cuts.clone() } else { feasible };
    *candidates
        .iter()
        .min_by(|&&c1, &&c2| {
            let cost = |c: usize| {
                b as f64
                    * (p.rho(c) / d.flops
                        + 8.0 * p.psi(c) / d.up_bps
                        + 8.0 * p.chi(c) / d.down_bps
                        + p.varpi(c) / d.flops)
            };
            cost(c1).partial_cmp(&cost(c2)).unwrap()
        })
        .unwrap()
}

/// Numerator of the fractional objective: 2ϑ (T_S + T_A/I).
fn numerator(ctx: &OptContext, dec: &Decisions) -> f64 {
    let lat = round_latency(ctx.profile, ctx.devices, ctx.server, dec);
    2.0 * ctx.bound.theta0 * (lat.t_split + lat.t_agg / ctx.interval.max(1) as f64)
}

/// Denominator: γ (ε − variance − drift). May be <= 0 (infeasible μ).
fn denominator(ctx: &OptContext, dec: &Decisions) -> f64 {
    ctx.bound.gamma
        * (ctx.epsilon
            - crate::convergence::variance_term(ctx.bound, &dec.batch)
            - crate::convergence::drift_term(ctx.bound, dec.l_c(), ctx.interval))
}

/// Dinkelbach iteration: q_{k+1} = Num(μ_k)/Den(μ_k) where μ_k minimizes the
/// parametric objective Num(μ) − q_k Den(μ) (inner solve: BCD). Converges
/// when F(q) = min Num − q Den ≈ 0.
pub fn solve_dinkelbach(ctx: &OptContext, batch: &[u32], rng: &mut Pcg32) -> Vec<usize> {
    let n = ctx.n();
    let cuts = &ctx.profile.valid_cuts;

    let parametric = |dec: &Decisions, q: f64| -> f64 {
        let den = denominator(ctx, dec);
        if den <= 0.0 || !crate::convergence::memory_feasible(ctx.profile, ctx.devices, dec) {
            return f64::INFINITY;
        }
        numerator(ctx, dec) - q * den
    };

    // Initial assignment: warm-start from a cheap BCD solve (the Dinkelbach
    // iteration then certifies/raises it on the fractional structure).
    let mut cut: Vec<usize> = solve_bcd(ctx, batch, rng, 2);
    let init = Decisions { batch: batch.to_vec(), cut: cut.clone() };
    let mut q = match ctx.objective(&init) {
        Some(v) => v,
        None => return cut,
    };

    for _ in 0..32 {
        // Inner BCD on the parametric objective.
        let mut changed = true;
        let mut guard = 0;
        while changed && guard < 64 {
            changed = false;
            guard += 1;
            for i in 0..n {
                let mut best_c = cut[i];
                let mut best_v = parametric(
                    &Decisions { batch: batch.to_vec(), cut: cut.clone() },
                    q,
                );
                for &c in cuts {
                    if c == cut[i] {
                        continue;
                    }
                    let mut trial = cut.clone();
                    trial[i] = c;
                    let v = parametric(&Decisions { batch: batch.to_vec(), cut: trial }, q);
                    if v < best_v {
                        best_v = v;
                        best_c = c;
                    }
                }
                if best_c != cut[i] {
                    cut[i] = best_c;
                    changed = true;
                }
            }
        }
        let dec = Decisions { batch: batch.to_vec(), cut: cut.clone() };
        let num = numerator(ctx, &dec);
        let den = denominator(ctx, &dec);
        if den <= 0.0 {
            break;
        }
        let f_q = num - q * den;
        let q_next = num / den;
        if f_q.abs() < 1e-9 * num.abs().max(1.0) || (q_next - q).abs() < 1e-9 * q.abs() {
            q = q_next;
            break;
        }
        q = q_next;
    }
    let _ = q;
    cut
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::testutil::Fixture;

    #[test]
    fn bcd_matches_exhaustive_on_small_instances() {
        for seed in [1u64, 7, 23] {
            let mut fx = Fixture::table1(3);
            fx.cfg.seed = seed;
            fx.devices = fx.cfg.sample_fleet();
            let ctx = fx.ctx();
            let batch = vec![16u32; 3];
            let oracle = solve_exhaustive(&ctx, &batch, 100_000).unwrap();
            let mut rng = Pcg32::seeded(seed);
            let bcd = solve_bcd(&ctx, &batch, &mut rng, 6);
            let vo = ctx
                .objective(&Decisions { batch: batch.clone(), cut: oracle.clone() })
                .unwrap();
            let vb = ctx
                .objective(&Decisions { batch: batch.clone(), cut: bcd.clone() })
                .unwrap();
            assert!(vb <= vo * 1.001, "seed {seed}: bcd {vb} oracle {vo}");
        }
    }

    #[test]
    fn dinkelbach_matches_exhaustive_on_small_instances() {
        let fx = Fixture::table1(3);
        let ctx = fx.ctx();
        let batch = vec![16u32; 3];
        let oracle = solve_exhaustive(&ctx, &batch, 100_000).unwrap();
        let mut rng = Pcg32::seeded(5);
        let dk = solve_dinkelbach(&ctx, &batch, &mut rng);
        let vo = ctx
            .objective(&Decisions { batch: batch.clone(), cut: oracle })
            .unwrap();
        let vd = ctx
            .objective(&Decisions { batch: batch.clone(), cut: dk })
            .unwrap();
        assert!(vd <= vo * 1.02, "dinkelbach {vd} oracle {vo}");
    }

    #[test]
    fn solved_cuts_prefer_shallow_on_slow_devices() {
        // A very weak device should not be assigned a deep cut: its client
        // compute would dominate the straggler max.
        let mut fx = Fixture::table1(4);
        fx.devices[2].flops = 1e10; // 100x weaker
        let ctx = fx.ctx();
        let batch = vec![16u32; 4];
        let mut rng = Pcg32::seeded(3);
        let cuts = solve_bcd(&ctx, &batch, &mut rng, 6);
        assert!(
            cuts[2] <= *cuts.iter().max().unwrap(),
            "weak device got the deepest cut: {cuts:?}"
        );
    }

    #[test]
    fn greedy_latency_cut_is_feasible() {
        let fx = Fixture::table1(4);
        let ctx = fx.ctx();
        for i in 0..4 {
            let c = greedy_latency_cut(&ctx, i, 16);
            assert!(ctx.profile.valid_cuts.contains(&c));
        }
    }

    #[test]
    fn exhaustive_none_when_all_infeasible() {
        let mut fx = Fixture::table1(2);
        for d in fx.devices.iter_mut() {
            d.mem_bytes = 1.0; // nothing fits
        }
        let ctx = fx.ctx();
        assert!(solve_exhaustive(&ctx, &[16, 16], 10_000).is_none());
    }
}
