//! Algorithm 2: the outer block-coordinate descent alternating the BS and
//! MS sub-problems until the Θ′ objective stabilises.

use super::bs::BsSubproblem;
use super::{ms, OptContext};
use crate::latency::Decisions;
use crate::rng::Pcg32;

/// Result of the joint optimization.
#[derive(Debug, Clone)]
pub struct JointSolution {
    /// Optimized per-device batch sizes and the shared cut layer.
    pub decisions: Decisions,
    /// Final Θ′ value (estimated seconds to epsilon-convergence).
    pub theta: f64,
    /// Outer BCD iterations used.
    pub iterations: usize,
}

/// Solve the joint BS+MS problem (Algorithm 2).
///
/// Alternates: (1) BS sub-problem via Newton–Jacobi + Proposition-1
/// discretization at the incumbent cuts, (2) MS sub-problem via multi-start
/// BCD (with a Dinkelbach polish) at the incumbent batches. Terminates when
/// |Θ′ improvement| <= `tol` (relative) or `max_iters` outer iterations.
pub fn solve_joint(ctx: &OptContext, rng: &mut Pcg32, max_iters: usize, tol: f64) -> JointSolution {
    let n = ctx.n();
    // Initial point: the best *uniform* (b, cut) grid point. Cheap
    // (|buckets| x L objective evaluations) and guarantees HASFL never
    // loses to a uniform configuration — the alternation only improves
    // from here.
    let mut dec = Decisions {
        batch: vec![16.min(ctx.batch_cap); n],
        cut: vec![ctx.profile.valid_cuts[0]; n],
    };
    let mut theta = ctx.objective(&dec).unwrap_or(f64::INFINITY);
    let mut b = 1u32;
    while b <= ctx.batch_cap {
        for &c in &ctx.profile.valid_cuts {
            let trial = Decisions::uniform(n, b, c);
            if let Some(v) = ctx.objective(&trial) {
                if v < theta {
                    theta = v;
                    dec = trial;
                }
            }
        }
        b *= 2;
    }
    let mut iterations = 0;

    for it in 0..max_iters {
        iterations = it + 1;

        // --- BS sub-problem (P1) at incumbent cuts ----------------------
        let sp = BsSubproblem::from_context(ctx, &dec);
        let batch = sp.solve();
        let trial = Decisions { batch: batch.clone(), cut: dec.cut.clone() };
        if let Some(v) = ctx.objective(&trial) {
            if v < theta {
                dec = trial;
                theta = v;
            }
        }

        // --- MS sub-problem (P2) at incumbent batches -------------------
        let cuts = ms::solve_bcd(ctx, &dec.batch, rng, 4);
        let trial = Decisions { batch: dec.batch.clone(), cut: cuts };
        let mut improved = false;
        if let Some(v) = ctx.objective(&trial) {
            if v < theta {
                dec = trial;
                theta = v;
                improved = true;
            }
        }
        // Dinkelbach polish on the MS block.
        let cuts = ms::solve_dinkelbach(ctx, &dec.batch, rng);
        let trial = Decisions { batch: dec.batch.clone(), cut: cuts };
        if let Some(v) = ctx.objective(&trial) {
            if v < theta * (1.0 - 1e-12) {
                dec = trial;
                theta = v;
                improved = true;
            }
        }

        // Convergence check on the outer loop.
        if !improved && it > 0 {
            break;
        }
        let prev = theta;
        if it > 0 && (prev - theta).abs() <= tol * prev.abs().max(1e-12) && !improved {
            break;
        }
    }

    JointSolution { decisions: dec, theta, iterations }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::testutil::Fixture;

    #[test]
    fn joint_solution_is_feasible_and_finite() {
        let fx = Fixture::table1(8);
        let ctx = fx.ctx();
        let mut rng = Pcg32::seeded(11);
        let sol = solve_joint(&ctx, &mut rng, 8, 1e-6);
        assert!(sol.theta.is_finite());
        assert_eq!(sol.decisions.n(), 8);
        assert!(ctx.objective(&sol.decisions).is_some());
        for &b in &sol.decisions.batch {
            assert!((1..=ctx.batch_cap).contains(&b));
        }
    }

    #[test]
    fn joint_beats_uniform_baselines() {
        let fx = Fixture::table1(10);
        let ctx = fx.ctx();
        let mut rng = Pcg32::seeded(3);
        let sol = solve_joint(&ctx, &mut rng, 8, 1e-6);
        // HASFL must beat every uniform (b, cut) grid point — this is the
        // paper's core claim in miniature.
        for b in [4u32, 16, 64] {
            for &c in &[2usize, 6, 10] {
                let dec = Decisions::uniform(10, b, c);
                if let Some(v) = ctx.objective(&dec) {
                    assert!(
                        sol.theta <= v * 1.0001,
                        "uniform b={b} cut={c} ({v}) beats HASFL ({})",
                        sol.theta
                    );
                }
            }
        }
    }

    #[test]
    fn stragglers_get_smaller_batches() {
        // Insight 1: a weaker client takes a smaller batch.
        let mut fx = Fixture::table1(6);
        fx.devices[0].flops = 1e11; // 10-20x weaker than the rest
        fx.devices[0].up_bps = 10e6; // and a much slower uplink
        let ctx = fx.ctx();
        let mut rng = Pcg32::seeded(9);
        let sol = solve_joint(&ctx, &mut rng, 8, 1e-6);
        let b0 = sol.decisions.batch[0];
        let others: f64 = sol.decisions.batch[1..]
            .iter()
            .map(|&b| b as f64)
            .sum::<f64>()
            / 5.0;
        assert!(
            (b0 as f64) <= others,
            "straggler batch {b0} > mean of others {others}"
        );
    }

    #[test]
    fn terminates_within_max_iters() {
        let fx = Fixture::table1(5);
        let ctx = fx.ctx();
        let mut rng = Pcg32::seeded(2);
        let sol = solve_joint(&ctx, &mut rng, 5, 1e-9);
        assert!(sol.iterations <= 5);
    }
}
