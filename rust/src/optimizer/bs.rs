//! The BS sub-problem P1 (Eqn 46) and its solution (Proposition 1).
//!
//! Following the paper's proof of Proposition 1, with MS and the auxiliary
//! variables T fixed the objective reduces to
//!
//!   Θ′(b) = 2ϑ (Σ_i b_i C_i + D) / (γ (A − Σ_i B / b_i))
//!
//! with A = ε − 1{I>1} 4β²γ²I² T₁, B = βγ Σ_j σ_j² / N², C_i the per-sample
//! server compute time of device i's tail, and D the fixed latency terms
//! T₃ + T₄ + (T₅+T₆)/I. Setting ∂Θ′/∂b_i = 0 and clearing denominators
//! yields the per-coordinate quadratic
//!
//!   C_i (A − S_b^{(i)}) b_i² − 2 B C_i b_i − B (S_c^{(i)} + D) = 0
//!
//! (S_b^{(i)} = Σ_{k≠i} B/b_k, S_c^{(i)} = Σ_{k≠i} b_k C_k), whose positive
//! root gives the Newton–Jacobi fixed-point update. The continuous solution
//! is then discretized by Eqn 48 with the caps κ_i from C4/R3/R4.

use super::OptContext;
use crate::latency::Decisions;

/// The reduced BS sub-problem.
#[derive(Debug, Clone)]
pub struct BsSubproblem {
    /// A = ε − drift(L_c, I).
    pub a: f64,
    /// B = βγ Σ_j σ_j² / N².
    pub b_const: f64,
    /// C_i — per-sample server compute latency of device i's tail.
    pub c: Vec<f64>,
    /// D — fixed latency terms (T₃ + T₄ + (T₅+T₆)/I at the incumbent).
    pub d: f64,
    /// κ_i — per-device upper caps from C4 / R3 / R4 / batch cap.
    pub kappa: Vec<f64>,
}

impl BsSubproblem {
    /// Build the sub-problem from the full context at incumbent decisions.
    /// The T-values are taken at the incumbent (the BCD outer loop refreshes
    /// them each iteration, mirroring Algorithm 2).
    pub fn from_context(ctx: &OptContext, incumbent: &Decisions) -> BsSubproblem {
        let p = ctx.profile;
        let bp = ctx.bound;
        let n = ctx.n() as f64;
        let l_c = incumbent.l_c();

        let a = ctx.epsilon - crate::convergence::drift_term(bp, l_c, ctx.interval);
        let b_const = bp.beta * bp.gamma * bp.sigma_sum() / (n * n);

        let c: Vec<f64> = incumbent
            .cut
            .iter()
            .map(|&ci| {
                (p.rho_total() - p.rho(ci) + p.varpi_total() - p.varpi(ci)) / ctx.server.flops
            })
            .collect();

        // Incumbent T3/T4 (device-phase maxima) and T5/T6 (aggregation).
        let lat = crate::latency::round_latency(p, ctx.devices, ctx.server, incumbent);
        let t3 = lat
            .per_device
            .iter()
            .map(|l| l.client_fwd + l.act_up)
            .fold(0.0, f64::max);
        let t4 = lat
            .per_device
            .iter()
            .map(|l| l.grad_down + l.client_bwd)
            .fold(0.0, f64::max);
        let t56 = lat.t_agg;
        let d = t3 + t4 + t56 / ctx.interval.max(1) as f64;

        // Caps κ_i = min{memory cap, T3 cap, T4 cap, batch cap}.
        let kappa: Vec<f64> = ctx
            .devices
            .iter()
            .enumerate()
            .map(|(i, dev)| {
                let cut = incumbent.cut[i];
                // C4: b (psi~ + chi~) + delta < v  =>  b < (v - delta)/(2 psi~)
                let mem_cap = {
                    let denom = 2.0 * p.psi_tilde(cut);
                    if denom > 0.0 {
                        ((dev.mem_bytes - p.delta(cut)) / denom).max(1.0)
                    } else {
                        f64::INFINITY
                    }
                };
                // R3: b (rho_c/f_i + 8 psi_c / r_up) <= T3
                let per_sample_up = p.rho(cut) / dev.flops + 8.0 * p.psi(cut) / dev.up_bps;
                let t3_cap = if per_sample_up > 0.0 { t3 / per_sample_up } else { f64::INFINITY };
                // R4: b (8 chi_c / r_down + varpi_c/f_i) <= T4
                let per_sample_down =
                    8.0 * p.chi(cut) / dev.down_bps + p.varpi(cut) / dev.flops;
                let t4_cap =
                    if per_sample_down > 0.0 { t4 / per_sample_down } else { f64::INFINITY };
                mem_cap.min(t3_cap).min(t4_cap).min(ctx.batch_cap as f64)
            })
            .collect();

        BsSubproblem { a, b_const, c, d, kappa }
    }

    /// Number of devices in the subproblem.
    pub fn n(&self) -> usize {
        self.c.len()
    }

    /// The reduced objective Θ′(b) up to the constant factor 2ϑ/γ
    /// (which does not affect the argmin). Returns +inf when infeasible.
    pub fn objective(&self, b: &[f64]) -> f64 {
        let num: f64 = b.iter().zip(&self.c).map(|(&bi, &ci)| bi * ci).sum::<f64>() + self.d;
        let den = self.a - b.iter().map(|&bi| self.b_const / bi.max(1e-12)).sum::<f64>();
        if den <= 0.0 {
            f64::INFINITY
        } else {
            num / den
        }
    }

    /// One Jacobi sweep: update each coordinate to the positive root of its
    /// first-order quadratic, holding the others fixed.
    fn jacobi_sweep(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        let sum_inv: f64 = b.iter().map(|&bi| self.b_const / bi).sum();
        let sum_bc: f64 = b.iter().zip(&self.c).map(|(&bi, &ci)| bi * ci).sum();
        (0..n)
            .map(|i| {
                let s_b = sum_inv - self.b_const / b[i];
                let s_c = sum_bc - b[i] * self.c[i];
                let a_eff = self.a - s_b;
                if a_eff <= 0.0 || self.c[i] <= 0.0 {
                    // Infeasible given others / zero server tail: push to cap.
                    return self.kappa[i].max(1.0);
                }
                let bb = self.b_const;
                // C (A - S_b) x^2 - 2 B C x - B (S_c + D) = 0
                // x = [B + sqrt(B^2 + (A - S_b) B (S_c + D) / C)] / (A - S_b)
                let disc = bb * bb + a_eff * bb * (s_c + self.d) / self.c[i];
                (bb + disc.sqrt()) / a_eff
            })
            .collect()
    }

    /// Newton–Jacobi fixed-point iteration to the continuous optimum b̂.
    pub fn newton_jacobi(&self, max_iters: usize, tol: f64) -> Vec<f64> {
        let mut b: Vec<f64> = self.kappa.iter().map(|&k| k.clamp(1.0, 16.0)).collect();
        for _ in 0..max_iters {
            let next = self.jacobi_sweep(&b);
            let delta: f64 = next
                .iter()
                .zip(&b)
                .map(|(a, c)| (a - c).abs())
                .fold(0.0, f64::max);
            b = next;
            if delta < tol {
                break;
            }
        }
        b
    }

    /// Proposition 1 / Eqn 48: discretize the continuous solution.
    pub fn discretize(&self, b_hat: &[f64]) -> Vec<u32> {
        let mut out: Vec<u32> = b_hat
            .iter()
            .zip(&self.kappa)
            .map(|(&bh, &k)| {
                if bh <= 1.0 {
                    1
                } else if bh >= k {
                    (k.floor().max(1.0)) as u32
                } else {
                    0 // placeholder: resolved by the floor/ceil comparison below
                }
            })
            .collect();
        // argmin over {floor, ceil} for interior coordinates, holding the
        // other coordinates at their current integer/continuous values.
        let mut bf: Vec<f64> = b_hat.to_vec();
        for i in 0..out.len() {
            if out[i] != 0 {
                bf[i] = out[i] as f64;
                continue;
            }
            let lo = b_hat[i].floor().max(1.0);
            let hi = (b_hat[i].ceil()).min(self.kappa[i].floor().max(1.0));
            let mut best = lo;
            let mut best_val = f64::INFINITY;
            for cand in [lo, hi] {
                bf[i] = cand;
                let v = self.objective(&bf);
                if v < best_val {
                    best_val = v;
                    best = cand;
                }
            }
            bf[i] = best;
            out[i] = best as u32;
        }
        out
    }

    /// Solve: continuous Newton–Jacobi then Proposition-1 discretization.
    pub fn solve(&self) -> Vec<u32> {
        let b_hat = self.newton_jacobi(200, 1e-9);
        self.discretize(&b_hat)
    }

    /// Exhaustive search over the 3^N Proposition-1 candidates
    /// {1, ⌊b̂⌋/⌈b̂⌉, ⌊κ⌋} — the paper's "global optimum for small-scale
    /// systems" used here as a test oracle.
    pub fn solve_exhaustive(&self) -> Vec<u32> {
        let b_hat = self.newton_jacobi(200, 1e-9);
        let cands: Vec<Vec<u32>> = (0..self.n())
            .map(|i| {
                let mut c = vec![
                    1u32,
                    b_hat[i].floor().max(1.0) as u32,
                    b_hat[i].ceil().max(1.0) as u32,
                    self.kappa[i].floor().max(1.0) as u32,
                ];
                c.sort_unstable();
                c.dedup();
                c.retain(|&x| x as f64 <= self.kappa[i].max(1.0));
                if c.is_empty() {
                    c.push(1);
                }
                c
            })
            .collect();
        let mut best: Vec<u32> = cands.iter().map(|c| c[0]).collect();
        let mut best_val = f64::INFINITY;
        let mut idx = vec![0usize; self.n()];
        loop {
            let b: Vec<f64> = idx
                .iter()
                .enumerate()
                .map(|(i, &k)| cands[i][k] as f64)
                .collect();
            let v = self.objective(&b);
            if v < best_val {
                best_val = v;
                best = b.iter().map(|&x| x as u32).collect();
            }
            // odometer increment
            let mut carry = true;
            for i in 0..self.n() {
                if carry {
                    idx[i] += 1;
                    if idx[i] == cands[i].len() {
                        idx[i] = 0;
                    } else {
                        carry = false;
                    }
                }
            }
            if carry {
                break;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::testutil::Fixture;

    fn subproblem(n: usize, cut: usize) -> (Fixture, Decisions) {
        let fx = Fixture::table1(n);
        let dec = Decisions::uniform(n, 16, cut);
        (fx, dec)
    }

    #[test]
    fn objective_diverges_at_tiny_batches() {
        let (fx, dec) = subproblem(4, 4);
        let sp = BsSubproblem::from_context(&fx.ctx(), &dec);
        // With b -> 0 the denominator goes negative -> infeasible.
        assert!(sp.objective(&vec![1e-6; 4]).is_infinite());
        assert!(sp.objective(&vec![16.0; 4]).is_finite());
    }

    #[test]
    fn newton_jacobi_converges_to_stationary_point() {
        let (fx, dec) = subproblem(6, 4);
        let sp = BsSubproblem::from_context(&fx.ctx(), &dec);
        let b_hat = sp.newton_jacobi(300, 1e-10);
        // Numerically verify first-order stationarity: perturbing any
        // coordinate up or down must not decrease the objective much.
        let base = sp.objective(&b_hat);
        assert!(base.is_finite());
        for i in 0..sp.n() {
            for mult in [0.9, 1.1] {
                let mut b = b_hat.clone();
                b[i] *= mult;
                assert!(
                    sp.objective(&b) >= base - base.abs() * 1e-6,
                    "coordinate {i} mult {mult} improved objective"
                );
            }
        }
    }

    #[test]
    fn discretize_respects_caps_and_integrality() {
        let (fx, dec) = subproblem(5, 4);
        let sp = BsSubproblem::from_context(&fx.ctx(), &dec);
        let b = sp.solve();
        assert_eq!(b.len(), 5);
        for (i, &bi) in b.iter().enumerate() {
            assert!(bi >= 1);
            assert!((bi as f64) <= sp.kappa[i].max(1.0) + 1e-9);
        }
    }

    #[test]
    fn solver_matches_exhaustive_candidates() {
        let (fx, dec) = subproblem(3, 3);
        let sp = BsSubproblem::from_context(&fx.ctx(), &dec);
        let fast = sp.solve();
        let oracle = sp.solve_exhaustive();
        let vf = sp.objective(&fast.iter().map(|&x| x as f64).collect::<Vec<_>>());
        let vo = sp.objective(&oracle.iter().map(|&x| x as f64).collect::<Vec<_>>());
        assert!(vf <= vo * 1.02, "fast {vf} oracle {vo}");
    }

    #[test]
    fn stronger_server_prefers_larger_batches() {
        // With a faster server, per-sample server cost C_i drops, so the
        // optimum shifts toward larger batches (variance reduction wins).
        let fx = Fixture::table1(4);
        let dec = Decisions::uniform(4, 16, 4);
        let weak = BsSubproblem::from_context(&fx.ctx(), &dec);

        let mut fx2 = Fixture::table1(4);
        fx2.server.flops *= 10.0;
        let strong = BsSubproblem::from_context(&fx2.ctx(), &dec);

        let bw: u32 = weak.solve().iter().sum();
        let bs_: u32 = strong.solve().iter().sum();
        assert!(bs_ >= bw, "strong server {bs_} < weak {bw}");
    }
}
