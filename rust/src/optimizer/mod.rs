//! Joint batch-size (BS) + model-splitting (MS) optimization — §V–VI of the
//! paper: problem P (Eqn 41) → P′ (Eqn 42) → P″ (Eqn 44), decomposed into
//! the BS sub-problem P1 (Newton–Jacobi + Proposition 1) and the MS
//! sub-problem P2 (Dinkelbach / BCD), alternated by the block-coordinate
//! descent of Algorithm 2.

pub mod bcd;
pub mod bs;
pub mod ms;
pub mod strategies;

pub use bcd::solve_joint;
pub use strategies::{decide, StrategyInputs};

use crate::config::{Device, Server};
use crate::convergence::{memory_feasible, theta_objective, BoundParams};
use crate::latency::Decisions;
use crate::model::ModelProfile;

/// Everything the optimizers need to evaluate the Θ′ objective exactly.
pub struct OptContext<'a> {
    /// Per-layer cost profile of the model being split.
    pub profile: &'a ModelProfile,
    /// Sampled device fleet.
    pub devices: &'a [Device],
    /// Edge-server resources.
    pub server: &'a Server,
    /// Convergence-bound parameters (Theorem 1 constants).
    pub bound: &'a BoundParams,
    /// Client-side aggregation interval I.
    pub interval: usize,
    /// Target convergence accuracy epsilon (constraint C1).
    pub epsilon: f64,
    /// Maximum batch size B (constraint C5's practical cap).
    pub batch_cap: u32,
}

impl<'a> OptContext<'a> {
    /// Number of devices in the fleet.
    pub fn n(&self) -> usize {
        self.devices.len()
    }

    /// Exact Θ(b, μ) objective (Eqn 43): estimated wall-clock time to
    /// epsilon-convergence. `None` = infeasible (convergence constraint C1
    /// unreachable or memory constraint C4 violated).
    pub fn objective(&self, dec: &Decisions) -> Option<f64> {
        if !memory_feasible(self.profile, self.devices, dec) {
            return None;
        }
        if dec.batch.iter().any(|&b| b == 0 || b > self.batch_cap) {
            return None;
        }
        theta_objective(
            self.profile,
            self.devices,
            self.server,
            self.bound,
            dec,
            self.interval,
            self.epsilon,
        )
    }

    /// Relaxed comparison metric (see
    /// [`crate::convergence::time_to_own_convergence`]): finite for any
    /// memory-feasible decision; equals [`Self::objective`] whenever the
    /// target epsilon is achievable.
    pub fn eval_time(&self, dec: &Decisions) -> Option<f64> {
        if dec.batch.iter().any(|&b| b == 0 || b > self.batch_cap) {
            return None;
        }
        crate::convergence::time_to_own_convergence(
            self.profile,
            self.devices,
            self.server,
            self.bound,
            dec,
            self.interval,
            self.epsilon,
        )
    }

    /// Cuts that satisfy memory constraint C4 for device `i` at batch `b`.
    pub fn feasible_cuts(&self, i: usize, b: u32) -> Vec<usize> {
        self.profile
            .valid_cuts
            .iter()
            .copied()
            .filter(|&c| self.profile.client_mem_bytes(c, b) < self.devices[i].mem_bytes)
            .collect()
    }

    /// Largest memory-feasible batch for device `i` at cut `c` (>= 1).
    pub fn max_feasible_batch(&self, i: usize, c: usize) -> u32 {
        let mut b = self.batch_cap;
        while b > 1 && self.profile.client_mem_bytes(c, b) >= self.devices[i].mem_bytes {
            b -= 1;
        }
        b
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::config::Config;

    pub struct Fixture {
        pub profile: ModelProfile,
        pub devices: Vec<Device>,
        pub server: Server,
        pub bound: BoundParams,
        pub cfg: Config,
    }

    impl Fixture {
        pub fn table1(n_devices: usize) -> Fixture {
            let mut cfg = Config::table1();
            cfg.fleet.n_devices = n_devices;
            let profile = ModelProfile::vgg16();
            let bound = BoundParams::default_for(&profile, cfg.train.lr);
            let devices = cfg.sample_fleet();
            let server = cfg.server.clone();
            Fixture { profile, devices, server, bound, cfg }
        }

        pub fn ctx(&self) -> OptContext<'_> {
            OptContext {
                profile: &self.profile,
                devices: &self.devices,
                server: &self.server,
                bound: &self.bound,
                interval: self.cfg.train.agg_interval,
                epsilon: self.cfg.train.epsilon,
                batch_cap: self.cfg.train.batch_cap,
            }
        }
    }
}
