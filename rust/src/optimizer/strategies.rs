//! The five BS/MS control strategies evaluated in §VII: HASFL plus the four
//! benchmarks (RBS+HAMS, HABS+RMS, RBS+RMS, RBS+RHAMS) and the fixed
//! ablation baselines of Figs 10–11.

use super::bs::BsSubproblem;
use super::{bcd, ms, OptContext};
use crate::config::StrategyKind;
use crate::latency::Decisions;
use crate::rng::Pcg32;

/// Extra inputs for strategies with fixed decisions.
#[derive(Debug, Clone, Copy)]
pub struct StrategyInputs {
    /// Batch size used by the fixed-batch strategies.
    pub fixed_batch: u32,
    /// Cut layer used by the fixed-cut strategies.
    pub fixed_cut: usize,
}

impl Default for StrategyInputs {
    fn default() -> Self {
        StrategyInputs { fixed_batch: 16, fixed_cut: 4 }
    }
}

fn random_batches(ctx: &OptContext, rng: &mut Pcg32) -> Vec<u32> {
    // Paper: "randomly drawing BS from 1 to 64 during model training".
    (0..ctx.n())
        .map(|_| rng.int_range(1, ctx.batch_cap))
        .collect()
}

/// Rejection-sample a random decision into the *realisable* region
/// (memory constraint C4 + batch cap). Convergence constraint C1 is NOT
/// enforced here: the paper's random baselines do run with convergence-
/// hostile decisions — they simply converge slower / to worse accuracy,
/// which the relaxed `eval_time` metric prices in. Falls back to a safe
/// uniform/greedy configuration if `tries` redraws all fail.
fn feasible_random<F>(ctx: &OptContext, rng: &mut Pcg32, tries: usize, mut draw: F) -> Decisions
where
    F: FnMut(&mut Pcg32) -> Decisions,
{
    for _ in 0..tries {
        let mut dec = draw(rng);
        clamp_feasible(ctx, &mut dec.batch, &dec.cut);
        if ctx.eval_time(&dec).is_some() {
            return dec;
        }
    }
    // Safe fallback: moderate uniform batch + per-device greedy cuts.
    let batch: Vec<u32> = (0..ctx.n()).map(|_| 16.min(ctx.batch_cap)).collect();
    let cut: Vec<usize> = (0..ctx.n())
        .map(|i| ms::greedy_latency_cut(ctx, i, batch[i]))
        .collect();
    let mut batch = batch;
    clamp_feasible(ctx, &mut batch, &cut);
    Decisions { batch, cut }
}

fn random_cuts(ctx: &OptContext, rng: &mut Pcg32, batch: &[u32]) -> Vec<usize> {
    (0..ctx.n())
        .map(|i| {
            let feas = ctx.feasible_cuts(i, batch[i]);
            let pool = if feas.is_empty() { ctx.profile.valid_cuts.clone() } else { feas };
            pool[rng.below(pool.len() as u32) as usize]
        })
        .collect()
}

/// Clamp batches so the (batch, cut) pair is memory-feasible.
fn clamp_feasible(ctx: &OptContext, batch: &mut [u32], cuts: &[usize]) {
    for i in 0..ctx.n() {
        let cap = ctx.max_feasible_batch(i, cuts[i]);
        if batch[i] > cap {
            batch[i] = cap;
        }
    }
}

/// Produce this round-window's decisions under the given strategy.
pub fn decide(
    kind: StrategyKind,
    ctx: &OptContext,
    rng: &mut Pcg32,
    inputs: StrategyInputs,
) -> Decisions {
    match kind {
        StrategyKind::Hasfl => bcd::solve_joint(ctx, rng, 8, 1e-6).decisions,

        StrategyKind::RbsHams => feasible_random(ctx, rng, 40, |r| {
            let batch = random_batches(ctx, r);
            let cut = ms::solve_bcd(ctx, &batch, r, 2);
            Decisions { batch, cut }
        }),

        StrategyKind::HabsRms => feasible_random(ctx, rng, 40, |r| {
            // Random cuts first, then the heterogeneity-aware BS solver.
            let probe = vec![inputs.fixed_batch.min(ctx.batch_cap); ctx.n()];
            let cut = random_cuts(ctx, r, &probe);
            let incumbent = Decisions { batch: probe, cut: cut.clone() };
            let sp = BsSubproblem::from_context(ctx, &incumbent);
            Decisions { batch: sp.solve(), cut }
        }),

        StrategyKind::RbsRms => feasible_random(ctx, rng, 40, |r| {
            let batch = random_batches(ctx, r);
            let cut = random_cuts(ctx, r, &batch);
            Decisions { batch, cut }
        }),

        StrategyKind::RbsRhams => feasible_random(ctx, rng, 40, |r| {
            // Random BS + resource-heterogeneity-aware MS heuristic [55]:
            // per-device latency-greedy cut, no convergence modelling.
            let batch = random_batches(ctx, r);
            let cut: Vec<usize> = (0..ctx.n())
                .map(|i| ms::greedy_latency_cut(ctx, i, batch[i]))
                .collect();
            Decisions { batch, cut }
        }),

        StrategyKind::Fixed => {
            let n = ctx.n();
            let cut = vec![inputs.fixed_cut; n];
            let mut batch = vec![inputs.fixed_batch; n];
            clamp_feasible(ctx, &mut batch, &cut);
            Decisions { batch, cut }
        }

        StrategyKind::HabsFixedCut => {
            // Fig 10 ablation arm: BS solver at a fixed uniform cut.
            let n = ctx.n();
            let cut = vec![inputs.fixed_cut; n];
            let incumbent = Decisions {
                batch: vec![inputs.fixed_batch.min(ctx.batch_cap); n],
                cut: cut.clone(),
            };
            let sp = BsSubproblem::from_context(ctx, &incumbent);
            let mut batch = sp.solve();
            clamp_feasible(ctx, &mut batch, &cut);
            Decisions { batch, cut }
        }

        StrategyKind::HamsFixedBatch => {
            // Fig 11 ablation arm: MS solver at a fixed uniform batch.
            let n = ctx.n();
            let batch = vec![inputs.fixed_batch.min(ctx.batch_cap); n];
            let cut = ms::solve_bcd(ctx, &batch, rng, 4);
            let mut batch = batch;
            clamp_feasible(ctx, &mut batch, &cut);
            Decisions { batch, cut }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::testutil::Fixture;

    fn all_kinds() -> Vec<StrategyKind> {
        vec![
            StrategyKind::Hasfl,
            StrategyKind::RbsHams,
            StrategyKind::HabsRms,
            StrategyKind::RbsRms,
            StrategyKind::RbsRhams,
            StrategyKind::Fixed,
        ]
    }

    #[test]
    fn every_strategy_yields_valid_decisions() {
        let fx = Fixture::table1(6);
        let ctx = fx.ctx();
        for kind in all_kinds() {
            let mut rng = Pcg32::seeded(17);
            let dec = decide(kind, &ctx, &mut rng, StrategyInputs::default());
            assert_eq!(dec.n(), 6, "{kind:?}");
            for (i, (&b, &c)) in dec.batch.iter().zip(&dec.cut).enumerate() {
                assert!(b >= 1 && b <= ctx.batch_cap, "{kind:?} dev {i} b={b}");
                assert!(ctx.profile.valid_cuts.contains(&c), "{kind:?} dev {i} c={c}");
            }
            assert!(
                crate::convergence::memory_feasible(ctx.profile, ctx.devices, &dec),
                "{kind:?} violates C4"
            );
        }
    }

    #[test]
    fn hasfl_objective_dominates_benchmarks() {
        let fx = Fixture::table1(8);
        let ctx = fx.ctx();
        let mut rng = Pcg32::seeded(23);
        let hasfl = decide(StrategyKind::Hasfl, &ctx, &mut rng, StrategyInputs::default());
        let hasfl_theta = ctx.eval_time(&hasfl).unwrap();
        // Average benchmark eval-time over several random draws (random
        // strategies are noisy; HASFL should beat their expectation). The
        // relaxed metric charges infeasible-for-target decisions the time
        // to their own plateau, mirroring the paper's measurements.
        for kind in [StrategyKind::RbsRms, StrategyKind::RbsRhams, StrategyKind::HabsRms] {
            let mut sum = 0.0;
            let mut cnt = 0;
            for seed in 0..5u64 {
                let mut r = Pcg32::seeded(100 + seed);
                let d = decide(kind, &ctx, &mut r, StrategyInputs::default());
                if let Some(v) = ctx.eval_time(&d) {
                    sum += v;
                    cnt += 1;
                }
            }
            assert!(cnt > 0, "{kind:?} always memory-infeasible");
            let avg = sum / cnt as f64;
            assert!(
                hasfl_theta <= avg,
                "{kind:?} avg {avg} beats HASFL {hasfl_theta}"
            );
        }
    }

    #[test]
    fn fixed_strategy_honours_inputs() {
        let fx = Fixture::table1(4);
        let ctx = fx.ctx();
        let mut rng = Pcg32::seeded(1);
        let dec = decide(
            StrategyKind::Fixed,
            &ctx,
            &mut rng,
            StrategyInputs { fixed_batch: 8, fixed_cut: 5 },
        );
        assert_eq!(dec.batch, vec![8; 4]);
        assert_eq!(dec.cut, vec![5; 4]);
    }

    #[test]
    fn random_strategies_are_deterministic_per_seed() {
        let fx = Fixture::table1(5);
        let ctx = fx.ctx();
        let mut r1 = Pcg32::seeded(42);
        let mut r2 = Pcg32::seeded(42);
        let d1 = decide(StrategyKind::RbsRms, &ctx, &mut r1, StrategyInputs::default());
        let d2 = decide(StrategyKind::RbsRms, &ctx, &mut r2, StrategyInputs::default());
        assert_eq!(d1, d2);
    }
}
