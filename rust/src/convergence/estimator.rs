//! Online estimation of the Assumption-2 constants from observed gradients,
//! following the profiling approach of Wang et al. [24] (the paper states
//! "the key parameters required for executing the algorithm (e.g. beta,
//! G_j^2 and sigma_j^2) are estimated following the approach in [24]").
//!
//! Per round, for every model block j we observe the per-device gradients
//! g_{i,j}. We estimate:
//!   G_j^2   ≈ EMA over rounds of mean_i ||g_{i,j}||^2
//!   sigma_j^2 ≈ EMA of b_bar * mean_i ||g_{i,j} - mean_i g_{i,j}||^2
//! (the mini-batch variance scales as sigma^2 / b, so multiplying the
//! observed cross-device variance by the mean batch recovers sigma^2), and
//!   beta ≈ EMA of ||grad f(w_t) - grad f(w_{t-1})|| / ||w_t - w_{t-1}||.

use crate::model::Tensor;

/// Serializable snapshot of a [`GradStatsEstimator`] (the checkpoint
/// subsystem persists it so a resumed run re-optimizes from the same
/// estimated Assumption-2 constants as the uninterrupted run).
#[derive(Debug, Clone, PartialEq)]
pub struct EstimatorState {
    /// Number of per-layer blocks tracked.
    pub n_blocks: usize,
    /// EMA smoothing factor.
    pub alpha: f64,
    /// Per-block gradient second moments (G_k^2).
    pub gsq: Vec<f64>,
    /// Per-block gradient variances (sigma_k^2).
    pub sigma_sq: Vec<f64>,
    /// Secant smoothness estimate (0 before enough data).
    pub beta: f64,
    /// Observations folded in so far.
    pub rounds_seen: usize,
    /// Previous flattened gradient, for the secant estimate.
    pub prev_flat_grad: Option<Vec<f64>>,
    /// Previous flattened parameters, for the secant estimate.
    pub prev_flat_param: Option<Vec<f64>>,
}

/// Exponential-moving-average estimator of per-layer bound constants.
#[derive(Debug, Clone)]
pub struct GradStatsEstimator {
    n_blocks: usize,
    alpha: f64,
    gsq: Vec<f64>,
    sigma_sq: Vec<f64>,
    beta: f64,
    rounds_seen: usize,
    // State for the beta (smoothness) secant estimate.
    prev_flat_grad: Option<Vec<f64>>,
    prev_flat_param: Option<Vec<f64>>,
}

impl GradStatsEstimator {
    /// Fresh estimator over `n_blocks` per-layer blocks.
    pub fn new(n_blocks: usize) -> Self {
        GradStatsEstimator {
            n_blocks,
            alpha: 0.2,
            gsq: vec![0.0; n_blocks],
            sigma_sq: vec![0.0; n_blocks],
            beta: 0.0,
            rounds_seen: 0,
            prev_flat_grad: None,
            prev_flat_param: None,
        }
    }

    fn ema(old: f64, new: f64, alpha: f64, first: bool) -> f64 {
        if first {
            new
        } else {
            (1.0 - alpha) * old + alpha * new
        }
    }

    /// Feed one round of observations.
    ///
    /// `per_device_grads[i]` holds device i's full-model gradient as
    /// 2 tensors per block `[w, b, w, b, ...]` (aligned across devices);
    /// `batch[i]` is device i's batch size this round.
    pub fn observe_round(&mut self, per_device_grads: &[Vec<Tensor>], batch: &[u32]) {
        let n_dev = per_device_grads.len();
        if n_dev == 0 {
            return;
        }
        let first = self.rounds_seen == 0;
        let b_bar = batch.iter().map(|&b| b as f64).sum::<f64>() / batch.len() as f64;

        for j in 0..self.n_blocks {
            let (wi, bi) = (2 * j, 2 * j + 1);
            // mean_i ||g_{i,j}||^2
            let mean_sq: f64 = per_device_grads
                .iter()
                .map(|g| g[wi].l2_sq() + g[bi].l2_sq())
                .sum::<f64>()
                / n_dev as f64;
            // cross-device variance: mean_i ||g_{i,j} - g_bar_j||^2
            let var = if n_dev > 1 {
                let mut acc = 0.0;
                for t in [wi, bi] {
                    let len = per_device_grads[0][t].data.len();
                    for e in 0..len {
                        let mean: f64 = per_device_grads
                            .iter()
                            .map(|g| g[t].data[e] as f64)
                            .sum::<f64>()
                            / n_dev as f64;
                        acc += per_device_grads
                            .iter()
                            .map(|g| {
                                let d = g[t].data[e] as f64 - mean;
                                d * d
                            })
                            .sum::<f64>()
                            / n_dev as f64;
                    }
                }
                acc
            } else {
                // Single device: fall back to a fraction of the second moment.
                0.5 * mean_sq
            };
            self.gsq[j] = Self::ema(self.gsq[j], mean_sq, self.alpha, first);
            self.sigma_sq[j] = Self::ema(self.sigma_sq[j], b_bar * var, self.alpha, first);
        }
        self.rounds_seen += 1;
    }

    /// Feed the aggregate gradient + parameter snapshot for the secant
    /// estimate of the smoothness beta.
    pub fn observe_smoothness(&mut self, flat_grad: Vec<f64>, flat_param: Vec<f64>) {
        if let (Some(pg), Some(pp)) = (&self.prev_flat_grad, &self.prev_flat_param) {
            let dg: f64 = flat_grad
                .iter()
                .zip(pg)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            let dw: f64 = flat_param
                .iter()
                .zip(pp)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            if dw > 1e-12 {
                let est = dg / dw;
                let first = self.beta == 0.0;
                self.beta = Self::ema(self.beta, est, self.alpha, first);
            }
        }
        self.prev_flat_grad = Some(flat_grad);
        self.prev_flat_param = Some(flat_param);
    }

    /// Estimated per-block gradient second moments (G_k^2).
    pub fn gsq(&self) -> &[f64] {
        &self.gsq
    }

    /// Estimated per-block gradient variances (sigma_k^2).
    pub fn sigma_sq(&self) -> &[f64] {
        &self.sigma_sq
    }

    /// Estimated smoothness; falls back to `fallback` before enough data.
    pub fn beta_or(&self, fallback: f64) -> f64 {
        if self.beta > 0.0 {
            self.beta
        } else {
            fallback
        }
    }

    /// Observations folded in so far.
    pub fn rounds_seen(&self) -> usize {
        self.rounds_seen
    }

    /// Full estimator state for checkpointing.
    pub fn to_state(&self) -> EstimatorState {
        EstimatorState {
            n_blocks: self.n_blocks,
            alpha: self.alpha,
            gsq: self.gsq.clone(),
            sigma_sq: self.sigma_sq.clone(),
            beta: self.beta,
            rounds_seen: self.rounds_seen,
            prev_flat_grad: self.prev_flat_grad.clone(),
            prev_flat_param: self.prev_flat_param.clone(),
        }
    }

    /// Rebuild an estimator from checkpointed state (exact inverse of
    /// [`GradStatsEstimator::to_state`]).
    pub fn from_state(s: EstimatorState) -> GradStatsEstimator {
        GradStatsEstimator {
            n_blocks: s.n_blocks,
            alpha: s.alpha,
            gsq: s.gsq,
            sigma_sq: s.sigma_sq,
            beta: s.beta,
            rounds_seen: s.rounds_seen,
            prev_flat_grad: s.prev_flat_grad,
            prev_flat_param: s.prev_flat_param,
        }
    }

    /// Produce BoundParams using current estimates (gamma/theta0 given).
    pub fn to_bound_params(&self, gamma: f64, theta0: f64) -> super::BoundParams {
        super::BoundParams {
            beta: self.beta_or(1.0 / gamma),
            gamma,
            theta0,
            sigma_sq: self.sigma_sq.clone(),
            gsq: self.gsq.clone(),
        }
    }
}

/// Assumption-2 variance-inflation factor of a staleness-weighted buffer
/// flush (DESIGN.md §16): with normalised staleness weights
/// `w_k ∝ (1 + lag_k)^-decay` over the `K` flushed updates, the
/// stochastic gradient-noise term of the convergence bound scales by
/// `K · Σ_k w_k²` relative to the uniform synchronous average — the
/// factor is exactly `1.0` at equal weights (any lag under `decay == 0`,
/// or equal lags at any decay) and grows as staleness skews the weights,
/// so uneven lag *inflates* the effective `sigma²` the optimizer prices.
/// Returns `1.0` for an empty flush.
pub fn staleness_variance_inflation(lags: &[u64], decay: f64) -> f64 {
    if lags.is_empty() {
        return 1.0;
    }
    let weights: Vec<f64> =
        lags.iter().map(|&l| crate::asynch::staleness_weight(l, decay)).collect();
    let sum: f64 = weights.iter().sum();
    if !(sum.is_finite() && sum > 0.0) {
        return 1.0;
    }
    let norm_sq: f64 = weights.iter().map(|w| (w / sum) * (w / sum)).sum();
    lags.len() as f64 * norm_sq
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor(v: &[f32]) -> Tensor {
        Tensor { shape: vec![v.len()], data: v.to_vec() }
    }

    #[test]
    fn state_roundtrip_preserves_estimates() {
        let mut est = GradStatsEstimator::new(1);
        let g1 = vec![tensor(&[3.0, 0.0]), tensor(&[4.0])];
        let g2 = vec![tensor(&[0.0, 3.0]), tensor(&[4.0])];
        est.observe_round(&[g1, g2], &[8, 8]);
        est.observe_smoothness(vec![2.0], vec![1.0]);
        let back = GradStatsEstimator::from_state(est.to_state());
        assert_eq!(back.to_state(), est.to_state());
        assert_eq!(back.gsq(), est.gsq());
        assert_eq!(back.rounds_seen(), est.rounds_seen());
    }

    #[test]
    fn staleness_inflation_is_one_at_uniform_weights_and_grows_with_skew() {
        // Equal lags (any decay) and zero decay (any lags) are the
        // uniform synchronous average: inflation exactly 1.
        assert!((staleness_variance_inflation(&[2, 2, 2, 2], 0.8) - 1.0).abs() < 1e-12);
        assert!((staleness_variance_inflation(&[0, 3, 7], 0.0) - 1.0).abs() < 1e-12);
        assert_eq!(staleness_variance_inflation(&[], 0.5), 1.0);
        // Skewed lags concentrate weight on the fresh update: Σw² of the
        // normalised weights exceeds the 1/K uniform minimum.
        let skewed = staleness_variance_inflation(&[0, 8, 8, 8], 1.0);
        assert!(skewed > 1.0, "{skewed}");
        // More skew (stronger decay) inflates more.
        assert!(staleness_variance_inflation(&[0, 8, 8, 8], 2.0) > skewed);
    }

    #[test]
    fn gsq_tracks_mean_square_norm() {
        let mut est = GradStatsEstimator::new(1);
        let g1 = vec![tensor(&[3.0, 0.0]), tensor(&[4.0])];
        let g2 = vec![tensor(&[0.0, 3.0]), tensor(&[4.0])];
        est.observe_round(&[g1, g2], &[8, 8]);
        assert!((est.gsq()[0] - 25.0).abs() < 1e-9);
    }

    #[test]
    fn identical_grads_have_zero_variance() {
        let mut est = GradStatsEstimator::new(1);
        let g = vec![tensor(&[1.0, 2.0]), tensor(&[3.0])];
        est.observe_round(&[g.clone(), g], &[8, 8]);
        assert!(est.sigma_sq()[0].abs() < 1e-9);
    }

    #[test]
    fn divergent_grads_have_positive_variance() {
        let mut est = GradStatsEstimator::new(1);
        let g1 = vec![tensor(&[1.0, 0.0]), tensor(&[0.0])];
        let g2 = vec![tensor(&[-1.0, 0.0]), tensor(&[0.0])];
        est.observe_round(&[g1, g2], &[4, 4]);
        assert!(est.sigma_sq()[0] > 0.0);
    }

    #[test]
    fn beta_secant_estimate() {
        let mut est = GradStatsEstimator::new(1);
        // grad = 2*w (so f is 1-smooth with beta=2)
        est.observe_smoothness(vec![2.0], vec![1.0]);
        est.observe_smoothness(vec![4.0], vec![2.0]);
        assert!((est.beta_or(0.0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn to_bound_params_carries_estimates() {
        let mut est = GradStatsEstimator::new(2);
        let g = vec![
            tensor(&[1.0]),
            tensor(&[0.0]),
            tensor(&[2.0]),
            tensor(&[0.0]),
        ];
        est.observe_round(&[g.clone(), g], &[8, 8]);
        let bp = est.to_bound_params(0.01, 2.0);
        assert_eq!(bp.gsq.len(), 2);
        assert!((bp.gamma - 0.01).abs() < 1e-12);
        assert!(bp.beta > 0.0);
    }
}
