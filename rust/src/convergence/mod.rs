//! Convergence-bound engine: Theorem 1, Corollary 1, and the Θ′ objective
//! (Eqn 45) that drives the joint BS+MS optimization, plus the online
//! estimator for the Assumption-2 constants (per-layer σ_j², G_j²) in the
//! style of Wang et al. [24].

mod estimator;

pub use estimator::{staleness_variance_inflation, EstimatorState, GradStatsEstimator};

use crate::latency::{round_latency, Decisions};
use crate::model::ModelProfile;
use crate::config::{Device, Server};

/// Constants of the convergence bound (Assumptions 1–2 + problem scale).
#[derive(Debug, Clone)]
pub struct BoundParams {
    /// Smoothness beta of the local loss functions (Assumption 1).
    pub beta: f64,
    /// Learning rate gamma (must satisfy 0 < gamma <= 1/beta).
    pub gamma: f64,
    /// vartheta = f(w^0) - f* — initial optimality gap.
    pub theta0: f64,
    /// Per-layer gradient-variance constants sigma_j^2 (variance = sigma_j^2 / b).
    pub sigma_sq: Vec<f64>,
    /// Per-layer second-moment bounds G_j^2.
    pub gsq: Vec<f64>,
}

impl BoundParams {
    /// Principled defaults for paper-scale simulation: per-layer constants
    /// proportional to layer parameter mass (gradient energy concentrates
    /// where the parameters are), normalised so that sum_j sigma_j^2 = s_tot
    /// and sum_j G_j^2 = g_tot. The executable path replaces these with
    /// estimates from real gradients (see `GradStatsEstimator`).
    pub fn default_for(profile: &ModelProfile, gamma: f64) -> BoundParams {
        let total: f64 = profile.layers.iter().map(|l| l.n_params as f64).sum();
        // Calibration: with beta = 1/gamma the drift multiplier is
        // 4 (beta*gamma)^2 I^2 = 4 I^2 (= 900 at the paper's I = 15), so
        // g_tot must sit well below epsilon/900 for shallow cuts to be
        // feasible while deep cuts price in a real convergence penalty
        // (Insight 2). s_tot is set so the variance floor at b = 1
        // approaches epsilon (Insight 1: tiny batches are priced out).
        let (s_tot, g_tot) = (8.0, 8e-4);
        let sigma_sq = profile
            .layers
            .iter()
            .map(|l| s_tot * l.n_params as f64 / total)
            .collect();
        let gsq = profile
            .layers
            .iter()
            .map(|l| g_tot * l.n_params as f64 / total)
            .collect();
        BoundParams { beta: 1.0 / gamma, gamma, theta0: 2.3, sigma_sq, gsq }
    }

    /// sum_{j=1}^{L} sigma_j^2.
    pub fn sigma_sum(&self) -> f64 {
        self.sigma_sq.iter().sum()
    }

    /// G~_j^2 = sum_{k<=j} G_k^2 (cumulative second moments).
    pub fn gsq_cum(&self, j: usize) -> f64 {
        self.gsq[..j].iter().sum()
    }

    /// Number of per-layer blocks in the bound.
    pub fn n_layers(&self) -> usize {
        self.sigma_sq.len()
    }
}

/// The variance term of Theorem 1:
/// beta*gamma * sum_i sum_j sigma_j^2 / b_i / N^2.
pub fn variance_term(bp: &BoundParams, batch: &[u32]) -> f64 {
    let n = batch.len() as f64;
    let s = bp.sigma_sum();
    let inv_b: f64 = batch.iter().map(|&b| 1.0 / b.max(1) as f64).sum();
    bp.beta * bp.gamma * s * inv_b / (n * n)
}

/// The client-drift term of Theorem 1:
/// 1{I>1} * 4 beta^2 gamma^2 I^2 * G~_{L_c}^2.
pub fn drift_term(bp: &BoundParams, l_c: usize, interval: usize) -> f64 {
    if interval <= 1 {
        return 0.0;
    }
    let i = interval as f64;
    4.0 * bp.beta * bp.beta * bp.gamma * bp.gamma * i * i * bp.gsq_cum(l_c)
}

/// Theorem 1 (Eqn 16): the bound on (1/R) sum_t E||grad f(w^{t-1})||^2.
pub fn theorem1_bound(
    bp: &BoundParams,
    batch: &[u32],
    l_c: usize,
    interval: usize,
    rounds: usize,
) -> f64 {
    2.0 * bp.theta0 / (bp.gamma * rounds.max(1) as f64)
        + variance_term(bp, batch)
        + drift_term(bp, l_c, interval)
}

/// Corollary 1 (Eqn 27): rounds needed to reach target accuracy epsilon.
/// Returns `None` when epsilon is unreachable (denominator <= 0): the
/// variance/drift floor exceeds the target.
pub fn rounds_to_epsilon(
    bp: &BoundParams,
    batch: &[u32],
    l_c: usize,
    interval: usize,
    epsilon: f64,
) -> Option<f64> {
    let den = epsilon - variance_term(bp, batch) - drift_term(bp, l_c, interval);
    if den <= 0.0 {
        return None;
    }
    Some(2.0 * bp.theta0 / (bp.gamma * den))
}

/// Θ(b, μ) — Eqn 43: estimated total training time to epsilon-convergence,
/// the objective of problem P′. `None` when infeasible.
pub fn theta_objective(
    profile: &ModelProfile,
    devices: &[Device],
    server: &Server,
    bp: &BoundParams,
    dec: &Decisions,
    interval: usize,
    epsilon: f64,
) -> Option<f64> {
    let r = rounds_to_epsilon(bp, &dec.batch, dec.l_c(), interval, epsilon)?;
    let lat = round_latency(profile, devices, server, dec);
    Some(r * (lat.t_split + lat.t_agg / interval.max(1) as f64))
}

/// Relaxed evaluation metric for cross-strategy comparison: time until the
/// decision reaches its *own* achievable accuracy plateau.
///
/// The paper measures converged time empirically (accuracy stagnation), so
/// benchmarks that cannot reach the target epsilon still get a finite
/// number — they converge to a worse accuracy. We mirror that: if the
/// decision's variance+drift floor exceeds the target, it is charged the
/// time to reach `1.25 x floor` (and would also report a worse converged
/// accuracy, as in Fig 6). Returns `None` only on memory infeasibility.
pub fn time_to_own_convergence(
    profile: &ModelProfile,
    devices: &[Device],
    server: &Server,
    bp: &BoundParams,
    dec: &Decisions,
    interval: usize,
    epsilon: f64,
) -> Option<f64> {
    if !memory_feasible(profile, devices, dec) {
        return None;
    }
    let floor = variance_term(bp, &dec.batch) + drift_term(bp, dec.l_c(), interval);
    let eps_eff = epsilon.max(1.25 * floor);
    let den = eps_eff - floor;
    if den <= 0.0 {
        return None;
    }
    let r = 2.0 * bp.theta0 / (bp.gamma * den);
    let lat = round_latency(profile, devices, server, dec);
    Some(r * (lat.t_split + lat.t_agg / interval.max(1) as f64))
}

/// Feasibility of the memory constraint C4 for every device.
pub fn memory_feasible(profile: &ModelProfile, devices: &[Device], dec: &Decisions) -> bool {
    devices
        .iter()
        .zip(dec.batch.iter().zip(&dec.cut))
        .all(|(d, (&b, &c))| profile.client_mem_bytes(c, b) < d.mem_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn setup() -> (ModelProfile, Vec<Device>, Server, BoundParams) {
        let cfg = Config::table1();
        let p = ModelProfile::vgg16();
        let bp = BoundParams::default_for(&p, cfg.train.lr);
        (p, cfg.sample_fleet(), cfg.server, bp)
    }

    #[test]
    fn variance_term_decreases_with_batch() {
        let (_, _, _, bp) = setup();
        let small = variance_term(&bp, &vec![4; 20]);
        let large = variance_term(&bp, &vec![32; 20]);
        assert!(small > large);
        assert!((small / large - 8.0).abs() < 1e-9);
    }

    #[test]
    fn batch_compensation_insight1() {
        // Insight 1: BSs compensate — {16,16} and a spread {8,?} can give
        // the same variance term when 1/b sums match: 1/8 + 1/x = 2/16.
        let (_, _, _, bp) = setup();
        let uniform = variance_term(&bp, &[16, 16]);
        // 1/8 + 1/b = 1/8 => only b -> inf; instead check ordering:
        let spread = variance_term(&bp, &[8, 64]);
        // 1/8 + 1/64 = 0.1406 > 2/16 = 0.125: spread is slightly worse.
        assert!(spread > uniform);
        let spread2 = variance_term(&bp, &[32, 32]);
        assert!(spread2 < uniform);
    }

    #[test]
    fn drift_term_zero_when_i_is_1() {
        let (_, _, _, bp) = setup();
        assert_eq!(drift_term(&bp, 8, 1), 0.0);
        assert!(drift_term(&bp, 8, 15) > 0.0);
    }

    #[test]
    fn drift_term_grows_with_cut_depth_insight2() {
        let (_, _, _, bp) = setup();
        assert!(drift_term(&bp, 10, 15) > drift_term(&bp, 2, 15));
    }

    #[test]
    fn theorem1_bound_decreases_with_rounds() {
        let (_, _, _, bp) = setup();
        let b = vec![16; 20];
        assert!(theorem1_bound(&bp, &b, 4, 15, 100) > theorem1_bound(&bp, &b, 4, 15, 1000));
    }

    #[test]
    fn rounds_to_epsilon_infeasible_when_floor_exceeds_target() {
        let (_, _, _, bp) = setup();
        // Tiny batches push the variance floor above a tight epsilon.
        let tight = 1e-9;
        assert!(rounds_to_epsilon(&bp, &vec![1; 20], 14, 15, tight).is_none());
    }

    #[test]
    fn rounds_decrease_with_larger_batch() {
        let (_, _, _, bp) = setup();
        let r8 = rounds_to_epsilon(&bp, &vec![8; 20], 4, 15, 0.5).unwrap();
        let r32 = rounds_to_epsilon(&bp, &vec![32; 20], 4, 15, 0.5).unwrap();
        assert!(r32 < r8);
    }

    #[test]
    fn theta_objective_feasible_on_table1() {
        let (p, devs, s, bp) = setup();
        let dec = Decisions::uniform(devs.len(), 16, 4);
        let t = theta_objective(&p, &devs, &s, &bp, &dec, 15, 0.5);
        assert!(t.is_some());
        assert!(t.unwrap() > 0.0);
    }

    #[test]
    fn memory_constraint_detects_violation() {
        let (p, mut devs, _, _) = setup();
        let dec = Decisions::uniform(devs.len(), 64, 13);
        assert!(memory_feasible(&p, &devs, &dec));
        for d in devs.iter_mut() {
            d.mem_bytes = 1024.0; // 1 KiB device
        }
        assert!(!memory_feasible(&p, &devs, &dec));
    }
}
