//! `hasfl` — the HASFL leader CLI.
//!
//! ```text
//! hasfl train    [--preset small|figure|table1] [--config cfg.json]
//!                [--strategy hasfl|rbs_hams|habs_rms|rbs_rms|rbs_rhams|fixed]
//!                [--rounds N] [--devices N] [--seed S] [--non-iid]
//!                [--artifacts DIR] [--out history.csv] [--concurrent]
//! hasfl optimize [--devices N] [--model vgg16|resnet18|splitcnn8] [--seed S]
//! hasfl latency  [--batch B] [--cut C] [--model ...] [--devices N]
//! hasfl info     [--artifacts DIR]
//! ```

use std::path::PathBuf;

use hasfl::config::{Config, ModelKind, Partition, StrategyKind};
use hasfl::convergence::BoundParams;
use hasfl::coordinator::Trainer;
use hasfl::latency::{round_latency, Decisions};
use hasfl::model::{Manifest, ModelProfile};
use hasfl::optimizer::{solve_joint, OptContext};
use hasfl::rng::Pcg32;
use hasfl::util::Args;

const USAGE: &str = "usage: hasfl <train|optimize|latency|info|config> [options]";

fn profile_arg(name: &str, artifacts: &std::path::Path) -> hasfl::Result<ModelProfile> {
    Ok(match name {
        "vgg16" => ModelProfile::vgg16(),
        "resnet18" => ModelProfile::resnet18(),
        "splitcnn8" => {
            let manifest = Manifest::load(artifacts)?;
            ModelProfile::from_manifest(&manifest)
        }
        _ => anyhow::bail!("unknown model '{name}'"),
    })
}

fn cmd_train(args: &Args) -> hasfl::Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::load(std::path::Path::new(path))?,
        None => match args.get("preset").unwrap_or("small") {
            "small" => Config::small(),
            "figure" => Config::figure_small(),
            "table1" => {
                let mut c = Config::table1();
                c.model = ModelKind::Splitcnn8;
                c
            }
            p => anyhow::bail!("unknown preset '{p}'"),
        },
    };
    if let Some(s) = args.get("strategy") {
        cfg.strategy = StrategyKind::parse(s)?;
    }
    if let Some(r) = args.get_opt::<usize>("rounds")? {
        cfg.train.rounds = r;
    }
    if let Some(n) = args.get_opt::<usize>("devices")? {
        cfg.fleet.n_devices = n;
    }
    if let Some(s) = args.get_opt::<u64>("seed")? {
        cfg.seed = s;
    }
    if args.flag("non-iid") {
        cfg.partition = Partition::NonIidShards;
    }
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));

    eprintln!(
        "training: N={} rounds={} strategy={} partition={}",
        cfg.fleet.n_devices,
        cfg.train.rounds,
        cfg.strategy.as_str(),
        cfg.partition.as_str()
    );
    let mut trainer = Trainer::new(cfg, &artifacts)?;
    if args.flag("concurrent") {
        trainer.run_concurrent()?;
    } else {
        trainer.run()?;
    }

    if let Some(&(round, time, acc)) = trainer.history.eval_points().last() {
        eprintln!(
            "done: round {round} sim_time {time:.1}s test_acc {:.2}% loss {:.4}",
            acc * 100.0,
            trainer.history.last_loss().unwrap_or(f64::NAN)
        );
    }
    if let Some((round, time, acc)) = trainer.history.converged(0.0002, 5) {
        eprintln!("converged @ round {round}: {:.2}% after {time:.1}s", acc * 100.0);
    }
    if let Some(path) = args.get("out") {
        let path = PathBuf::from(path);
        trainer.history.write_csv(&path)?;
        eprintln!("history -> {}", path.display());
    }
    let stats = trainer.engine.stats_blocking()?;
    eprintln!(
        "engine: {} execs ({:.2}s exec, {:.2}s marshal), {} compiles ({:.1}s)",
        stats.executions, stats.exec_secs, stats.marshal_secs, stats.compiles, stats.compile_secs
    );
    trainer.engine.shutdown();
    Ok(())
}

fn cmd_optimize(args: &Args) -> hasfl::Result<()> {
    let devices = args.get_or("devices", 20usize)?;
    let seed = args.get_or("seed", 2025u64)?;
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let profile = profile_arg(args.get("model").unwrap_or("vgg16"), &artifacts)?;

    let mut cfg = Config::table1();
    cfg.fleet.n_devices = devices;
    cfg.seed = seed;
    let bound = BoundParams::default_for(&profile, cfg.train.lr);
    let fleet = cfg.sample_fleet();
    let ctx = OptContext {
        profile: &profile,
        devices: &fleet,
        server: &cfg.server,
        bound: &bound,
        interval: cfg.train.agg_interval,
        epsilon: cfg.train.epsilon,
        batch_cap: cfg.train.batch_cap,
    };
    let mut rng = Pcg32::new(seed, 0x0CD);
    let sol = solve_joint(&ctx, &mut rng, 8, 1e-6);
    println!("model: {}", profile.name);
    println!("theta (est. seconds to eps-convergence): {:.2}", sol.theta);
    println!("iterations: {}", sol.iterations);
    println!("device  flops(T)  up(Mbps)  batch  cut");
    for (i, d) in fleet.iter().enumerate() {
        println!(
            "{:>6}  {:>8.2}  {:>8.1}  {:>5}  {:>3}",
            i,
            d.flops / 1e12,
            d.up_bps / 1e6,
            sol.decisions.batch[i],
            sol.decisions.cut[i]
        );
    }
    Ok(())
}

fn cmd_latency(args: &Args) -> hasfl::Result<()> {
    let batch = args.get_or("batch", 16u32)?;
    let cut = args.get_or("cut", 8usize)?;
    let devices = args.get_or("devices", 20usize)?;
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let profile = profile_arg(args.get("model").unwrap_or("vgg16"), &artifacts)?;

    let mut cfg = Config::table1();
    cfg.fleet.n_devices = devices;
    let fleet = cfg.sample_fleet();
    let dec = Decisions::uniform(devices, batch, cut);
    let lat = round_latency(&profile, &fleet, &cfg.server, &dec);
    println!("model: {} batch: {batch} cut: {cut}", profile.name);
    println!("T_S (split round): {:.4}s", lat.t_split);
    println!("  server fwd+bwd : {:.4}s", lat.server_fwd + lat.server_bwd);
    println!("T_A (aggregation): {:.4}s", lat.t_agg);
    Ok(())
}

fn cmd_info(args: &Args) -> hasfl::Result<()> {
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let m = Manifest::load(&artifacts)?;
    println!("model: {} ({} classes)", m.model, m.num_classes);
    println!(
        "blocks: {} | cuts: {:?} | buckets: {:?}",
        m.num_blocks, m.valid_cuts, m.buckets
    );
    println!("artifacts: {}", m.artifacts.len());
    let total_bytes: u64 = m
        .artifacts
        .iter()
        .filter_map(|a| std::fs::metadata(m.dir.join(&a.path)).ok())
        .map(|md| md.len())
        .sum();
    println!("total HLO text: {:.1} MiB", total_bytes as f64 / (1024.0 * 1024.0));
    Ok(())
}

fn cmd_config(args: &Args) -> hasfl::Result<()> {
    let cfg = match args.get("preset").unwrap_or("table1") {
        "small" => Config::small(),
        "figure" => Config::figure_small(),
        "table1" => Config::table1(),
        p => anyhow::bail!("unknown preset '{p}'"),
    };
    match args.get("out") {
        Some(path) => {
            let path = PathBuf::from(path);
            cfg.save(&path)?;
            eprintln!("config -> {}", path.display());
        }
        None => println!("{}", cfg.to_json().dump()),
    }
    Ok(())
}

fn main() -> hasfl::Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("optimize") => cmd_optimize(&args),
        Some("latency") => cmd_latency(&args),
        Some("info") => cmd_info(&args),
        Some("config") => cmd_config(&args),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
}
