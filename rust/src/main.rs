//! `hasfl` — the HASFL leader CLI.
//!
//! ```text
//! hasfl train    [--preset small|figure|table1] [--config cfg.json]
//!                [--strategy hasfl|rbs_hams|habs_rms|rbs_rms|rbs_rhams|fixed]
//!                [--rounds N] [--devices N] [--seed S] [--non-iid]
//!                [--artifacts DIR] [--out history.csv] [--concurrent]
//!                [--pool N] [--early-stop] [--progress]
//! hasfl optimize [--devices N] [--model vgg16|resnet18|splitcnn8] [--seed S]
//! hasfl latency  [--batch B] [--cut C] [--model ...] [--devices N]
//! hasfl info     [--artifacts DIR]
//! hasfl config   [--preset small|figure|table1] [--out cfg.json]
//! ```

use std::path::PathBuf;

use hasfl::config::{Config, StrategyKind};
use hasfl::convergence::BoundParams;
use hasfl::experiment::{CsvHistory, EarlyStop, Experiment, Preset, ProgressLogger};
use hasfl::latency::{round_latency, Decisions};
use hasfl::metrics::{CONVERGENCE_ACC_THRESHOLD, CONVERGENCE_WINDOW};
use hasfl::model::{Manifest, ModelProfile};
use hasfl::optimizer::{solve_joint, OptContext};
use hasfl::rng::Pcg32;
use hasfl::runtime::EngineHandle;
use hasfl::util::Args;

const USAGE: &str = "usage: hasfl <train|optimize|latency|info|config> [options]";

fn profile_arg(name: &str, artifacts: &std::path::Path) -> hasfl::Result<ModelProfile> {
    Ok(match name {
        "vgg16" => ModelProfile::vgg16(),
        "resnet18" => ModelProfile::resnet18(),
        "splitcnn8" => {
            let manifest = Manifest::load(artifacts)?;
            ModelProfile::from_manifest(&manifest)
        }
        _ => anyhow::bail!("unknown model '{name}'"),
    })
}

fn cmd_train(args: &Args) -> hasfl::Result<()> {
    let mut builder = match args.get("config") {
        Some(path) => Experiment::builder().config(Config::load(std::path::Path::new(path))?),
        None => Experiment::builder().preset(Preset::parse(args.get("preset").unwrap_or("small"))?),
    };
    if let Some(s) = args.get("strategy") {
        builder = builder.strategy(StrategyKind::parse(s)?);
    }
    if let Some(r) = args.get_opt::<usize>("rounds")? {
        builder = builder.rounds(r);
    }
    if let Some(n) = args.get_opt::<usize>("devices")? {
        builder = builder.devices(n);
    }
    if let Some(s) = args.get_opt::<u64>("seed")? {
        builder = builder.seed(s);
    }
    if args.flag("non-iid") {
        builder = builder.non_iid();
    }
    if let Some(p) = args.get_opt::<usize>("pool")? {
        builder = builder.engine_pool(p);
    }
    builder = builder
        .artifacts(args.get("artifacts").unwrap_or("artifacts"))
        .concurrent(args.flag("concurrent"));
    let out = args.get("out").map(PathBuf::from);
    if let Some(path) = &out {
        builder = builder.observe(CsvHistory::new(path));
    }
    if args.flag("early-stop") {
        builder = builder.observe(EarlyStop::paper_default());
    }
    if args.flag("progress") {
        builder = builder.observe(ProgressLogger);
    }

    let mut session = builder.build()?;
    {
        let cfg = session.config();
        eprintln!(
            "training: N={} rounds={} strategy={} partition={}",
            cfg.fleet.n_devices,
            cfg.train.rounds,
            cfg.strategy.as_str(),
            cfg.partition.as_str()
        );
    }
    session.run_to_completion()?;

    if let Some(&(round, time, acc)) = session.history().eval_points().last() {
        eprintln!(
            "done: round {round} sim_time {time:.1}s test_acc {:.2}% loss {:.4}",
            acc * 100.0,
            session.history().last_loss().unwrap_or(f64::NAN)
        );
    }
    if let Some((round, time, acc)) =
        session.history().converged(CONVERGENCE_ACC_THRESHOLD, CONVERGENCE_WINDOW)
    {
        eprintln!("converged @ round {round}: {:.2}% after {time:.1}s", acc * 100.0);
    }
    let stats = session.engine_stats()?;
    eprintln!("engine: {}", stats.summary());
    session.finish()?; // flushes the CSV observer
    if let Some(path) = out {
        eprintln!("history -> {}", path.display());
    }
    Ok(())
}

fn cmd_optimize(args: &Args) -> hasfl::Result<()> {
    let devices = args.get_or("devices", 20usize)?;
    let seed = args.get_or("seed", 2025u64)?;
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let profile = profile_arg(args.get("model").unwrap_or("vgg16"), &artifacts)?;

    let cfg = Experiment::builder()
        .config(Config::table1())
        .devices(devices)
        .seed(seed)
        .build_config()?;
    let bound = BoundParams::default_for(&profile, cfg.train.lr);
    let fleet = cfg.sample_fleet();
    let ctx = OptContext {
        profile: &profile,
        devices: &fleet,
        server: &cfg.server,
        bound: &bound,
        interval: cfg.train.agg_interval,
        epsilon: cfg.train.epsilon,
        batch_cap: cfg.train.batch_cap,
    };
    let mut rng = Pcg32::new(seed, 0x0CD);
    let sol = solve_joint(&ctx, &mut rng, 8, 1e-6);
    println!("model: {}", profile.name);
    println!("theta (est. seconds to eps-convergence): {:.2}", sol.theta);
    println!("iterations: {}", sol.iterations);
    println!("device  flops(T)  up(Mbps)  batch  cut");
    for (i, d) in fleet.iter().enumerate() {
        println!(
            "{:>6}  {:>8.2}  {:>8.1}  {:>5}  {:>3}",
            i,
            d.flops / 1e12,
            d.up_bps / 1e6,
            sol.decisions.batch[i],
            sol.decisions.cut[i]
        );
    }
    Ok(())
}

fn cmd_latency(args: &Args) -> hasfl::Result<()> {
    let batch = args.get_or("batch", 16u32)?;
    let cut = args.get_or("cut", 8usize)?;
    let devices = args.get_or("devices", 20usize)?;
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let profile = profile_arg(args.get("model").unwrap_or("vgg16"), &artifacts)?;

    let cfg = Experiment::builder()
        .config(Config::table1())
        .devices(devices)
        .build_config()?;
    let fleet = cfg.sample_fleet();
    let dec = Decisions::uniform(devices, batch, cut);
    let lat = round_latency(&profile, &fleet, &cfg.server, &dec);
    println!("model: {} batch: {batch} cut: {cut}", profile.name);
    println!("T_S (split round): {:.4}s", lat.t_split);
    println!("  server fwd+bwd : {:.4}s", lat.server_fwd + lat.server_bwd);
    println!("T_A (aggregation): {:.4}s", lat.t_agg);
    Ok(())
}

fn cmd_info(args: &Args) -> hasfl::Result<()> {
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let m = Manifest::load(&artifacts)?;
    println!("model: {} ({} classes)", m.model, m.num_classes);
    println!(
        "blocks: {} | cuts: {:?} | buckets: {:?}",
        m.num_blocks, m.valid_cuts, m.buckets
    );
    println!("artifacts: {}", m.artifacts.len());
    let total_bytes: u64 = m
        .artifacts
        .iter()
        .filter_map(|a| std::fs::metadata(m.dir.join(&a.path)).ok())
        .map(|md| md.len())
        .sum();
    println!("total HLO text: {:.1} MiB", total_bytes as f64 / (1024.0 * 1024.0));

    // Runtime smoke (best-effort: `info` stays usable when the PJRT
    // runtime cannot initialize): spawn one engine lane, warm the smallest
    // monolithic artifact, and report the execution-statistics fields
    // (marshal split, buffer-cache counters, pool width).
    match engine_smoke(&artifacts, &m) {
        Ok(stats) => {
            println!("engine pool width: {} (info uses 1 lane; training uses", stats.pool_width);
            println!("  `engine_pool` from the config, 0 = auto = min(fleet, cores, 8))");
            println!("engine: {}", stats.summary());
            println!(
                "  upload {} B / download {} B / buffer hits {} ({} B) / misses {}",
                stats.upload_bytes,
                stats.download_bytes,
                stats.buffer_hits,
                stats.buffer_hit_bytes,
                stats.buffer_misses
            );
        }
        Err(e) => eprintln!("engine smoke skipped (PJRT unavailable): {e}"),
    }
    Ok(())
}

fn engine_smoke(
    artifacts: &std::path::Path,
    m: &Manifest,
) -> hasfl::Result<hasfl::runtime::EngineStats> {
    let engine = EngineHandle::spawn(artifacts.to_path_buf())?;
    let smallest = m.buckets.iter().copied().min().unwrap_or(1);
    engine.warm_blocking(&Manifest::full_name("full_fwd", smallest))?;
    let stats = engine.stats_blocking()?;
    engine.shutdown();
    Ok(stats)
}

fn cmd_config(args: &Args) -> hasfl::Result<()> {
    // Emits the *raw* preset configs (Table I keeps its analytic VGG-16
    // model here; `train --preset table1` swaps in the executable model).
    let cfg = match args.get("preset").unwrap_or("table1") {
        "small" => Config::small(),
        "figure" => Config::figure_small(),
        "table1" => Config::table1(),
        p => anyhow::bail!("unknown preset '{p}'"),
    };
    match args.get("out") {
        Some(path) => {
            let path = PathBuf::from(path);
            cfg.save(&path)?;
            eprintln!("config -> {}", path.display());
        }
        None => println!("{}", cfg.to_json().dump()),
    }
    Ok(())
}

fn main() -> hasfl::Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("optimize") => cmd_optimize(&args),
        Some("latency") => cmd_latency(&args),
        Some("info") => cmd_info(&args),
        Some("config") => cmd_config(&args),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::USAGE;

    #[test]
    fn usage_names_every_subcommand() {
        // The doc comment, USAGE string, and main() dispatch must stay in
        // sync; this guards the USAGE half.
        for sub in ["train", "optimize", "latency", "info", "config"] {
            assert!(USAGE.contains(sub), "USAGE is missing '{sub}'");
        }
    }
}
