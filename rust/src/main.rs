//! `hasfl` — the HASFL leader CLI.
//!
//! ```text
//! hasfl train    [--preset small|figure|table1] [--config cfg.json]
//!                [--strategy hasfl|rbs_hams|habs_rms|rbs_rms|rbs_rhams|fixed]
//!                [--rounds N] [--devices N] [--seed S] [--non-iid]
//!                [--backend auto|native|pjrt]
//!                [--scenario static|drifting-channels|diurnal|churn-heavy|mega-fleet|spec.json]
//!                [--faults flaky|chaos|spec.json] [--cells N] [--async-buffer K]
//!                [--artifacts DIR] [--out history.csv] [--fleet-out trace.csv]
//!                [--concurrent] [--pool N] [--early-stop] [--progress]
//!                [--checkpoint-every N] [--checkpoint-dir D] [--checkpoint-keep K]
//!                [--resume ckpt.hckpt]
//! hasfl scenario [--preset ...|--spec spec.json] [--devices N] [--rounds R]
//!                [--seed S] [--model vgg16|resnet18] [--strategy ...]
//!                [--out trace.csv]
//! hasfl optimize [--devices N] [--model vgg16|resnet18|splitcnn8] [--seed S]
//! hasfl latency  [--batch B] [--cut C] [--model ...] [--devices N]
//! hasfl info     [--artifacts DIR] [--backend auto|native|pjrt] [--json]
//! hasfl config   [--preset small|figure|table1] [--out cfg.json]
//! hasfl serve    [--addr HOST:PORT] [--state-dir DIR] [--workers N]
//!                [--artifacts DIR] [--max-conns N] [--io-timeout-ms MS]
//!                [--queue-cap N]
//! hasfl bench-diff --base BENCH_A.json --head BENCH_B.json
//!                [--max-regress PCT]
//! ```
//!
//! `--backend` picks the execution engine (DESIGN.md §11): `native` is the
//! pure-Rust backend that needs no AOT artifacts and no Python/XLA
//! toolchain; `pjrt` executes the AOT-lowered HLO artifacts; `auto` (the
//! default, also settable via `HASFL_BACKEND`) uses pjrt when artifacts
//! exist and native otherwise.

use std::path::PathBuf;

use hasfl::backend::{BackendKind, ModelSpec};
use hasfl::checkpoint::CheckpointObserver;
use hasfl::config::{Config, StrategyKind};
use hasfl::convergence::BoundParams;
use hasfl::experiment::{CsvHistory, EarlyStop, Experiment, FleetTraceCsv, Preset, ProgressLogger};
use hasfl::latency::{round_latency, Decisions};
use hasfl::metrics::{CONVERGENCE_ACC_THRESHOLD, CONVERGENCE_WINDOW};
use hasfl::model::{Manifest, ModelProfile};
use hasfl::optimizer::{solve_joint, OptContext};
use hasfl::rng::Pcg32;
use hasfl::fault::{FaultPreset, FaultSpec};
use hasfl::scenario::{Scenario, ScenarioPreset, ScenarioSim};
use hasfl::util::Args;

const USAGE: &str =
    "usage: hasfl <train|scenario|optimize|latency|info|config|serve|bench-diff> [options]";

/// Resolve a `--scenario` value: a path to a spec JSON (anything that
/// exists on disk) or a preset name.
fn scenario_arg(value: &str) -> hasfl::Result<Scenario> {
    let path = std::path::Path::new(value);
    if path.exists() {
        return Scenario::load(path);
    }
    ScenarioPreset::parse(value)
        .map(|p| p.scenario())
        .map_err(|e| anyhow::anyhow!("--scenario '{value}': no such spec file, and {e}"))
}

/// Resolve a `--faults` value: a path to a fault-spec JSON (anything that
/// exists on disk) or a preset name (`flaky`, `chaos`).
fn faults_arg(value: &str) -> hasfl::Result<FaultSpec> {
    let path = std::path::Path::new(value);
    if path.exists() {
        return FaultSpec::load(path);
    }
    FaultPreset::parse(value)
        .map(|p| p.spec())
        .map_err(|e| anyhow::anyhow!("--faults '{value}': no such spec file, and {e}"))
}

fn profile_arg(name: &str, artifacts: &std::path::Path) -> hasfl::Result<ModelProfile> {
    Ok(match name {
        "vgg16" => ModelProfile::vgg16(),
        "resnet18" => ModelProfile::resnet18(),
        "splitcnn8" => {
            // The on-disk manifest when AOT artifacts exist, the in-Rust
            // model spec otherwise — the cost tables are identical. Say
            // so out loud: a user who built non-default artifacts (e.g.
            // `make artifacts100`) must not silently get 10-class costs.
            let manifest = if artifacts.join("manifest.json").exists() {
                Manifest::load(artifacts)?
            } else {
                eprintln!(
                    "no AOT artifacts at '{}'; using the native 10-class SplitCNN-8 spec",
                    artifacts.display()
                );
                ModelSpec::splitcnn8(10).manifest()
            };
            ModelProfile::from_manifest(&manifest)
        }
        _ => anyhow::bail!("unknown model '{name}'"),
    })
}

fn cmd_train(args: &Args) -> hasfl::Result<()> {
    // `--resume` makes the checkpoint's embedded config authoritative.
    // Flags that would alter the training numerics are rejected loudly
    // instead of being silently ignored; only the round budget
    // (`--rounds`) and runtime-only knobs (`--pool`, `--concurrent`,
    // observers) apply on top.
    if args.get("resume").is_some() {
        for flag in [
            "config", "preset", "strategy", "devices", "seed", "scenario", "faults", "backend",
            "cells", "async-buffer",
        ] {
            anyhow::ensure!(
                args.get(flag).is_none(),
                "--{flag} conflicts with --resume (the checkpoint's embedded config is \
                 authoritative; only --rounds and runtime knobs like --pool apply)"
            );
        }
        anyhow::ensure!(
            !args.flag("non-iid"),
            "--non-iid conflicts with --resume (the checkpoint's embedded config is \
             authoritative)"
        );
    }
    let mut builder = match args.get("config") {
        Some(path) => Experiment::builder().config(Config::load(std::path::Path::new(path))?),
        None => Experiment::builder().preset(Preset::parse(args.get("preset").unwrap_or("small"))?),
    };
    if let Some(s) = args.get("strategy") {
        builder = builder.strategy(StrategyKind::parse(s)?);
    }
    if let Some(r) = args.get_opt::<usize>("rounds")? {
        builder = builder.rounds(r);
    }
    if let Some(n) = args.get_opt::<usize>("devices")? {
        builder = builder.devices(n);
    }
    if let Some(s) = args.get_opt::<u64>("seed")? {
        builder = builder.seed(s);
    }
    if args.flag("non-iid") {
        builder = builder.non_iid();
    }
    if let Some(p) = args.get_opt::<usize>("pool")? {
        builder = builder.engine_pool(p);
    }
    if let Some(b) = args.get("backend") {
        builder = builder.backend(BackendKind::parse(b)?);
    }
    if let Some(s) = args.get("scenario") {
        builder = builder.scenario(scenario_arg(s)?);
    }
    // Hierarchical cell topology (DESIGN.md §15): bit-identical numerics
    // at any cell count, per-cell reporting and lane affinity on top.
    // `--cells 0` = auto (one cell per engine lane).
    if let Some(c) = args.get_opt::<usize>("cells")? {
        builder = builder.cells(c);
    }
    // Seeded fault injection + graceful degradation (DESIGN.md §13).
    if let Some(f) = args.get("faults") {
        builder = builder.faults(faults_arg(f)?);
    }
    // Buffered-asynchronous rounds (DESIGN.md §16, docs/ASYNC.md): each
    // round flushes a staleness-weighted buffer of K completions instead
    // of waiting for the slowest device. The flag sets the buffer size
    // only; a config file's "async" section keeps its max_staleness and
    // decay (defaults otherwise).
    if let Some(k) = args.get_opt::<usize>("async-buffer")? {
        builder = builder.tune(|c| {
            let mut spec = c.async_spec.clone().unwrap_or_default();
            spec.buffer_k = k;
            c.async_spec = Some(spec);
        });
    }
    // Crash-safe checkpointing (DESIGN.md §10): periodic snapshots of the
    // complete training state, and bit-identical warm restarts from them.
    // `--resume` makes the checkpoint's embedded config authoritative
    // (an explicit `--rounds` still extends the budget).
    if let Some(path) = args.get("resume") {
        builder = builder.resume_from(path);
    }
    match args.get_opt::<usize>("checkpoint-every")? {
        Some(every) => {
            anyhow::ensure!(every >= 1, "--checkpoint-every must be >= 1");
            let dir = args.get("checkpoint-dir").unwrap_or("checkpoints");
            let keep = args.get_or("checkpoint-keep", 3usize)?;
            builder = builder.observe(CheckpointObserver::new(dir, every).keep_last(keep));
        }
        None => {
            // A typo'd cadence must not silently run 1000 rounds with no
            // crash protection.
            anyhow::ensure!(
                args.get("checkpoint-dir").is_none() && args.get("checkpoint-keep").is_none(),
                "--checkpoint-dir/--checkpoint-keep require --checkpoint-every"
            );
        }
    }
    builder = builder
        .artifacts(args.get("artifacts").unwrap_or("artifacts"))
        .concurrent(args.flag("concurrent"));
    let out = args.get("out").map(PathBuf::from);
    if let Some(path) = &out {
        builder = builder.observe(CsvHistory::new(path));
    }
    if let Some(path) = args.get("fleet-out") {
        builder = builder.observe(FleetTraceCsv::new(path));
    }
    if args.flag("early-stop") {
        builder = builder.observe(EarlyStop::paper_default());
    }
    if args.flag("progress") {
        builder = builder.observe(ProgressLogger);
    }

    let mut session = builder.build()?;
    {
        let cfg = session.config();
        let cells = match &cfg.topology {
            Some(t) => format!(" cells={}", t.resolve_cells(session.engine_width())),
            None => String::new(),
        };
        eprintln!(
            "training: N={} rounds={} strategy={} partition={} backend={}{cells}",
            cfg.fleet.n_devices,
            cfg.train.rounds,
            cfg.strategy.as_str(),
            cfg.partition.as_str(),
            cfg.backend.as_str()
        );
    }
    // The run_to_completion loop, kept inline so the last round's per-cell
    // stats stay in hand for the end-of-run summary below.
    let mut last_cells = Vec::new();
    while !session.is_done() {
        let report = session.step()?;
        if !report.cells.is_empty() {
            last_cells = report.cells;
        }
        if session.stop_requested() {
            break;
        }
    }

    if let Some(&(round, time, acc)) = session.history().eval_points().last() {
        eprintln!(
            "done: round {round} sim_time {time:.1}s test_acc {:.2}% loss {:.4}",
            acc * 100.0,
            session.history().last_loss().unwrap_or(f64::NAN)
        );
    }
    if let Some((round, time, acc)) =
        session.history().converged(CONVERGENCE_ACC_THRESHOLD, CONVERGENCE_WINDOW)
    {
        eprintln!("converged @ round {round}: {:.2}% after {time:.1}s", acc * 100.0);
    }
    if !last_cells.is_empty() {
        eprintln!("cells (final round):");
        for c in &last_cells {
            eprintln!(
                "  cell {}: {}/{} participants, {} abandoned, t_split {:.4}s",
                c.cell, c.participants, c.devices, c.abandoned, c.t_split
            );
        }
    }
    let stats = session.engine_stats()?;
    eprintln!("engine: {}", stats.summary());
    session.finish()?; // flushes the CSV observer
    if let Some(path) = out {
        eprintln!("history -> {}", path.display());
    }
    Ok(())
}

fn cmd_scenario(args: &Args) -> hasfl::Result<()> {
    // Analytic dynamic-fleet simulation: scenario engine + latency model +
    // BS/MS optimizer, no PJRT runtime (scales to 1k+ devices).
    let (preset, scenario) = match args.get("spec") {
        Some(path) => (None, Scenario::load(std::path::Path::new(path))?),
        None => {
            let p = ScenarioPreset::parse(args.get("preset").unwrap_or("drifting-channels"))?;
            (Some(p), p.scenario())
        }
    };
    let default_devices = preset.and_then(|p| p.suggested_devices()).unwrap_or(20);
    let devices = args.get_or("devices", default_devices)?;
    let rounds = args.get_or("rounds", 100usize)?;
    anyhow::ensure!(rounds >= 1, "--rounds must be >= 1");
    let seed = args.get_or("seed", 2025u64)?;

    let mut cfg = Config::table1();
    cfg.fleet.n_devices = devices;
    cfg.seed = seed;
    cfg.model = hasfl::config::ModelKind::parse(args.get("model").unwrap_or("vgg16"))?;
    cfg.strategy = match args.get("strategy") {
        Some(s) => StrategyKind::parse(s)?,
        None => preset
            .and_then(|p| p.suggested_strategy())
            .unwrap_or(cfg.strategy),
    };

    let mut sim = ScenarioSim::new(cfg, scenario.clone())?;
    eprintln!(
        "scenario '{}': N={devices} rounds={rounds} strategy={} seed={seed}",
        scenario.name,
        sim.config().strategy.as_str()
    );
    sim.run(rounds);

    let trace = sim.trace();
    let split = trace.split_summary().expect("rounds >= 1");
    let drift = trace.drift_summary().expect("rounds >= 1");
    println!("rounds: {} | sim_time: {:.2}s", trace.len(), sim.sim_time());
    println!(
        "active: final {} | partial rounds: {} | re-solves: {}",
        trace.rounds.last().map_or(0, |r| r.n_active),
        trace.partial_rounds(),
        trace.resolves()
    );
    println!(
        "t_split: p50 {:.4}s p95 {:.4}s max {:.4}s | drift: p50 {:.4} max {:.4}",
        split.p50, split.p95, split.max, drift.p50, drift.max
    );
    if let Some(path) = args.get("out") {
        let path = PathBuf::from(path);
        trace.write_csv(&path)?;
        eprintln!("fleet trace -> {}", path.display());
    }
    Ok(())
}

fn cmd_optimize(args: &Args) -> hasfl::Result<()> {
    let devices = args.get_or("devices", 20usize)?;
    let seed = args.get_or("seed", 2025u64)?;
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let profile = profile_arg(args.get("model").unwrap_or("vgg16"), &artifacts)?;

    let cfg = Experiment::builder()
        .config(Config::table1())
        .devices(devices)
        .seed(seed)
        .build_config()?;
    let bound = BoundParams::default_for(&profile, cfg.train.lr);
    let fleet = cfg.sample_fleet();
    let ctx = OptContext {
        profile: &profile,
        devices: &fleet,
        server: &cfg.server,
        bound: &bound,
        interval: cfg.train.agg_interval,
        epsilon: cfg.train.epsilon,
        batch_cap: cfg.train.batch_cap,
    };
    let mut rng = Pcg32::new(seed, 0x0CD);
    let sol = solve_joint(&ctx, &mut rng, 8, 1e-6);
    println!("model: {}", profile.name);
    println!("theta (est. seconds to eps-convergence): {:.2}", sol.theta);
    println!("iterations: {}", sol.iterations);
    println!("device  flops(T)  up(Mbps)  batch  cut");
    for (i, d) in fleet.iter().enumerate() {
        println!(
            "{:>6}  {:>8.2}  {:>8.1}  {:>5}  {:>3}",
            i,
            d.flops / 1e12,
            d.up_bps / 1e6,
            sol.decisions.batch[i],
            sol.decisions.cut[i]
        );
    }
    Ok(())
}

fn cmd_latency(args: &Args) -> hasfl::Result<()> {
    let batch = args.get_or("batch", 16u32)?;
    let cut = args.get_or("cut", 8usize)?;
    let devices = args.get_or("devices", 20usize)?;
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let profile = profile_arg(args.get("model").unwrap_or("vgg16"), &artifacts)?;

    let cfg = Experiment::builder()
        .config(Config::table1())
        .devices(devices)
        .build_config()?;
    let fleet = cfg.sample_fleet();
    let dec = Decisions::uniform(devices, batch, cut);
    let lat = round_latency(&profile, &fleet, &cfg.server, &dec);
    println!("model: {} batch: {batch} cut: {cut}", profile.name);
    println!("T_S (split round): {:.4}s", lat.t_split);
    println!("  server fwd+bwd : {:.4}s", lat.server_fwd + lat.server_bwd);
    println!("T_A (aggregation): {:.4}s", lat.t_agg);
    Ok(())
}

fn cmd_info(args: &Args) -> hasfl::Result<()> {
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let kind = match args.get("backend") {
        Some(b) => BackendKind::parse(b)?,
        None => BackendKind::from_env().unwrap_or(BackendKind::Auto),
    }
    .resolve(&artifacts);
    if args.flag("json") {
        // The same document the serve daemon answers on GET /info and
        // /healthz, so probes parse one schema either way.
        println!("{}", hasfl::serve::info_json(kind, &artifacts)?.dump());
        return Ok(());
    }
    let m = match kind {
        BackendKind::Pjrt => Manifest::load(&artifacts)?,
        // `info` has no class flag; the native spec defaults to the
        // 10-class model every preset trains.
        _ => ModelSpec::splitcnn8(10).manifest(),
    };
    println!("backend: {}", kind.as_str());
    println!("model: {} ({} classes)", m.model, m.num_classes);
    println!(
        "blocks: {} | cuts: {:?} | buckets: {:?}",
        m.num_blocks, m.valid_cuts, m.buckets
    );
    println!("artifacts: {}", m.artifacts.len());
    if kind == BackendKind::Pjrt {
        let total_bytes: u64 = m
            .artifacts
            .iter()
            .filter_map(|a| std::fs::metadata(m.dir.join(&a.path)).ok())
            .map(|md| md.len())
            .sum();
        println!("total HLO text: {:.1} MiB", total_bytes as f64 / (1024.0 * 1024.0));
    } else {
        println!("total HLO text: 0.0 MiB (native backend synthesizes the manifest)");
    }

    // Runtime smoke (best-effort: `info` stays usable when the PJRT
    // runtime cannot initialize): spawn one engine lane, warm the smallest
    // monolithic artifact, and report the execution-statistics fields
    // (marshal split, buffer-cache counters, pool width).
    match hasfl::serve::engine_smoke(kind, &artifacts, &m) {
        Ok(stats) => {
            println!("engine pool width: {} (info uses 1 lane; training uses", stats.pool_width);
            println!("  `engine_pool` from the config, 0 = auto = min(fleet, cores, 8))");
            println!("engine: {}", stats.summary());
            println!(
                "  upload {} B / download {} B / buffer hits {} ({} B) / misses {}",
                stats.upload_bytes,
                stats.download_bytes,
                stats.buffer_hits,
                stats.buffer_hit_bytes,
                stats.buffer_misses
            );
        }
        Err(e) => eprintln!("engine smoke skipped (backend unavailable): {e}"),
    }
    Ok(())
}

fn cmd_config(args: &Args) -> hasfl::Result<()> {
    // Emits the *raw* preset configs (Table I keeps its analytic VGG-16
    // model here; `train --preset table1` swaps in the executable model).
    let cfg = match args.get("preset").unwrap_or("table1") {
        "small" => Config::small(),
        "figure" => Config::figure_small(),
        "table1" => Config::table1(),
        p => anyhow::bail!("unknown preset '{p}'"),
    };
    match args.get("out") {
        Some(path) => {
            let path = PathBuf::from(path);
            cfg.save(&path)?;
            eprintln!("config -> {}", path.display());
        }
        None => println!("{}", cfg.to_json().dump()),
    }
    Ok(())
}

/// SIGINT/SIGTERM flag for `hasfl serve` (set from the handler, polled by
/// the main loop). No libc crate in the no-new-deps world, so the handler
/// is registered through `signal(2)` directly.
#[cfg(unix)]
static SERVE_SHUTDOWN: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

#[cfg(unix)]
fn install_shutdown_signals() {
    extern "C" fn on_signal(_sig: i32) {
        SERVE_SHUTDOWN.store(true, std::sync::atomic::Ordering::SeqCst);
    }
    extern "C" {
        fn signal(sig: i32, handler: usize) -> usize;
    }
    unsafe {
        signal(2, on_signal as usize); // SIGINT
        signal(15, on_signal as usize); // SIGTERM
    }
}

#[cfg(unix)]
fn shutdown_signalled() -> bool {
    SERVE_SHUTDOWN.load(std::sync::atomic::Ordering::SeqCst)
}

#[cfg(not(unix))]
fn install_shutdown_signals() {}

#[cfg(not(unix))]
fn shutdown_signalled() -> bool {
    false
}

fn cmd_serve(args: &Args) -> hasfl::Result<()> {
    let cfg = hasfl::serve::ServeConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:4780").to_string(),
        state_dir: PathBuf::from(args.get("state-dir").unwrap_or("serve-state")),
        workers: args.get_or("workers", 2usize)?,
        artifacts: PathBuf::from(args.get("artifacts").unwrap_or("artifacts")),
        max_conns: args.get_or("max-conns", hasfl::serve::DEFAULT_MAX_CONNS)?,
        io_timeout: std::time::Duration::from_millis(args.get_or("io-timeout-ms", 10_000u64)?),
        queue_cap: args.get_or("queue-cap", hasfl::serve::DEFAULT_QUEUE_CAP)?,
    };
    install_shutdown_signals();
    let daemon = hasfl::serve::Daemon::start(cfg)?;
    eprintln!(
        "hasfl serve: listening on http://{} ({} live session{})",
        daemon.addr(),
        daemon.live_sessions(),
        if daemon.live_sessions() == 1 { "" } else { "s" }
    );
    while !shutdown_signalled() && !daemon.shutdown_requested() {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    eprintln!("hasfl serve: shutting down (checkpointing live sessions)");
    daemon.stop()
}

fn cmd_bench_diff(args: &Args) -> hasfl::Result<()> {
    let base_path = args.get("base").ok_or_else(|| anyhow::anyhow!("--base is required"))?;
    let head_path = args.get("head").ok_or_else(|| anyhow::anyhow!("--head is required"))?;
    let max_regress = args.get_or("max-regress", 25.0f64)?;
    let load = |p: &str| -> hasfl::Result<hasfl::util::Json> {
        let text = std::fs::read_to_string(p)
            .map_err(|e| anyhow::anyhow!("cannot read '{p}': {e}"))?;
        hasfl::util::Json::parse(&text).map_err(|e| anyhow::anyhow!("'{p}': {e}"))
    };
    let base = load(base_path)?;
    let head = load(head_path)?;
    // Environment skew (different pool width, core count, backend, ...)
    // makes latency deltas apples-to-oranges: warn loudly, never gate.
    for w in hasfl::metrics::bench_meta_mismatches(&base, &head) {
        eprintln!("WARNING: bench environments differ — {w}");
    }
    let deltas = hasfl::metrics::bench_diff(&base, &head);
    anyhow::ensure!(
        !deltas.is_empty(),
        "'{base_path}' and '{head_path}' share no numeric fields — not comparable bench reports"
    );
    println!("{:<40} {:>14} {:>14} {:>9}", "metric", "base", "head", "delta");
    for d in &deltas {
        println!("{:<40} {:>14.6} {:>14.6} {:>+8.2}%", d.path, d.base, d.head, d.delta_pct);
    }
    let regressions = hasfl::metrics::bench_regressions(&deltas, max_regress);
    if !regressions.is_empty() {
        for d in &regressions {
            eprintln!("REGRESSION: {} {:+.2}% (limit {max_regress}%)", d.path, d.delta_pct);
        }
        anyhow::bail!(
            "{} tail-latency metric(s) regressed beyond {max_regress}%",
            regressions.len()
        );
    }
    eprintln!("ok: no p50/p95 regression beyond {max_regress}%");
    Ok(())
}

fn main() -> hasfl::Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("scenario") => cmd_scenario(&args),
        Some("optimize") => cmd_optimize(&args),
        Some("latency") => cmd_latency(&args),
        Some("info") => cmd_info(&args),
        Some("config") => cmd_config(&args),
        Some("serve") => cmd_serve(&args),
        Some("bench-diff") => cmd_bench_diff(&args),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::USAGE;

    #[test]
    fn usage_names_every_subcommand() {
        // The doc comment, USAGE string, and main() dispatch must stay in
        // sync; this guards the USAGE half.
        for sub in
            ["train", "scenario", "optimize", "latency", "info", "config", "serve", "bench-diff"]
        {
            assert!(USAGE.contains(sub), "USAGE is missing '{sub}'");
        }
    }
}
