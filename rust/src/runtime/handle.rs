//! Thread-safe handle to a pool of dedicated engine threads, generic over
//! the execution backend (DESIGN.md §11).
//!
//! PJRT wrapper types hold raw pointers and are not `Send`, so each engine
//! lives on its own OS thread ("lane") that owns its executable cache and
//! parameter-buffer cache; coordinator actors (device threads) talk to
//! lanes through mpsc request channels with per-request reply channels. A
//! single CPU PJRT client serializes compute, so concurrent rounds only
//! overlap for real when the pool has width > 1 (measured in
//! rust/benches/e2e_round.rs). Native lanes follow the same shape: the
//! pure-Rust engine is `Send`, but keeping it behind lane threads makes
//! the two backends interchangeable and per-lane stats meaningful.

use std::path::PathBuf;
use std::sync::mpsc;

use super::engine::{Engine, EngineStats, ExecInput, HostTensor};
use crate::backend::{BackendKind, ModelSpec, NativeEngine};
use crate::model::Manifest;

/// What a lane thread should construct: the resolved backend plus the
/// context it needs (artifacts directory for PJRT, model spec for native).
#[derive(Debug, Clone)]
pub enum EngineSpec {
    /// PJRT over an AOT artifacts directory.
    Pjrt { artifacts_dir: PathBuf },
    /// Pure-Rust engine for `classes`-way SplitCNN-8.
    Native { classes: usize },
}

impl EngineSpec {
    /// Resolve a backend kind into a lane spec (`Auto` resolves against
    /// the artifacts directory).
    pub fn resolve(
        kind: BackendKind,
        artifacts_dir: &std::path::Path,
        classes: usize,
    ) -> EngineSpec {
        match kind.resolve(artifacts_dir) {
            BackendKind::Pjrt => EngineSpec::Pjrt { artifacts_dir: artifacts_dir.to_path_buf() },
            _ => EngineSpec::Native { classes },
        }
    }

    /// The concrete backend this spec builds.
    pub fn kind(&self) -> BackendKind {
        match self {
            EngineSpec::Pjrt { .. } => BackendKind::Pjrt,
            EngineSpec::Native { .. } => BackendKind::Native,
        }
    }

    /// The manifest this spec's engine serves: loaded from disk for PJRT,
    /// synthesized in-process for native. The single source of truth for
    /// every caller that pairs an engine pool with its manifest.
    pub fn manifest(&self) -> crate::Result<Manifest> {
        match self {
            EngineSpec::Pjrt { artifacts_dir } => Manifest::load(artifacts_dir),
            EngineSpec::Native { classes } => Ok(ModelSpec::splitcnn8(*classes).manifest()),
        }
    }
}

/// One lane's engine: either backend behind the same execute/warm/stats
/// surface.
enum LaneEngine {
    Pjrt(Box<Engine>),
    Native(Box<NativeEngine>),
}

impl LaneEngine {
    fn build(spec: &EngineSpec) -> crate::Result<LaneEngine> {
        Ok(match spec {
            EngineSpec::Pjrt { artifacts_dir } => {
                LaneEngine::Pjrt(Box::new(Engine::load(artifacts_dir)?))
            }
            EngineSpec::Native { classes } => {
                LaneEngine::Native(Box::new(NativeEngine::new(ModelSpec::splitcnn8(*classes))))
            }
        })
    }

    fn execute(&mut self, name: &str, inputs: &[ExecInput]) -> crate::Result<Vec<HostTensor>> {
        match self {
            LaneEngine::Pjrt(e) => e.execute(name, inputs),
            LaneEngine::Native(e) => e.execute(name, inputs),
        }
    }

    fn warm(&mut self, name: &str) -> crate::Result<bool> {
        match self {
            LaneEngine::Pjrt(e) => e.warm(name),
            LaneEngine::Native(e) => e.warm(name),
        }
    }

    fn stats(&self) -> EngineStats {
        match self {
            LaneEngine::Pjrt(e) => e.stats().clone(),
            LaneEngine::Native(e) => e.stats().clone(),
        }
    }
}

enum Request {
    Execute {
        name: String,
        inputs: Vec<ExecInput>,
        resp: mpsc::Sender<crate::Result<Vec<HostTensor>>>,
    },
    Warm {
        name: String,
        resp: mpsc::Sender<crate::Result<bool>>,
    },
    Stats {
        resp: mpsc::Sender<EngineStats>,
    },
    Shutdown,
}

/// Cloneable handle to the engine pool. Each clone carries its own channel
/// senders, so handles can move freely into device threads.
#[derive(Clone)]
pub struct EngineHandle {
    lanes: Vec<mpsc::Sender<Request>>,
    backend: BackendKind,
}

fn spawn_lane(spec: EngineSpec, lane: usize) -> crate::Result<mpsc::Sender<Request>> {
    let (tx, rx) = mpsc::channel::<Request>();
    let (ready_tx, ready_rx) = mpsc::channel::<crate::Result<()>>();
    std::thread::Builder::new()
        .name(format!("{}-engine-{lane}", spec.kind().as_str()))
        .spawn(move || {
            let mut engine = match LaneEngine::build(&spec) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Execute { name, inputs, resp } => {
                        let _ = resp.send(engine.execute(&name, &inputs));
                    }
                    Request::Warm { name, resp } => {
                        let _ = resp.send(engine.warm(&name));
                    }
                    Request::Stats { resp } => {
                        let _ = resp.send(engine.stats());
                    }
                    Request::Shutdown => break,
                }
            }
        })
        .expect("spawn engine thread");
    ready_rx.recv().expect("engine thread alive")?;
    Ok(tx)
}

impl EngineHandle {
    /// Spawn a single-lane PJRT engine over an artifacts directory (the
    /// seed behaviour; numerics are identical at any width).
    pub fn spawn(artifacts_dir: PathBuf) -> crate::Result<EngineHandle> {
        EngineHandle::spawn_pool(artifacts_dir, 1)
    }

    /// Spawn a PJRT engine pool of `width` lanes over an artifacts
    /// directory (backwards-compatible entry point; backend-aware callers
    /// use [`EngineHandle::spawn_backend`]).
    pub fn spawn_pool(artifacts_dir: PathBuf, width: usize) -> crate::Result<EngineHandle> {
        EngineHandle::spawn_backend(EngineSpec::Pjrt { artifacts_dir }, width)
    }

    /// Spawn a single-lane native engine (no artifacts needed).
    pub fn spawn_native(classes: usize) -> crate::Result<EngineHandle> {
        EngineHandle::spawn_backend(EngineSpec::Native { classes }, 1)
    }

    /// Spawn an engine pool of `width` lanes (clamped to >= 1) over the
    /// given backend spec. Each lane owns its own engine and compiles (or,
    /// natively, dispatches) lazily, so lanes only pay for the artifacts
    /// they actually execute.
    pub fn spawn_backend(spec: EngineSpec, width: usize) -> crate::Result<EngineHandle> {
        let width = width.max(1);
        let backend = spec.kind();
        let mut lanes = Vec::with_capacity(width);
        for lane in 0..width {
            match spawn_lane(spec.clone(), lane) {
                Ok(tx) => lanes.push(tx),
                Err(e) => {
                    for tx in &lanes {
                        let _ = tx.send(Request::Shutdown);
                    }
                    return Err(e);
                }
            }
        }
        Ok(EngineHandle { lanes, backend })
    }

    /// The concrete backend this pool runs on.
    pub fn backend(&self) -> BackendKind {
        self.backend
    }

    /// Number of engine lanes in the pool.
    pub fn width(&self) -> usize {
        self.lanes.len()
    }

    /// Execute an artifact on lane 0 with fresh (uncached) inputs. This is
    /// the seed-compatible entry point used by tests and micro-benches.
    pub fn execute_blocking(
        &self,
        name: &str,
        inputs: Vec<HostTensor>,
    ) -> crate::Result<Vec<HostTensor>> {
        let inputs = inputs.into_iter().map(ExecInput::Fresh).collect();
        self.execute_inputs_blocking(0, name, inputs)
    }

    /// Execute an artifact on a specific lane (`lane % width`), blocking
    /// the calling thread until done. Versioned inputs hit that lane's
    /// parameter-buffer cache.
    pub fn execute_inputs_blocking(
        &self,
        lane: usize,
        name: &str,
        inputs: Vec<ExecInput>,
    ) -> crate::Result<Vec<HostTensor>> {
        let lane = lane % self.lanes.len();
        let (resp, rx) = mpsc::channel();
        self.lanes[lane]
            .send(Request::Execute { name: name.to_string(), inputs, resp })
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine dropped reply"))?
    }

    /// Pre-compile an artifact on every lane (returns true if any lane had
    /// a cache miss; always false on native lanes, which have nothing to
    /// compile).
    pub fn warm_blocking(&self, name: &str) -> crate::Result<bool> {
        let mut missed = false;
        for tx in &self.lanes {
            let (resp, rx) = mpsc::channel();
            tx.send(Request::Warm { name: name.to_string(), resp })
                .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
            missed |= rx.recv().map_err(|_| anyhow::anyhow!("engine dropped reply"))??;
        }
        Ok(missed)
    }

    /// Pool-wide statistics: per-lane stats merged, with `pool_width`
    /// reporting the number of lanes.
    pub fn stats_blocking(&self) -> crate::Result<EngineStats> {
        let mut total = EngineStats::default();
        for tx in &self.lanes {
            let (resp, rx) = mpsc::channel();
            tx.send(Request::Stats { resp })
                .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
            let lane = rx.recv().map_err(|_| anyhow::anyhow!("engine dropped reply"))?;
            total.merge(&lane);
        }
        Ok(total)
    }

    pub fn shutdown(&self) {
        for tx in &self.lanes {
            let _ = tx.send(Request::Shutdown);
        }
    }
}
