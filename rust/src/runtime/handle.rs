//! Thread-safe handle to a pool of dedicated engine threads.
//!
//! PJRT wrapper types hold raw pointers and are not `Send`, so each engine
//! lives on its own OS thread ("lane") that owns a PJRT CPU client, an
//! executable cache, and a parameter-buffer cache; coordinator actors
//! (device threads) talk to lanes through mpsc request channels with
//! per-request reply channels. A single CPU PJRT client serializes compute,
//! so concurrent rounds only overlap for real when the pool has width > 1
//! (measured in rust/benches/e2e_round.rs).

use std::path::PathBuf;
use std::sync::mpsc;

use super::engine::{Engine, EngineStats, ExecInput, HostTensor};

enum Request {
    Execute {
        name: String,
        inputs: Vec<ExecInput>,
        resp: mpsc::Sender<crate::Result<Vec<HostTensor>>>,
    },
    Warm {
        name: String,
        resp: mpsc::Sender<crate::Result<bool>>,
    },
    Stats {
        resp: mpsc::Sender<EngineStats>,
    },
    Shutdown,
}

/// Cloneable handle to the engine pool. Each clone carries its own channel
/// senders, so handles can move freely into device threads.
#[derive(Clone)]
pub struct EngineHandle {
    lanes: Vec<mpsc::Sender<Request>>,
}

fn spawn_lane(artifacts_dir: PathBuf, lane: usize) -> crate::Result<mpsc::Sender<Request>> {
    let (tx, rx) = mpsc::channel::<Request>();
    let (ready_tx, ready_rx) = mpsc::channel::<crate::Result<()>>();
    std::thread::Builder::new()
        .name(format!("pjrt-engine-{lane}"))
        .spawn(move || {
            let mut engine = match Engine::load(&artifacts_dir) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Execute { name, inputs, resp } => {
                        let _ = resp.send(engine.execute(&name, &inputs));
                    }
                    Request::Warm { name, resp } => {
                        let _ = resp.send(engine.warm(&name));
                    }
                    Request::Stats { resp } => {
                        let _ = resp.send(engine.stats().clone());
                    }
                    Request::Shutdown => break,
                }
            }
        })
        .expect("spawn engine thread");
    ready_rx.recv().expect("engine thread alive")?;
    Ok(tx)
}

impl EngineHandle {
    /// Spawn a single-lane engine over an artifacts directory (the seed
    /// behaviour; numerics are identical at any width).
    pub fn spawn(artifacts_dir: PathBuf) -> crate::Result<EngineHandle> {
        EngineHandle::spawn_pool(artifacts_dir, 1)
    }

    /// Spawn an engine pool of `width` lanes (clamped to >= 1). Each lane
    /// owns its own PJRT CPU client and compiles lazily, so lanes only pay
    /// for the artifacts they actually execute.
    pub fn spawn_pool(artifacts_dir: PathBuf, width: usize) -> crate::Result<EngineHandle> {
        let width = width.max(1);
        let mut lanes = Vec::with_capacity(width);
        for lane in 0..width {
            match spawn_lane(artifacts_dir.clone(), lane) {
                Ok(tx) => lanes.push(tx),
                Err(e) => {
                    for tx in &lanes {
                        let _ = tx.send(Request::Shutdown);
                    }
                    return Err(e);
                }
            }
        }
        Ok(EngineHandle { lanes })
    }

    /// Number of engine lanes in the pool.
    pub fn width(&self) -> usize {
        self.lanes.len()
    }

    /// Execute an artifact on lane 0 with fresh (uncached) inputs. This is
    /// the seed-compatible entry point used by tests and micro-benches.
    pub fn execute_blocking(
        &self,
        name: &str,
        inputs: Vec<HostTensor>,
    ) -> crate::Result<Vec<HostTensor>> {
        let inputs = inputs.into_iter().map(ExecInput::Fresh).collect();
        self.execute_inputs_blocking(0, name, inputs)
    }

    /// Execute an artifact on a specific lane (`lane % width`), blocking
    /// the calling thread until done. Versioned inputs hit that lane's
    /// parameter-buffer cache.
    pub fn execute_inputs_blocking(
        &self,
        lane: usize,
        name: &str,
        inputs: Vec<ExecInput>,
    ) -> crate::Result<Vec<HostTensor>> {
        let lane = lane % self.lanes.len();
        let (resp, rx) = mpsc::channel();
        self.lanes[lane]
            .send(Request::Execute { name: name.to_string(), inputs, resp })
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine dropped reply"))?
    }

    /// Pre-compile an artifact on every lane (returns true if any lane had
    /// a cache miss).
    pub fn warm_blocking(&self, name: &str) -> crate::Result<bool> {
        let mut missed = false;
        for tx in &self.lanes {
            let (resp, rx) = mpsc::channel();
            tx.send(Request::Warm { name: name.to_string(), resp })
                .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
            missed |= rx.recv().map_err(|_| anyhow::anyhow!("engine dropped reply"))??;
        }
        Ok(missed)
    }

    /// Pool-wide statistics: per-lane stats merged, with `pool_width`
    /// reporting the number of lanes.
    pub fn stats_blocking(&self) -> crate::Result<EngineStats> {
        let mut total = EngineStats::default();
        for tx in &self.lanes {
            let (resp, rx) = mpsc::channel();
            tx.send(Request::Stats { resp })
                .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
            let lane = rx.recv().map_err(|_| anyhow::anyhow!("engine dropped reply"))?;
            total.merge(&lane);
        }
        Ok(total)
    }

    pub fn shutdown(&self) {
        for tx in &self.lanes {
            let _ = tx.send(Request::Shutdown);
        }
    }
}
