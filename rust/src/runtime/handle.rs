//! Thread-safe handle to a dedicated engine thread.
//!
//! PJRT wrapper types hold raw pointers and are not `Send`, so the engine
//! lives on its own OS thread; coordinator actors (device threads) talk to
//! it through an mpsc request channel with per-request reply channels. On a
//! CPU PJRT client compute is serialized anyway, so a single engine thread
//! is not a bottleneck (measured in rust/benches/runtime_hotpath.rs).

use std::path::PathBuf;
use std::sync::mpsc;

use super::engine::{Engine, EngineStats, HostTensor};

enum Request {
    Execute {
        name: String,
        inputs: Vec<HostTensor>,
        resp: mpsc::Sender<crate::Result<Vec<HostTensor>>>,
    },
    Warm {
        name: String,
        resp: mpsc::Sender<crate::Result<bool>>,
    },
    Stats {
        resp: mpsc::Sender<EngineStats>,
    },
    Shutdown,
}

/// Cloneable handle to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Request>,
}

impl EngineHandle {
    /// Spawn the engine thread over an artifacts directory.
    pub fn spawn(artifacts_dir: PathBuf) -> crate::Result<EngineHandle> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<crate::Result<()>>();
        std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || {
                let mut engine = match Engine::load(&artifacts_dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Execute { name, inputs, resp } => {
                            let _ = resp.send(engine.execute(&name, &inputs));
                        }
                        Request::Warm { name, resp } => {
                            let _ = resp.send(engine.warm(&name));
                        }
                        Request::Stats { resp } => {
                            let _ = resp.send(engine.stats().clone());
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .expect("spawn engine thread");
        ready_rx.recv().expect("engine thread alive")?;
        Ok(EngineHandle { tx })
    }

    /// Execute an artifact (blocks the calling thread until done).
    pub fn execute_blocking(
        &self,
        name: &str,
        inputs: Vec<HostTensor>,
    ) -> crate::Result<Vec<HostTensor>> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Request::Execute { name: name.to_string(), inputs, resp })
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine dropped reply"))?
    }

    /// Pre-compile an artifact (returns true on a cache miss).
    pub fn warm_blocking(&self, name: &str) -> crate::Result<bool> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Request::Warm { name: name.to_string(), resp })
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine dropped reply"))?
    }

    pub fn stats_blocking(&self) -> crate::Result<EngineStats> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Request::Stats { resp })
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine dropped reply"))
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}
