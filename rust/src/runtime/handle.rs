//! Thread-safe handle to a pool of dedicated engine threads, generic over
//! the execution backend (DESIGN.md §11).
//!
//! PJRT wrapper types hold raw pointers and are not `Send`, so each engine
//! lives on its own OS thread ("lane") that owns its executable cache and
//! parameter-buffer cache; coordinator actors (device threads) talk to
//! lanes through mpsc request channels with per-request reply channels. A
//! single CPU PJRT client serializes compute, so concurrent rounds only
//! overlap for real when the pool has width > 1 (measured in
//! rust/benches/e2e_round.rs). Native lanes follow the same shape: the
//! pure-Rust engine is `Send`, but keeping it behind lane threads makes
//! the two backends interchangeable and per-lane stats meaningful.

use std::path::PathBuf;
use std::sync::{mpsc, Arc, Mutex, MutexGuard};
use std::time::Duration;

use super::engine::{Engine, EngineStats, ExecInput, HostTensor};
use crate::backend::{BackendKind, ModelSpec, NativeEngine};
use crate::model::Manifest;

/// What a lane thread should construct: the resolved backend plus the
/// context it needs (artifacts directory for PJRT, model spec for native).
#[derive(Debug, Clone)]
pub enum EngineSpec {
    /// PJRT over an AOT artifacts directory.
    Pjrt {
        /// Directory holding the AOT-compiled artifacts + manifest.json.
        artifacts_dir: PathBuf,
    },
    /// Pure-Rust engine for `classes`-way SplitCNN-8.
    Native {
        /// Number of classifier classes the synthesized model serves.
        classes: usize,
        /// Per-lane worker-thread budget for the blocked native kernels
        /// (DESIGN.md §14). `0` means auto: resolved at pool spawn to
        /// `max(1, cores / width)` so pooled lanes never oversubscribe
        /// the machine. Bit-neutral — thread count never changes output.
        threads: usize,
    },
}

impl EngineSpec {
    /// Resolve a backend kind into a lane spec (`Auto` resolves against
    /// the artifacts directory). Native specs start with the auto thread
    /// budget; [`EngineHandle::spawn_backend`] pins it per lane.
    pub fn resolve(
        kind: BackendKind,
        artifacts_dir: &std::path::Path,
        classes: usize,
    ) -> EngineSpec {
        match kind.resolve(artifacts_dir) {
            BackendKind::Pjrt => EngineSpec::Pjrt { artifacts_dir: artifacts_dir.to_path_buf() },
            _ => EngineSpec::Native { classes, threads: 0 },
        }
    }

    /// The concrete backend this spec builds.
    pub fn kind(&self) -> BackendKind {
        match self {
            EngineSpec::Pjrt { .. } => BackendKind::Pjrt,
            EngineSpec::Native { .. } => BackendKind::Native,
        }
    }

    /// The manifest this spec's engine serves: loaded from disk for PJRT,
    /// synthesized in-process for native. The single source of truth for
    /// every caller that pairs an engine pool with its manifest.
    pub fn manifest(&self) -> crate::Result<Manifest> {
        match self {
            EngineSpec::Pjrt { artifacts_dir } => Manifest::load(artifacts_dir),
            EngineSpec::Native { classes, .. } => Ok(ModelSpec::splitcnn8(*classes).manifest()),
        }
    }

    /// Pin the per-lane kernel thread budget for a pool of `width` lanes.
    /// A native spec with `threads == 0` (auto) gets `max(1, cores /
    /// width)` so the lanes of a pool collectively never oversubscribe
    /// the machine; the `HASFL_NATIVE_THREADS` environment variable
    /// overrides the computed per-lane budget. Explicit budgets and PJRT
    /// specs pass through unchanged. Purely a wall-clock decision: the
    /// budget never affects numerics (DESIGN.md §14).
    fn with_thread_budget(self, width: usize) -> EngineSpec {
        match self {
            EngineSpec::Native { classes, threads: 0 } => {
                let env = std::env::var("HASFL_NATIVE_THREADS")
                    .ok()
                    .and_then(|v| v.parse::<usize>().ok())
                    .filter(|&t| t >= 1);
                let threads = env.unwrap_or_else(|| {
                    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
                    (cores / width.max(1)).max(1)
                });
                EngineSpec::Native { classes, threads }
            }
            pinned => pinned,
        }
    }
}

/// One lane's engine: either backend behind the same execute/warm/stats
/// surface.
enum LaneEngine {
    Pjrt(Box<Engine>),
    Native(Box<NativeEngine>),
}

impl LaneEngine {
    fn build(spec: &EngineSpec) -> crate::Result<LaneEngine> {
        Ok(match spec {
            EngineSpec::Pjrt { artifacts_dir } => {
                LaneEngine::Pjrt(Box::new(Engine::load(artifacts_dir)?))
            }
            EngineSpec::Native { classes, threads } => {
                let model = ModelSpec::splitcnn8(*classes);
                LaneEngine::Native(Box::new(NativeEngine::with_threads(model, (*threads).max(1))))
            }
        })
    }

    fn execute(&mut self, name: &str, inputs: &[ExecInput]) -> crate::Result<Vec<HostTensor>> {
        match self {
            LaneEngine::Pjrt(e) => e.execute(name, inputs),
            LaneEngine::Native(e) => e.execute(name, inputs),
        }
    }

    fn warm(&mut self, name: &str) -> crate::Result<bool> {
        match self {
            LaneEngine::Pjrt(e) => e.warm(name),
            LaneEngine::Native(e) => e.warm(name),
        }
    }

    fn stats(&self) -> EngineStats {
        match self {
            LaneEngine::Pjrt(e) => e.stats().clone(),
            LaneEngine::Native(e) => e.stats().clone(),
        }
    }
}

enum Request {
    Execute {
        name: String,
        /// `Arc`-shared so the handle keeps a zero-copy replay reference:
        /// if the lane dies mid-job, supervision respawns it and resends
        /// the same inputs without ever cloning tensor data.
        inputs: Arc<Vec<ExecInput>>,
        resp: mpsc::Sender<crate::Result<Vec<HostTensor>>>,
    },
    Warm {
        name: String,
        resp: mpsc::Sender<crate::Result<bool>>,
    },
    Stats {
        resp: mpsc::Sender<EngineStats>,
    },
    /// Fault injection (`crate::fault`): the lane thread exits abruptly —
    /// no reply, no drain — exactly like a lane that segfaulted or was
    /// OOM-killed. Queued and in-flight requests observe a disconnected
    /// channel and flow into the supervision path.
    Crash,
    Shutdown,
}

/// One supervised lane: the live channel sender plus a generation counter
/// so concurrent callers that both observe a dead lane respawn it exactly
/// once (the loser of the lock race sees a bumped generation and just
/// retries on the fresh sender).
struct LaneSlot {
    gen: u64,
    tx: mpsc::Sender<Request>,
}

fn lock_slot(m: &Mutex<LaneSlot>) -> MutexGuard<'_, LaneSlot> {
    // A poisoned slot mutex only means another thread panicked while
    // holding it; the slot data (sender + generation) is always coherent.
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// How many times one execute call will respawn a dead lane before giving
/// up. Each attempt rebuilds the engine from the retained [`EngineSpec`],
/// so repeated failures here mean the backend itself cannot come up.
const LANE_RESPAWN_ATTEMPTS: usize = 3;

/// Cloneable handle to the engine pool. Each clone shares the supervised
/// lane slots, so a respawn performed by any caller is visible to all.
#[derive(Clone)]
pub struct EngineHandle {
    lanes: Arc<Vec<Mutex<LaneSlot>>>,
    /// Retained for lane supervision: a crashed lane is rebuilt from the
    /// same spec (fresh caches, identical numerics).
    spec: EngineSpec,
    backend: BackendKind,
}

fn spawn_lane(spec: EngineSpec, lane: usize) -> crate::Result<mpsc::Sender<Request>> {
    let (tx, rx) = mpsc::channel::<Request>();
    let (ready_tx, ready_rx) = mpsc::channel::<crate::Result<()>>();
    std::thread::Builder::new()
        .name(format!("{}-engine-{lane}", spec.kind().as_str()))
        .spawn(move || {
            let mut engine = match LaneEngine::build(&spec) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Execute { name, inputs, resp } => {
                        let _ = resp.send(engine.execute(&name, &inputs));
                    }
                    Request::Warm { name, resp } => {
                        let _ = resp.send(engine.warm(&name));
                    }
                    Request::Stats { resp } => {
                        let _ = resp.send(engine.stats());
                    }
                    // Injected crash: die without replying or draining.
                    Request::Crash => return,
                    Request::Shutdown => break,
                }
            }
        })
        .expect("spawn engine thread");
    ready_rx.recv().expect("engine thread alive")?;
    Ok(tx)
}

impl EngineHandle {
    /// Spawn a single-lane PJRT engine over an artifacts directory (the
    /// seed behaviour; numerics are identical at any width).
    pub fn spawn(artifacts_dir: PathBuf) -> crate::Result<EngineHandle> {
        EngineHandle::spawn_pool(artifacts_dir, 1)
    }

    /// Spawn a PJRT engine pool of `width` lanes over an artifacts
    /// directory (backwards-compatible entry point; backend-aware callers
    /// use [`EngineHandle::spawn_backend`]).
    pub fn spawn_pool(artifacts_dir: PathBuf, width: usize) -> crate::Result<EngineHandle> {
        EngineHandle::spawn_backend(EngineSpec::Pjrt { artifacts_dir }, width)
    }

    /// Spawn a single-lane native engine (no artifacts needed) with the
    /// auto kernel thread budget.
    pub fn spawn_native(classes: usize) -> crate::Result<EngineHandle> {
        EngineHandle::spawn_backend(EngineSpec::Native { classes, threads: 0 }, 1)
    }

    /// Spawn an engine pool of `width` lanes (clamped to >= 1) over the
    /// given backend spec. Each lane owns its own engine and compiles (or,
    /// natively, dispatches) lazily, so lanes only pay for the artifacts
    /// they actually execute. Native specs with the auto thread budget get
    /// it pinned here to `max(1, cores / width)` per lane
    /// ([`EngineSpec::Native`]), so wider pools run leaner lanes.
    pub fn spawn_backend(spec: EngineSpec, width: usize) -> crate::Result<EngineHandle> {
        let width = width.max(1);
        let spec = spec.with_thread_budget(width);
        let backend = spec.kind();
        let mut lanes = Vec::with_capacity(width);
        for lane in 0..width {
            match spawn_lane(spec.clone(), lane) {
                Ok(tx) => lanes.push(Mutex::new(LaneSlot { gen: 0, tx })),
                Err(e) => {
                    for slot in &lanes {
                        let _ = lock_slot(slot).tx.send(Request::Shutdown);
                    }
                    return Err(e);
                }
            }
        }
        Ok(EngineHandle { lanes: Arc::new(lanes), spec, backend })
    }

    /// The concrete backend this pool runs on.
    pub fn backend(&self) -> BackendKind {
        self.backend
    }

    /// Number of engine lanes in the pool.
    pub fn width(&self) -> usize {
        self.lanes.len()
    }

    /// Execute an artifact on lane 0 with fresh (uncached) inputs. This is
    /// the seed-compatible entry point used by tests and micro-benches.
    pub fn execute_blocking(
        &self,
        name: &str,
        inputs: Vec<HostTensor>,
    ) -> crate::Result<Vec<HostTensor>> {
        let inputs = inputs.into_iter().map(ExecInput::Fresh).collect();
        self.execute_inputs_blocking(0, name, inputs)
    }

    /// Execute an artifact on a specific lane (`lane % width`), blocking
    /// the calling thread until done. Versioned inputs hit that lane's
    /// parameter-buffer cache. Equivalent to
    /// [`EngineHandle::execute_inputs_deadline`] with no deadline.
    pub fn execute_inputs_blocking(
        &self,
        lane: usize,
        name: &str,
        inputs: Vec<ExecInput>,
    ) -> crate::Result<Vec<HostTensor>> {
        self.execute_inputs_deadline(lane, name, inputs, None)
    }

    /// Execute with lane supervision and an optional reply deadline.
    ///
    /// Supervision: a dead lane (crashed thread, injected or genuine) is
    /// respawned from the retained spec — at most `LANE_RESPAWN_ATTEMPTS`
    /// times per call — and the in-flight job replayed from its
    /// `Arc`-shared inputs. The fresh lane starts with cold caches;
    /// numerics are unaffected (the buffer cache is a packing
    /// optimisation, not state).
    ///
    /// Deadline: bounds the wait for the lane's reply. On expiry the call
    /// fails (the lane is *not* respawned — it is busy, not dead) and the
    /// eventual reply is discarded by the dropped channel.
    pub fn execute_inputs_deadline(
        &self,
        lane: usize,
        name: &str,
        inputs: Vec<ExecInput>,
        deadline: Option<Duration>,
    ) -> crate::Result<Vec<HostTensor>> {
        let idx = lane % self.lanes.len();
        let inputs = Arc::new(inputs);
        let mut respawn_err: Option<anyhow::Error> = None;
        for _ in 0..=LANE_RESPAWN_ATTEMPTS {
            let (gen, tx) = {
                let slot = lock_slot(&self.lanes[idx]);
                (slot.gen, slot.tx.clone())
            };
            let (resp, rx) = mpsc::channel();
            let sent = tx
                .send(Request::Execute {
                    name: name.to_string(),
                    inputs: Arc::clone(&inputs),
                    resp,
                })
                .is_ok();
            if sent {
                match deadline {
                    Some(d) => match rx.recv_timeout(d) {
                        Ok(res) => return res,
                        Err(mpsc::RecvTimeoutError::Timeout) => anyhow::bail!(
                            "engine lane {idx} exceeded the {}ms deadline for '{name}'",
                            d.as_millis()
                        ),
                        Err(mpsc::RecvTimeoutError::Disconnected) => {}
                    },
                    None => {
                        if let Ok(res) = rx.recv() {
                            return res;
                        }
                    }
                }
            }
            // Send failed or the lane died mid-job: respawn and replay.
            if let Err(e) = self.respawn(idx, gen) {
                respawn_err = Some(e);
                break;
            }
        }
        Err(match respawn_err {
            Some(e) => e.context(format!("engine lane {idx} died and could not be respawned")),
            None => anyhow::anyhow!(
                "engine lane {idx} kept dying: gave up after {LANE_RESPAWN_ATTEMPTS} respawns"
            ),
        })
    }

    /// Respawn lane `idx` if its generation still matches `seen_gen`
    /// (another caller may have already done it — the generation counter
    /// makes the respawn idempotent across racing threads).
    fn respawn(&self, idx: usize, seen_gen: u64) -> crate::Result<()> {
        let mut slot = lock_slot(&self.lanes[idx]);
        if slot.gen != seen_gen {
            return Ok(());
        }
        slot.tx = spawn_lane(self.spec.clone(), idx)?;
        slot.gen += 1;
        Ok(())
    }

    /// Fault-injection surface (`crate::fault`): make lane `lane % width`
    /// exit abruptly, as if its thread died. The next execute routed there
    /// flows through the supervision path (respawn + replay).
    pub fn inject_lane_crash(&self, lane: usize) {
        let idx = lane % self.lanes.len();
        let tx = lock_slot(&self.lanes[idx]).tx.clone();
        let _ = tx.send(Request::Crash);
    }

    /// Pre-compile an artifact on every lane (returns true if any lane had
    /// a cache miss; always false on native lanes, which have nothing to
    /// compile).
    pub fn warm_blocking(&self, name: &str) -> crate::Result<bool> {
        let mut missed = false;
        for slot in self.lanes.iter() {
            let tx = lock_slot(slot).tx.clone();
            let (resp, rx) = mpsc::channel();
            tx.send(Request::Warm { name: name.to_string(), resp })
                .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
            missed |= rx.recv().map_err(|_| anyhow::anyhow!("engine dropped reply"))??;
        }
        Ok(missed)
    }

    /// Pool-wide statistics: per-lane stats merged, with `pool_width`
    /// reporting the number of lanes.
    pub fn stats_blocking(&self) -> crate::Result<EngineStats> {
        let mut total = EngineStats::default();
        for slot in self.lanes.iter() {
            let tx = lock_slot(slot).tx.clone();
            let (resp, rx) = mpsc::channel();
            tx.send(Request::Stats { resp })
                .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
            let lane = rx.recv().map_err(|_| anyhow::anyhow!("engine dropped reply"))?;
            total.merge(&lane);
        }
        Ok(total)
    }

    /// Ask every lane thread to exit (best-effort; lanes drain their queue
    /// first).
    pub fn shutdown(&self) {
        for slot in self.lanes.iter() {
            let _ = lock_slot(slot).tx.send(Request::Shutdown);
        }
    }
}
