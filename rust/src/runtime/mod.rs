//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust training path.
//!
//! Two layers:
//! - [`Engine`] — owns the `xla::PjRtClient` and a lazily-populated cache of
//!   compiled executables keyed by artifact name. **Not `Send`** (PJRT
//!   wrappers hold raw pointers), so it must live on one thread.
//! - [`EngineHandle`] — a cloneable, thread-safe handle that proxies
//!   execution requests to a dedicated engine thread over channels. This is
//!   what the tokio coordinator actors use.

mod engine;
mod handle;

pub use engine::{Engine, EngineStats, HostTensor};
pub use handle::EngineHandle;

use crate::model::{Manifest, Tensor};

/// Convert a parameter tensor into a runtime host tensor (borrowing shape).
pub fn tensor_to_host(t: &Tensor) -> HostTensor {
    HostTensor { shape: t.shape.clone(), data: t.data.clone() }
}

/// Convert a runtime output back into a parameter tensor.
pub fn host_to_tensor(h: HostTensor) -> Tensor {
    Tensor { shape: h.shape, data: h.data }
}

/// Rescale a gradient computed on a padded bucket back to the true batch.
///
/// The model normalises the loss by sum(weights) == true batch size, so the
/// gradients are already exact for the true batch — no rescale is needed.
/// This helper exists to make that contract explicit and is verified by
/// `rust/tests/integration_runtime.rs` (padded vs unpadded equality).
pub fn padded_gradient_is_exact() -> bool {
    true
}

/// Resolve artifact names for one split step at a (cut, true-batch) pair.
#[derive(Debug, Clone, PartialEq)]
pub struct StepArtifacts {
    pub client_fwd: String,
    pub server_step: String,
    pub client_bwd: String,
    pub bucket: u32,
}

impl StepArtifacts {
    pub fn resolve(manifest: &Manifest, cut: usize, batch: u32) -> crate::Result<StepArtifacts> {
        let bucket = manifest
            .bucket_for(batch)
            .ok_or_else(|| anyhow::anyhow!("batch {batch} exceeds max exported bucket"))?;
        Ok(StepArtifacts {
            client_fwd: Manifest::split_name("client_fwd", cut, bucket),
            server_step: Manifest::split_name("server_step", cut, bucket),
            client_bwd: Manifest::split_name("client_bwd", cut, bucket),
            bucket,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_artifact_names() {
        // Use a synthetic manifest (no file IO) via the manifest test helper
        // pattern: construct directly.
        let mut m = Manifest {
            model: "splitcnn8".into(),
            num_classes: 10,
            img: 32,
            in_ch: 3,
            num_blocks: 8,
            valid_cuts: (1..8).collect(),
            buckets: vec![1, 2, 4, 8, 16, 32, 64],
            param_shapes: vec![],
            block_table: vec![],
            artifacts: vec![],
            dir: std::path::PathBuf::new(),
            index: Default::default(),
        };
        m.reindex();
        let sa = StepArtifacts::resolve(&m, 3, 11).unwrap();
        assert_eq!(sa.bucket, 16);
        assert_eq!(sa.client_fwd, "client_fwd_c3_b16");
        assert_eq!(sa.server_step, "server_step_c3_b16");
        assert!(StepArtifacts::resolve(&m, 3, 100).is_err());
    }
}
