//! Execution runtime: runs SplitCNN-8 step functions by artifact name on
//! one of two interchangeable backends (DESIGN.md §11) — the PJRT engine
//! over AOT HLO-text artifacts produced by `python/compile/aot.py`, or the
//! pure-Rust [`crate::backend::NativeEngine`] (no artifacts required).
//!
//! Two layers:
//! - [`Engine`] — the PJRT backend: owns an `xla::PjRtClient`, a
//!   lazily-populated cache of compiled executables keyed by artifact
//!   name, and a parameter-buffer cache of packed literals keyed by
//!   [`BufKey`] + version. **Not `Send`** (PJRT wrappers hold raw
//!   pointers), so each engine lives on one thread.
//! - [`EngineHandle`] — a cloneable, thread-safe handle that proxies
//!   execution requests to a pool of dedicated engine threads ("lanes")
//!   over channels, each lane running the backend selected by
//!   [`EngineSpec`]. Devices are routed to `lane = idx % width`, so
//!   concurrent rounds overlap for real when the pool has width > 1.
//!
//! Inputs cross the boundary as [`ExecInput`]: `Fresh` tensors (packed into
//! a literal on every call) or `Cached` tensors (packed once per version,
//! then served from the lane's buffer cache). The full data path is
//! documented in DESIGN.md §8.

mod engine;
mod handle;

pub use engine::{BufKey, Engine, EngineStats, ExecInput, HostTensor};
pub use handle::{EngineHandle, EngineSpec};

use std::sync::Arc;

use crate::model::{Manifest, Tensor};

/// Convert a parameter tensor into a runtime host tensor (borrowing shape).
pub fn tensor_to_host(t: &Tensor) -> HostTensor {
    HostTensor { shape: t.shape.clone(), data: t.data.clone() }
}

/// Convert a parameter tensor into a shared host tensor: the one host-side
/// copy a round makes per parameter. Everything downstream (device threads,
/// engine requests, the cf/cb double use) clones the `Arc`, not the data.
pub fn tensor_to_shared(t: &Tensor) -> Arc<HostTensor> {
    Arc::new(tensor_to_host(t))
}

/// Convert a runtime output back into a parameter tensor.
pub fn host_to_tensor(h: HostTensor) -> Tensor {
    Tensor { shape: h.shape, data: h.data }
}

/// Rescale a gradient computed on a padded bucket back to the true batch.
///
/// The model normalises the loss by sum(weights) == true batch size, so the
/// gradients are already exact for the true batch — no rescale is needed.
/// This helper exists to make that contract explicit and is verified by
/// `rust/tests/integration_runtime.rs` (padded vs unpadded equality).
pub fn padded_gradient_is_exact() -> bool {
    true
}

/// Resolve artifact names for one split step at a (cut, true-batch) pair.
#[derive(Debug, Clone, PartialEq)]
pub struct StepArtifacts {
    /// Artifact name of the client forward pass (step a1).
    pub client_fwd: String,
    /// Artifact name of the server step (loss + grads, step a3).
    pub server_step: String,
    /// Artifact name of the client backward pass (step a5).
    pub client_bwd: String,
    /// Batch bucket the three artifacts are specialised for.
    pub bucket: u32,
}

impl StepArtifacts {
    /// Pick the bucket for `batch` and derive the three artifact names.
    pub fn resolve(manifest: &Manifest, cut: usize, batch: u32) -> crate::Result<StepArtifacts> {
        let bucket = manifest
            .bucket_for(batch)
            .ok_or_else(|| anyhow::anyhow!("batch {batch} exceeds max exported bucket"))?;
        Ok(StepArtifacts {
            client_fwd: Manifest::split_name("client_fwd", cut, bucket),
            server_step: Manifest::split_name("server_step", cut, bucket),
            client_bwd: Manifest::split_name("client_bwd", cut, bucket),
            bucket,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_artifact_names() {
        // Use a synthetic manifest (no file IO) via the manifest test helper
        // pattern: construct directly.
        let mut m = Manifest {
            model: "splitcnn8".into(),
            num_classes: 10,
            img: 32,
            in_ch: 3,
            num_blocks: 8,
            valid_cuts: (1..8).collect(),
            buckets: vec![1, 2, 4, 8, 16, 32, 64],
            param_shapes: vec![],
            block_table: vec![],
            artifacts: vec![],
            dir: std::path::PathBuf::new(),
            index: Default::default(),
        };
        m.reindex();
        let sa = StepArtifacts::resolve(&m, 3, 11).unwrap();
        assert_eq!(sa.bucket, 16);
        assert_eq!(sa.client_fwd, "client_fwd_c3_b16");
        assert_eq!(sa.server_step, "server_step_c3_b16");
        assert!(StepArtifacts::resolve(&m, 3, 100).is_err());
    }

    #[test]
    fn exec_input_carries_its_tensor() {
        let t = HostTensor { shape: vec![2], data: vec![1.0, 2.0] };
        let fresh = ExecInput::Fresh(t.clone());
        assert_eq!(fresh.tensor(), &t);
        let cached = ExecInput::cached(BufKey { set: 3, slot: 7 }, 42, Arc::new(t.clone()));
        assert_eq!(cached.tensor(), &t);
        // Cloning a cached input is an Arc bump, not a data copy.
        let c2 = cached.clone();
        match (&cached, &c2) {
            (ExecInput::Cached { tensor: a, .. }, ExecInput::Cached { tensor: b, .. }) => {
                assert!(Arc::ptr_eq(a, b));
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn reserved_buf_sets_are_distinct() {
        let ids = [BufKey::COMMON_SET, BufKey::SYNC_SET, BufKey::EVAL_SET];
        for (i, a) in ids.iter().enumerate() {
            for b in &ids[i + 1..] {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn reserved_buf_set_values_are_pinned() {
        // The reserved ids are part of the buffer-cache keying contract:
        // moving any of them silently aliases cached literals across
        // devices, so their exact values are pinned here.
        assert_eq!(BufKey::COMMON_SET, u64::MAX);
        assert_eq!(BufKey::SYNC_SET, u64::MAX - 1);
        assert_eq!(BufKey::EVAL_SET, u64::MAX - 2);
        assert_eq!(BufKey::RESERVED_FLOOR, u64::MAX - 15);
        assert!(BufKey::RESERVED_FLOOR <= BufKey::EVAL_SET);
        assert_eq!(BufKey::SLOT_X, u32::MAX);
    }

    #[test]
    fn device_sets_never_collide_with_reserved_sets() {
        // Any realistic fleet index maps far below the reserved floor.
        for i in [0usize, 1, 1_000, 1_000_000, 1 << 40] {
            assert_eq!(BufKey::device_set(i), i as u64);
            assert!(BufKey::device_set(i) < BufKey::RESERVED_FLOOR);
        }
    }

    #[test]
    fn engine_stats_merge_sums_lanes() {
        let mut a = EngineStats {
            executions: 2,
            upload_secs: 0.5,
            download_secs: 0.25,
            upload_bytes: 100,
            buffer_hits: 3,
            buffer_hit_bytes: 40,
            pool_width: 1,
            ..EngineStats::default()
        };
        let b = EngineStats {
            executions: 1,
            upload_secs: 0.5,
            buffer_misses: 2,
            pool_width: 1,
            ..EngineStats::default()
        };
        a.merge(&b);
        assert_eq!(a.executions, 3);
        assert_eq!(a.pool_width, 2);
        assert_eq!(a.buffer_hits, 3);
        assert_eq!(a.buffer_misses, 2);
        assert!((a.marshal_secs() - 1.25).abs() < 1e-12);
        assert!(!a.summary().is_empty());
    }
}
