//! The PJRT engine: compile-on-first-use executable cache over the AOT
//! artifacts (pattern adapted from /opt/xla-example/load_hlo), plus an
//! engine-resident parameter-buffer cache so versioned tensors are packed
//! into PJRT literals once per version instead of once per execute.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::model::Manifest;

/// A host-side tensor (f32, row-major) crossing the engine boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    /// Row-major tensor shape (empty for scalars).
    pub shape: Vec<usize>,
    /// Flat element storage.
    pub data: Vec<f32>,
}

impl HostTensor {
    /// A rank-0 tensor holding one value.
    pub fn scalar(v: f32) -> HostTensor {
        HostTensor { shape: vec![], data: vec![v] }
    }

    /// Element count of the declared shape (scalars count as 1).
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Identity of a cacheable engine buffer: `(parameter set, tensor slot)`.
///
/// Sets `0..N` are the per-device parameter sets; the reserved ids below
/// mark regions that are provably identical across devices for a round, so
/// devices sharing an engine lane also share the packed literal
/// (invalidation rules: DESIGN.md §8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BufKey {
    /// Parameter-set id (device index or a reserved shared-set id).
    pub set: u64,
    /// Tensor slot within the set (global tensor index, or
    /// [`BufKey::SLOT_X`] for the input batch).
    pub slot: u32,
}

impl BufKey {
    /// Set id for the fleet-common server sub-model (averaged every round).
    pub const COMMON_SET: u64 = u64::MAX;
    /// Set id for the fully-synchronised model (round after a forged sync).
    pub const SYNC_SET: u64 = u64::MAX - 1;
    /// Set id for the evaluation-time global-average model.
    pub const EVAL_SET: u64 = u64::MAX - 2;
    /// Floor of the reserved set-id space: every id in
    /// `RESERVED_FLOOR..=u64::MAX` is reserved for the shared sets above
    /// (plus headroom for future ones). Device indices must stay below —
    /// [`BufKey::device_set`] guards the boundary, and fleet sizes are
    /// validated against it up front (`ExperimentBuilder`).
    pub const RESERVED_FLOOR: u64 = u64::MAX - 15;
    /// Slot id for the per-device input batch (parameters use their global
    /// tensor index as the slot).
    pub const SLOT_X: u32 = u32::MAX;

    /// The per-device buffer set id for device index `i`, guarded against
    /// collision with the reserved shared sets (a collision would silently
    /// serve one device's packed literals to another).
    pub fn device_set(i: usize) -> u64 {
        let set = i as u64;
        debug_assert!(
            set < Self::RESERVED_FLOOR,
            "device index {i} collides with the reserved buffer-set ids"
        );
        set
    }
}

/// One engine input: either a transient tensor packed fresh on every call,
/// or a versioned tensor backed by the engine-resident buffer cache.
#[derive(Debug, Clone)]
pub enum ExecInput {
    /// One-shot tensor (activations, gradients, labels, weights).
    Fresh(HostTensor),
    /// Versioned tensor: the engine reuses its cached literal while the
    /// version matches and re-packs from `tensor` when it does not.
    Cached { key: BufKey, version: u64, tensor: Arc<HostTensor> },
}

impl ExecInput {
    /// A versioned, buffer-cacheable input.
    pub fn cached(key: BufKey, version: u64, tensor: Arc<HostTensor>) -> ExecInput {
        ExecInput::Cached { key, version, tensor }
    }

    /// The host tensor carried by this input.
    pub fn tensor(&self) -> &HostTensor {
        match self {
            ExecInput::Fresh(t) => t,
            ExecInput::Cached { tensor, .. } => tensor,
        }
    }
}

/// Execution statistics for the §Perf pass. One instance per engine lane;
/// [`EngineStats::merge`] folds lanes into pool-wide totals.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// Executable invocations.
    pub executions: u64,
    /// Artifact compilations (cold executable-cache misses).
    pub compiles: u64,
    /// Seconds spent inside PJRT execute calls.
    pub exec_secs: f64,
    /// Seconds spent compiling artifacts.
    pub compile_secs: f64,
    /// Seconds packing input literals (host -> engine upload).
    pub upload_secs: f64,
    /// Seconds unpacking output literals (engine -> host download).
    pub download_secs: f64,
    /// Bytes packed into input literals (fresh tensors + buffer misses).
    pub upload_bytes: u64,
    /// Bytes read back from output literals.
    pub download_bytes: u64,
    /// Cacheable inputs served from the buffer cache (no re-pack).
    pub buffer_hits: u64,
    /// Cacheable inputs that had to be (re)packed.
    pub buffer_misses: u64,
    /// Bytes that skipped re-packing thanks to the buffer cache.
    pub buffer_hit_bytes: u64,
    /// Engine lanes contributing to these stats (1 per lane; summed on
    /// merge, so pool-wide stats report the pool width).
    pub pool_width: usize,
}

impl EngineStats {
    /// Total seconds spent packing/unpacking literals.
    pub fn marshal_secs(&self) -> f64 {
        self.upload_secs + self.download_secs
    }

    /// Fold another lane's stats into this one.
    pub fn merge(&mut self, o: &EngineStats) {
        self.executions += o.executions;
        self.compiles += o.compiles;
        self.exec_secs += o.exec_secs;
        self.compile_secs += o.compile_secs;
        self.upload_secs += o.upload_secs;
        self.download_secs += o.download_secs;
        self.upload_bytes += o.upload_bytes;
        self.download_bytes += o.download_bytes;
        self.buffer_hits += o.buffer_hits;
        self.buffer_misses += o.buffer_misses;
        self.buffer_hit_bytes += o.buffer_hit_bytes;
        self.pool_width += o.pool_width;
    }

    /// One-line human summary (CLI `train`/`info` and the benches).
    pub fn summary(&self) -> String {
        const MIB: f64 = 1024.0 * 1024.0;
        format!(
            "{} execs on {} lane(s): exec {:.2}s, marshal {:.2}s (up {:.2}s / down {:.2}s), \
             uploaded {:.1} MiB, {:.1} MiB served by {} buffer hits ({} misses), \
             {} compiles ({:.1}s)",
            self.executions,
            self.pool_width.max(1),
            self.exec_secs,
            self.marshal_secs(),
            self.upload_secs,
            self.download_secs,
            self.upload_bytes as f64 / MIB,
            self.buffer_hit_bytes as f64 / MIB,
            self.buffer_hits,
            self.buffer_misses,
            self.compiles,
            self.compile_secs,
        )
    }
}

/// Pack a host tensor into a PJRT literal (the upload marshal step).
fn pack_literal(name: &str, t: &HostTensor) -> crate::Result<xla::Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, &t.shape, bytes)
        .map_err(|e| anyhow::anyhow!("literal {name}: {e:?}"))
}

/// PJRT CPU engine with an executable cache and a parameter-buffer cache.
/// Lives on one thread (one pool lane).
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Engine-resident literals for versioned inputs, keyed by (set, slot)
    /// and tagged with the version and shape they were packed from.
    buffers: HashMap<BufKey, (u64, Vec<usize>, xla::Literal)>,
    stats: EngineStats,
}

impl Engine {
    /// Create an engine over an artifacts directory (loads manifest.json).
    pub fn load(artifacts_dir: &std::path::Path) -> crate::Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Engine {
            client,
            manifest,
            cache: HashMap::new(),
            buffers: HashMap::new(),
            stats: EngineStats { pool_width: 1, ..EngineStats::default() },
        })
    }

    /// The manifest this engine serves artifacts from.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execution statistics accumulated so far.
    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// Ensure an artifact is compiled; returns whether it was a cache miss.
    pub fn warm(&mut self, name: &str) -> crate::Result<bool> {
        if self.cache.contains_key(name) {
            return Ok(false);
        }
        let path = self
            .manifest
            .artifact_path(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact {name}"))?;
        let t0 = Instant::now();
        // HLO text interchange: jax >= 0.5 emits 64-bit-id protos that
        // xla_extension 0.5.1 rejects; the text parser reassigns ids.
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parse {name}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        self.stats.compiles += 1;
        self.stats.compile_secs += t0.elapsed().as_secs_f64();
        self.cache.insert(name.to_string(), exe);
        Ok(true)
    }

    /// Execute an artifact with the given inputs; returns all outputs.
    ///
    /// Inputs must match the manifest's arg specs (checked). Outputs are the
    /// decomposed elements of the return tuple, in manifest order. Cached
    /// inputs whose version matches the buffer cache skip literal packing.
    pub fn execute(&mut self, name: &str, inputs: &[ExecInput]) -> crate::Result<Vec<HostTensor>> {
        self.warm(name)?;
        // Disjoint field borrows keep one manifest lookup alive for the
        // whole call (the seed re-fetched the entry after execution because
        // the borrow of `self` had to be released for the stats updates).
        let Engine { manifest, cache, buffers, stats, .. } = self;
        let entry = manifest.get(name).expect("warmed artifact exists");
        if inputs.len() != entry.args.len() {
            anyhow::bail!(
                "{name}: {} inputs given, {} expected",
                inputs.len(),
                entry.args.len()
            );
        }
        for (inp, spec) in inputs.iter().zip(&entry.args) {
            let t = inp.tensor();
            if t.shape != spec.shape {
                anyhow::bail!(
                    "{name}: arg {} shape {:?} != spec {:?}",
                    spec.name,
                    t.shape,
                    spec.shape
                );
            }
            if t.data.len() != spec.numel() {
                anyhow::bail!("{name}: arg {} data len mismatch", spec.name);
            }
        }

        // Upload: pack fresh tensors, serve versioned ones from the buffer
        // cache. Cached literals are moved out for the call and re-inserted
        // after success, so no literal is ever cloned; an error path drops
        // them, trading one redundant repack on the next call for simple
        // error handling.
        let t0 = Instant::now();
        let mut literals: Vec<xla::Literal> = Vec::with_capacity(inputs.len());
        for inp in inputs {
            match inp {
                ExecInput::Fresh(t) => {
                    literals.push(pack_literal(name, t)?);
                    stats.upload_bytes += (t.data.len() * 4) as u64;
                }
                ExecInput::Cached { key, version, tensor } => match buffers.remove(key) {
                    // A hit must match version AND shape: a caller reusing
                    // a key across shapes degrades to a repack, never to a
                    // stale literal.
                    Some((v, shape, lit)) if v == *version && shape == tensor.shape => {
                        stats.buffer_hits += 1;
                        stats.buffer_hit_bytes += (tensor.data.len() * 4) as u64;
                        literals.push(lit);
                    }
                    _ => {
                        stats.buffer_misses += 1;
                        literals.push(pack_literal(name, tensor)?);
                        stats.upload_bytes += (tensor.data.len() * 4) as u64;
                    }
                },
            }
        }
        stats.upload_secs += t0.elapsed().as_secs_f64();

        let exe = cache.get(name).expect("warmed");
        let t1 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
        stats.executions += 1;
        stats.exec_secs += t1.elapsed().as_secs_f64();

        // Return versioned literals to the buffer cache for the next call.
        for (inp, lit) in inputs.iter().zip(literals) {
            if let ExecInput::Cached { key, version, tensor } = inp {
                buffers.insert(*key, (*version, tensor.shape.clone(), lit));
            }
        }

        let t2 = Instant::now();
        // aot.py lowers with return_tuple=True: root is always a tuple.
        let parts = root
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))?;
        if parts.len() != entry.outputs.len() {
            anyhow::bail!(
                "{name}: {} outputs, {} expected",
                parts.len(),
                entry.outputs.len()
            );
        }
        let outputs = parts
            .into_iter()
            .zip(&entry.outputs)
            .map(|(lit, spec)| {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("read {name}/{}: {e:?}", spec.name))?;
                stats.download_bytes += (data.len() * 4) as u64;
                Ok(HostTensor { shape: spec.shape.clone(), data })
            })
            .collect::<crate::Result<Vec<_>>>()?;
        stats.download_secs += t2.elapsed().as_secs_f64();
        Ok(outputs)
    }

    /// Number of compiled executables in the cache.
    pub fn cached_len(&self) -> usize {
        self.cache.len()
    }

    /// Live entries in the parameter-buffer cache.
    pub fn buffer_len(&self) -> usize {
        self.buffers.len()
    }
}
