//! The single-threaded PJRT engine: compile-on-first-use executable cache
//! over the AOT artifacts (pattern adapted from /opt/xla-example/load_hlo).

use std::collections::HashMap;
use std::time::Instant;

use crate::model::Manifest;

/// A host-side tensor (f32, row-major) crossing the engine boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn scalar(v: f32) -> HostTensor {
        HostTensor { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Execution statistics for the §Perf pass.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub executions: u64,
    pub compiles: u64,
    /// Seconds spent inside PJRT execute calls.
    pub exec_secs: f64,
    /// Seconds spent compiling artifacts.
    pub compile_secs: f64,
    /// Seconds spent packing/unpacking literals.
    pub marshal_secs: f64,
}

/// PJRT CPU engine with an executable cache. Lives on one thread.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    stats: EngineStats,
}

impl Engine {
    /// Create an engine over an artifacts directory (loads manifest.json).
    pub fn load(artifacts_dir: &std::path::Path) -> crate::Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Engine { client, manifest, cache: HashMap::new(), stats: EngineStats::default() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// Ensure an artifact is compiled; returns whether it was a cache miss.
    pub fn warm(&mut self, name: &str) -> crate::Result<bool> {
        if self.cache.contains_key(name) {
            return Ok(false);
        }
        let path = self
            .manifest
            .artifact_path(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact {name}"))?;
        let t0 = Instant::now();
        // HLO text interchange: jax >= 0.5 emits 64-bit-id protos that
        // xla_extension 0.5.1 rejects; the text parser reassigns ids.
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parse {name}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        self.stats.compiles += 1;
        self.stats.compile_secs += t0.elapsed().as_secs_f64();
        self.cache.insert(name.to_string(), exe);
        Ok(true)
    }

    /// Execute an artifact with the given inputs; returns all outputs.
    ///
    /// Inputs must match the manifest's arg specs (checked). Outputs are the
    /// decomposed elements of the return tuple, in manifest order.
    pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> crate::Result<Vec<HostTensor>> {
        self.warm(name)?;
        let entry = self.manifest.get(name).expect("warmed artifact exists");
        if inputs.len() != entry.args.len() {
            anyhow::bail!(
                "{name}: {} inputs given, {} expected",
                inputs.len(),
                entry.args.len()
            );
        }
        for (inp, spec) in inputs.iter().zip(&entry.args) {
            if inp.shape != spec.shape {
                anyhow::bail!(
                    "{name}: arg {} shape {:?} != spec {:?}",
                    spec.name,
                    inp.shape,
                    spec.shape
                );
            }
            if inp.data.len() != spec.numel() {
                anyhow::bail!("{name}: arg {} data len mismatch", spec.name);
            }
        }

        let t0 = Instant::now();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &t.shape,
                    bytes,
                )
                .map_err(|e| anyhow::anyhow!("literal {name}: {e:?}"))
            })
            .collect::<crate::Result<Vec<_>>>()?;
        self.stats.marshal_secs += t0.elapsed().as_secs_f64();

        let exe = self.cache.get(name).expect("warmed");
        let t1 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
        self.stats.executions += 1;
        self.stats.exec_secs += t1.elapsed().as_secs_f64();

        let t2 = Instant::now();
        // aot.py lowers with return_tuple=True: root is always a tuple.
        let parts = root
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))?;
        let entry = self.manifest.get(name).expect("exists");
        if parts.len() != entry.outputs.len() {
            anyhow::bail!(
                "{name}: {} outputs, {} expected",
                parts.len(),
                entry.outputs.len()
            );
        }
        let outputs = parts
            .into_iter()
            .zip(&entry.outputs)
            .map(|(lit, spec)| {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("read {name}/{}: {e:?}", spec.name))?;
                Ok(HostTensor { shape: spec.shape.clone(), data })
            })
            .collect::<crate::Result<Vec<_>>>()?;
        self.stats.marshal_secs += t2.elapsed().as_secs_f64();
        Ok(outputs)
    }

    pub fn cached_len(&self) -> usize {
        self.cache.len()
    }
}
