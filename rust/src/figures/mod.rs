//! Figure/table regeneration harness — one generator per paper exhibit
//! (Table I, Figs 2–11). See DESIGN.md §6 for the experiment index.
//!
//! Two data paths:
//! - **Executable runs** (Figs 2a, 3a, 5, 6, 10, 11): real SplitCNN-8
//!   training through the PJRT runtime on the synthetic corpus, with
//!   simulated wall-clock from the latency model.
//! - **Analytic paper-scale runs** (Figs 2b, 3b, 7, 8, 9): the exact
//!   latency model + convergence bound on the VGG-16 profile with Table I
//!   resources — no model execution needed, so these run at N=20+ scale.

use std::path::{Path, PathBuf};

use crate::config::{Config, Partition, StrategyKind};
use crate::convergence::BoundParams;
use crate::experiment::Experiment;
use crate::latency::{round_latency, Decisions};
use crate::metrics::{CsvTable, History};
use crate::model::ModelProfile;
use crate::optimizer::{decide, OptContext, StrategyInputs};
use crate::rng::Pcg32;

/// Options shared by all generators.
#[derive(Debug, Clone)]
pub struct FigureOpts {
    /// Directory CSVs are written into.
    pub out_dir: PathBuf,
    /// Compiled-artifact cache directory for the engine.
    pub artifacts: PathBuf,
    /// Override the real-training round budget (None = preset default).
    pub rounds: Option<usize>,
    /// Override the fleet size for real-training figures.
    pub devices: Option<usize>,
    /// Root seed for every figure's deterministic streams.
    pub seed: u64,
}

impl Default for FigureOpts {
    fn default() -> Self {
        FigureOpts {
            out_dir: PathBuf::from("results"),
            artifacts: PathBuf::from("artifacts"),
            rounds: None,
            devices: None,
            seed: 2025,
        }
    }
}

fn training_config(opts: &FigureOpts, partition: Partition, strategy: StrategyKind) -> Config {
    let mut cfg = Config::figure_small();
    cfg.seed = opts.seed;
    cfg.partition = partition;
    cfg.strategy = strategy;
    if let Some(r) = opts.rounds {
        cfg.train.rounds = r;
    }
    if let Some(n) = opts.devices {
        cfg.fleet.n_devices = n;
    }
    cfg
}

fn run_training(cfg: Config, artifacts: &Path) -> crate::Result<History> {
    let mut session = Experiment::builder().config(cfg).artifacts(artifacts).build()?;
    session.run_to_completion()?;
    session.finish()
}

fn strategy_tag(kind: StrategyKind) -> &'static str {
    kind.as_str()
}

/// The paper's benchmark suite: HASFL plus its four ablations.
pub const BENCHMARKS: [StrategyKind; 5] = [
    StrategyKind::Hasfl,
    StrategyKind::RbsHams,
    StrategyKind::HabsRms,
    StrategyKind::RbsRms,
    StrategyKind::RbsRhams,
];

// ---------------------------------------------------------------------------
// Table I
// ---------------------------------------------------------------------------

/// Emit the Table I parameter set actually used by the harness.
pub fn table1(opts: &FigureOpts) -> crate::Result<()> {
    let cfg = Config::table1();
    let mut t = CsvTable::new(&["parameter", "value"]);
    let rows: Vec<(&str, String)> = vec![
        ("f_s (FLOPS)", format!("{:.0}", cfg.server.flops)),
        ("N", cfg.fleet.n_devices.to_string()),
        ("f_i (FLOPS)", format!("[{:.0}, {:.0}]", cfg.fleet.flops.lo, cfg.fleet.flops.hi)),
        ("r_i^U (bps)", format!("[{:.0}, {:.0}]", cfg.fleet.up_bps.lo, cfg.fleet.up_bps.hi)),
        ("r_i^D (bps)", format!("[{:.0}, {:.0}]", cfg.fleet.down_bps.lo, cfg.fleet.down_bps.hi)),
        ("r_s (bps)", format!("{:.0}", cfg.server.to_fed_bps)),
        ("gamma", format!("{}", cfg.train.lr)),
        ("I", cfg.train.agg_interval.to_string()),
    ];
    for (k, v) in rows {
        t.row(&[k.to_string(), v]);
    }
    t.write(&opts.out_dir.join("table1.csv"))
}

// ---------------------------------------------------------------------------
// Fig 2 — impact of batch size
// ---------------------------------------------------------------------------

/// Fig 2(a): test accuracy vs round for fixed uniform BS (real training,
/// non-IID, fixed cut). Fig 2(b): per-round latency vs BS (analytic VGG-16
/// at Table I scale).
pub fn fig2(opts: &FigureOpts) -> crate::Result<()> {
    // (a) executable sweep.
    let mut curves = CsvTable::new(&["batch", "round", "sim_time", "test_acc"]);
    for b in [8u32, 16, 32] {
        let mut cfg = training_config(opts, Partition::NonIidShards, StrategyKind::Fixed);
        cfg.fixed_batch = b;
        cfg.fixed_cut = 4;
        let h = run_training(cfg, &opts.artifacts)?;
        for (round, st, acc) in h.eval_points() {
            curves.rowf(&[b as f64, round as f64, st, acc]);
        }
    }
    curves.write(&opts.out_dir.join("fig2a_acc_vs_round.csv"))?;

    // (b) analytic per-round latency at paper scale.
    let cfg = Config::table1();
    let profile = ModelProfile::vgg16();
    let devices = cfg.sample_fleet();
    let mut t = CsvTable::new(&["batch", "t_split", "t_client", "t_comm", "t_server"]);
    for b in [4u32, 8, 16, 32, 64] {
        let dec = Decisions::uniform(devices.len(), b, 8); // paper: L_c = 8
        let lat = round_latency(&profile, &devices, &cfg.server, &dec);
        let t_client = lat
            .per_device
            .iter()
            .map(|l| l.client_fwd + l.client_bwd)
            .fold(0.0, f64::max);
        let t_comm = lat
            .per_device
            .iter()
            .map(|l| l.act_up + l.grad_down)
            .fold(0.0, f64::max);
        t.rowf(&[b as f64, lat.t_split, t_client, t_comm, lat.server_fwd + lat.server_bwd]);
    }
    t.write(&opts.out_dir.join("fig2b_latency_vs_batch.csv"))
}

// ---------------------------------------------------------------------------
// Fig 3 — impact of model splitting
// ---------------------------------------------------------------------------

/// Fig 3(a): accuracy vs round for fixed cuts (real training, non-IID,
/// b=16). Fig 3(b): computing + communication overhead per cut (analytic).
pub fn fig3(opts: &FigureOpts) -> crate::Result<()> {
    let mut curves = CsvTable::new(&["cut", "round", "sim_time", "test_acc"]);
    for cut in [1usize, 3, 5, 7] {
        let mut cfg = training_config(opts, Partition::NonIidShards, StrategyKind::Fixed);
        cfg.fixed_batch = 16;
        cfg.fixed_cut = cut;
        let h = run_training(cfg, &opts.artifacts)?;
        for (round, st, acc) in h.eval_points() {
            curves.rowf(&[cut as f64, round as f64, st, acc]);
        }
    }
    curves.write(&opts.out_dir.join("fig3a_acc_vs_round.csv"))?;

    let profile = ModelProfile::vgg16();
    let mut t = CsvTable::new(&["cut", "client_gflops", "comm_mbytes"]);
    for cut in 1..profile.n_layers() {
        t.rowf(&[
            cut as f64,
            crate::latency::round_client_flops(&profile, 16, cut) / 1e9,
            crate::latency::round_comm_bytes(&profile, 16, cut) / 1e6,
        ]);
    }
    t.write(&opts.out_dir.join("fig3b_overhead_vs_cut.csv"))
}

// ---------------------------------------------------------------------------
// Figs 5 + 6 — HASFL vs benchmarks (training curves + converged bars)
// ---------------------------------------------------------------------------

/// Run the five-strategy comparison for one data setting; emits the Fig 5
/// curves and returns per-strategy converged (accuracy, time) for Fig 6.
pub fn fig5_setting(
    opts: &FigureOpts,
    partition: Partition,
    label: &str,
) -> crate::Result<Vec<(StrategyKind, f64, f64)>> {
    let mut curves = CsvTable::new(&["strategy", "round", "sim_time", "test_acc"]);
    let mut converged = Vec::new();
    // The paper compares accuracy at equal *wall-clock*, not equal rounds:
    // a strategy with cheap rounds (HASFL often picks small batches) gets
    // proportionally more of them. Budget = what the reference uniform
    // configuration (b=16, cut=4) spends on `opts.rounds` rounds.
    let budget_secs = {
        let cfg = training_config(opts, partition, StrategyKind::Fixed);
        let profile = crate::model::ModelProfile::from_manifest(
            &crate::model::Manifest::load(&opts.artifacts)?,
        );
        let devices = cfg.sample_fleet();
        let dec = Decisions::uniform(devices.len(), 16, 4);
        let lat = round_latency(&profile, &devices, &cfg.server, &dec);
        lat.t_split * cfg.train.rounds as f64
    };
    for kind in BENCHMARKS {
        let mut cfg = training_config(opts, partition, kind);
        // Probe the strategy's round cost to convert the time budget into
        // a round budget (clamped to keep runtime sane).
        let probe = {
            let session =
                Experiment::builder().config(cfg.clone()).artifacts(&opts.artifacts).build()?;
            let lat = session.current_latency();
            session.finish()?;
            lat.t_split.max(1e-9)
        };
        let rounds = ((budget_secs / probe).ceil() as usize)
            .clamp(cfg.train.rounds, cfg.train.rounds * 25);
        cfg.train.rounds = rounds;
        cfg.train.eval_every = (rounds / 25).max(5);
        let h = run_training(cfg, &opts.artifacts)?;
        for (round, st, acc) in h.eval_points() {
            curves.row(&[
                strategy_tag(kind).to_string(),
                round.to_string(),
                format!("{st:.4}"),
                format!("{acc:.6}"),
            ]);
        }
        let (_, time, acc) = h
            .converged_or_last()
            .ok_or_else(|| anyhow::anyhow!("no eval points"))?;
        let best = h.best_acc().unwrap_or(acc);
        converged.push((kind, best, time));
    }
    curves.write(&opts.out_dir.join(format!("fig5_{label}.csv")))?;
    Ok(converged)
}

/// Figs 5(a,b) + 6(a,b): CIFAR-10-like, IID and non-IID. (The c/d panels
/// need 100-class artifacts: build with `make artifacts100` and pass that
/// directory; the harness then emits fig5_cifar100_*.)
pub fn fig56(opts: &FigureOpts) -> crate::Result<()> {
    let mut bars = CsvTable::new(&["setting", "strategy", "converged_acc", "converged_time"]);
    for (partition, label) in [
        (Partition::Iid, "cifar10_iid"),
        (Partition::NonIidShards, "cifar10_noniid"),
    ] {
        let rows = fig5_setting(opts, partition, label)?;
        for (kind, acc, time) in rows {
            bars.row(&[
                label.to_string(),
                strategy_tag(kind).to_string(),
                format!("{acc:.6}"),
                format!("{time:.4}"),
            ]);
        }
    }
    bars.write(&opts.out_dir.join("fig6_converged.csv"))
}

// ---------------------------------------------------------------------------
// Figs 7 / 8 / 9 — converged time vs resources / fleet size (analytic)
// ---------------------------------------------------------------------------

/// Estimated converged time (seconds) of a strategy at paper scale:
/// Θ′ = R(ε)·(T_S + T_A/I) evaluated at the strategy's decisions on the
/// VGG-16 profile; random strategies are averaged over `draws` draws.
pub fn analytic_converged_time(
    cfg: &Config,
    kind: StrategyKind,
    sigma_mult: f64,
    draws: usize,
) -> Option<f64> {
    let profile = ModelProfile::vgg16();
    let mut bound = BoundParams::default_for(&profile, cfg.train.lr);
    for s in bound.sigma_sq.iter_mut() {
        *s *= sigma_mult; // non-IID: higher effective gradient variance
    }
    let devices = cfg.sample_fleet();
    let ctx = OptContext {
        profile: &profile,
        devices: &devices,
        server: &cfg.server,
        bound: &bound,
        interval: cfg.train.agg_interval,
        epsilon: cfg.train.epsilon,
        batch_cap: cfg.train.batch_cap,
    };
    let is_random = matches!(
        kind,
        StrategyKind::RbsHams | StrategyKind::HabsRms | StrategyKind::RbsRms | StrategyKind::RbsRhams
    );
    let n_draws = if is_random { draws } else { 1 };
    let mut sum = 0.0;
    let mut cnt = 0usize;
    for d in 0..n_draws {
        let mut rng = Pcg32::new(cfg.seed + d as u64, 0xF19);
        let dec = decide(kind, &ctx, &mut rng, StrategyInputs::default());
        // Relaxed metric: decisions that cannot reach the target epsilon
        // are charged the time to their own plateau (see convergence::
        // time_to_own_convergence) — the paper's converged-time analogue.
        if let Some(v) = ctx.eval_time(&dec) {
            sum += v;
            cnt += 1;
        }
    }
    if cnt == 0 {
        None
    } else {
        Some(sum / cnt as f64)
    }
}

/// Fig 7: converged time vs (a) device compute scale, (b) server compute.
pub fn fig7(opts: &FigureOpts) -> crate::Result<()> {
    let mut t = CsvTable::new(&["axis", "value", "strategy", "converged_time"]);
    for scale in [0.5f64, 0.75, 1.0, 1.5, 2.0] {
        let mut cfg = Config::table1();
        cfg.seed = opts.seed;
        cfg.fleet.flops = cfg.fleet.flops.scale(scale);
        for kind in BENCHMARKS {
            if let Some(v) = analytic_converged_time(&cfg, kind, 1.0, 8) {
                t.row(&[
                    "device_flops_scale".into(),
                    format!("{scale}"),
                    strategy_tag(kind).into(),
                    format!("{v:.2}"),
                ]);
            }
        }
    }
    for fs in [10e12f64, 15e12, 20e12, 30e12, 40e12] {
        let mut cfg = Config::table1();
        cfg.seed = opts.seed;
        cfg.server.flops = fs;
        for kind in BENCHMARKS {
            if let Some(v) = analytic_converged_time(&cfg, kind, 1.0, 8) {
                t.row(&[
                    "server_flops".into(),
                    format!("{fs:.0}"),
                    strategy_tag(kind).into(),
                    format!("{v:.2}"),
                ]);
            }
        }
    }
    t.write(&opts.out_dir.join("fig7_compute_resources.csv"))
}

/// Fig 8: converged time vs (a) device uplink, (b) inter-server rate.
pub fn fig8(opts: &FigureOpts) -> crate::Result<()> {
    let mut t = CsvTable::new(&["axis", "value", "strategy", "converged_time"]);
    for scale in [0.25f64, 0.5, 1.0, 1.5, 2.0] {
        let mut cfg = Config::table1();
        cfg.seed = opts.seed;
        cfg.fleet.up_bps = cfg.fleet.up_bps.scale(scale);
        for kind in BENCHMARKS {
            if let Some(v) = analytic_converged_time(&cfg, kind, 1.0, 8) {
                t.row(&[
                    "uplink_scale".into(),
                    format!("{scale}"),
                    strategy_tag(kind).into(),
                    format!("{v:.2}"),
                ]);
            }
        }
    }
    for scale in [0.25f64, 0.5, 1.0, 2.0] {
        let mut cfg = Config::table1();
        cfg.seed = opts.seed;
        cfg.server.to_fed_bps *= scale;
        cfg.server.from_fed_bps *= scale;
        for kind in BENCHMARKS {
            if let Some(v) = analytic_converged_time(&cfg, kind, 1.0, 8) {
                t.row(&[
                    "interserver_scale".into(),
                    format!("{scale}"),
                    strategy_tag(kind).into(),
                    format!("{v:.2}"),
                ]);
            }
        }
    }
    t.write(&opts.out_dir.join("fig8_comm_resources.csv"))
}

/// Fig 9: converged time vs number of devices, IID + non-IID.
pub fn fig9(opts: &FigureOpts) -> crate::Result<()> {
    let mut t = CsvTable::new(&["setting", "n_devices", "strategy", "converged_time"]);
    for (sigma_mult, label) in [(1.0f64, "iid"), (2.0, "noniid")] {
        for n in [5usize, 10, 20, 30, 40] {
            let mut cfg = Config::table1();
            cfg.seed = opts.seed;
            cfg.fleet.n_devices = n;
            for kind in BENCHMARKS {
                if let Some(v) = analytic_converged_time(&cfg, kind, sigma_mult, 8) {
                    t.row(&[
                        label.into(),
                        n.to_string(),
                        strategy_tag(kind).into(),
                        format!("{v:.2}"),
                    ]);
                }
            }
        }
    }
    t.write(&opts.out_dir.join("fig9_num_devices.csv"))
}

// ---------------------------------------------------------------------------
// Figs 10 / 11 — ablations (HABS and HAMS in isolation)
// ---------------------------------------------------------------------------

/// Fig 10: HABS vs fixed uniform BS (IID + non-IID, fixed cut).
pub fn fig10(opts: &FigureOpts) -> crate::Result<()> {
    let mut curves = CsvTable::new(&["setting", "arm", "round", "sim_time", "test_acc"]);
    for (partition, plabel) in [
        (Partition::Iid, "iid"),
        (Partition::NonIidShards, "noniid"),
    ] {
        // Fixed-BS arms.
        for b in [8u32, 16, 32] {
            let mut cfg = training_config(opts, partition, StrategyKind::Fixed);
            cfg.fixed_batch = b;
            cfg.fixed_cut = 4;
            let h = run_training(cfg, &opts.artifacts)?;
            for (round, st, acc) in h.eval_points() {
                curves.row(&[
                    plabel.into(),
                    format!("b{b}"),
                    round.to_string(),
                    format!("{st:.4}"),
                    format!("{acc:.6}"),
                ]);
            }
        }
        // HABS arm: heterogeneity-aware BS at the same fixed cut. Uses a
        // config whose strategy recomputes BS each window via the solver.
        let mut cfg = training_config(opts, partition, StrategyKind::HabsFixedCut);
        cfg.fixed_cut = 4;
        let h = run_training(cfg, &opts.artifacts)?;
        for (round, st, acc) in h.eval_points() {
            curves.row(&[
                plabel.into(),
                "habs".into(),
                round.to_string(),
                format!("{st:.4}"),
                format!("{acc:.6}"),
            ]);
        }
    }
    curves.write(&opts.out_dir.join("fig10_habs_ablation.csv"))
}

/// Fig 11: HAMS vs fixed cuts (IID + non-IID, b = 16).
pub fn fig11(opts: &FigureOpts) -> crate::Result<()> {
    let mut curves = CsvTable::new(&["setting", "arm", "round", "sim_time", "test_acc"]);
    for (partition, plabel) in [
        (Partition::Iid, "iid"),
        (Partition::NonIidShards, "noniid"),
    ] {
        for cut in [2usize, 4, 6] {
            let mut cfg = training_config(opts, partition, StrategyKind::Fixed);
            cfg.fixed_batch = 16;
            cfg.fixed_cut = cut;
            let h = run_training(cfg, &opts.artifacts)?;
            for (round, st, acc) in h.eval_points() {
                curves.row(&[
                    plabel.into(),
                    format!("cut{cut}"),
                    round.to_string(),
                    format!("{st:.4}"),
                    format!("{acc:.6}"),
                ]);
            }
        }
        let mut cfg = training_config(opts, partition, StrategyKind::HamsFixedBatch);
        cfg.fixed_batch = 16;
        let h = run_training(cfg, &opts.artifacts)?;
        for (round, st, acc) in h.eval_points() {
            curves.row(&[
                plabel.into(),
                "hams".into(),
                round.to_string(),
                format!("{st:.4}"),
                format!("{acc:.6}"),
            ]);
        }
    }
    curves.write(&opts.out_dir.join("fig11_hams_ablation.csv"))
}
