//! Buffered-asynchronous training: specs, staleness weighting, and the
//! online (EMA) completion-time model.
//!
//! In synchronous HASFL every round waits for its slowest participant —
//! the round barrier prices the straggler tail into `t_split` (Eqn 34).
//! The buffered-asynchronous mode (DESIGN.md §16, docs/ASYNC.md) removes
//! the barrier: devices submit split-training updates as they finish, and
//! the coordinator flushes a buffer of `buffer_k` completions per global
//! version. Each buffered update is weighted by a polynomial decay on its
//! *version lag* (how many global versions elapsed since the update's
//! weights were dispatched), and the decayed weights are folded through
//! the existing Eqn-39 weighted partial-aggregation path.
//!
//! This module holds the pure data types and math:
//!
//! - [`AsyncSpec`] — the config knobs (`buffer_k`, `max_staleness`,
//!   `decay`), JSON round-trippable like every other config section.
//! - [`staleness_weight`] — the `(1 + lag)^(-decay)` weight.
//! - [`AsyncState`] — the checkpointable runtime state: per-device
//!   in-flight dispatch versions and completion times, the global model
//!   version, and the per-device EMA latency model that replaces the
//!   analytic completion-time estimate once observations exist.
//! - [`AsyncRoundStats`] — per-flush observability threaded through
//!   `RoundReport`, the serve JSON, and the fleet trace CSV.
//!
//! The scheduler that consumes these types lives in
//! `coordinator/async_round.rs`; determinism of the completion order is
//! its contract (seeded jitter, total order on `(ready_at, device)`).

use crate::util::Json;

/// Smoothing factor for the per-device EMA completion-time model
/// ([`AsyncState::observe_latency`]). 0.3 tracks drifting channels within
/// a few observations while still damping single-round noise.
pub const EMA_ALPHA: f64 = 0.3;

/// When re-solving BS/MS against observed completion times, the
/// observed/analytic ratio is clamped to `[1/EMA_CLAMP, EMA_CLAMP]` so a
/// single wild observation cannot push the optimizer off a cliff.
pub const EMA_CLAMP: f64 = 4.0;

/// Configuration for buffered-asynchronous rounds.
///
/// `None` on `Config.async_spec` (the default) keeps the synchronous
/// round barrier byte-identical to previous releases; `Some` switches the
/// coordinator to buffered flushes. Serialized under the `"async"` key of
/// the config JSON.
#[derive(Debug, Clone, PartialEq)]
pub struct AsyncSpec {
    /// Updates per buffer flush: the coordinator aggregates exactly this
    /// many completions per global version (FedBuff's K).
    pub buffer_k: usize,
    /// Maximum tolerated version lag. An update whose lag exceeds this is
    /// dropped (counted in [`AsyncRoundStats::dropped_stale`]) and the
    /// device is re-dispatched from the current model.
    pub max_staleness: usize,
    /// Polynomial staleness-decay exponent: an update with version lag
    /// `s` carries weight `(1 + s)^(-decay)`. `0.0` disables decay
    /// (pure FedBuff averaging); larger values trust stale updates less.
    pub decay: f64,
}

impl Default for AsyncSpec {
    fn default() -> Self {
        AsyncSpec { buffer_k: 4, max_staleness: 8, decay: 0.5 }
    }
}

impl AsyncSpec {
    /// Validate against a fleet of `n_devices`. Errors name the field.
    pub fn validate(&self, n_devices: usize) -> crate::Result<()> {
        anyhow::ensure!(self.buffer_k >= 1, "buffer_k must be >= 1, got {}", self.buffer_k);
        anyhow::ensure!(
            self.buffer_k <= n_devices,
            "buffer_k ({}) must not exceed the fleet size ({n_devices})",
            self.buffer_k
        );
        anyhow::ensure!(
            self.max_staleness >= 1,
            "max_staleness must be >= 1, got {}",
            self.max_staleness
        );
        anyhow::ensure!(
            self.decay.is_finite() && self.decay >= 0.0,
            "decay must be finite and >= 0, got {}",
            self.decay
        );
        Ok(())
    }

    /// Serialize to a JSON object (sparse: always writes all three knobs
    /// so a config file documents its own effective values).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("buffer_k", Json::Num(self.buffer_k as f64))
            .set("max_staleness", Json::Num(self.max_staleness as f64))
            .set("decay", Json::Num(self.decay));
        j
    }

    /// Parse from JSON; absent fields take [`AsyncSpec::default`] values.
    pub fn from_json(j: &Json) -> crate::Result<AsyncSpec> {
        let d = AsyncSpec::default();
        let opt_usize = |key: &str, dv: usize| -> crate::Result<usize> {
            match j.get(key) {
                Some(v) => v.as_usize(),
                None => Ok(dv),
            }
        };
        let opt_f64 = |key: &str, dv: f64| -> crate::Result<f64> {
            match j.get(key) {
                Some(v) => v.as_f64(),
                None => Ok(dv),
            }
        };
        Ok(AsyncSpec {
            buffer_k: opt_usize("buffer_k", d.buffer_k)?,
            max_staleness: opt_usize("max_staleness", d.max_staleness)?,
            decay: opt_f64("decay", d.decay)?,
        })
    }
}

/// The Eqn-39 staleness weight: `(1 + lag)^(-decay)`.
///
/// `lag` is the version lag of a buffered update (global model version at
/// flush minus the version its weights were dispatched from). A fresh
/// update (`lag == 0`) always weighs `1.0`; `decay == 0.0` makes every
/// update weigh `1.0` regardless of lag.
pub fn staleness_weight(lag: u64, decay: f64) -> f64 {
    (1.0 + lag as f64).powf(-decay)
}

/// Per-flush asynchrony statistics, reported on `RoundReport.asynchrony`
/// and (flattened) in the fleet trace CSV.
#[derive(Debug, Clone, PartialEq)]
pub struct AsyncRoundStats {
    /// Updates aggregated in this flush (== `buffer_k` unless the active
    /// roster shrank below it).
    pub flushed: usize,
    /// Updates discarded for exceeding `max_staleness` before this flush
    /// filled.
    pub dropped_stale: usize,
    /// Mean version lag over the flushed updates.
    pub staleness_mean: f64,
    /// Maximum version lag over the flushed updates.
    pub staleness_max: u64,
    /// Global model version *after* this flush.
    pub model_version: u64,
    /// Simulated wall-clock this flush spanned (seconds): time from the
    /// previous flush until the K-th completion landed. The sync-barrier
    /// comparison point is `t_split` of the same scenario round.
    pub flush_span_s: f64,
}

impl AsyncRoundStats {
    /// Serialize for the round report / serve JSON (`"async"` block).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("flushed", Json::Num(self.flushed as f64))
            .set("dropped_stale", Json::Num(self.dropped_stale as f64))
            .set("staleness_mean", Json::Num(self.staleness_mean))
            .set("staleness_max", Json::Num(self.staleness_max as f64))
            .set("model_version", Json::Num(self.model_version as f64))
            .set("flush_span_s", Json::Num(self.flush_span_s));
        j
    }
}

/// Checkpointable runtime state of the buffered-asynchronous scheduler.
///
/// All vectors are indexed by device id (fleet order, length fixed at
/// `n_devices`). The in-flight "buffer" is the set of devices with
/// `in_flight[i] == true`: each carries the model version its work was
/// dispatched from (`dispatch_version[i]`) and the simulated absolute
/// time its result lands (`ready_at[i]`). Checkpointing this struct and
/// restoring it resumes the flush schedule bit-identically (pinned by
/// `tests/async_rounds.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct AsyncState {
    /// Global model version: the number of buffer flushes applied so far.
    pub model_version: u64,
    /// Simulated absolute time of the most recent flush (seconds).
    pub now: f64,
    /// Per device: model version its in-flight work was dispatched from.
    pub dispatch_version: Vec<u64>,
    /// Per device: simulated absolute time its in-flight work was
    /// dispatched (start of the completion interval; the EMA model
    /// observes `ready_at - dispatch_at`).
    pub dispatch_at: Vec<f64>,
    /// Per device: simulated absolute completion time of in-flight work.
    pub ready_at: Vec<f64>,
    /// Per device: whether the device currently has in-flight work.
    pub in_flight: Vec<bool>,
    /// Per device: dispatch counter (keys the seeded completion-time
    /// jitter so a resumed run replays the same schedule).
    pub dispatch_seq: Vec<u64>,
    /// Per device: EMA of observed completion times (seconds); only
    /// meaningful where `ema_seen[i]`.
    pub ema_latency: Vec<f64>,
    /// Per device: whether `ema_latency[i]` has absorbed an observation.
    pub ema_seen: Vec<bool>,
}

impl AsyncState {
    /// Fresh state for a fleet of `n` devices: version 0, empty buffer.
    pub fn new(n: usize) -> AsyncState {
        AsyncState {
            model_version: 0,
            now: 0.0,
            dispatch_version: vec![0; n],
            dispatch_at: vec![0.0; n],
            ready_at: vec![0.0; n],
            in_flight: vec![false; n],
            dispatch_seq: vec![0; n],
            ema_latency: vec![0.0; n],
            ema_seen: vec![false; n],
        }
    }

    /// Defensive roster-resize: scenario rosters are fixed-size (churn
    /// toggles membership, never length), but if a future fleet source
    /// resizes, new entries join idle at the current version and excess
    /// entries are dropped.
    pub fn ensure_len(&mut self, n: usize) {
        let v = self.model_version;
        self.dispatch_version.resize(n, v);
        self.dispatch_at.resize(n, self.now);
        self.ready_at.resize(n, self.now);
        self.in_flight.resize(n, false);
        self.dispatch_seq.resize(n, 0);
        self.ema_latency.resize(n, 0.0);
        self.ema_seen.resize(n, false);
    }

    /// Number of devices this state tracks.
    pub fn n_devices(&self) -> usize {
        self.in_flight.len()
    }

    /// Fold an observed completion time for device `i` into the EMA
    /// latency model (the "observed distribution" the optimizer re-solves
    /// against; [`EMA_ALPHA`] smoothing, first observation seeds the EMA).
    pub fn observe_latency(&mut self, i: usize, seconds: f64) {
        if self.ema_seen[i] {
            self.ema_latency[i] = (1.0 - EMA_ALPHA) * self.ema_latency[i] + EMA_ALPHA * seconds;
        } else {
            self.ema_latency[i] = seconds;
            self.ema_seen[i] = true;
        }
    }

    /// Observed EMA completion time for device `i`, if any observation
    /// has been folded in.
    pub fn ema(&self, i: usize) -> Option<f64> {
        if self.ema_seen[i] {
            Some(self.ema_latency[i])
        } else {
            None
        }
    }

    /// The observed/analytic slowdown ratio for device `i`, clamped to
    /// `[1/EMA_CLAMP, EMA_CLAMP]`; `1.0` before any observation or when
    /// the analytic estimate is degenerate.
    pub fn slowdown(&self, i: usize, analytic_seconds: f64) -> f64 {
        match self.ema(i) {
            Some(obs) if analytic_seconds > 0.0 && obs.is_finite() => {
                (obs / analytic_seconds).clamp(1.0 / EMA_CLAMP, EMA_CLAMP)
            }
            _ => 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn staleness_weight_is_one_for_fresh_updates() {
        assert!((staleness_weight(0, 0.5) - 1.0).abs() < 1e-12);
        assert!((staleness_weight(0, 3.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn staleness_weight_decays_monotonically() {
        let d = 0.5;
        let mut prev = staleness_weight(0, d);
        for lag in 1..10 {
            let w = staleness_weight(lag, d);
            assert!(w < prev, "weight must strictly decay with lag");
            assert!(w > 0.0);
            prev = w;
        }
    }

    #[test]
    fn zero_decay_disables_staleness_weighting() {
        for lag in 0..20 {
            assert!((staleness_weight(lag, 0.0) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn spec_default_validates() {
        AsyncSpec::default().validate(20).expect("default spec valid");
    }

    #[test]
    fn spec_validation_names_bad_fields() {
        let mut s = AsyncSpec::default();
        s.buffer_k = 0;
        assert!(s.validate(4).unwrap_err().to_string().contains("buffer_k"));
        let mut s = AsyncSpec::default();
        s.buffer_k = 8;
        assert!(s.validate(4).unwrap_err().to_string().contains("fleet size"));
        let mut s = AsyncSpec::default();
        s.max_staleness = 0;
        assert!(s.validate(4).unwrap_err().to_string().contains("max_staleness"));
        let mut s = AsyncSpec::default();
        s.decay = f64::NAN;
        assert!(s.validate(4).unwrap_err().to_string().contains("decay"));
    }

    #[test]
    fn spec_json_roundtrip() {
        let s = AsyncSpec { buffer_k: 3, max_staleness: 12, decay: 1.25 };
        let back = AsyncSpec::from_json(&s.to_json()).expect("parse");
        assert_eq!(back, s);
    }

    #[test]
    fn spec_sparse_json_takes_defaults() {
        let j = Json::parse("{\"buffer_k\": 2}").expect("json");
        let s = AsyncSpec::from_json(&j).expect("parse");
        assert_eq!(s.buffer_k, 2);
        assert_eq!(s.max_staleness, AsyncSpec::default().max_staleness);
        assert!((s.decay - AsyncSpec::default().decay).abs() < 1e-12);
    }

    #[test]
    fn ema_seeds_then_smooths() {
        let mut st = AsyncState::new(2);
        assert_eq!(st.ema(0), None);
        st.observe_latency(0, 10.0);
        assert!((st.ema(0).unwrap() - 10.0).abs() < 1e-12);
        st.observe_latency(0, 20.0);
        let expect = (1.0 - EMA_ALPHA) * 10.0 + EMA_ALPHA * 20.0;
        assert!((st.ema(0).unwrap() - expect).abs() < 1e-12);
        assert_eq!(st.ema(1), None);
    }

    #[test]
    fn slowdown_is_clamped_and_neutral_without_observations() {
        let mut st = AsyncState::new(1);
        assert!((st.slowdown(0, 5.0) - 1.0).abs() < 1e-12);
        st.observe_latency(0, 100.0);
        assert!((st.slowdown(0, 1.0) - EMA_CLAMP).abs() < 1e-12);
        assert!((st.slowdown(0, 1e9) - 1.0 / EMA_CLAMP).abs() < 1e-12);
        assert!((st.slowdown(0, 0.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fresh_state_has_empty_buffer() {
        let st = AsyncState::new(3);
        assert_eq!(st.n_devices(), 3);
        assert_eq!(st.model_version, 0);
        assert!(st.in_flight.iter().all(|f| !f));
    }

    #[test]
    fn stats_json_carries_all_fields() {
        let s = AsyncRoundStats {
            flushed: 4,
            dropped_stale: 1,
            staleness_mean: 0.75,
            staleness_max: 3,
            model_version: 9,
            flush_span_s: 1.5,
        };
        let j = s.to_json();
        assert_eq!(j.get("flushed").and_then(|v| v.as_usize().ok()), Some(4));
        assert_eq!(j.get("dropped_stale").and_then(|v| v.as_usize().ok()), Some(1));
        assert_eq!(j.get("staleness_max").and_then(|v| v.as_usize().ok()), Some(3));
        assert_eq!(j.get("model_version").and_then(|v| v.as_usize().ok()), Some(9));
        assert!(j.get("staleness_mean").is_some() && j.get("flush_span_s").is_some());
    }
}
