//! Seeded fault injection and the degradation contract it exercises.
//!
//! HASFL's premise is that edge devices fail, stall, and straggle — this
//! module turns that premise into an executable test surface. A
//! [`FaultSpec`] (carried in `Config.faults`, serde-round-trippable through
//! the in-repo JSON codec exactly like `Scenario`) describes what to break;
//! a [`FaultInjector`] turns spec + experiment seed into *pure-function*
//! per-round fault plans, injected at real system boundaries:
//!
//! - device step errors / panics / delays inside the round loop
//!   (`coordinator::round`), bounded by a per-device deadline and
//!   retry-with-backoff budget;
//! - engine-lane crashes in `runtime::handle` (the lane thread exits
//!   mid-round; supervision respawns it and replays the in-flight job);
//! - torn checkpoint writes in `experiment::Session::checkpoint`
//!   (simulating file corruption the `HASFLCKP` checksum must catch).
//!
//! Connection-level faults against the serve daemon (slow-loris reads,
//! mid-body disconnects) are client-side behaviours and live in
//! `tests/chaos.rs` / `ci.sh` — the daemon's caps and socket deadlines are
//! configuration (`serve::ServeConfig`), not injection.
//!
//! # Determinism contract (DESIGN.md §13)
//!
//! Every draw is a pure function of `(seed, round)`: plans are pre-drawn
//! for the whole roster in device order from `Pcg32::new(seed ^ stream,
//! round)` before any worker thread runs, so worker scheduling cannot
//! reorder draws and no fault-RNG cursor needs checkpointing. Two runs of
//! the same seeded spec are bit-identical.
//!
//! Randomly drawn attempt faults are *transient by construction*: the
//! final retry attempt of a non-[`kill`](FaultSpec::kill) device is always
//! drawn clean, so random faults exercise retry/backoff/deadline paths
//! without ever abandoning a healthy device. Only `kill` membership,
//! genuine engine errors, and real deadline overruns abandon a device —
//! which is what makes the survivor-equivalence guarantee hold: a run with
//! `kill = [j]` produces byte-identical surviving-device history to a run
//! with `blackout = [j]` (same roster size, device `j` never scheduled).
//! `tests/chaos.rs` asserts exactly that.

use crate::rng::Pcg32;
use crate::util::Json;

/// Stream-id salts separating the three independent fault-draw streams
/// from each other and from every training stream.
const STREAM_DEVICE: u64 = 0xFA17_0D01;
const STREAM_LANE: u64 = 0xFA17_1A4E;
const STREAM_TEAR: u64 = 0xFA17_7EA2;

/// What the injector does to one device-step attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttemptFault {
    /// Execute normally.
    None,
    /// Fail the attempt with an injected error before executing.
    Error,
    /// Panic inside the attempt (caught by the round loop's unwind guard,
    /// converted into a retryable failure).
    Panic,
    /// Sleep `ms` before executing; if `ms` exceeds the per-device
    /// deadline the attempt is abandoned deterministically *without*
    /// sleeping (the violation is decided by arithmetic, not wall clock).
    Delay(u64),
}

/// Pre-drawn fault plan for one round: `attempts[device][attempt]`.
/// Drawn for the whole roster (participating or not) so the draw protocol
/// is independent of participation.
#[derive(Debug, Clone)]
pub struct RoundPlan {
    /// Per-device, per-attempt fault outcomes, indexed `[device][attempt]`.
    pub attempts: Vec<Vec<AttemptFault>>,
}

/// Declarative fault-injection spec, carried in `Config.faults`.
///
/// All rates are per-draw probabilities in `[0, 1]`. Rounds are 1-based
/// (the first executed round is round 1, matching `Trainer::rounds_run`).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Human-readable spec name (a preset name or `custom`), carried into
    /// checkpoints and logs.
    pub name: String,
    /// Devices that never participate in any round — the clean baseline
    /// the survivor-equivalence tests compare against. Excluded at
    /// `begin_round`, before any sampling or scheduling.
    pub blackout: Vec<usize>,
    /// Devices whose every step attempt fails (all rounds): the
    /// deterministic fatal-fault targets. They burn their retry budget,
    /// accumulate strikes, and end up quarantined.
    pub kill: Vec<usize>,
    /// Per-attempt probability of an injected step error.
    pub error_rate: f64,
    /// Per-attempt probability of an injected step panic.
    pub panic_rate: f64,
    /// Per-attempt probability of an injected step delay of `delay_ms`.
    pub delay_rate: f64,
    /// Injected delay length (milliseconds).
    pub delay_ms: u64,
    /// Per-device round deadline in milliseconds (0 = no deadline). Also
    /// bounds *real* engine stalls via `recv_timeout` on the lane reply.
    pub deadline_ms: u64,
    /// Retries per device step after the first attempt.
    pub max_retries: u32,
    /// Base backoff between attempts (milliseconds, doubled per retry,
    /// capped at 1 s).
    pub backoff_ms: u64,
    /// Abandonments before a device is quarantined — excluded from all
    /// later rounds and surfaced in `RoundReport` (0 = never quarantine).
    pub quarantine_after: u32,
    /// Per-round probability that one engine lane crashes at round start.
    pub lane_crash_rate: f64,
    /// Per-checkpoint probability the write is torn (truncated bytes land
    /// at the final path, as if the writer died mid-write).
    pub torn_checkpoint_rate: f64,
    /// Last round (1-based, inclusive) the random injections are active;
    /// 0 = forever. `blackout`/`kill` membership is structural and is not
    /// gated by this window.
    pub until_round: usize,
}

impl Default for FaultSpec {
    fn default() -> FaultSpec {
        FaultSpec {
            name: "none".to_string(),
            blackout: Vec::new(),
            kill: Vec::new(),
            error_rate: 0.0,
            panic_rate: 0.0,
            delay_rate: 0.0,
            delay_ms: 0,
            deadline_ms: 0,
            max_retries: 2,
            backoff_ms: 5,
            quarantine_after: 0,
            lane_crash_rate: 0.0,
            torn_checkpoint_rate: 0.0,
            until_round: 0,
        }
    }
}

impl FaultSpec {
    /// Validate against a fleet of `n_devices` roster members.
    pub fn validate(&self, n_devices: usize) -> crate::Result<()> {
        anyhow::ensure!(
            n_devices >= 1,
            "fault spec '{}' needs a non-empty fleet (n_devices >= 1)",
            self.name
        );
        for (what, ids) in [("blackout", &self.blackout), ("kill", &self.kill)] {
            for &i in ids.iter() {
                anyhow::ensure!(
                    i < n_devices,
                    "fault {what} device {i} outside the roster (n_devices = {n_devices})"
                );
            }
        }
        anyhow::ensure!(
            self.blackout.len() < n_devices,
            "fault blackout covers the whole fleet ({n_devices} devices): nothing would train"
        );
        for (name, p) in [
            ("error_rate", self.error_rate),
            ("panic_rate", self.panic_rate),
            ("delay_rate", self.delay_rate),
            ("lane_crash_rate", self.lane_crash_rate),
            ("torn_checkpoint_rate", self.torn_checkpoint_rate),
        ] {
            anyhow::ensure!((0.0..=1.0).contains(&p), "fault {name} {p} outside [0, 1]");
        }
        anyhow::ensure!(
            self.error_rate + self.panic_rate + self.delay_rate <= 1.0,
            "fault attempt rates sum to {} > 1",
            self.error_rate + self.panic_rate + self.delay_rate
        );
        if self.delay_rate > 0.0 {
            anyhow::ensure!(self.delay_ms > 0, "fault delay_rate > 0 needs delay_ms > 0");
        }
        Ok(())
    }

    /// True for device ids that must never be scheduled in any round.
    pub fn blacked_out(&self, device: usize) -> bool {
        self.blackout.contains(&device)
    }

    /// Serialize to the JSON form accepted by [`FaultSpec::from_json`].
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()))
            .set("blackout", Json::from_usizes(&self.blackout))
            .set("kill", Json::from_usizes(&self.kill))
            .set("error_rate", Json::Num(self.error_rate))
            .set("panic_rate", Json::Num(self.panic_rate))
            .set("delay_rate", Json::Num(self.delay_rate))
            .set("delay_ms", Json::Num(self.delay_ms as f64))
            .set("deadline_ms", Json::Num(self.deadline_ms as f64))
            .set("max_retries", Json::Num(self.max_retries as f64))
            .set("backoff_ms", Json::Num(self.backoff_ms as f64))
            .set("quarantine_after", Json::Num(self.quarantine_after as f64))
            .set("lane_crash_rate", Json::Num(self.lane_crash_rate))
            .set("torn_checkpoint_rate", Json::Num(self.torn_checkpoint_rate))
            .set("until_round", Json::Num(self.until_round as f64));
        j
    }

    /// Parse from JSON. Every field except `name` is optional and defaults
    /// to [`FaultSpec::default`], so a spec file only states what it breaks.
    pub fn from_json(j: &Json) -> crate::Result<FaultSpec> {
        let d = FaultSpec::default();
        let opt_f64 = |key: &str, dv: f64| -> crate::Result<f64> {
            match j.get(key) {
                Some(v) => v.as_f64(),
                None => Ok(dv),
            }
        };
        let opt_u64 = |key: &str, dv: u64| -> crate::Result<u64> {
            match j.get(key) {
                Some(v) => v.as_u64(),
                None => Ok(dv),
            }
        };
        let opt_ids = |key: &str| -> crate::Result<Vec<usize>> {
            match j.get(key) {
                Some(v) => v.usize_vec(),
                None => Ok(Vec::new()),
            }
        };
        Ok(FaultSpec {
            name: j.req("name")?.as_str()?.to_string(),
            blackout: opt_ids("blackout")?,
            kill: opt_ids("kill")?,
            error_rate: opt_f64("error_rate", d.error_rate)?,
            panic_rate: opt_f64("panic_rate", d.panic_rate)?,
            delay_rate: opt_f64("delay_rate", d.delay_rate)?,
            delay_ms: opt_u64("delay_ms", d.delay_ms)?,
            deadline_ms: opt_u64("deadline_ms", d.deadline_ms)?,
            max_retries: opt_u64("max_retries", d.max_retries as u64)? as u32,
            backoff_ms: opt_u64("backoff_ms", d.backoff_ms)?,
            quarantine_after: opt_u64("quarantine_after", d.quarantine_after as u64)? as u32,
            lane_crash_rate: opt_f64("lane_crash_rate", d.lane_crash_rate)?,
            torn_checkpoint_rate: opt_f64("torn_checkpoint_rate", d.torn_checkpoint_rate)?,
            until_round: opt_u64("until_round", d.until_round as u64)? as usize,
        })
    }

    /// Load a spec from a JSON file (see [`FaultSpec::from_json`]).
    pub fn load(path: &std::path::Path) -> crate::Result<FaultSpec> {
        let text = std::fs::read_to_string(path)?;
        FaultSpec::from_json(&Json::parse(&text)?)
    }

    /// Write the spec to `path` as JSON — the inverse of [`FaultSpec::load`].
    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        std::fs::write(path, self.to_json().dump())?;
        Ok(())
    }
}

/// Named fault presets for `hasfl train --faults <preset>` and ci.sh's
/// chaos smoke: roster-size-agnostic (no device ids), so they validate
/// against any fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPreset {
    /// Transient-only noise: errors, panics, and sub-deadline delays that
    /// retries always absorb. Survivor set = full roster.
    Flaky,
    /// Everything at once: heavy transient step faults, a lane crash
    /// roughly every other round, and occasional torn checkpoints.
    Chaos,
}

impl FaultPreset {
    /// Every preset, for CLI help text and exhaustive tests.
    pub const ALL: [FaultPreset; 2] = [FaultPreset::Flaky, FaultPreset::Chaos];

    /// Canonical lowercase name — the inverse of [`FaultPreset::parse`].
    pub fn as_str(&self) -> &'static str {
        match self {
            FaultPreset::Flaky => "flaky",
            FaultPreset::Chaos => "chaos",
        }
    }

    /// Parse a preset name as accepted by `--faults` (flaky|chaos).
    pub fn parse(s: &str) -> crate::Result<FaultPreset> {
        Ok(match s {
            "flaky" => FaultPreset::Flaky,
            "chaos" => FaultPreset::Chaos,
            _ => anyhow::bail!("unknown fault preset '{s}' (expected flaky|chaos)"),
        })
    }

    /// Materialize the preset's concrete [`FaultSpec`].
    pub fn spec(&self) -> FaultSpec {
        let name = self.as_str().to_string();
        match self {
            FaultPreset::Flaky => FaultSpec {
                name,
                error_rate: 0.2,
                panic_rate: 0.05,
                delay_rate: 0.1,
                delay_ms: 2,
                deadline_ms: 60_000,
                max_retries: 3,
                backoff_ms: 1,
                ..FaultSpec::default()
            },
            FaultPreset::Chaos => FaultSpec {
                name,
                error_rate: 0.25,
                panic_rate: 0.1,
                delay_rate: 0.15,
                delay_ms: 2,
                deadline_ms: 60_000,
                max_retries: 3,
                backoff_ms: 1,
                quarantine_after: 3,
                lane_crash_rate: 0.5,
                torn_checkpoint_rate: 0.25,
                ..FaultSpec::default()
            },
        }
    }
}

/// Mutable per-run fault bookkeeping: strike counts and the quarantine
/// roster. This is the only injector state that affects numerics, so it is
/// the only part persisted in checkpoints (as a trailing optional field of
/// the `HASFLCKP` payload — legacy checkpoints simply lack it).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultState {
    /// Cumulative abandonments per roster device.
    pub strikes: Vec<u32>,
    /// Devices excluded from all future rounds (repeat offenders).
    pub quarantined: Vec<bool>,
}

impl FaultState {
    /// Fresh state for a roster of `n_devices`: no strikes, no quarantine.
    pub fn new(n_devices: usize) -> FaultState {
        FaultState { strikes: vec![0; n_devices], quarantined: vec![false; n_devices] }
    }

    /// Record an abandonment; returns true when the device just crossed
    /// the quarantine threshold.
    pub fn note_abandoned(&mut self, device: usize, quarantine_after: u32) -> bool {
        self.strikes[device] = self.strikes[device].saturating_add(1);
        if quarantine_after > 0
            && self.strikes[device] >= quarantine_after
            && !self.quarantined[device]
        {
            self.quarantined[device] = true;
            return true;
        }
        false
    }

    /// Ascending ids of quarantined devices.
    pub fn quarantined_ids(&self) -> Vec<usize> {
        self.quarantined
            .iter()
            .enumerate()
            .filter_map(|(i, &q)| q.then_some(i))
            .collect()
    }
}

/// Turns spec + experiment seed into per-round fault decisions. Stateless:
/// every method is a pure function of its arguments, so plans survive
/// checkpoint/resume and worker-pool scheduling unchanged.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    spec: FaultSpec,
    seed: u64,
}

impl FaultInjector {
    /// Bind a spec to the experiment seed all draws derive from.
    pub fn new(spec: FaultSpec, seed: u64) -> FaultInjector {
        FaultInjector { spec, seed }
    }

    /// The spec this injector draws from.
    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Whether the random injections apply to (1-based) `round`.
    fn active(&self, round: u64) -> bool {
        self.spec.until_round == 0 || round <= self.spec.until_round as u64
    }

    /// Pre-draw the round's device fault plan: one uniform draw per
    /// (device, attempt) in device order, whole roster, so the protocol is
    /// independent of participation and scheduling. The final attempt of a
    /// non-`kill` device is always clean (see the module docs).
    pub fn round_plan(&self, round: u64, n_devices: usize) -> RoundPlan {
        let mut rng = Pcg32::new(self.seed ^ STREAM_DEVICE, round);
        let active = self.active(round);
        let n_attempts = self.spec.max_retries as usize + 1;
        let mut attempts = Vec::with_capacity(n_devices);
        for device in 0..n_devices {
            let killed = self.spec.kill.contains(&device);
            let mut plan = Vec::with_capacity(n_attempts);
            for attempt in 0..n_attempts {
                // Always consume the draw: fixed draw count per round
                // keeps the stream layout independent of spec details.
                let u = rng.next_f64();
                let fault = if killed {
                    AttemptFault::Error
                } else if !active || attempt + 1 == n_attempts {
                    AttemptFault::None
                } else if u < self.spec.panic_rate {
                    AttemptFault::Panic
                } else if u < self.spec.panic_rate + self.spec.error_rate {
                    AttemptFault::Error
                } else if u < self.spec.panic_rate + self.spec.error_rate + self.spec.delay_rate {
                    AttemptFault::Delay(self.spec.delay_ms)
                } else {
                    AttemptFault::None
                };
                plan.push(fault);
            }
            attempts.push(plan);
        }
        RoundPlan { attempts }
    }

    /// Which engine lane (if any) crashes at the start of `round`.
    pub fn lane_crash(&self, round: u64, n_lanes: usize) -> Option<usize> {
        if n_lanes == 0 || self.spec.lane_crash_rate <= 0.0 || !self.active(round) {
            return None;
        }
        let mut rng = Pcg32::new(self.seed ^ STREAM_LANE, round);
        (rng.next_f64() < self.spec.lane_crash_rate)
            .then(|| rng.below(n_lanes as u32) as usize)
    }

    /// Whether the checkpoint written after `round` is torn.
    pub fn tear_checkpoint(&self, round: u64) -> bool {
        if self.spec.torn_checkpoint_rate <= 0.0 || !self.active(round) {
            return false;
        }
        let mut rng = Pcg32::new(self.seed ^ STREAM_TEAR, round);
        rng.next_f64() < self.spec.torn_checkpoint_rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec_with_everything() -> FaultSpec {
        FaultSpec {
            name: "everything".into(),
            blackout: vec![0],
            kill: vec![2],
            error_rate: 0.2,
            panic_rate: 0.1,
            delay_rate: 0.1,
            delay_ms: 500,
            deadline_ms: 100,
            max_retries: 3,
            backoff_ms: 2,
            quarantine_after: 2,
            lane_crash_rate: 0.5,
            torn_checkpoint_rate: 0.3,
            until_round: 10,
        }
    }

    #[test]
    fn spec_roundtrips_through_json() {
        let s = spec_with_everything();
        let back = FaultSpec::from_json(&Json::parse(&s.to_json().dump()).unwrap()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn sparse_json_fills_defaults() {
        let j = Json::parse(r#"{"name":"minimal","kill":[1]}"#).unwrap();
        let s = FaultSpec::from_json(&j).unwrap();
        assert_eq!(s.kill, vec![1]);
        assert_eq!(s.max_retries, FaultSpec::default().max_retries);
        assert_eq!(s.error_rate, 0.0);
    }

    #[test]
    fn save_load_roundtrip() {
        let s = spec_with_everything();
        let path = std::env::temp_dir().join("hasfl_fault_rt.json");
        s.save(&path).unwrap();
        assert_eq!(FaultSpec::load(&path).unwrap(), s);
    }

    #[test]
    fn presets_parse_validate_and_roundtrip() {
        for p in FaultPreset::ALL {
            assert_eq!(FaultPreset::parse(p.as_str()).unwrap(), p);
            let s = p.spec();
            s.validate(4).unwrap();
            let back = FaultSpec::from_json(&Json::parse(&s.to_json().dump()).unwrap()).unwrap();
            assert_eq!(s, back, "preset '{}'", p.as_str());
        }
        assert!(FaultPreset::parse("bogus").is_err());
    }

    #[test]
    fn bad_specs_are_rejected() {
        let mut s = spec_with_everything();
        s.error_rate = 1.5;
        assert!(s.validate(4).is_err());

        let mut s = spec_with_everything();
        s.kill = vec![9];
        assert!(s.validate(4).is_err());

        let mut s = spec_with_everything();
        s.blackout = vec![0, 1, 2, 3];
        assert!(s.validate(4).is_err());

        let mut s = spec_with_everything();
        s.error_rate = 0.6;
        s.panic_rate = 0.5;
        assert!(s.validate(4).is_err());

        let mut s = spec_with_everything();
        s.delay_rate = 0.1;
        s.delay_ms = 0;
        assert!(s.validate(4).is_err());
    }

    #[test]
    fn plans_are_pure_functions_of_seed_and_round() {
        let inj = FaultInjector::new(spec_with_everything(), 77);
        let a = inj.round_plan(3, 6);
        let b = inj.round_plan(3, 6);
        assert_eq!(a.attempts, b.attempts);
        assert_eq!(inj.lane_crash(3, 4), inj.lane_crash(3, 4));
        assert_eq!(inj.tear_checkpoint(3), inj.tear_checkpoint(3));
        // Different rounds draw from different streams.
        let c = inj.round_plan(4, 6);
        assert_ne!(a.attempts, c.attempts);
    }

    #[test]
    fn killed_devices_fail_every_attempt_and_survivors_end_clean() {
        let inj = FaultInjector::new(spec_with_everything(), 77);
        for round in 1..=10 {
            let plan = inj.round_plan(round, 6);
            assert!(plan.attempts[2].iter().all(|f| *f == AttemptFault::Error));
            for (d, attempts) in plan.attempts.iter().enumerate() {
                if d != 2 {
                    assert_eq!(
                        *attempts.last().unwrap(),
                        AttemptFault::None,
                        "transient guarantee: final attempt of device {d} must be clean"
                    );
                }
            }
        }
    }

    #[test]
    fn until_round_silences_random_faults_but_not_kill() {
        let inj = FaultInjector::new(spec_with_everything(), 77);
        let plan = inj.round_plan(11, 6);
        for (d, attempts) in plan.attempts.iter().enumerate() {
            if d == 2 {
                assert!(attempts.iter().all(|f| *f == AttemptFault::Error));
            } else {
                assert!(attempts.iter().all(|f| *f == AttemptFault::None));
            }
        }
        assert_eq!(inj.lane_crash(11, 4), None);
        assert!(!inj.tear_checkpoint(11));
    }

    #[test]
    fn fault_state_quarantines_after_threshold() {
        let mut st = FaultState::new(4);
        assert!(!st.note_abandoned(1, 2));
        assert!(st.note_abandoned(1, 2));
        assert!(!st.note_abandoned(1, 2)); // already quarantined
        assert_eq!(st.quarantined_ids(), vec![1]);
        // Threshold 0 never quarantines.
        for _ in 0..10 {
            assert!(!st.note_abandoned(2, 0));
        }
        assert_eq!(st.quarantined_ids(), vec![1]);
    }
}
