//! Cell shards: the execution plan and streaming result collector behind
//! hierarchical aggregation (DESIGN.md §15).
//!
//! A [`CellPlan`] binds one topology cell (a contiguous device-id range)
//! to a dedicated slice of the engine-lane/worker pool. The concurrent
//! round gives each cell its own work queue, so cells stop contending on
//! one shared queue and a lane only ever packs buffers for one cell's
//! devices (cell-affine COMMON/SYNC buffer scoping falls out of the lane
//! partition — caches are per-lane).
//!
//! [`RoundCollector`] is the root coordinator's streaming sink: device
//! results are absorbed in *completion* order — the SGD update touches
//! only the finishing device's own parameters, so application order is
//! bitwise-irrelevant — while the per-round statistics are re-ordered
//! into canonical ascending-id form at [`RoundCollector::finish`]. That
//! is what lets a 10k-device round run in bounded memory: gradients are
//! dropped as they are applied instead of being buffered for the whole
//! round, except for the bounded estimator sample below.

use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::{Mutex, MutexGuard, PoisonError};

use super::round::DeviceResult;
use crate::aggregation::CellAggregate;
use crate::model::{Params, Tensor};
use crate::topology::{balanced_ranges, Topology};

/// How many participants feed the Assumption-2 gradient-statistics
/// estimator per round: the `ESTIMATOR_SAMPLE_CAP` smallest-id
/// participants (a deterministic sample — independent of completion
/// order). The estimator's cross-device variance needs all sampled
/// gradients simultaneously, so an uncapped fleet would hold every
/// gradient in memory (~700 KB/device: 7 GB at 10k devices). For fleets
/// at or under the cap the sample is the full participant set and the
/// estimate is bit-identical to the unsampled historical path.
pub(crate) const ESTIMATOR_SAMPLE_CAP: usize = 256;

/// One cell's execution plan: its device-id range and the engine-lane
/// slice its workers drive. With `cells <= width` the lanes partition
/// among cells (one worker per lane); with more cells than lanes, cells
/// wrap onto lanes round-robin and each lane runs its cells' devices in
/// cell order through a single worker — total worker threads never
/// exceed the pool width either way.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) struct CellPlan {
    /// Cell index (position in the topology's fixed cell order).
    pub cell: usize,
    /// Contiguous device-id range this cell owns.
    pub devices: Range<usize>,
    /// Engine-lane slice this cell's devices route to.
    pub lanes: Range<usize>,
}

impl CellPlan {
    /// Engine lane device `i` routes to. For the flat single-cell plan
    /// this is exactly the historical `i % width`.
    pub fn lane_of(&self, i: usize) -> usize {
        debug_assert!(self.devices.contains(&i));
        self.lanes.start + (i - self.devices.start) % self.lanes.len().max(1)
    }
}

/// Build the round execution plan: no topology = one flat cell over the
/// whole roster and the whole pool (bit- and thread-identical to the
/// historical path); a topology partitions devices into balanced
/// contiguous cells and lanes into cell-affine slices.
pub(crate) fn plan_cells(
    topology: Option<&Topology>,
    n_devices: usize,
    width: usize,
) -> Vec<CellPlan> {
    let width = width.max(1);
    let Some(t) = topology else {
        return vec![CellPlan { cell: 0, devices: 0..n_devices, lanes: 0..width }];
    };
    let c = t.resolve_cells(width);
    let lane_slices: Vec<Range<usize>> = if c <= width {
        balanced_ranges(width, c)
    } else {
        (0..c).map(|k| (k % width)..(k % width + 1)).collect()
    };
    Topology::cell_ranges(c, n_devices)
        .into_iter()
        .zip(lane_slices)
        .enumerate()
        .map(|(k, (devices, lanes))| CellPlan { cell: k, devices, lanes })
        .collect()
}

/// Lock a round queue, recovering from poison: a worker that panicked
/// mid-pop leaves the queue structurally intact (pop completed or not),
/// and the round surfaces the failure through its own result channel —
/// the same survivable-poison stance as `crate::serve::lock`.
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Streaming sink for a round's device results (the root coordinator's
/// half of the shard/root split; see module docs for the memory
/// contract).
pub(crate) struct RoundCollector {
    lr: f64,
    cap: usize,
    /// `(idx, loss, correct, true_batch)` per completed device, in
    /// completion order; sorted ascending at `finish`.
    meta: Vec<(usize, f64, f64, u32)>,
    /// Gradients + batch of the `cap` smallest-id participants seen so
    /// far (the estimator sample). Bounded: an insert past the cap evicts
    /// the largest id, so the final content is independent of completion
    /// order.
    retained: BTreeMap<usize, (Vec<Tensor>, u32)>,
}

impl RoundCollector {
    pub fn new(lr: f64, cap: usize) -> RoundCollector {
        RoundCollector { lr, cap, meta: Vec::new(), retained: BTreeMap::new() }
    }

    /// Absorb one device's result: apply its SGD update immediately (the
    /// update touches only `params[r.idx]`, so absorption order cannot
    /// change any bit of the outcome) and keep the small per-device
    /// statistics + the bounded estimator sample.
    pub fn absorb(&mut self, params: &mut [Params], r: DeviceResult) {
        let nt = params[r.idx].tensors.len();
        debug_assert_eq!(r.grads.len(), nt);
        params[r.idx].sgd_update_range(0..nt, &r.grads, self.lr);
        self.meta.push((r.idx, r.loss, r.correct, r.true_batch));
        if self.retained.len() < self.cap
            || self.retained.last_key_value().map_or(false, |(&k, _)| k > r.idx)
        {
            self.retained.insert(r.idx, (r.grads, r.true_batch));
            if self.retained.len() > self.cap {
                self.retained.pop_last();
            }
        }
    }

    /// Close the round: per-cell aggregates in fixed cell order (each
    /// cell's participants ascending) plus the estimator sample
    /// `(per-device gradients, batches)` in ascending-id order.
    #[allow(clippy::type_complexity)]
    pub fn finish(self, plans: &[CellPlan]) -> (Vec<CellAggregate>, Vec<Vec<Tensor>>, Vec<u32>) {
        let mut meta = self.meta;
        meta.sort_by_key(|m| m.0);
        let mut cells = Vec::with_capacity(plans.len());
        let mut pos = 0usize;
        for p in plans {
            let mut agg = CellAggregate { cell: p.cell, ..CellAggregate::default() };
            while pos < meta.len() && meta[pos].0 < p.devices.end {
                let (idx, loss, correct, tb) = meta[pos];
                debug_assert!(p.devices.contains(&idx), "result {idx} outside cell {}", p.cell);
                agg.participants.push(idx);
                agg.weights.push(tb as f64);
                agg.losses.push(loss);
                agg.corrects.push(correct);
                agg.batches.push(tb);
                pos += 1;
            }
            cells.push(agg);
        }
        debug_assert_eq!(pos, meta.len(), "results outside every cell range");
        let mut grads = Vec::with_capacity(self.retained.len());
        let mut batches = Vec::with_capacity(self.retained.len());
        for (_, (g, b)) in self.retained {
            grads.push(g);
            batches.push(b);
        }
        (cells, grads, batches)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests may unwrap; the deny covers the round path
mod tests {
    use super::*;

    #[test]
    fn flat_plan_reproduces_historical_lane_routing() {
        let plans = plan_cells(None, 10, 4);
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].devices, 0..10);
        assert_eq!(plans[0].lanes, 0..4);
        for i in 0..10 {
            assert_eq!(plans[0].lane_of(i), i % 4);
        }
        // cells=1 is the same single-cell plan.
        let one = plan_cells(Some(&Topology::with_cells(1)), 10, 4);
        assert_eq!(one, plans);
    }

    #[test]
    fn cells_partition_lanes_when_they_fit() {
        let plans = plan_cells(Some(&Topology::with_cells(2)), 10, 4);
        assert_eq!(plans[0].devices, 0..5);
        assert_eq!(plans[1].devices, 5..10);
        assert_eq!(plans[0].lanes, 0..2);
        assert_eq!(plans[1].lanes, 2..4);
        // Lane routing stays inside the cell's slice.
        assert_eq!(plans[1].lane_of(5), 2);
        assert_eq!(plans[1].lane_of(6), 3);
        assert_eq!(plans[1].lane_of(7), 2);
    }

    #[test]
    fn excess_cells_wrap_lanes_round_robin() {
        let plans = plan_cells(Some(&Topology::with_cells(5)), 10, 2);
        let total_workers: usize = {
            // One worker per distinct lane slice start: must not exceed
            // the pool width.
            let mut starts: Vec<usize> = plans.iter().map(|p| p.lanes.start).collect();
            starts.sort_unstable();
            starts.dedup();
            starts.len()
        };
        assert_eq!(total_workers, 2);
        assert_eq!(plans[0].lanes, 0..1);
        assert_eq!(plans[1].lanes, 1..2);
        assert_eq!(plans[2].lanes, 0..1);
        // Auto sizing: one cell per lane.
        let auto = plan_cells(Some(&Topology::auto()), 10, 2);
        assert_eq!(auto.len(), 2);
    }

    #[test]
    fn collector_sample_is_completion_order_independent() {
        use crate::model::Tensor;
        let mk_params = |n: usize| -> Vec<Params> {
            (0..n)
                .map(|_| Params {
                    tensors: vec![Tensor { shape: vec![2], data: vec![1.0, 2.0] }],
                    n_blocks: 1,
                    version: 0,
                })
                .collect()
        };
        let result = |idx: usize| DeviceResult {
            idx,
            grads: vec![Tensor { shape: vec![2], data: vec![0.5, 0.5] }],
            loss: idx as f64,
            correct: 1.0,
            true_batch: 2,
        };
        let run = |order: &[usize]| {
            let mut params = mk_params(6);
            let mut c = RoundCollector::new(0.1, 3);
            for &i in order {
                c.absorb(&mut params, result(i));
            }
            let plans = plan_cells(Some(&Topology::with_cells(2)), 6, 2);
            let (cells, grads, batches) = c.finish(&plans);
            (params, cells, grads, batches)
        };
        let (pa, ca, ga, ba) = run(&[0, 1, 2, 3, 4, 5]);
        let (pb, cb, gb, bb) = run(&[5, 2, 4, 0, 3, 1]);
        assert_eq!(ca, cb);
        assert_eq!(ga, gb);
        assert_eq!(ba, bb);
        // The sample is the 3 smallest ids regardless of arrival order.
        assert_eq!(ga.len(), 3);
        assert_eq!(ba, vec![2, 2, 2]);
        for (x, y) in pa.iter().zip(&pb) {
            assert_eq!(x.tensors[0].data, y.tensors[0].data);
        }
        // Per-cell split respects the fixed cell order.
        assert_eq!(ca[0].participants, vec![0, 1, 2]);
        assert_eq!(ca[1].participants, vec![3, 4, 5]);
    }
}
