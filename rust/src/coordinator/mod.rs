//! The HASFL coordinator: Algorithm 1's training loop over the PJRT
//! runtime, with simulated-network timing from the latency model and
//! periodic BS/MS re-optimization (Algorithm 2) every `I` rounds.
//!
//! [`Trainer`] owns the per-round primitives; the driving loop lives in
//! [`crate::experiment::Session`], which steps the trainer one round at a
//! time. Two execution modes with identical numerics:
//! - `Trainer::run_round` — sequential round (single caller thread,
//!   engine lane 0).
//! - `Trainer::run_round_concurrent` — actor round: a bounded pool of
//!   at most `pool_width` worker threads pulls device work off per-cell
//!   queues (a 10k-device round costs `pool_width` threads, not 10k),
//!   each cell routed to its own engine-lane slice so device legs
//!   genuinely overlap when the pool has width > 1. Results stream into
//!   the root collector in completion order (SGD updates are per-device
//!   disjoint, so order cannot change a bit), and the per-round
//!   statistics are canonicalised to ascending id order, so numerics are
//!   bit-identical to sequential mode (`tests/parity_modes`) and to any
//!   cell count (`tests/cells_parity`, DESIGN.md §15).

// Shard workers must have no panic path outside injected faults: the
// whole coordinator denies `clippy::unwrap_used`, and queue-lock
// poisoning is recovered (`shard::lock`) instead of cascading.
#![deny(clippy::unwrap_used)]

mod async_round;
mod round;
mod shard;

pub use round::RoundOutcome;

use shard::{plan_cells, CellPlan};

use std::path::Path;
use std::sync::Arc;

use crate::aggregation::{
    aggregate_common, aggregate_common_partial, aggregate_forged, aggregate_forged_partial,
    global_average,
};
use crate::checkpoint::CheckpointState;
use crate::config::{Config, Device, ModelKind};
use crate::convergence::{BoundParams, GradStatsEstimator};
use crate::data::{partition, BatchSampler, Dataset};
use crate::fault::{FaultInjector, FaultState};
use crate::latency::{round_latency, round_latency_subset, Decisions, RoundLatency};
use crate::metrics::{CellStats, History, Record};
use crate::model::{profile_for, Manifest, ModelProfile, Params};
use crate::optimizer::{decide, OptContext, StrategyInputs};
use crate::rng::Pcg32;
use crate::runtime::{
    tensor_to_shared, BufKey, EngineHandle, EngineSpec, ExecInput, HostTensor, StepArtifacts,
};
use crate::scenario::{FleetSnapshot, ScenarioEngine};

/// Post-round bookkeeping result (latency + aggregation events), consumed
/// by [`crate::experiment::Session::step`] when assembling the round
/// report.
#[derive(Debug, Clone)]
pub(crate) struct PostRound {
    pub latency: RoundLatency,
    pub aggregated: bool,
    pub reoptimized: bool,
    /// Per-cell execution stats (hierarchical-topology runs only; empty
    /// on flat rosters so flat reports are byte-identical to before).
    pub cells: Vec<CellStats>,
}

/// The full training system state.
///
/// Fields are crate-private; drivers go through
/// [`crate::experiment::Session`] and the read accessors below.
pub struct Trainer {
    pub(crate) cfg: Config,
    pub(crate) engine: EngineHandle,
    pub(crate) manifest: Manifest,
    pub(crate) profile: ModelProfile,
    pub(crate) devices: Vec<Device>,
    pub(crate) train_set: Dataset,
    pub(crate) test_set: Dataset,
    samplers: Vec<BatchSampler>,
    /// Per-device full-model parameters w_i (client part + server part).
    pub(crate) params: Vec<Params>,
    pub(crate) estimator: GradStatsEstimator,
    strategy_rng: Pcg32,
    pub(crate) history: History,
    pub(crate) sim_time: f64,
    pub(crate) dec: Decisions,
    strategy_inputs: StrategyInputs,
    /// Per-device artifact names resolved once per decision window
    /// (refreshed only when `dec` changes, not on every round).
    pub(crate) step_artifacts: Vec<Arc<StepArtifacts>>,
    /// Rounds started so far; versions the per-round input batch buffers.
    pub(crate) rounds_run: u64,
    /// Evaluations run so far; versions the eval-time global-model buffers.
    eval_epoch: u64,
    /// Version of the fleet-common server sub-model (bumped by the
    /// per-round Eqn-4 aggregation in [`Trainer::post_round`]).
    pub(crate) common_version: u64,
    /// Version of the last full fleet synchronisation (forged aggregation).
    pub(crate) sync_version: u64,
    /// True while every device provably holds identical parameters (at
    /// init, and on the round right after a forged sync) — lets devices
    /// share packed client-side literals. Cleared by the first SGD update.
    pub(crate) fleet_synced: bool,
    /// Dynamic-fleet scenario engine (`None` = the historical static
    /// fleet; no scenario code runs on that path).
    scenario: Option<ScenarioEngine>,
    /// Snapshot of the round currently executing (scenario runs only);
    /// handed to the round report by [`Trainer::take_snapshot`].
    last_snapshot: Option<FleetSnapshot>,
    /// Roster-sized mask of devices that execute the current round (active
    /// and not dropped mid-round). All-true without a scenario.
    participation: Vec<bool>,
    /// Devices that completed the last round (ascending ids) and the
    /// samples each processed — the Eqn-39 weights for partial
    /// aggregation under churn.
    round_participants: Vec<usize>,
    round_weights: Vec<f64>,
    /// Seeded fault injector (`None` = no injection and no tolerance: a
    /// device error fails the round, the historical behaviour).
    pub(crate) faults: Option<FaultInjector>,
    /// Strike counts + quarantine roster — the only fault bookkeeping
    /// that affects numerics, so the only part checkpointed.
    pub(crate) fault_state: FaultState,
    /// Devices abandoned by the round that just executed (ascending ids;
    /// transient, rebuilt every round).
    pub(crate) round_abandoned: Vec<usize>,
    /// The round execution plan: one [`CellPlan`] per topology cell, or
    /// a single flat cell spanning the roster and the whole pool when no
    /// topology is configured. Replanned by [`Trainer::begin_round`]
    /// when scenario churn resizes the roster.
    cells: Vec<CellPlan>,
    /// Buffered-asynchronous scheduler state (`None` = the historical
    /// synchronous barrier; no async code runs on that path). Checkpointed
    /// so a resume replays the identical flush schedule (DESIGN.md §16).
    pub(crate) async_state: Option<crate::asynch::AsyncState>,
}

/// Resolve the configured engine-pool width: 0 = auto (fleet size capped by
/// host parallelism and 8 — lanes beyond the core count only add memory).
fn resolve_pool_width(configured: usize, n_devices: usize) -> usize {
    if configured > 0 {
        return configured;
    }
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    n_devices.min(cores).clamp(1, 8)
}

impl Trainer {
    /// Build a trainer from a config and an artifacts directory.
    ///
    /// Callers go through [`crate::experiment::ExperimentBuilder::build`],
    /// which validates the config (executable model kind, cut/bucket
    /// bounds, artifact compatibility) before reaching here.
    pub(crate) fn new(cfg: Config, artifacts_dir: &Path) -> crate::Result<Trainer> {
        debug_assert_eq!(cfg.model, ModelKind::Splitcnn8, "builder admits only the executable model");
        let width = resolve_pool_width(cfg.engine_pool, cfg.fleet.n_devices);
        // Backend selection (DESIGN.md §11): the builder resolved `Auto`
        // into a concrete kind already; resolving again here is a no-op
        // for concrete kinds and keeps direct `Trainer` construction safe.
        let spec = EngineSpec::resolve(cfg.backend, artifacts_dir, cfg.train.classes);
        let manifest = spec.manifest()?;
        let engine = EngineHandle::spawn_backend(spec, width)?;
        anyhow::ensure!(
            manifest.num_classes == cfg.train.classes,
            "artifacts built for {} classes, config wants {}",
            manifest.num_classes,
            cfg.train.classes
        );
        let profile = profile_for(cfg.model, Some(&manifest));
        let devices = cfg.sample_fleet();
        let n = devices.len();

        let (train_set, test_set) = Dataset::train_test(
            cfg.train.train_samples,
            cfg.train.test_samples,
            cfg.train.classes,
            cfg.seed,
        );
        let mut rng = Pcg32::new(cfg.seed, 0xDA7A0);
        let parts = partition(&train_set, cfg.partition, n, &mut rng);
        let samplers = parts
            .into_iter()
            .enumerate()
            .map(|(i, idx)| BatchSampler::new(idx, rng.fork(i as u64)))
            .collect();

        // All devices start from the same initial model (Alg 1 line 1).
        let init = Params::init(&manifest, cfg.seed);
        let params = vec![init; n];

        let estimator = GradStatsEstimator::new(manifest.num_blocks);
        let strategy_rng = Pcg32::new(cfg.seed, 0x57A7);
        let strategy_inputs =
            StrategyInputs { fixed_batch: cfg.fixed_batch, fixed_cut: cfg.fixed_cut };
        // The scenario engine shares the experiment seed, so the analytic
        // sim and the executable path see the same fleet evolution.
        let scenario = match &cfg.scenario {
            Some(spec) => Some(ScenarioEngine::new(spec.clone(), devices.clone(), cfg.seed)?),
            None => None,
        };
        // The fault injector shares the experiment seed: every injected
        // failure is a pure function of (seed, round), so two runs of the
        // same spec break identically (DESIGN.md §13).
        let faults = cfg.faults.as_ref().map(|s| FaultInjector::new(s.clone(), cfg.seed));
        // Async scheduler state exists iff the config asks for buffered
        // asynchrony — the sync path carries (and serializes) nothing.
        let async_state = cfg.async_spec.as_ref().map(|_| crate::asynch::AsyncState::new(n));

        let mut t = Trainer {
            cfg,
            engine,
            manifest,
            profile,
            devices,
            train_set,
            test_set,
            samplers,
            params,
            estimator,
            strategy_rng,
            history: History::default(),
            sim_time: 0.0,
            dec: Decisions::uniform(n, 1, 1),
            strategy_inputs,
            step_artifacts: Vec::new(),
            rounds_run: 0,
            eval_epoch: 0,
            common_version: 0,
            sync_version: 0,
            // Every device holds a clone of `init` until the first update.
            fleet_synced: true,
            scenario,
            last_snapshot: None,
            participation: vec![true; n],
            round_participants: Vec::new(),
            round_weights: Vec::new(),
            faults,
            fault_state: FaultState::new(n),
            round_abandoned: Vec::new(),
            cells: Vec::new(),
            async_state,
        };
        t.cells = plan_cells(t.cfg.topology.as_ref(), n, t.engine.width());
        t.dec = t.next_decisions();
        t.refresh_step_artifacts()?;
        Ok(t)
    }

    /// Re-resolve per-device artifact names from the decisions in force.
    /// Called whenever `dec` changes so `prepare_device` (the per-round
    /// path) only clones an `Arc`.
    fn refresh_step_artifacts(&mut self) -> crate::Result<()> {
        let n = self.dec.cut.len();
        let mut arts = Vec::with_capacity(n);
        for i in 0..n {
            let sa = StepArtifacts::resolve(&self.manifest, self.dec.cut[i], self.dec.batch[i])?;
            arts.push(Arc::new(sa));
        }
        self.step_artifacts = arts;
        Ok(())
    }

    /// The experiment configuration.
    pub fn cfg(&self) -> &Config {
        &self.cfg
    }

    /// Handle to the engine pool (PJRT or native lanes).
    pub fn engine(&self) -> &EngineHandle {
        &self.engine
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The latency-model profile in use.
    pub fn profile(&self) -> &ModelProfile {
        &self.profile
    }

    /// The sampled heterogeneous fleet.
    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    /// Accumulated run history.
    pub fn history(&self) -> &History {
        &self.history
    }

    /// The decisions currently in force.
    pub fn decisions(&self) -> &Decisions {
        &self.dec
    }

    /// Simulated wall-clock so far (seconds).
    pub fn sim_time(&self) -> f64 {
        self.sim_time
    }

    /// The Assumption-2 gradient-statistics estimator.
    pub fn estimator(&self) -> &GradStatsEstimator {
        &self.estimator
    }

    /// Per-device model parameters (read access for parity tests and
    /// diagnostics).
    pub fn params(&self) -> &[Params] {
        &self.params
    }

    /// Roster-sized mask of devices executing the current round.
    pub fn participation(&self) -> &[bool] {
        &self.participation
    }

    /// Advance the dynamic scenario (if any) at the top of a round:
    /// refresh effective device resources from the engine and rebuild the
    /// participation mask (active members minus mid-round dropouts), then
    /// subtract the fault layer's exclusions (blacked-out devices and the
    /// quarantine roster). A no-op — no RNG draws, no state changes — on
    /// static fleets without fault injection.
    pub(crate) fn begin_round(&mut self) {
        if let Some(engine) = self.scenario.as_mut() {
            let snap = engine.advance();
            self.devices = engine.effective_roster().to_vec();
            self.participation = snap.participation(self.devices.len());
            self.last_snapshot = Some(snap);
        } else if self.faults.is_some() {
            // Static fleets only rebuild the mask when the fault layer
            // can shrink it (last round's abandonments cleared bits).
            self.participation = vec![true; self.devices.len()];
        }
        if let Some(inj) = &self.faults {
            for i in 0..self.participation.len() {
                if inj.spec().blacked_out(i) || self.fault_state.quarantined[i] {
                    self.participation[i] = false;
                }
            }
        }
        self.round_abandoned.clear();
        // Scenario churn can resize the roster: keep the cell plan's
        // contiguous ranges covering it (a pure function of
        // (topology, n, width) — no RNG, so replanning is deterministic).
        if self.cells.last().map_or(0, |c| c.devices.end) != self.devices.len() {
            self.cells =
                plan_cells(self.cfg.topology.as_ref(), self.devices.len(), self.engine.width());
        }
    }

    /// Hand the current round's fleet snapshot to the round report.
    pub(crate) fn take_snapshot(&mut self) -> Option<FleetSnapshot> {
        self.last_snapshot.take()
    }

    pub(crate) fn push_record(&mut self, rec: Record) {
        self.history.push(rec);
    }

    pub(crate) fn take_history(&mut self) -> History {
        std::mem::take(&mut self.history)
    }

    /// Capture the complete training state between rounds — everything
    /// [`Trainer::restore`] needs to reproduce the uninterrupted run
    /// bit-for-bit. `round` is the session's completed-round counter.
    ///
    /// The capture clones the per-device `Params` (one transient extra
    /// copy of the fleet's parameters while the checkpoint serializes) —
    /// accepted for the executable path's fleet sizes; a borrowing
    /// serializer is the upgrade path if checkpointing ever runs at the
    /// analytic sim's 1k+-device scale.
    pub(crate) fn capture(&self, round: usize) -> CheckpointState {
        CheckpointState {
            config_json: self.cfg.to_json().dump(),
            round: round as u64,
            rounds_run: self.rounds_run,
            eval_epoch: self.eval_epoch,
            common_version: self.common_version,
            sync_version: self.sync_version,
            fleet_synced: self.fleet_synced,
            sim_time: self.sim_time,
            params: self.params.clone(),
            dec: self.dec.clone(),
            history: self.history.records.clone(),
            estimator: self.estimator.to_state(),
            strategy_rng: self.strategy_rng.state_parts(),
            sampler_rngs: self.samplers.iter().map(|s| s.rng_state()).collect(),
            scenario: self.scenario.as_ref().map(|e| e.to_state()),
            fault: self.faults.as_ref().map(|_| self.fault_state.clone()),
            async_state: self.async_state.clone(),
        }
    }

    /// Restore a freshly-built trainer (same config) to checkpointed
    /// state. [`Trainer::new`] already rebuilt the deterministic substrate
    /// (engine, manifest, datasets, partitions) from the config; this
    /// overlays every piece of state that evolves during training: params,
    /// RNG streams, sampler cursors, estimator, scenario engine, incumbent
    /// decisions, history, clocks, and the buffer-cache version counters.
    /// Takes the state by value and moves the heavy payloads (params,
    /// history) in, so a resume never holds a third copy of the fleet's
    /// parameters.
    pub(crate) fn restore(&mut self, state: CheckpointState) -> crate::Result<()> {
        let n = self.params.len();
        anyhow::ensure!(
            state.params.len() == n,
            "checkpoint holds {} device models, config fleet has {n}",
            state.params.len()
        );
        for (i, (have, want)) in self.params.iter().zip(&state.params).enumerate() {
            anyhow::ensure!(
                have.tensors.len() == want.tensors.len() && have.n_blocks == want.n_blocks,
                "checkpoint device {i} holds {} tensors / {} blocks, model expects {} / {}",
                want.tensors.len(),
                want.n_blocks,
                have.tensors.len(),
                have.n_blocks
            );
        }
        anyhow::ensure!(
            state.sampler_rngs.len() == self.samplers.len(),
            "checkpoint holds {} sampler streams, fleet has {}",
            state.sampler_rngs.len(),
            self.samplers.len()
        );
        anyhow::ensure!(
            state.dec.n() == n,
            "checkpoint decisions cover {} devices, fleet has {n}",
            state.dec.n()
        );
        match (&mut self.scenario, &state.scenario) {
            (Some(engine), Some(s)) => {
                engine.restore_state(s)?;
                // The optimizer's fleet view: the persistent effective
                // roster as of the checkpointed round.
                self.devices = engine.effective_roster().to_vec();
            }
            (None, None) => {}
            (Some(_), None) => {
                anyhow::bail!("config has a scenario but the checkpoint carries no engine state")
            }
            (None, Some(_)) => {
                anyhow::bail!("checkpoint carries scenario state but the config has no scenario")
            }
        }
        match (&self.faults, &state.fault) {
            (Some(_), Some(f)) => {
                anyhow::ensure!(
                    f.strikes.len() == n && f.quarantined.len() == n,
                    "checkpoint fault state covers {} devices, fleet has {n}",
                    f.strikes.len()
                );
                self.fault_state = f.clone();
            }
            (None, None) => {}
            (Some(_), None) => {
                anyhow::bail!("config has a fault spec but the checkpoint carries no fault state")
            }
            (None, Some(_)) => {
                anyhow::bail!("checkpoint carries fault state but the config has no fault spec")
            }
        }
        match (&self.cfg.async_spec, &state.async_state) {
            (Some(_), Some(a)) => {
                anyhow::ensure!(
                    a.n_devices() == n,
                    "checkpoint async state covers {} devices, fleet has {n}",
                    a.n_devices()
                );
                self.async_state = Some(a.clone());
            }
            (None, None) => {}
            (Some(_), None) => {
                anyhow::bail!("config has an async spec but the checkpoint carries no async state")
            }
            (None, Some(_)) => {
                anyhow::bail!("checkpoint carries async state but the config has no async spec")
            }
        }
        self.params = state.params;
        self.dec = state.dec;
        self.refresh_step_artifacts()?;
        self.history = History { records: state.history };
        self.estimator = GradStatsEstimator::from_state(state.estimator);
        self.strategy_rng = Pcg32::from_state_parts(state.strategy_rng.0, state.strategy_rng.1);
        for (s, &(st, inc)) in self.samplers.iter_mut().zip(&state.sampler_rngs) {
            s.restore_rng(st, inc);
        }
        self.sim_time = state.sim_time;
        self.rounds_run = state.rounds_run;
        self.eval_epoch = state.eval_epoch;
        self.common_version = state.common_version;
        self.sync_version = state.sync_version;
        self.fleet_synced = state.fleet_synced;
        // Per-round transients: rebuilt by `begin_round`/`apply_results`
        // at the top of the next step, exactly as in the uninterrupted run.
        self.last_snapshot = None;
        self.participation = vec![true; n];
        self.round_participants.clear();
        self.round_weights.clear();
        self.round_abandoned.clear();
        Ok(())
    }

    /// Latency breakdown of one round under the current decisions. With a
    /// scenario attached, only the round's participants gate the phases
    /// (Eqn 38's maxima run over the surviving devices), priced at the
    /// snapshot's *realized* rates — transient straggler slowdowns included
    /// (the optimizer, by contrast, sees the persistent straggler-free
    /// rates in `self.devices`).
    pub fn current_round_latency(&self) -> RoundLatency {
        match &self.last_snapshot {
            Some(snap) => {
                let mut devices = Vec::with_capacity(snap.active.len());
                let mut batch = Vec::with_capacity(snap.active.len());
                let mut cut = Vec::with_capacity(snap.active.len());
                for (k, &id) in snap.active.iter().enumerate() {
                    if !self.participation[id] {
                        continue;
                    }
                    devices.push(snap.devices[k].clone());
                    batch.push(self.dec.batch[id]);
                    cut.push(self.dec.cut[id]);
                }
                if devices.is_empty() {
                    // Every participant dropped: the round moved no data
                    // and took no time (an explicitly empty round; see
                    // `RoundOutcome::is_empty`).
                    return RoundLatency {
                        per_device: Vec::new(),
                        server_fwd: 0.0,
                        server_bwd: 0.0,
                        t_split: 0.0,
                        t_agg: 0.0,
                    };
                }
                let sub = Decisions { batch, cut };
                round_latency(&self.profile, &devices, &self.cfg.server, &sub)
            }
            None if self.scenario.is_some() || !self.participation.iter().all(|&p| p) => {
                // Partial participation without a snapshot: a scenario run
                // priced between rounds, or a static fleet whose mask the
                // fault layer shrank (blackout / quarantine / abandonment).
                round_latency_subset(
                    &self.profile,
                    &self.devices,
                    &self.cfg.server,
                    &self.dec,
                    &self.participation,
                )
            }
            None => round_latency(&self.profile, &self.devices, &self.cfg.server, &self.dec),
        }
    }

    /// Current bound parameters: estimated from real gradients once the
    /// estimator has seen data, otherwise the principled defaults.
    pub fn bound_params(&self) -> BoundParams {
        if self.estimator.rounds_seen() >= 2 {
            self.estimator
                .to_bound_params(self.cfg.train.lr, 2.0f64.max(self.history.last_loss().unwrap_or(2.3)))
        } else {
            BoundParams::default_for(&self.profile, self.cfg.train.lr)
        }
    }

    /// Run the strategy to get the next window's decisions.
    ///
    /// Epsilon handling: when the bound constants are *estimated* from real
    /// gradients (the paper's approach via [24]), the configured epsilon may
    /// fall below the achievable floor (variance at b = cap + drift at the
    /// shallowest cut), making C1 infeasible for every decision. We follow
    /// the practical route and re-anchor epsilon just above that floor so
    /// the optimizer always compares decisions on a live trade-off.
    pub(crate) fn next_decisions(&mut self) -> Decisions {
        let bound = self.bound_params();
        let n = self.devices.len();
        let cap = self.cfg.train.batch_cap.min(self.manifest.max_bucket());
        let min_cut = *self.profile.valid_cuts.first().unwrap_or(&1);
        let floor = crate::convergence::variance_term(&bound, &vec![cap; n])
            + crate::convergence::drift_term(&bound, min_cut, self.cfg.train.agg_interval);
        let epsilon = self.cfg.train.epsilon.max(floor * 2.0);
        // Async runs re-solve against the *observed* completion-time
        // distribution: the EMA latency model scales each device's
        // analytic rates by its clamped observed/analytic ratio
        // (`observed_devices`, DESIGN.md §16). `None` — and therefore the
        // untouched analytic roster — on every synchronous run.
        let observed = self.observed_devices();
        let devices: &[Device] = observed.as_deref().unwrap_or(&self.devices);
        let ctx = OptContext {
            profile: &self.profile,
            devices,
            server: &self.cfg.server,
            bound: &bound,
            interval: self.cfg.train.agg_interval,
            epsilon,
            batch_cap: cap,
        };
        decide(self.cfg.strategy, &ctx, &mut self.strategy_rng, self.strategy_inputs)
    }

    /// Evaluate test accuracy of the averaged global model through the
    /// `full_fwd` artifact.
    pub(crate) fn evaluate(&mut self) -> crate::Result<f64> {
        let global = global_average(&self.params);
        self.eval_epoch += 1;
        let bucket = self.manifest.max_bucket();
        let classes = self.cfg.train.classes;
        let name = Manifest::full_name("full_fwd", bucket);
        let px = crate::data::PIXELS;

        // Pack the averaged model once per evaluation; every chunk after
        // the first serves the parameters from the engine buffer cache.
        let mut global_inputs = Vec::with_capacity(global.tensors.len());
        for (s, t) in global.tensors.iter().enumerate() {
            let key = BufKey { set: BufKey::EVAL_SET, slot: s as u32 };
            global_inputs.push(ExecInput::cached(key, self.eval_epoch, tensor_to_shared(t)));
        }

        let mut correct = 0usize;
        let mut total = 0usize;
        let n = self.test_set.len();
        let mut i = 0usize;
        while i < n {
            let take = ((n - i) as u32).min(bucket) as usize;
            let mut x = vec![0.0f32; bucket as usize * px];
            for r in 0..take {
                x[r * px..(r + 1) * px].copy_from_slice(self.test_set.image(i + r));
            }
            let mut inputs = Vec::with_capacity(1 + global_inputs.len());
            inputs.push(ExecInput::Fresh(HostTensor {
                shape: vec![bucket as usize, 32, 32, 3],
                data: x,
            }));
            inputs.extend(global_inputs.iter().cloned());
            let out = self.engine.execute_inputs_blocking(0, &name, inputs)?;
            let logits = &out[0];
            for r in 0..take {
                let row = &logits.data[r * classes..(r + 1) * classes];
                // total_cmp: identical ordering to partial_cmp on the
                // non-NaN logits the engine produces, with no panic path
                // (the coordinator-wide unwrap deny).
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map_or(0, |(k, _)| k);
                if pred == self.test_set.labels[i + r] as usize {
                    correct += 1;
                }
            }
            total += take;
            i += take;
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    /// Advance the simulated clock for round `t` and perform the periodic
    /// aggregation + re-optimization bookkeeping. Returns the latency and
    /// aggregation events for the round report.
    ///
    /// Scenario runs aggregate *partially*: only this round's surviving
    /// participants contribute (sample-weighted, the Eqn-39 aggregation
    /// event's weights), and every roster member — dropped and offline
    /// devices included — receives the result, preserving the runtime's
    /// fleet-identical buffer-cache invariants. Fleet drift crossing the
    /// scenario's `resolve_drift` trigger pulls the next aggregation +
    /// BS/MS re-solve forward instead of waiting for the fixed window.
    pub(crate) fn post_round(&mut self, t: usize) -> crate::Result<PostRound> {
        let latency = self.current_round_latency();
        self.post_round_with(t, latency)
    }

    /// [`Trainer::post_round`] with the round latency supplied by the
    /// caller: the synchronous path prices the barrier
    /// ([`Trainer::current_round_latency`]); the buffered-asynchronous
    /// path prices the flush span (`async_round.rs`). Everything else —
    /// aggregation, drift triggers, re-solve — is the same pipeline.
    pub(crate) fn post_round_with(
        &mut self,
        t: usize,
        latency: RoundLatency,
    ) -> crate::Result<PostRound> {
        self.sim_time += latency.t_split;
        // Per-cell fleet trace (topology runs only): derived at the root
        // from the canonical participant/abandoned lists + cell ranges,
        // so sequential and concurrent modes report identical stats.
        let cells = if self.cfg.topology.is_some() { self.cell_stats() } else { Vec::new() };

        // Per-round server-side common aggregation (Eqn 4). After it, the
        // common region is identical on every device, which is what lets
        // `prepare_device` key those tensors under `BufKey::COMMON_SET`.
        // Full-participation rounds use the paper's unweighted mean (so a
        // `static` scenario is bit-identical to a plain session); rounds
        // with offline/dropped/abandoned members — scenario churn or the
        // fault layer's exclusions — aggregate partially.
        let partial = self.round_participants.len() < self.params.len();
        // A round where every participant dropped moves no parameters:
        // skip the Eqn-4 aggregation entirely and keep `common_version`
        // stable, so the COMMON_SET cache keys stay valid and the next
        // non-empty round is not forced into a spurious repack.
        let empty_round = self.round_participants.is_empty();
        if !empty_round {
            if partial {
                aggregate_common_partial(
                    &mut self.params,
                    &self.dec,
                    &self.round_participants,
                    &self.round_weights,
                );
            } else {
                aggregate_common(&mut self.params, &self.dec);
            }
            self.common_version += 1;
        }

        let drift_hit = match (&self.scenario, &self.last_snapshot) {
            (Some(engine), Some(snap)) => engine
                .spec()
                .resolve_drift
                .map_or(false, |thr| snap.drift >= thr),
            _ => false,
        };
        // An empty round also defers the forged-sync event: a
        // zero-participant sync would be a no-op that leaves the fleet
        // non-identical, and the re-solve it triggers could move L_c —
        // which is only safe when the *whole* model is fleet-identical
        // (the COMMON_SET keying contract). The next window (or the
        // drift trigger, which keeps accumulating) picks the event up.
        let aggregated = (t % self.cfg.train.agg_interval == 0 || drift_hit) && !empty_round;
        if aggregated {
            // Steps b1-b3 (Eqn 7) + re-optimization (Alg 1 line 24).
            if partial {
                aggregate_forged_partial(
                    &mut self.params,
                    &self.dec,
                    &self.round_participants,
                    &self.round_weights,
                );
            } else {
                aggregate_forged(&mut self.params, &self.dec);
            }
            // Both forms broadcast the aggregate to the full roster, so
            // the fleet is provably identical from here (empty rounds
            // never reach this branch).
            self.fleet_synced = true;
            self.sim_time += latency.t_agg;
            self.sync_version += 1;
            // Re-optimization may move L_c; that is only safe for the
            // COMMON_SET keying because it happens on forged-sync rounds,
            // when the *whole* model is fleet-identical (partial
            // aggregation broadcasts to the full roster for this reason).
            self.dec = self.next_decisions();
            self.refresh_step_artifacts()?;
            if let Some(engine) = self.scenario.as_mut() {
                engine.mark_resolved();
            }
        }
        Ok(PostRound { latency, aggregated, reoptimized: aggregated, cells })
    }

    /// Per-cell stats of the round that just executed: membership,
    /// participant/abandoned counts from the canonical ascending lists,
    /// and the cell's own straggler-gated split-training latency.
    fn cell_stats(&self) -> Vec<CellStats> {
        self.cells
            .iter()
            .map(|plan| {
                let in_range = |ids: &[usize]| {
                    ids.iter().filter(|&&i| plan.devices.contains(&i)).count()
                };
                CellStats {
                    cell: plan.cell,
                    devices: plan.devices.len(),
                    participants: in_range(&self.round_participants),
                    abandoned: in_range(&self.round_abandoned),
                    t_split: self.cell_split_latency(&plan.devices),
                }
            })
            .collect()
    }

    /// Split-training latency (Eqn 38's maxima) of one cell's surviving
    /// participants — the same pricing as [`Trainer::current_round_latency`]
    /// restricted to the cell's id range. `0.0` for a cell with no
    /// survivors (it gated nothing).
    fn cell_split_latency(&self, range: &std::ops::Range<usize>) -> f64 {
        match &self.last_snapshot {
            Some(snap) => {
                let mut devices = Vec::new();
                let mut batch = Vec::new();
                let mut cut = Vec::new();
                for (k, &id) in snap.active.iter().enumerate() {
                    if !range.contains(&id) || !self.participation[id] {
                        continue;
                    }
                    devices.push(snap.devices[k].clone());
                    batch.push(self.dec.batch[id]);
                    cut.push(self.dec.cut[id]);
                }
                if devices.is_empty() {
                    return 0.0;
                }
                let sub = Decisions { batch, cut };
                round_latency(&self.profile, &devices, &self.cfg.server, &sub).t_split
            }
            None => {
                let mut mask = vec![false; self.devices.len()];
                let mut any = false;
                for i in range.clone() {
                    if self.participation[i] {
                        mask[i] = true;
                        any = true;
                    }
                }
                if !any {
                    return 0.0;
                }
                round_latency_subset(&self.profile, &self.devices, &self.cfg.server, &self.dec, &mask)
                    .t_split
            }
        }
    }

    /// Number of devices currently in the fleet roster.
    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Ascending ids of devices the fault layer has quarantined (repeat
    /// abandonment past the spec's `quarantine_after` threshold). Empty
    /// when faults are off.
    pub fn quarantined_devices(&self) -> Vec<usize> {
        self.fault_state.quarantined_ids()
    }

    /// Devices abandoned by the round that just executed (ascending ids;
    /// cleared at the top of the next round).
    pub fn last_abandoned(&self) -> &[usize] {
        &self.round_abandoned
    }

    /// Fault hook for `Session::checkpoint`: whether the write after
    /// completed round `round` must be torn mid-file. A pure draw of
    /// (seed, round) — never consults the wall clock.
    pub(crate) fn tear_checkpoint(&self, round: usize) -> bool {
        self.faults
            .as_ref()
            .map_or(false, |inj| inj.tear_checkpoint(round as u64))
    }
}
