//! The HASFL coordinator: Algorithm 1's training loop over the PJRT
//! runtime, with simulated-network timing from the latency model and
//! periodic BS/MS re-optimization (Algorithm 2) every `I` rounds.
//!
//! [`Trainer`] owns the per-round primitives; the driving loop lives in
//! [`crate::experiment::Session`], which steps the trainer one round at a
//! time. Two execution modes with identical numerics:
//! - [`Trainer::run_round`] — sequential round (single caller thread).
//! - [`Trainer::run_round_concurrent`] — actor round: one OS thread per edge
//!   device runs steps a1/a5 and the server exchange; the PJRT engine
//!   thread serializes actual compute (CPU client), so this mode exercises
//!   the real message-passing topology without changing results.

mod round;

pub use round::RoundOutcome;

use std::path::Path;

use crate::aggregation::{aggregate_common, aggregate_forged, global_average};
use crate::config::{Config, Device, ModelKind};
use crate::convergence::{BoundParams, GradStatsEstimator};
use crate::data::{partition, BatchSampler, Dataset};
use crate::latency::{round_latency, Decisions, RoundLatency};
use crate::metrics::{History, Record};
use crate::model::{profile_for, Manifest, ModelProfile, Params};
use crate::optimizer::{decide, OptContext, StrategyInputs};
use crate::rng::Pcg32;
use crate::runtime::EngineHandle;

/// Post-round bookkeeping result (latency + aggregation events), consumed
/// by [`crate::experiment::Session::step`] when assembling the round
/// report.
#[derive(Debug, Clone)]
pub(crate) struct PostRound {
    pub latency: RoundLatency,
    pub aggregated: bool,
    pub reoptimized: bool,
}

/// The full training system state.
///
/// Fields are crate-private; drivers go through
/// [`crate::experiment::Session`] and the read accessors below.
pub struct Trainer {
    pub(crate) cfg: Config,
    pub(crate) engine: EngineHandle,
    pub(crate) manifest: Manifest,
    pub(crate) profile: ModelProfile,
    pub(crate) devices: Vec<Device>,
    pub(crate) train_set: Dataset,
    pub(crate) test_set: Dataset,
    samplers: Vec<BatchSampler>,
    /// Per-device full-model parameters w_i (client part + server part).
    pub(crate) params: Vec<Params>,
    pub(crate) estimator: GradStatsEstimator,
    strategy_rng: Pcg32,
    pub(crate) history: History,
    pub(crate) sim_time: f64,
    pub(crate) dec: Decisions,
    strategy_inputs: StrategyInputs,
}

impl Trainer {
    /// Build a trainer from a config and an artifacts directory.
    ///
    /// Callers go through [`crate::experiment::ExperimentBuilder::build`],
    /// which validates the config (executable model kind, cut/bucket
    /// bounds, artifact compatibility) before reaching here.
    pub(crate) fn new(cfg: Config, artifacts_dir: &Path) -> crate::Result<Trainer> {
        debug_assert_eq!(cfg.model, ModelKind::Splitcnn8, "builder admits only the executable model");
        let engine = EngineHandle::spawn(artifacts_dir.to_path_buf())?;
        let manifest = Manifest::load(artifacts_dir)?;
        anyhow::ensure!(
            manifest.num_classes == cfg.train.classes,
            "artifacts built for {} classes, config wants {}",
            manifest.num_classes,
            cfg.train.classes
        );
        let profile = profile_for(cfg.model, Some(&manifest));
        let devices = cfg.sample_fleet();
        let n = devices.len();

        let (train_set, test_set) = Dataset::train_test(
            cfg.train.train_samples,
            cfg.train.test_samples,
            cfg.train.classes,
            cfg.seed,
        );
        let mut rng = Pcg32::new(cfg.seed, 0xDA7A0);
        let parts = partition(&train_set, cfg.partition, n, &mut rng);
        let samplers = parts
            .into_iter()
            .enumerate()
            .map(|(i, idx)| BatchSampler::new(idx, rng.fork(i as u64)))
            .collect();

        // All devices start from the same initial model (Alg 1 line 1).
        let init = Params::init(&manifest, cfg.seed);
        let params = vec![init; n];

        let estimator = GradStatsEstimator::new(manifest.num_blocks);
        let strategy_rng = Pcg32::new(cfg.seed, 0x57A7);
        let strategy_inputs =
            StrategyInputs { fixed_batch: cfg.fixed_batch, fixed_cut: cfg.fixed_cut };

        let mut t = Trainer {
            cfg,
            engine,
            manifest,
            profile,
            devices,
            train_set,
            test_set,
            samplers,
            params,
            estimator,
            strategy_rng,
            history: History::default(),
            sim_time: 0.0,
            dec: Decisions::uniform(n, 1, 1),
            strategy_inputs,
        };
        t.dec = t.next_decisions();
        Ok(t)
    }

    /// The experiment configuration.
    pub fn cfg(&self) -> &Config {
        &self.cfg
    }

    /// Handle to the PJRT engine thread.
    pub fn engine(&self) -> &EngineHandle {
        &self.engine
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The latency-model profile in use.
    pub fn profile(&self) -> &ModelProfile {
        &self.profile
    }

    /// The sampled heterogeneous fleet.
    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    /// Accumulated run history.
    pub fn history(&self) -> &History {
        &self.history
    }

    /// The decisions currently in force.
    pub fn decisions(&self) -> &Decisions {
        &self.dec
    }

    /// Simulated wall-clock so far (seconds).
    pub fn sim_time(&self) -> f64 {
        self.sim_time
    }

    /// The Assumption-2 gradient-statistics estimator.
    pub fn estimator(&self) -> &GradStatsEstimator {
        &self.estimator
    }

    pub(crate) fn push_record(&mut self, rec: Record) {
        self.history.push(rec);
    }

    pub(crate) fn take_history(&mut self) -> History {
        std::mem::take(&mut self.history)
    }

    /// Latency breakdown of one round under the current decisions.
    pub fn current_round_latency(&self) -> RoundLatency {
        round_latency(&self.profile, &self.devices, &self.cfg.server, &self.dec)
    }

    /// Current bound parameters: estimated from real gradients once the
    /// estimator has seen data, otherwise the principled defaults.
    pub fn bound_params(&self) -> BoundParams {
        if self.estimator.rounds_seen() >= 2 {
            self.estimator
                .to_bound_params(self.cfg.train.lr, 2.0f64.max(self.history.last_loss().unwrap_or(2.3)))
        } else {
            BoundParams::default_for(&self.profile, self.cfg.train.lr)
        }
    }

    /// Run the strategy to get the next window's decisions.
    ///
    /// Epsilon handling: when the bound constants are *estimated* from real
    /// gradients (the paper's approach via [24]), the configured epsilon may
    /// fall below the achievable floor (variance at b = cap + drift at the
    /// shallowest cut), making C1 infeasible for every decision. We follow
    /// the practical route and re-anchor epsilon just above that floor so
    /// the optimizer always compares decisions on a live trade-off.
    pub(crate) fn next_decisions(&mut self) -> Decisions {
        let bound = self.bound_params();
        let n = self.devices.len();
        let cap = self.cfg.train.batch_cap.min(self.manifest.max_bucket());
        let min_cut = *self.profile.valid_cuts.first().unwrap_or(&1);
        let floor = crate::convergence::variance_term(&bound, &vec![cap; n])
            + crate::convergence::drift_term(&bound, min_cut, self.cfg.train.agg_interval);
        let epsilon = self.cfg.train.epsilon.max(floor * 2.0);
        let ctx = OptContext {
            profile: &self.profile,
            devices: &self.devices,
            server: &self.cfg.server,
            bound: &bound,
            interval: self.cfg.train.agg_interval,
            epsilon,
            batch_cap: cap,
        };
        decide(self.cfg.strategy, &ctx, &mut self.strategy_rng, self.strategy_inputs)
    }

    /// Evaluate test accuracy of the averaged global model through the
    /// `full_fwd` artifact.
    pub(crate) fn evaluate(&mut self) -> crate::Result<f64> {
        let global = global_average(&self.params);
        let bucket = self.manifest.max_bucket();
        let classes = self.cfg.train.classes;
        let name = Manifest::full_name("full_fwd", bucket);
        let px = crate::data::PIXELS;

        let mut correct = 0usize;
        let mut total = 0usize;
        let n = self.test_set.len();
        let mut i = 0usize;
        while i < n {
            let take = ((n - i) as u32).min(bucket) as usize;
            let mut x = vec![0.0f32; bucket as usize * px];
            for r in 0..take {
                x[r * px..(r + 1) * px].copy_from_slice(self.test_set.image(i + r));
            }
            let mut inputs = vec![crate::runtime::HostTensor {
                shape: vec![bucket as usize, 32, 32, 3],
                data: x,
            }];
            inputs.extend(global.tensors.iter().map(crate::runtime::tensor_to_host));
            let out = self.engine.execute_blocking(&name, inputs)?;
            let logits = &out[0];
            for r in 0..take {
                let row = &logits.data[r * classes..(r + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred == self.test_set.labels[i + r] as usize {
                    correct += 1;
                }
            }
            total += take;
            i += take;
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    /// Advance the simulated clock for round `t` and perform the periodic
    /// aggregation + re-optimization bookkeeping. Returns the latency and
    /// aggregation events for the round report.
    pub(crate) fn post_round(&mut self, t: usize) -> PostRound {
        let latency = self.current_round_latency();
        self.sim_time += latency.t_split;

        // Per-round server-side common aggregation (Eqn 4).
        aggregate_common(&mut self.params, &self.dec);

        let aggregated = t % self.cfg.train.agg_interval == 0;
        if aggregated {
            // Steps b1-b3 (Eqn 7) + re-optimization (Alg 1 line 24).
            aggregate_forged(&mut self.params, &self.dec);
            self.sim_time += latency.t_agg;
            self.dec = self.next_decisions();
        }
        PostRound { latency, aggregated, reoptimized: aggregated }
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }
}
