//! One split-training round (Algorithm 1, steps a1–a5) over the PJRT
//! runtime, in sequential and concurrent-actor forms.

use super::Trainer;
use crate::model::Tensor;
use crate::runtime::{host_to_tensor, tensor_to_host, HostTensor, StepArtifacts};

/// Aggregate result of one round.
#[derive(Debug, Clone)]
pub struct RoundOutcome {
    /// Mean training loss across devices.
    pub mean_loss: f64,
    /// Weighted training accuracy across devices this round.
    pub train_acc: f64,
}

/// Everything one device needs for its round, detached from the trainer so
/// async tasks can own it.
struct DeviceWork {
    idx: usize,
    #[allow(dead_code)] // kept for tracing/debug parity with the paper notation
    cut: usize,
    artifacts: StepArtifacts,
    x: HostTensor,
    onehot: HostTensor,
    weights: HostTensor,
    client_params: Vec<HostTensor>,
    server_params: Vec<HostTensor>,
    true_batch: u32,
}

/// Result of one device's round: full-model gradient + stats.
struct DeviceResult {
    idx: usize,
    grads: Vec<Tensor>,
    loss: f64,
    correct: f64,
    true_batch: u32,
}

impl Trainer {
    fn prepare_device(&mut self, i: usize) -> crate::Result<DeviceWork> {
        let cut = self.dec.cut[i];
        let b = self.dec.batch[i];
        let artifacts = StepArtifacts::resolve(&self.manifest, cut, b)?;
        let bucket = artifacts.bucket;
        let classes = self.cfg.train.classes;

        // Step a1 precondition: sample the mini-batch B_i^t ⊆ D_i.
        // (disjoint field borrows: samplers mutably, train_set immutably)
        let batch = self.samplers[i].sample(&self.train_set, b, bucket);

        let params = &self.params[i];
        Ok(DeviceWork {
            idx: i,
            cut,
            artifacts,
            x: HostTensor { shape: vec![bucket as usize, 32, 32, 3], data: batch.x },
            onehot: HostTensor { shape: vec![bucket as usize, classes], data: batch.onehot },
            weights: HostTensor { shape: vec![bucket as usize], data: batch.weights },
            client_params: params.client_slice(cut).iter().map(tensor_to_host).collect(),
            server_params: params.server_slice(cut).iter().map(tensor_to_host).collect(),
            true_batch: batch.true_batch,
        })
    }

    /// Execute steps a1–a5 for one device through the engine (blocking).
    fn exec_device_blocking(
        engine: &crate::runtime::EngineHandle,
        work: DeviceWork,
    ) -> crate::Result<DeviceResult> {
        // a1) client-side forward propagation.
        let mut cf_in = Vec::with_capacity(1 + work.client_params.len());
        cf_in.push(work.x.clone());
        cf_in.extend(work.client_params.iter().cloned());
        let mut cf_out = engine.execute_blocking(&work.artifacts.client_fwd, cf_in)?;
        let activations = cf_out.remove(0);

        // a2) activations + labels to the edge server (message passing is
        // simulated by the latency model; data moves via this call).
        // a3) server-side FP + BP.
        let mut ss_in = Vec::with_capacity(3 + work.server_params.len());
        ss_in.push(activations);
        ss_in.push(work.onehot.clone());
        ss_in.push(work.weights.clone());
        ss_in.extend(work.server_params.iter().cloned());
        let mut ss_out = engine.execute_blocking(&work.artifacts.server_step, ss_in)?;
        let loss = ss_out.remove(0).data[0] as f64;
        let correct = ss_out.remove(0).data[0] as f64;
        let grad_a = ss_out.remove(0);
        let server_grads: Vec<Tensor> = ss_out.into_iter().map(host_to_tensor).collect();

        // a4) activations' gradients back to the device.
        // a5) client-side backward pass (recompute-based VJP).
        let mut cb_in = Vec::with_capacity(2 + work.client_params.len());
        cb_in.push(work.x);
        cb_in.push(grad_a);
        cb_in.extend(work.client_params);
        let cb_out = engine.execute_blocking(&work.artifacts.client_bwd, cb_in)?;
        let mut grads: Vec<Tensor> = cb_out.into_iter().map(host_to_tensor).collect();
        grads.extend(server_grads);

        Ok(DeviceResult { idx: work.idx, grads, loss, correct, true_batch: work.true_batch })
    }

    fn apply_results(&mut self, results: Vec<DeviceResult>) -> RoundOutcome {
        let n = results.len().max(1);
        let lr = self.cfg.train.lr;
        let mut loss_sum = 0.0;
        let mut correct_sum = 0.0;
        let mut batch_sum = 0u32;

        let mut per_device_grads: Vec<Vec<Tensor>> = Vec::with_capacity(n);
        let mut batches: Vec<u32> = Vec::with_capacity(n);
        let mut sorted = results;
        sorted.sort_by_key(|r| r.idx);

        for r in sorted {
            loss_sum += r.loss;
            correct_sum += r.correct;
            batch_sum += r.true_batch;
            let nt = self.params[r.idx].tensors.len();
            debug_assert_eq!(r.grads.len(), nt);
            self.params[r.idx].sgd_update_range(0..nt, &r.grads, lr);
            batches.push(r.true_batch);
            per_device_grads.push(r.grads);
        }
        // Feed the Assumption-2 constants estimator (approach of [24]).
        self.estimator.observe_round(&per_device_grads, &batches);

        RoundOutcome {
            mean_loss: loss_sum / n as f64,
            train_acc: correct_sum / batch_sum.max(1) as f64,
        }
    }

    /// Sequential round: steps a1–a5 for every device, then SGD updates.
    pub(crate) fn run_round(&mut self) -> crate::Result<RoundOutcome> {
        let n = self.n_devices();
        let mut results = Vec::with_capacity(n);
        for i in 0..n {
            let work = self.prepare_device(i)?;
            results.push(Self::exec_device_blocking(&self.engine, work)?);
        }
        Ok(self.apply_results(results))
    }

    /// Actor round: one OS thread per device, true message-passing
    /// concurrency (the CPU engine serializes compute, so numerics match
    /// the sequential mode exactly — verified by integration tests).
    pub(crate) fn run_round_concurrent(&mut self) -> crate::Result<RoundOutcome> {
        let n = self.n_devices();
        let mut works = Vec::with_capacity(n);
        for i in 0..n {
            works.push(self.prepare_device(i)?);
        }
        let engine = self.engine.clone();
        let results: Vec<crate::Result<DeviceResult>> = std::thread::scope(|scope| {
            let handles: Vec<_> = works
                .into_iter()
                .map(|work| {
                    let engine = engine.clone();
                    scope.spawn(move || Self::exec_device_blocking(&engine, work))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .map_err(|_| anyhow::anyhow!("device thread panicked"))?
                })
                .collect()
        });
        let results = results.into_iter().collect::<crate::Result<Vec<_>>>()?;
        Ok(self.apply_results(results))
    }
}
