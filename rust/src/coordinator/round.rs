//! One split-training round (Algorithm 1, steps a1–a5) over the PJRT
//! runtime, in sequential and concurrent-actor forms.
//!
//! Data-movement contract: a round *moves* activations and gradients, not
//! weights. Parameters are copied out of [`Trainer::params`] exactly once
//! (into shared `Arc` tensors) and everything downstream — the device
//! threads, the engine channel, the cf/cb double use — clones handles, not
//! data. The engine's buffer cache then packs each versioned tensor into a
//! PJRT literal at most once per lane per version (DESIGN.md §8).
//!
//! Shard/root split (DESIGN.md §15): devices execute under their cell's
//! [`super::shard::CellPlan`] — per-cell work queues over cell-affine
//! lane slices — while the root coordinator streams results through a
//! [`super::shard::RoundCollector`], applying each device's SGD update
//! the moment it completes (order-irrelevant: updates are per-device
//! disjoint) instead of buffering every gradient until round end. A
//! failed round can therefore leave some devices already stepped; the
//! round errors out and the session is not continuable past it, exactly
//! as before — only the parameters left behind differ, never a completed
//! round's numerics.
//!
//! Fault tolerance (DESIGN.md §13): with [`crate::fault`] armed, each
//! device's step runs under `catch_unwind` with a per-round deadline and
//! bounded retry-with-backoff; a device that exhausts its attempts is
//! *abandoned* — excluded from this round's participant set so Eqn-39
//! partial aggregation prices the round over the survivors — never
//! failing the round. With faults off (`Config::faults == None`) the
//! paths below are byte-identical to the historical behaviour: a single
//! attempt per device, and any error fails the round.

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::shard::{lock, RoundCollector, ESTIMATOR_SAMPLE_CAP};
use super::Trainer;
use crate::aggregation::merge_cell_aggregates;
use crate::fault::{AttemptFault, RoundPlan};
use crate::model::Tensor;
use crate::runtime::{
    host_to_tensor, tensor_to_shared, BufKey, EngineHandle, ExecInput, HostTensor, StepArtifacts,
};

/// Aggregate result of one round.
#[derive(Debug, Clone)]
pub struct RoundOutcome {
    /// Mean training loss across participating devices (`NaN` on an empty
    /// round — no device completed any work).
    pub mean_loss: f64,
    /// Weighted training accuracy across participating devices (`NaN` on
    /// an empty round).
    pub train_acc: f64,
    /// Devices that completed the round. `0` marks an explicitly empty
    /// round: no samples were processed and no parameters moved.
    pub participants: usize,
}

impl RoundOutcome {
    /// The explicit empty-round marker (heavy churn can drop every
    /// participant): NaN stats instead of a fake `0.0` loss that would
    /// pollute CSV histories and convergence detection.
    pub fn empty() -> RoundOutcome {
        RoundOutcome { mean_loss: f64::NAN, train_acc: f64::NAN, participants: 0 }
    }

    /// True when no device completed the round.
    pub fn is_empty(&self) -> bool {
        self.participants == 0
    }
}

/// Everything one device needs for its round, detached from the trainer so
/// async tasks can own it. Parameter inputs are `Arc`-backed handles.
pub(super) struct DeviceWork {
    idx: usize,
    #[allow(dead_code)] // kept for tracing/debug parity with the paper notation
    cut: usize,
    /// Engine-pool lane this device's executes are routed to.
    lane: usize,
    artifacts: Arc<StepArtifacts>,
    x: ExecInput,
    onehot: ExecInput,
    weights: ExecInput,
    client_params: Vec<ExecInput>,
    server_params: Vec<ExecInput>,
    true_batch: u32,
}

/// Result of one device's round: full-model gradient + stats.
pub(super) struct DeviceResult {
    pub idx: usize,
    pub grads: Vec<Tensor>,
    pub loss: f64,
    pub correct: f64,
    pub true_batch: u32,
}

/// Outcome of one device's round under fault tolerance.
pub(super) enum DeviceRound {
    Done(DeviceResult),
    /// Every attempt failed: the device sits this round out. The round
    /// carries on without it (Eqn-39 partial aggregation).
    Abandoned { idx: usize },
}

/// Run one device's step under the fault layer: consult the pre-drawn
/// per-attempt plan, catch injected and genuine panics, honour the device
/// deadline, and back off (exponentially, capped at 1 s) between attempts.
///
/// The plan guarantees the final attempt of a non-`kill` device draws
/// clean (see `FaultInjector::round_plan`), so randomly injected faults
/// exercise this machinery without ever abandoning a healthy device —
/// only `kill` membership, genuine engine errors, and real deadline
/// overruns reach [`DeviceRound::Abandoned`].
pub(super) fn run_device_with_faults(
    engine: &EngineHandle,
    work: &DeviceWork,
    plan: &[AttemptFault],
    deadline_ms: u64,
    backoff_ms: u64,
) -> DeviceRound {
    let deadline = (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms));
    for (attempt, fault) in plan.iter().enumerate() {
        if attempt > 0 && backoff_ms > 0 {
            let wait = backoff_ms.saturating_mul(1u64 << (attempt - 1).min(10)).min(1000);
            std::thread::sleep(Duration::from_millis(wait));
        }
        // AssertUnwindSafe: on an unwind we retry from the same immutable
        // `work` (failed attempts mutate no trainer state) or abandon the
        // device entirely — no broken invariant can be observed.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || -> crate::Result<DeviceResult> {
                match fault {
                    AttemptFault::Error => anyhow::bail!(
                        "injected step error (device {}, attempt {attempt})",
                        work.idx
                    ),
                    AttemptFault::Panic => panic!(
                        "injected step panic (device {}, attempt {attempt})",
                        work.idx
                    ),
                    AttemptFault::Delay(ms) => {
                        if deadline_ms > 0 && *ms > deadline_ms {
                            // The injected stall provably overruns the
                            // deadline: fail the attempt by arithmetic,
                            // without actually sleeping it out — keeps
                            // chaos runs fast *and* deterministic.
                            anyhow::bail!(
                                "injected {ms}ms stall exceeds the {deadline_ms}ms device \
                                 deadline (device {})",
                                work.idx
                            );
                        }
                        // In-budget stall: sleep a bounded slice so the
                        // delay path really runs, then execute normally.
                        std::thread::sleep(Duration::from_millis((*ms).min(100)));
                        Trainer::exec_device_blocking(engine, work, deadline)
                    }
                    AttemptFault::None => Trainer::exec_device_blocking(engine, work, deadline),
                }
            },
        ));
        match outcome {
            Ok(Ok(res)) => return DeviceRound::Done(res),
            // Failed attempt (error or panic): fall through to the next
            // one. The specific cause is deliberately not propagated —
            // abandonment is the only caller-visible signal.
            Ok(Err(_)) | Err(_) => {}
        }
    }
    DeviceRound::Abandoned { idx: work.idx }
}

impl Trainer {
    /// One shared `Arc` per fleet-identical tensor slot for this round
    /// (`None` where the slot is device-specific), built from device 0 so
    /// the identical bytes are host-copied once per round, not per device.
    pub(super) fn shared_param_arcs(&self) -> Vec<Option<Arc<HostTensor>>> {
        let p0 = &self.params[0];
        let common_lo = 2 * self.dec.l_c().min(p0.n_blocks);
        let mut shared = Vec::with_capacity(p0.tensors.len());
        for (slot, t) in p0.tensors.iter().enumerate() {
            if slot >= common_lo || self.fleet_synced {
                shared.push(Some(tensor_to_shared(t)));
            } else {
                shared.push(None);
            }
        }
        shared
    }

    pub(super) fn prepare_device(
        &mut self,
        i: usize,
        lane: usize,
        shared: &[Option<Arc<HostTensor>>],
    ) -> crate::Result<DeviceWork> {
        let cut = self.dec.cut[i];
        let b = self.dec.batch[i];
        let artifacts = Arc::clone(&self.step_artifacts[i]);
        let bucket = artifacts.bucket;
        let classes = self.cfg.train.classes;

        // Step a1 precondition: sample the mini-batch B_i^t ⊆ D_i.
        // (disjoint field borrows: samplers mutably, train_set immutably)
        let batch = self.samplers[i].sample(&self.train_set, b, bucket);

        // Buffer-cache keying: the slot is the global tensor index; the set
        // is the device, except for regions that are provably identical
        // across the fleet this round — the common server sub-model (Eqn 4
        // averages it every round) and, right after a forged sync, the
        // whole model. Shared sets let devices on the same engine lane
        // reuse one packed literal (invalidation rules: DESIGN.md §8).
        let params = &self.params[i];
        let common_lo = 2 * self.dec.l_c().min(params.n_blocks);
        let pv = params.version;
        let (common_version, sync_version) = (self.common_version, self.sync_version);
        // The shared arcs snapshot the *round-start* fleet-identical
        // values; the sequential path may have already streamed earlier
        // devices' SGD updates into `self.params`, so the invariant is
        // checked against the snapshot, not against device 0's live state.
        #[cfg(debug_assertions)]
        for (slot, t) in params.tensors.iter().enumerate() {
            if let Some(arc) = &shared[slot] {
                debug_assert_eq!(
                    t.data, arc.data,
                    "shared-set keying requires fleet-identical tensors (slot {slot})"
                );
            }
        }
        let keyed = |slot: usize, t: &Tensor| -> ExecInput {
            match &shared[slot] {
                Some(arc) if slot >= common_lo => ExecInput::cached(
                    BufKey { set: BufKey::COMMON_SET, slot: slot as u32 },
                    common_version,
                    Arc::clone(arc),
                ),
                Some(arc) => ExecInput::cached(
                    BufKey { set: BufKey::SYNC_SET, slot: slot as u32 },
                    sync_version,
                    Arc::clone(arc),
                ),
                None => ExecInput::cached(
                    BufKey { set: BufKey::device_set(i), slot: slot as u32 },
                    pv,
                    tensor_to_shared(t),
                ),
            }
        };
        let mut client_params = Vec::with_capacity(2 * cut);
        let mut server_params = Vec::with_capacity(params.tensors.len() - 2 * cut);
        for (slot, t) in params.tensors.iter().enumerate() {
            if slot < 2 * cut {
                client_params.push(keyed(slot, t));
            } else {
                server_params.push(keyed(slot, t));
            }
        }

        Ok(DeviceWork {
            idx: i,
            cut,
            lane,
            artifacts,
            x: ExecInput::cached(
                BufKey { set: BufKey::device_set(i), slot: BufKey::SLOT_X },
                self.rounds_run,
                Arc::new(HostTensor { shape: vec![bucket as usize, 32, 32, 3], data: batch.x }),
            ),
            onehot: ExecInput::Fresh(HostTensor {
                shape: vec![bucket as usize, classes],
                data: batch.onehot,
            }),
            weights: ExecInput::Fresh(HostTensor {
                shape: vec![bucket as usize],
                data: batch.weights,
            }),
            client_params,
            server_params,
            true_batch: batch.true_batch,
        })
    }

    /// Execute steps a1–a5 for one device through the engine (blocking).
    ///
    /// Borrows the work so a fault-layer retry replays the *same*
    /// mini-batch — the device's sampler stream is never re-advanced by a
    /// failed attempt. Input clones are handle clones (Arc bumps) except
    /// the small fresh label/weight tensors. `deadline`, when set, is the
    /// budget for the whole three-call step; each engine call gets what
    /// remains of it.
    pub(super) fn exec_device_blocking(
        engine: &EngineHandle,
        work: &DeviceWork,
        deadline: Option<Duration>,
    ) -> crate::Result<DeviceResult> {
        let started = Instant::now();
        let remaining = |started: Instant| -> crate::Result<Option<Duration>> {
            match deadline {
                None => Ok(None),
                Some(d) => match d.checked_sub(started.elapsed()) {
                    Some(left) => Ok(Some(left)),
                    None => anyhow::bail!(
                        "device {} exceeded its {}ms round deadline",
                        work.idx,
                        d.as_millis()
                    ),
                },
            }
        };

        // a1) client-side forward propagation. `x` and the client params
        // are needed again in a5 (and on retries), so clone the handles.
        let mut cf_in = Vec::with_capacity(1 + work.client_params.len());
        cf_in.push(work.x.clone());
        cf_in.extend(work.client_params.iter().cloned());
        let mut cf_out = engine.execute_inputs_deadline(
            work.lane,
            &work.artifacts.client_fwd,
            cf_in,
            remaining(started)?,
        )?;
        let activations = cf_out.remove(0);

        // a2) activations + labels to the edge server (message passing is
        // simulated by the latency model; data moves via this call).
        // a3) server-side FP + BP.
        let mut ss_in = Vec::with_capacity(3 + work.server_params.len());
        ss_in.push(ExecInput::Fresh(activations));
        ss_in.push(work.onehot.clone());
        ss_in.push(work.weights.clone());
        ss_in.extend(work.server_params.iter().cloned());
        let mut ss_out = engine.execute_inputs_deadline(
            work.lane,
            &work.artifacts.server_step,
            ss_in,
            remaining(started)?,
        )?;
        let loss = ss_out.remove(0).data[0] as f64;
        let correct = ss_out.remove(0).data[0] as f64;
        let grad_a = ss_out.remove(0);
        let server_grads: Vec<Tensor> = ss_out.into_iter().map(host_to_tensor).collect();

        // a4) activations' gradients back to the device.
        // a5) client-side backward pass (recompute-based VJP).
        let mut cb_in = Vec::with_capacity(2 + work.client_params.len());
        cb_in.push(work.x.clone());
        cb_in.push(ExecInput::Fresh(grad_a));
        cb_in.extend(work.client_params.iter().cloned());
        let cb_out = engine.execute_inputs_deadline(
            work.lane,
            &work.artifacts.client_bwd,
            cb_in,
            remaining(started)?,
        )?;
        let mut grads: Vec<Tensor> = cb_out.into_iter().map(host_to_tensor).collect();
        grads.extend(server_grads);

        Ok(DeviceResult {
            idx: work.idx,
            grads,
            loss,
            correct,
            true_batch: work.true_batch,
        })
    }

    /// Root phase of a round: split the collector's results along the
    /// cell plan, merge the cell aggregates in fixed cell order
    /// (bit-identical to the flat path by the merge-order contract,
    /// DESIGN.md §15), install the round's participant set + Eqn-39
    /// weights, and feed the estimator its bounded gradient sample.
    pub(super) fn finalize_round(&mut self, collector: RoundCollector) -> RoundOutcome {
        let (cell_aggs, sample_grads, sample_batches) = collector.finish(&self.cells);
        let merged = merge_cell_aggregates(&cell_aggs);
        self.round_participants = merged.participants;
        self.round_weights = merged.weights;
        let n = self.round_participants.len();
        if n == 0 {
            // Every participant dropped (churn-heavy rounds): nothing to
            // update, nothing to estimate — report the round explicitly
            // empty instead of a fake 0.0 loss. `fleet_synced` is left
            // untouched: no parameters moved, so nothing diverged.
            return RoundOutcome::empty();
        }
        // Devices just diverged: per-device buffer keys from here on.
        self.fleet_synced = false;
        // Feed the Assumption-2 constants estimator (approach of [24]).
        self.estimator.observe_round(&sample_grads, &sample_batches);

        RoundOutcome {
            mean_loss: merged.loss_sum / n as f64,
            train_acc: merged.correct_sum / merged.batch_sum.max(1) as f64,
            participants: n,
        }
    }

    /// Fault hook at the top of a round: deliver the round's lane crash
    /// (if any) and pre-draw the whole roster's device fault plan. `None`
    /// when faults are off.
    pub(super) fn inject_round_faults(&self, round: u64) -> Option<RoundPlan> {
        let inj = self.faults.as_ref()?;
        if let Some(lane) = inj.lane_crash(round, self.engine.width()) {
            self.engine.inject_lane_crash(lane);
        }
        Some(inj.round_plan(round, self.n_devices()))
    }

    /// The retry knobs from the armed fault spec: (deadline_ms, backoff_ms).
    pub(super) fn fault_knobs(&self) -> (u64, u64) {
        match &self.faults {
            Some(inj) => (inj.spec().deadline_ms, inj.spec().backoff_ms),
            None => (0, 0),
        }
    }

    /// Post-execution bookkeeping for abandoned devices: drop them from
    /// the round's participation mask (so latency pricing matches a run
    /// where they never took part), count strikes, and quarantine repeat
    /// offenders.
    pub(super) fn finish_abandoned(&mut self, mut abandoned: Vec<usize>) {
        abandoned.sort_unstable();
        let quarantine_after = self.faults.as_ref().map_or(0, |i| i.spec().quarantine_after);
        for &idx in &abandoned {
            self.participation[idx] = false;
            self.fault_state.note_abandoned(idx, quarantine_after);
        }
        self.round_abandoned = abandoned;
    }

    /// Sequential round: steps a1–a5 for every participating device in
    /// ascending id order, each result streamed into the collector as it
    /// lands. All traffic routes to engine lane 0 — extra pool lanes
    /// stay cold (no compiles, no buffer copies) for sequential sessions.
    /// With a scenario attached, offline members and mid-round dropouts
    /// are skipped; partial aggregation handles them in `post_round`.
    pub(crate) fn run_round(&mut self) -> crate::Result<RoundOutcome> {
        self.begin_round();
        self.rounds_run += 1;
        let plan = self.inject_round_faults(self.rounds_run);
        let (deadline_ms, backoff_ms) = self.fault_knobs();
        let n = self.n_devices();
        let shared = self.shared_param_arcs();
        let mut collector = RoundCollector::new(self.cfg.train.lr, ESTIMATOR_SAMPLE_CAP);
        let mut abandoned = Vec::new();
        for i in 0..n {
            if !self.participation()[i] {
                continue;
            }
            let work = self.prepare_device(i, 0, &shared)?;
            match &plan {
                None => {
                    let r = Self::exec_device_blocking(&self.engine, &work, None)?;
                    collector.absorb(&mut self.params, r);
                }
                Some(p) => match run_device_with_faults(
                    &self.engine,
                    &work,
                    &p.attempts[i],
                    deadline_ms,
                    backoff_ms,
                ) {
                    DeviceRound::Done(r) => collector.absorb(&mut self.params, r),
                    DeviceRound::Abandoned { idx } => abandoned.push(idx),
                },
            }
        }
        self.finish_abandoned(abandoned);
        Ok(self.finalize_round(collector))
    }

    /// Actor round over the cell plan: each cell's participating devices
    /// queue in ascending order on the cell's own work queue, pulled by
    /// one worker per lane of the cell's lane slice — at most
    /// `engine.width()` OS threads in total at any cell count (excess
    /// cells share lanes round-robin through one combined queue per
    /// lane). The calling thread is the root coordinator: it streams
    /// completed results off an mpsc channel into the round collector,
    /// applying SGD in completion order (bitwise order-irrelevant — the
    /// updates are per-device disjoint) so a 10k-device round never
    /// buffers the fleet's gradients. Numerics match the sequential mode
    /// exactly (`rust/tests/parity_modes.rs`,
    /// `rust/tests/cells_parity.rs`).
    pub(crate) fn run_round_concurrent(&mut self) -> crate::Result<RoundOutcome> {
        self.begin_round();
        self.rounds_run += 1;
        let plan = self.inject_round_faults(self.rounds_run);
        let (deadline_ms, backoff_ms) = self.fault_knobs();
        let shared = self.shared_param_arcs();
        let lr = self.cfg.train.lr;

        // Per-cell work queues in fixed cell order. Cells sharing a lane
        // (more cells than lanes) share one queue, their devices enqueued
        // in cell order; `workers[q]` is the lane count of the queue's
        // slice, so total worker threads never exceed the pool width.
        let plans = self.cells.clone();
        let mut queues: Vec<std::collections::VecDeque<DeviceWork>> = Vec::new();
        let mut workers: Vec<usize> = Vec::new();
        let mut queue_of_lane: std::collections::HashMap<usize, usize> = Default::default();
        for p in &plans {
            let qi = match queue_of_lane.get(&p.lanes.start) {
                Some(&qi) => qi,
                None => {
                    queues.push(Default::default());
                    workers.push(p.lanes.len());
                    queue_of_lane.insert(p.lanes.start, queues.len() - 1);
                    queues.len() - 1
                }
            };
            for i in p.devices.clone() {
                if !self.participation[i] {
                    continue;
                }
                let lane = p.lane_of(i);
                let work = self.prepare_device(i, lane, &shared)?;
                queues[qi].push_back(work);
            }
        }
        for (qi, q) in queues.iter().enumerate() {
            workers[qi] = workers[qi].min(q.len());
        }

        let engine = self.engine.clone();
        let plan_ref = &plan;
        let queue_mutexes: Vec<std::sync::Mutex<std::collections::VecDeque<DeviceWork>>> =
            queues.into_iter().map(std::sync::Mutex::new).collect();
        let (tx, rx) = std::sync::mpsc::channel::<crate::Result<DeviceRound>>();
        let mut collector = RoundCollector::new(lr, ESTIMATOR_SAMPLE_CAP);
        let mut abandoned: Vec<usize> = Vec::new();
        let mut round_err: Option<anyhow::Error> = None;
        let params = &mut self.params;
        std::thread::scope(|scope| {
            for (qi, q) in queue_mutexes.iter().enumerate() {
                for _ in 0..workers[qi] {
                    let tx = tx.clone();
                    let engine = engine.clone();
                    scope.spawn(move || loop {
                        let work = lock(q).pop_front();
                        let Some(work) = work else { break };
                        // A genuine engine-path panic must not take the
                        // whole process down mid-scope: surface it as the
                        // round's error through the result channel (the
                        // historical behaviour, minus the thread count).
                        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || match plan_ref {
                                None => Trainer::exec_device_blocking(&engine, &work, None)
                                    .map(DeviceRound::Done),
                                Some(p) => Ok(run_device_with_faults(
                                    &engine,
                                    &work,
                                    &p.attempts[work.idx],
                                    deadline_ms,
                                    backoff_ms,
                                )),
                            },
                        ))
                        .unwrap_or_else(|_| {
                            Err(anyhow::anyhow!("device worker panicked (device {})", work.idx))
                        });
                        if tx.send(res).is_err() {
                            break;
                        }
                    });
                }
            }
            drop(tx);
            // Root phase: stream results in completion order. On a device
            // error, keep draining so the workers run to completion, then
            // fail the round with the first error.
            for res in rx {
                match res {
                    Ok(DeviceRound::Done(r)) => collector.absorb(params, r),
                    Ok(DeviceRound::Abandoned { idx }) => abandoned.push(idx),
                    Err(e) => {
                        round_err.get_or_insert(e);
                    }
                }
            }
        });
        if let Some(e) = round_err {
            return Err(e);
        }
        self.finish_abandoned(abandoned);
        Ok(self.finalize_round(collector))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests may unwrap; the deny covers the round path
mod tests {
    use super::RoundOutcome;

    #[test]
    fn empty_round_is_nan_marked_not_zero() {
        // Regression: a round where every participant dropped used to
        // report mean_loss = 0.0 / train_acc = 0.0, polluting histories
        // and convergence detection with fake-perfect losses.
        let empty = RoundOutcome::empty();
        assert!(empty.is_empty());
        assert_eq!(empty.participants, 0);
        assert!(empty.mean_loss.is_nan());
        assert!(empty.train_acc.is_nan());

        let real = RoundOutcome { mean_loss: 1.5, train_acc: 0.5, participants: 3 };
        assert!(!real.is_empty());
    }
}
