//! Buffered-asynchronous rounds (DESIGN.md §16, docs/ASYNC.md): the
//! FedBuff-style flush loop that replaces the synchronous round barrier
//! when `Config.async_spec` is set.
//!
//! One "round" of the async mode is one *buffer flush*: the coordinator
//! pops simulated device completions in `(ready_at, device id)` order
//! until `buffer_k` updates have landed, executes each popped device's
//! split-training step through the engine at that moment (client
//! sub-model = the device's own — possibly stale — parameters; server
//! sub-model = the current common aggregate, exactly the split-learning
//! topology), and folds the buffered updates through the existing Eqn-39
//! weighted partial-aggregation path with each weight multiplied by the
//! polynomial staleness decay `(1 + lag)^(-decay)`.
//!
//! # Determinism contract
//!
//! The completion schedule is simulated, never wall-clock: each dispatch
//! draws its completion interval from the analytic per-device latency
//! legs (Eqns 28/29/32/33, at the scenario's realized rates when one is
//! attached) times a jitter factor seeded by
//! `(config seed, device id, per-device dispatch counter)` under the
//! dedicated `0xA57C0` stream salt. Pops follow the total order
//! `(ready_at, device id)` and execute sequentially on engine lane 0, so
//! async histories are bit-identical across runs, pool widths, and
//! checkpoint resumes (`tests/async_rounds.rs`). No RNG stream used by
//! the synchronous path is ever advanced differently.

use crate::asynch::{staleness_weight, AsyncRoundStats, AsyncState};
use crate::config::Device;
use crate::latency::{
    act_upload_latency, client_bwd_latency, client_fwd_latency, grad_download_latency,
    RoundLatency,
};
use crate::rng::Pcg32;

use super::round::{run_device_with_faults, DeviceRound};
use super::shard::{RoundCollector, ESTIMATOR_SAMPLE_CAP};
use super::{PostRound, RoundOutcome, Trainer};

/// Stream salt for the completion-time jitter RNG (one fresh salt per
/// subsystem: data 0xDA7A0, strategy 0x57A7, faults 0xFA17_*, …).
const ASYNC_SALT: u64 = 0xA57C0;

/// Jitter band: a dispatch's completion interval is the analytic
/// per-device time scaled by a uniform draw in `[LO, LO + SPAN)`.
const JITTER_LO: f64 = 0.75;
const JITTER_SPAN: f64 = 0.5;

impl Trainer {
    /// Analytic completion interval for device `i` under the decisions in
    /// force: the four per-device legs of Eqn 38 (client forward +
    /// activation upload + gradient download + client backward) priced at
    /// `d`'s rates. The server-side sums are shared pipeline cost and are
    /// deliberately excluded — they cancel in the observed/analytic ratio.
    fn analytic_device_seconds(&self, d: &Device, i: usize) -> f64 {
        let b = self.dec.batch[i];
        let c = self.dec.cut[i];
        client_fwd_latency(&self.profile, d, b, c)
            + act_upload_latency(&self.profile, d, b, c)
            + grad_download_latency(&self.profile, d, b, c)
            + client_bwd_latency(&self.profile, d, b, c)
    }

    /// The optimizer's fleet view for async re-solves: every device's
    /// analytic rates scaled down by its clamped observed/analytic EMA
    /// slowdown ratio, so BS/MS decisions track the *observed*
    /// completion-time distribution instead of the synchronous latency
    /// model. `None` when the async mode is off (the synchronous path
    /// must not even clone the roster).
    pub(super) fn observed_devices(&self) -> Option<Vec<Device>> {
        let st = self.async_state.as_ref()?;
        let mut scaled = self.devices.clone();
        let n = scaled.len().min(st.n_devices()).min(self.dec.n());
        for (i, d) in scaled.iter_mut().enumerate().take(n) {
            let analytic = self.analytic_device_seconds(d, i);
            let slow = st.slowdown(i, analytic);
            d.flops /= slow;
            d.up_bps /= slow;
            d.down_bps /= slow;
        }
        Some(scaled)
    }

    /// Seeded completion-interval jitter for the `seq`-th dispatch of
    /// device `i`: a pure function of `(config seed, i, seq)`, so a
    /// resumed run replays the identical schedule.
    fn dispatch_jitter(&self, i: usize, seq: u64) -> f64 {
        let mut rng = Pcg32::new(
            self.cfg.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            ASYNC_SALT ^ seq,
        );
        JITTER_LO + JITTER_SPAN * rng.next_f64()
    }

    /// Dispatch device `i` from the current model at simulated time `at`:
    /// record the dispatch version (for the staleness lag at pop time)
    /// and draw its seeded completion time from the realized rates.
    fn dispatch(&mut self, st: &mut AsyncState, i: usize, at: f64, realized: &Device) {
        let seq = st.dispatch_seq[i];
        let jitter = self.dispatch_jitter(i, seq);
        let dur = self.analytic_device_seconds(realized, i) * jitter;
        st.dispatch_seq[i] = seq + 1;
        st.dispatch_version[i] = st.model_version;
        st.dispatch_at[i] = at;
        st.ready_at[i] = at + dur;
        st.in_flight[i] = true;
    }

    /// Realized per-device rates for completion-time pricing: the
    /// scenario snapshot's devices (transient straggler slowdowns
    /// included) where one is attached, else the persistent roster.
    fn realized_device(&self, i: usize) -> Device {
        if let Some(snap) = &self.last_snapshot {
            for (k, &id) in snap.active.iter().enumerate() {
                if id == i {
                    return snap.devices[k].clone();
                }
            }
        }
        self.devices[i].clone()
    }

    /// One buffered-asynchronous flush (the async mode's "round"):
    /// advance the scenario/fault layers exactly like a synchronous
    /// round, keep every participating device dispatched, pop completions
    /// in `(ready_at, id)` order, drop updates staler than
    /// `max_staleness`, execute and absorb the rest until `buffer_k`
    /// have landed, then fold the staleness-decayed Eqn-39 weights into
    /// the round's partial-aggregation weights.
    pub(crate) fn run_round_async(&mut self) -> crate::Result<(RoundOutcome, AsyncRoundStats)> {
        let spec = match &self.cfg.async_spec {
            Some(s) => s.clone(),
            None => anyhow::bail!("run_round_async without Config.async_spec"),
        };
        self.begin_round();
        self.rounds_run += 1;
        let plan = self.inject_round_faults(self.rounds_run);
        let (deadline_ms, backoff_ms) = self.fault_knobs();
        let n = self.n_devices();

        let mut st = match self.async_state.take() {
            Some(st) => st,
            None => anyhow::bail!("async spec configured but the trainer carries no async state"),
        };
        st.ensure_len(n);
        let flush_start = st.now;

        // Keep the whole participating roster in flight: idle (or newly
        // participating) devices dispatch from the current model at the
        // current simulated time.
        for i in 0..n {
            if self.participation[i] && !st.in_flight[i] {
                let realized = self.realized_device(i);
                self.dispatch(&mut st, i, st.now, &realized);
            }
        }

        let shared = self.shared_param_arcs();
        let mut collector = RoundCollector::new(self.cfg.train.lr, ESTIMATOR_SAMPLE_CAP);
        let mut abandoned: Vec<usize> = Vec::new();
        // Version lag of each flushed update, keyed by device id; folded
        // into the Eqn-39 weights after `finalize_round` canonicalises
        // the participant order.
        let mut lags: Vec<(usize, u64)> = Vec::new();
        let mut dropped_stale = 0usize;
        let mut flushed = 0usize;

        while flushed < spec.buffer_k {
            // Next completion: total order on (ready_at, device id).
            let mut next: Option<usize> = None;
            for i in 0..n {
                if !st.in_flight[i] {
                    continue;
                }
                next = match next {
                    None => Some(i),
                    Some(j) if st.ready_at[i] < st.ready_at[j] => Some(i),
                    keep => keep,
                };
            }
            let Some(i) = next else {
                break; // nothing in flight (heavy churn / blackout)
            };

            st.in_flight[i] = false;
            st.now = st.now.max(st.ready_at[i]);
            st.observe_latency(i, st.ready_at[i] - st.dispatch_at[i]);

            if !self.participation[i] {
                // Left / dropped / quarantined since dispatch: its update
                // evaporates with it; the device re-enters the schedule
                // when a later round's participation mask readmits it.
                continue;
            }

            let lag = st.model_version - st.dispatch_version[i];
            if lag > spec.max_staleness as u64 {
                // Too stale to fold in: discard and re-dispatch from the
                // current model (lag resets to 0 for the next pop).
                dropped_stale += 1;
                let realized = self.realized_device(i);
                self.dispatch(&mut st, i, st.now, &realized);
                continue;
            }

            // Execute the popped device's split-training step now: its
            // client sub-model is its own (stale) parameter copy, the
            // server sub-model is the current common aggregate. Lane 0,
            // sequential — pool width cannot move a bit.
            let work = self.prepare_device(i, 0, &shared)?;
            match &plan {
                None => {
                    let r = Self::exec_device_blocking(&self.engine, &work, None)?;
                    collector.absorb(&mut self.params, r);
                }
                Some(p) => match run_device_with_faults(
                    &self.engine,
                    &work,
                    &p.attempts[i],
                    deadline_ms,
                    backoff_ms,
                ) {
                    DeviceRound::Done(r) => collector.absorb(&mut self.params, r),
                    DeviceRound::Abandoned { idx } => {
                        abandoned.push(idx);
                        continue; // participation cleared in finish_abandoned
                    }
                },
            }
            lags.push((i, lag));
            flushed += 1;
            // The device is NOT re-dispatched yet: FedBuff devices wait
            // for the flush that incorporates their update before pulling
            // the new model — re-dispatch happens after the version bump
            // below (and guarantees each device contributes at most once
            // per flush, so the collector never sees a duplicate id).
        }

        self.finish_abandoned(abandoned);
        let outcome = self.finalize_round(collector);

        // Fold the polynomial staleness decay into the Eqn-39 weights the
        // partial aggregations will use (`post_round` runs next).
        for (k, &p) in self.round_participants.iter().enumerate() {
            if let Some(&(_, lag)) = lags.iter().find(|&&(id, _)| id == p) {
                self.round_weights[k] *= staleness_weight(lag, spec.decay);
            }
        }

        let stats = self.async_stats(&mut st, flushed, dropped_stale, &lags, flush_start);
        // The flushed devices re-enter the schedule from the freshly
        // flushed model (dispatch_version = the bumped model_version).
        for &(i, _) in &lags {
            if self.participation[i] && !st.in_flight[i] {
                let realized = self.realized_device(i);
                self.dispatch(&mut st, i, st.now, &realized);
            }
        }
        self.async_state = Some(st);
        Ok((outcome, stats))
    }

    /// Per-flush bookkeeping: bump the global model version (a flush that
    /// aggregated nothing leaves it — and the clock — untouched, exactly
    /// like an empty synchronous round) and assemble the report stats.
    fn async_stats(
        &self,
        st: &mut AsyncState,
        flushed: usize,
        dropped_stale: usize,
        lags: &[(usize, u64)],
        flush_start: f64,
    ) -> AsyncRoundStats {
        if flushed > 0 {
            st.model_version += 1;
        } else {
            st.now = flush_start;
        }
        let lag_sum: u64 = lags.iter().map(|&(_, l)| l).sum();
        AsyncRoundStats {
            flushed,
            dropped_stale,
            staleness_mean: if lags.is_empty() {
                0.0
            } else {
                lag_sum as f64 / lags.len() as f64
            },
            staleness_max: lags.iter().map(|&(_, l)| l).max().unwrap_or(0),
            model_version: st.model_version,
            flush_span_s: st.now - flush_start,
        }
    }

    /// Post-round bookkeeping for a flush: the synchronous
    /// [`Trainer::post_round`] pipeline (Eqn-4 / Eqn-7 aggregation,
    /// drift-triggered re-solve, cell stats) priced at the flush's
    /// simulated span instead of the barrier latency. `t_agg` keeps the
    /// analytic Eqn-39 exchange cost — the aggregation traffic is the
    /// same either way.
    pub(crate) fn post_round_async(
        &mut self,
        t: usize,
        stats: &AsyncRoundStats,
    ) -> crate::Result<PostRound> {
        let t_agg = self.current_round_latency().t_agg;
        let latency = RoundLatency {
            per_device: Vec::new(),
            server_fwd: 0.0,
            server_bwd: 0.0,
            t_split: stats.flush_span_s,
            t_agg,
        };
        self.post_round_with(t, latency)
    }
}
