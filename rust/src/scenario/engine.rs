//! The deterministic scenario engine: evolves a roster of devices round by
//! round under a [`Scenario`](super::Scenario) spec and emits per-round
//! [`FleetSnapshot`]s.
//!
//! Determinism contract: the engine owns a single PCG stream seeded from
//! the experiment seed; every draw is a pure function of (seed, spec,
//! round), so two engines built from the same inputs produce bit-identical
//! snapshot sequences regardless of who consumes them (asserted by
//! `rust/tests/scenario_determinism.rs`).

use crate::config::Device;
use crate::rng::Pcg32;

use super::{ChurnModel, Drift, Scenario};

/// Per-roster-member evolution state.
#[derive(Debug, Clone)]
struct DeviceState {
    base: Device,
    channel_mult: f64,
    compute_mult: f64,
    active: bool,
    /// Phase offset (fraction of a period) for `Drift::Periodic`.
    phase: f64,
}

/// Serializable snapshot of one roster member's evolution state (the
/// checkpoint subsystem persists the full roster so a resumed run replays
/// the exact fleet trajectory of the uninterrupted one).
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceEvoState {
    /// The device as originally sampled (multipliers apply on top).
    pub base: Device,
    /// Current multiplier on all four link rates.
    pub channel_mult: f64,
    /// Current multiplier on device compute (`f_i`).
    pub compute_mult: f64,
    /// Whether the device is currently in the fleet.
    pub active: bool,
    /// Per-device phase offset for cyclic (diurnal) drift.
    pub phase: f64,
}

/// Complete serializable state of a [`ScenarioEngine`]: RNG cursor, round
/// counter, roster evolution, and the drift reference. The spec itself is
/// not included — it travels with the embedded `Config`.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioEngineState {
    /// Raw PCG state `(state, inc)`.
    pub rng: (u64, u64),
    /// Rounds evolved so far.
    pub round: usize,
    /// Evolution state of every device ever rostered.
    pub roster: Vec<DeviceEvoState>,
    /// Devices with multipliers applied, as of the last evolve.
    pub effective: Vec<Device>,
    /// Effective fleet at the last BS/MS re-solve (drift baseline).
    pub reference: Vec<Device>,
    /// Activity flags captured alongside `reference`.
    pub reference_active: Vec<bool>,
}

/// One round's fleet state, as consumed by the latency model, the
/// coordinator, and the round report.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSnapshot {
    /// 1-based round index this snapshot describes.
    pub round: usize,
    /// Stable roster ids of the devices active this round (ascending).
    pub active: Vec<usize>,
    /// *Realized* resources of each active device this round (same order
    /// as `active`): base rates x channel multiplier, base FLOPS x compute
    /// multiplier, with any transient straggler slowdown applied. Feed
    /// these to the latency model; feed the *persistent* rates
    /// ([`ScenarioEngine::effective_roster`], straggler-free) to the
    /// optimizer, so a one-round slowdown is never baked into a whole
    /// decision window.
    pub devices: Vec<Device>,
    /// Roster ids (subset of `active`) that fail mid-round: they complete
    /// no work this round but remain fleet members.
    pub dropped: Vec<usize>,
    /// Roster ids that came online this round.
    pub joined: Vec<usize>,
    /// Roster ids that went offline this round.
    pub left: Vec<usize>,
    /// Mean relative deviation of the fleet from its state at the last
    /// re-solve (membership changes count 1.0 each); drives the
    /// `resolve_drift` trigger.
    pub drift: f64,
}

impl FleetSnapshot {
    /// Roster-sized participation mask: active and not dropped mid-round.
    pub fn participation(&self, roster: usize) -> Vec<bool> {
        let mut mask = vec![false; roster];
        for &i in &self.active {
            mask[i] = true;
        }
        for &i in &self.dropped {
            mask[i] = false;
        }
        mask
    }

    /// Ids of devices that complete the round (active minus dropped).
    pub fn survivors(&self) -> Vec<usize> {
        self.active.iter().copied().filter(|i| !self.dropped.contains(i)).collect()
    }
}

/// Evolve one multiplier one round forward.
fn evolve(drift: &Drift, mult: f64, round: usize, phase: f64, rng: &mut Pcg32) -> f64 {
    match *drift {
        Drift::Static => mult,
        Drift::GaussMarkov { rho, sigma, floor, ceil } => {
            let next = 1.0 + rho * (mult - 1.0) + sigma * rng.normal();
            next.clamp(floor, ceil)
        }
        Drift::Periodic { period, amplitude } => {
            let x = 2.0 * std::f64::consts::PI * (round as f64 / period + phase);
            1.0 + amplitude * x.sin()
        }
    }
}

/// Effective device under the current multipliers and slowdown factor.
fn effective(base: &Device, channel: f64, compute: f64, slow: f64) -> Device {
    Device {
        flops: base.flops * compute / slow,
        up_bps: base.up_bps * channel / slow,
        down_bps: base.down_bps * channel / slow,
        fed_up_bps: base.fed_up_bps * channel / slow,
        fed_down_bps: base.fed_down_bps * channel / slow,
        mem_bytes: base.mem_bytes,
    }
}

/// The seeded fleet evolver. See the [module docs](self).
pub struct ScenarioEngine {
    spec: Scenario,
    roster: Vec<DeviceState>,
    rng: Pcg32,
    round: usize,
    /// Effective roster state (all members) as of the current round.
    effective: Vec<Device>,
    /// Effective roster state + membership at the last re-solve: the drift
    /// reference.
    reference: Vec<Device>,
    reference_active: Vec<bool>,
}

impl ScenarioEngine {
    /// Build an engine over a sampled base fleet. The whole roster starts
    /// active with unit multipliers.
    pub fn new(spec: Scenario, base: Vec<Device>, seed: u64) -> crate::Result<ScenarioEngine> {
        spec.validate(base.len())?;
        let n = base.len();
        let roster: Vec<DeviceState> = base
            .into_iter()
            .enumerate()
            .map(|(i, d)| DeviceState {
                base: d,
                channel_mult: 1.0,
                compute_mult: 1.0,
                active: true,
                phase: i as f64 / n as f64,
            })
            .collect();
        let effective: Vec<Device> = roster.iter().map(|s| s.base.clone()).collect();
        let reference = effective.clone();
        Ok(ScenarioEngine {
            spec,
            roster,
            rng: Pcg32::new(seed, 0x5CE7A),
            round: 0,
            effective,
            reference,
            reference_active: vec![true; n],
        })
    }

    /// The scenario this engine is evolving.
    pub fn spec(&self) -> &Scenario {
        &self.spec
    }

    /// Devices ever rostered (active or not).
    pub fn roster_len(&self) -> usize {
        self.roster.len()
    }

    /// Rounds evolved so far.
    pub fn round(&self) -> usize {
        self.round
    }

    /// Persistent effective resources of the whole roster (inactive
    /// members included, transient straggler slowdowns excluded) as of the
    /// last [`ScenarioEngine::advance`] — the optimizer's view of the
    /// fleet. Per-round realized rates live in
    /// [`FleetSnapshot::devices`].
    pub fn effective_roster(&self) -> &[Device] {
        &self.effective
    }

    /// Reset the drift reference to the current fleet state. Called by the
    /// coordinator/sim right after a BS/MS re-solve so `drift` measures
    /// deviation since the decisions in force were computed.
    pub fn mark_resolved(&mut self) {
        self.reference = self.effective.clone();
        self.reference_active = self.roster.iter().map(|s| s.active).collect();
    }

    /// Full engine state for checkpointing.
    pub fn to_state(&self) -> ScenarioEngineState {
        ScenarioEngineState {
            rng: self.rng.state_parts(),
            round: self.round,
            roster: self
                .roster
                .iter()
                .map(|s| DeviceEvoState {
                    base: s.base.clone(),
                    channel_mult: s.channel_mult,
                    compute_mult: s.compute_mult,
                    active: s.active,
                    phase: s.phase,
                })
                .collect(),
            effective: self.effective.clone(),
            reference: self.reference.clone(),
            reference_active: self.reference_active.clone(),
        }
    }

    /// Restore a freshly-built engine (same spec + base fleet) to
    /// checkpointed state, so the next [`ScenarioEngine::advance`] emits
    /// exactly the snapshot the uninterrupted run would have seen.
    pub fn restore_state(&mut self, s: &ScenarioEngineState) -> crate::Result<()> {
        anyhow::ensure!(
            s.roster.len() == self.roster.len()
                && s.effective.len() == self.roster.len()
                && s.reference.len() == self.roster.len()
                && s.reference_active.len() == self.roster.len(),
            "scenario checkpoint covers {} roster members, engine has {}",
            s.roster.len(),
            self.roster.len()
        );
        self.rng = Pcg32::from_state_parts(s.rng.0, s.rng.1);
        self.round = s.round;
        for (st, evo) in self.roster.iter_mut().zip(&s.roster) {
            st.base = evo.base.clone();
            st.channel_mult = evo.channel_mult;
            st.compute_mult = evo.compute_mult;
            st.active = evo.active;
            st.phase = evo.phase;
        }
        self.effective = s.effective.clone();
        self.reference = s.reference.clone();
        self.reference_active = s.reference_active.clone();
        Ok(())
    }

    /// Evolve the fleet one round and return its snapshot.
    pub fn advance(&mut self) -> FleetSnapshot {
        self.round += 1;
        let round = self.round;
        let n = self.roster.len();

        // 1) Membership churn. One uniform draw per roster member per round
        //    keeps the stream layout independent of membership state.
        let mut joined = Vec::new();
        let mut left = Vec::new();
        if let Some(ChurnModel { leave_prob, join_prob, min_active, .. }) = self.spec.churn {
            let min_active = min_active.min(n);
            let mut active_count = self.roster.iter().filter(|s| s.active).count();
            for i in 0..n {
                let u = self.rng.next_f64();
                if self.roster[i].active {
                    if u < leave_prob && active_count > min_active {
                        self.roster[i].active = false;
                        active_count -= 1;
                        left.push(i);
                    }
                } else if u < join_prob {
                    self.roster[i].active = true;
                    active_count += 1;
                    joined.push(i);
                }
            }
        }

        // 2) Channel/compute drift evolves for the whole roster (inactive
        //    members keep drifting, so a rejoining device does not come back
        //    with frozen conditions).
        for st in self.roster.iter_mut() {
            st.channel_mult =
                evolve(&self.spec.channel, st.channel_mult, round, st.phase, &mut self.rng);
            st.compute_mult =
                evolve(&self.spec.compute, st.compute_mult, round, st.phase, &mut self.rng);
        }

        let active: Vec<usize> = (0..n).filter(|&i| self.roster[i].active).collect();

        // 3) Transient straggler: slow one random active device this round.
        let mut straggler: Option<(usize, f64)> = None;
        if let Some(sg) = self.spec.straggler {
            if self.rng.next_f64() < sg.prob && !active.is_empty() {
                let victim = active[self.rng.below(active.len() as u32) as usize];
                let factor = sg.slowdown.sample(&mut self.rng);
                straggler = Some((victim, factor));
            }
        }

        // 4) Mid-round dropout: at least one device always survives.
        let mut dropped = Vec::new();
        if let Some(ChurnModel { dropout_prob, .. }) = self.spec.churn {
            if dropout_prob > 0.0 {
                let mut survivors = active.len();
                for &i in &active {
                    let u = self.rng.next_f64();
                    if u < dropout_prob && survivors > 1 {
                        dropped.push(i);
                        survivors -= 1;
                    }
                }
            }
        }

        // 5) Persistent effective roster resources (straggler-free): the
        //    optimizer's view of the fleet, and the drift baseline. The
        //    transient straggler slowdown is applied only to the snapshot's
        //    realized per-round rates below.
        for (i, st) in self.roster.iter().enumerate() {
            self.effective[i] = effective(&st.base, st.channel_mult, st.compute_mult, 1.0);
        }

        // 6) Drift vs the last-re-solve reference: mean relative deviation
        //    of compute + links over still-active devices, plus 1.0 per
        //    membership flip. Straggler-free on both sides, so a one-round
        //    spike cannot attract a re-solve by itself.
        let rel = |now: f64, was: f64| ((now - was) / was).abs();
        let mut acc = 0.0;
        for i in 0..n {
            let is_active = self.roster[i].active;
            if is_active != self.reference_active[i] {
                acc += 1.0;
                continue;
            }
            if !is_active {
                continue;
            }
            let (e, r) = (&self.effective[i], &self.reference[i]);
            acc += (rel(e.flops, r.flops) + rel(e.up_bps, r.up_bps) + rel(e.down_bps, r.down_bps))
                / 3.0;
        }
        let drift = acc / active.len().max(1) as f64;

        let devices: Vec<Device> = active
            .iter()
            .map(|&i| {
                let slow = match straggler {
                    Some((v, f)) if v == i => f,
                    _ => 1.0,
                };
                let st = &self.roster[i];
                effective(&st.base, st.channel_mult, st.compute_mult, slow)
            })
            .collect();
        FleetSnapshot { round, active, devices, dropped, joined, left, drift }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::scenario::ScenarioPreset;

    fn engine(preset: ScenarioPreset, n: usize, seed: u64) -> ScenarioEngine {
        let mut cfg = Config::table1();
        cfg.fleet.n_devices = n;
        cfg.seed = seed;
        ScenarioEngine::new(preset.scenario(), cfg.sample_fleet(), seed).unwrap()
    }

    #[test]
    fn state_roundtrip_resumes_the_snapshot_stream() {
        // An engine restored from round-k state must emit the same
        // snapshots as the uninterrupted engine — for every preset.
        for preset in ScenarioPreset::ALL {
            let mut live = engine(preset, 10, 77);
            for _ in 0..12 {
                live.advance();
            }
            let state = live.to_state();
            let mut resumed = engine(preset, 10, 77);
            resumed.restore_state(&state).unwrap();
            for t in 0..20 {
                assert_eq!(
                    live.advance(),
                    resumed.advance(),
                    "preset '{}' round {t} after resume",
                    preset.as_str()
                );
            }
        }
    }

    #[test]
    fn restore_rejects_roster_size_mismatch() {
        let small = engine(ScenarioPreset::ChurnHeavy, 4, 1).to_state();
        let mut big = engine(ScenarioPreset::ChurnHeavy, 8, 1);
        assert!(big.restore_state(&small).is_err());
    }

    #[test]
    fn rejects_empty_roster() {
        let err = ScenarioEngine::new(ScenarioPreset::Static.scenario(), vec![], 1).unwrap_err();
        assert!(err.to_string().contains("non-empty fleet"), "{err}");
    }

    #[test]
    fn static_scenario_never_moves_the_fleet() {
        let mut eng = engine(ScenarioPreset::Static, 6, 7);
        let base = eng.effective_roster().to_vec();
        for t in 1..=10 {
            let snap = eng.advance();
            assert_eq!(snap.round, t);
            assert_eq!(snap.active, (0..6).collect::<Vec<_>>());
            assert!(snap.dropped.is_empty() && snap.joined.is_empty() && snap.left.is_empty());
            assert_eq!(snap.drift, 0.0);
            assert_eq!(snap.devices, base);
        }
    }

    #[test]
    fn snapshots_are_bit_identical_across_engines() {
        for preset in ScenarioPreset::ALL {
            let mut a = engine(preset, 12, 99);
            let mut b = engine(preset, 12, 99);
            for _ in 0..25 {
                assert_eq!(a.advance(), b.advance(), "preset '{}'", preset.as_str());
            }
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = engine(ScenarioPreset::DriftingChannels, 8, 1);
        let mut b = engine(ScenarioPreset::DriftingChannels, 8, 2);
        let differs = (0..10).any(|_| a.advance().devices != b.advance().devices);
        assert!(differs);
    }

    #[test]
    fn churn_respects_min_active_and_survivors() {
        let mut eng = engine(ScenarioPreset::ChurnHeavy, 10, 3);
        let min_active = eng.spec().churn.unwrap().min_active;
        let mut saw_membership_change = false;
        let mut saw_dropout = false;
        for _ in 0..200 {
            let snap = eng.advance();
            assert!(snap.active.len() >= min_active, "active {} < min", snap.active.len());
            assert!(!snap.survivors().is_empty(), "a round must have >= 1 survivor");
            for d in &snap.dropped {
                assert!(snap.active.contains(d), "dropped device not active");
            }
            saw_membership_change |= !snap.joined.is_empty() || !snap.left.is_empty();
            saw_dropout |= !snap.dropped.is_empty();
        }
        assert!(saw_membership_change, "churn-heavy produced no churn in 200 rounds");
        assert!(saw_dropout, "churn-heavy produced no dropout in 200 rounds");
    }

    #[test]
    fn gauss_markov_rates_stay_clamped_and_drift_grows() {
        let mut eng = engine(ScenarioPreset::DriftingChannels, 8, 11);
        let base = eng.effective_roster().to_vec();
        let mut max_drift = 0.0f64;
        for _ in 0..50 {
            let snap = eng.advance();
            for (id, d) in snap.active.iter().zip(&snap.devices) {
                // Clamp bounds are [0.3, 1.7]; widen a hair for the f64
                // multiply/divide round-trip.
                let ratio = d.up_bps / base[*id].up_bps;
                assert!((0.299..=1.701).contains(&ratio), "ratio {ratio}");
            }
            max_drift = max_drift.max(snap.drift);
        }
        assert!(max_drift > 0.0, "drifting channels produced zero drift");
    }

    #[test]
    fn mark_resolved_resets_the_drift_reference() {
        let mut eng = engine(ScenarioPreset::DriftingChannels, 8, 13);
        for _ in 0..20 {
            eng.advance();
        }
        eng.mark_resolved();
        // One step after a re-solve, AR(1) drift is small vs 20 steps.
        let after = eng.advance().drift;
        assert!(after < 0.3, "post-resolve drift {after} unexpectedly large");
    }

    #[test]
    fn diurnal_fading_is_periodic_and_phase_offset() {
        let mut eng = engine(ScenarioPreset::Diurnal, 4, 17);
        let mut per_round: Vec<Vec<f64>> = Vec::new();
        for _ in 0..96 {
            let snap = eng.advance();
            per_round.push(snap.devices.iter().map(|d| d.up_bps).collect());
        }
        // Period 48: round t and t+48 coincide (deterministic, no RNG).
        for t in 0..48 {
            for i in 0..4 {
                let (a, b) = (per_round[t][i], per_round[t + 48][i]);
                assert!((a - b).abs() < 1e-6 * a.abs(), "round {t} dev {i}: {a} vs {b}");
            }
        }
        // Distinct phases: devices are not in lock-step within a round.
        let r0 = &per_round[10];
        assert!(r0.iter().any(|&v| (v - r0[0]).abs() > 1e-9));
    }
}
