//! Dynamic scenario engine: time-varying channels, compute jitter, device
//! churn, and straggler injection over the simulated edge fleet.
//!
//! HASFL's premise is that BS/MS decisions must track *heterogeneous and
//! time-varying* edge conditions (§I of the paper; AdaptSFL and ParallelSFL
//! both evaluate under fluctuating channels and device dropout). The static
//! fleets of `config::FleetConfig` never exercise that: rates are fixed for
//! the life of a run, so the optimizer's re-solve cadence is only ever
//! driven by the fixed decision window. This module adds a deterministic,
//! seeded [`Scenario`] spec that evolves fleet state round by round:
//!
//! - [`Drift`] — per-device channel-rate and compute-capability evolution
//!   (Gauss–Markov AR(1) drift or deterministic periodic/diurnal fading).
//! - [`ChurnModel`] — devices leave, rejoin, and drop out *mid-round*
//!   (dropouts complete no work that round; partial aggregation handles
//!   them, see `aggregation::aggregate_common_partial`).
//! - [`StragglerModel`] — transient one-round slowdowns of a random victim.
//! - `resolve_drift` — a relative fleet-drift threshold that pulls the next
//!   aggregation + BS/MS re-solve *forward* instead of waiting for the
//!   fixed window (DESIGN.md §9).
//!
//! [`engine::ScenarioEngine`] turns a spec + base fleet into a per-round
//! [`engine::FleetSnapshot`] stream; [`sim::ScenarioSim`] drives the
//! analytic latency model + optimizer over that stream (no PJRT runtime
//! needed, scales to 1k+ devices — the `mega-fleet` preset is the standing
//! scale benchmark, `rust/benches/scenario_fleet.rs`). The executable
//! training path attaches the same engine through
//! `ExperimentBuilder::scenario`.
//!
//! Everything is specified by value and serialised through the in-repo
//! JSON substrate, exactly like [`crate::config::Config`]; same seed + same
//! spec ⇒ bit-identical snapshot and round-history streams
//! (`rust/tests/scenario_determinism.rs`).

pub mod engine;
pub mod sim;

pub use engine::{DeviceEvoState, FleetSnapshot, ScenarioEngine, ScenarioEngineState};
pub use sim::{ScenarioSim, SimRound};

use crate::config::{Range, StrategyKind};
use crate::util::Json;

/// Per-round evolution of a per-device multiplier (applied to channel
/// rates or compute capability; 1.0 = the device's sampled base value).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Drift {
    /// No evolution: the multiplier stays at 1.0.
    Static,
    /// Gauss–Markov AR(1) drift around 1.0:
    /// `m' = clamp(1 + rho*(m - 1) + sigma*xi, floor, ceil)`, xi ~ N(0,1).
    GaussMarkov { rho: f64, sigma: f64, floor: f64, ceil: f64 },
    /// Deterministic periodic (diurnal) fading:
    /// `m(t) = 1 + amplitude * sin(2*pi*(t/period + phase_i))`, with a
    /// per-device phase offset so the fleet does not fade in lock-step.
    Periodic { period: f64, amplitude: f64 },
}

impl Drift {
    fn validate(&self, what: &str) -> crate::Result<()> {
        match *self {
            Drift::Static => Ok(()),
            Drift::GaussMarkov { rho, sigma, floor, ceil } => {
                anyhow::ensure!(
                    (0.0..1.0).contains(&rho),
                    "{what}: Gauss-Markov rho {rho} outside [0, 1)"
                );
                anyhow::ensure!(
                    sigma.is_finite() && sigma >= 0.0,
                    "{what}: Gauss-Markov sigma {sigma} must be finite and >= 0"
                );
                anyhow::ensure!(
                    floor > 0.0 && ceil >= floor,
                    "{what}: Gauss-Markov clamp [{floor}, {ceil}] must satisfy 0 < floor <= ceil"
                );
                Ok(())
            }
            Drift::Periodic { period, amplitude } => {
                anyhow::ensure!(period > 0.0, "{what}: period {period} must be > 0");
                anyhow::ensure!(
                    (0.0..1.0).contains(&amplitude),
                    "{what}: amplitude {amplitude} outside [0, 1) (would zero a rate)"
                );
                Ok(())
            }
        }
    }

    fn to_json(self) -> Json {
        let mut j = Json::obj();
        match self {
            Drift::Static => {
                j.set("kind", Json::Str("static".into()));
            }
            Drift::GaussMarkov { rho, sigma, floor, ceil } => {
                j.set("kind", Json::Str("gauss_markov".into()))
                    .set("rho", Json::Num(rho))
                    .set("sigma", Json::Num(sigma))
                    .set("floor", Json::Num(floor))
                    .set("ceil", Json::Num(ceil));
            }
            Drift::Periodic { period, amplitude } => {
                j.set("kind", Json::Str("periodic".into()))
                    .set("period", Json::Num(period))
                    .set("amplitude", Json::Num(amplitude));
            }
        }
        j
    }

    fn from_json(j: &Json) -> crate::Result<Drift> {
        Ok(match j.req("kind")?.as_str()? {
            "static" => Drift::Static,
            "gauss_markov" => Drift::GaussMarkov {
                rho: j.req("rho")?.as_f64()?,
                sigma: j.req("sigma")?.as_f64()?,
                floor: j.req("floor")?.as_f64()?,
                ceil: j.req("ceil")?.as_f64()?,
            },
            "periodic" => Drift::Periodic {
                period: j.req("period")?.as_f64()?,
                amplitude: j.req("amplitude")?.as_f64()?,
            },
            other => anyhow::bail!("unknown drift kind '{other}'"),
        })
    }
}

/// Device churn: membership changes between rounds plus mid-round dropout.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnModel {
    /// Per-round probability an active device goes offline.
    pub leave_prob: f64,
    /// Per-round probability an offline device rejoins the fleet.
    pub join_prob: f64,
    /// Per-round probability an active device fails *mid-round*: it
    /// completes no work that round but stays in the fleet.
    pub dropout_prob: f64,
    /// Churn never shrinks the active set below this (clamped to the
    /// roster size at engine construction).
    pub min_active: usize,
}

impl ChurnModel {
    fn validate(&self) -> crate::Result<()> {
        for (name, p) in [
            ("leave_prob", self.leave_prob),
            ("join_prob", self.join_prob),
            ("dropout_prob", self.dropout_prob),
        ] {
            anyhow::ensure!((0.0..=1.0).contains(&p), "churn {name} {p} outside [0, 1]");
        }
        anyhow::ensure!(
            self.min_active >= 1,
            "churn min_active must be >= 1: an empty fleet has no round latency \
             and no L_c (Decisions::l_c would silently be 0)"
        );
        Ok(())
    }

    fn to_json(self) -> Json {
        let mut j = Json::obj();
        j.set("leave_prob", Json::Num(self.leave_prob))
            .set("join_prob", Json::Num(self.join_prob))
            .set("dropout_prob", Json::Num(self.dropout_prob))
            .set("min_active", Json::Num(self.min_active as f64));
        j
    }

    fn from_json(j: &Json) -> crate::Result<ChurnModel> {
        Ok(ChurnModel {
            leave_prob: j.req("leave_prob")?.as_f64()?,
            join_prob: j.req("join_prob")?.as_f64()?,
            dropout_prob: j.req("dropout_prob")?.as_f64()?,
            min_active: j.req("min_active")?.as_usize()?,
        })
    }
}

/// Transient straggler injection: with probability `prob` per round, one
/// random active device is slowed by a factor drawn from `slowdown` (rates
/// and compute divided by it) for that round only.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StragglerModel {
    /// Per-round probability that a straggler event fires.
    pub prob: f64,
    /// Slowdown-factor range the event draws from.
    pub slowdown: Range,
}

impl StragglerModel {
    fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.prob),
            "straggler prob {} outside [0, 1]",
            self.prob
        );
        anyhow::ensure!(
            self.slowdown.lo >= 1.0,
            "straggler slowdown lower bound {} must be >= 1 (a factor)",
            self.slowdown.lo
        );
        Ok(())
    }

    fn to_json(self) -> Json {
        let mut j = Json::obj();
        j.set("prob", Json::Num(self.prob))
            .set("slowdown", Json::from_f64s(&[self.slowdown.lo, self.slowdown.hi]));
        j
    }

    fn from_json(j: &Json) -> crate::Result<StragglerModel> {
        let s = j.req("slowdown")?.f64_vec()?;
        anyhow::ensure!(s.len() == 2, "slowdown needs [lo, hi]");
        Ok(StragglerModel { prob: j.req("prob")?.as_f64()?, slowdown: Range::new(s[0], s[1]) })
    }
}

/// A complete dynamic-fleet scenario, applied on top of the base fleet
/// sampled from `Config.fleet`. Serde-style round-trippable through the
/// in-repo JSON codec ([`Scenario::to_json`] / [`Scenario::from_json`]).
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Human-readable scenario name (reported in traces and benches).
    pub name: String,
    /// Evolution of the per-device channel multiplier (all four link rates).
    pub channel: Drift,
    /// Evolution of the per-device compute multiplier (`f_i`).
    pub compute: Drift,
    /// Device join/leave dynamics, if any.
    pub churn: Option<ChurnModel>,
    /// Transient straggler injection, if any.
    pub straggler: Option<StragglerModel>,
    /// Mean relative fleet drift (vs the state at the last re-solve) that
    /// triggers an *early* aggregation + BS/MS re-solve. `None` = re-solve
    /// only on the fixed decision window.
    pub resolve_drift: Option<f64>,
}

impl Scenario {
    /// Validate the spec against a fleet of `n_devices` roster members.
    ///
    /// Empty fleets are rejected here (not deep inside the latency model):
    /// `Decisions::l_c()` over zero devices would silently report 0 and
    /// every phase maximum would collapse to 0 seconds.
    pub fn validate(&self, n_devices: usize) -> crate::Result<()> {
        anyhow::ensure!(
            n_devices >= 1,
            "scenario '{}' needs a non-empty fleet (n_devices >= 1)",
            self.name
        );
        self.channel.validate("channel")?;
        self.compute.validate("compute")?;
        if let Some(c) = &self.churn {
            c.validate()?;
        }
        if let Some(s) = &self.straggler {
            s.validate()?;
        }
        if let Some(thr) = self.resolve_drift {
            anyhow::ensure!(
                thr.is_finite() && thr > 0.0,
                "resolve_drift {thr} must be finite and > 0"
            );
        }
        Ok(())
    }

    /// Serialize to the JSON form accepted by [`Scenario::from_json`].
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()))
            .set("channel", self.channel.to_json())
            .set("compute", self.compute.to_json());
        if let Some(c) = self.churn {
            j.set("churn", c.to_json());
        }
        if let Some(s) = self.straggler {
            j.set("straggler", s.to_json());
        }
        if let Some(thr) = self.resolve_drift {
            j.set("resolve_drift", Json::Num(thr));
        }
        j
    }

    /// Decode and validate a scenario.
    pub fn from_json(j: &Json) -> crate::Result<Scenario> {
        Ok(Scenario {
            name: j.req("name")?.as_str()?.to_string(),
            channel: Drift::from_json(j.req("channel")?)?,
            compute: Drift::from_json(j.req("compute")?)?,
            churn: match j.get("churn") {
                Some(c) => Some(ChurnModel::from_json(c)?),
                None => None,
            },
            straggler: match j.get("straggler") {
                Some(s) => Some(StragglerModel::from_json(s)?),
                None => None,
            },
            resolve_drift: match j.get("resolve_drift") {
                Some(v) => Some(v.as_f64()?),
                None => None,
            },
        })
    }

    /// Read and decode a JSON scenario file.
    pub fn load(path: &std::path::Path) -> crate::Result<Scenario> {
        let text = std::fs::read_to_string(path)?;
        Scenario::from_json(&Json::parse(&text)?)
    }

    /// Write the scenario as JSON to `path`.
    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        std::fs::write(path, self.to_json().dump())?;
        Ok(())
    }
}

/// Named scenario presets spanning the evaluation axes of the paper's
/// related work: static control, channel drift, diurnal fading, heavy
/// churn, and the 1k+-device scale stressor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioPreset {
    /// Control: the historical fixed fleet, expressed as a scenario.
    Static,
    /// Gauss–Markov channel-rate drift with drift-triggered re-solves.
    DriftingChannels,
    /// Deterministic day/night fading of channels and compute.
    Diurnal,
    /// Aggressive join/leave churn + mid-round dropout + stragglers.
    ChurnHeavy,
    /// The standing scale benchmark: gentle drift + churn, intended for
    /// fleets of >= 1000 simulated devices (see `suggested_devices`).
    MegaFleet,
}

impl ScenarioPreset {
    /// Every preset, in CLI listing order.
    pub const ALL: [ScenarioPreset; 5] = [
        ScenarioPreset::Static,
        ScenarioPreset::DriftingChannels,
        ScenarioPreset::Diurnal,
        ScenarioPreset::ChurnHeavy,
        ScenarioPreset::MegaFleet,
    ];

    /// Canonical kebab-case name — the inverse of [`ScenarioPreset::parse`].
    pub fn as_str(&self) -> &'static str {
        match self {
            ScenarioPreset::Static => "static",
            ScenarioPreset::DriftingChannels => "drifting-channels",
            ScenarioPreset::Diurnal => "diurnal",
            ScenarioPreset::ChurnHeavy => "churn-heavy",
            ScenarioPreset::MegaFleet => "mega-fleet",
        }
    }

    /// Parse a preset name (kebab- or snake-case accepted).
    pub fn parse(s: &str) -> crate::Result<ScenarioPreset> {
        Ok(match s {
            "static" => ScenarioPreset::Static,
            "drifting-channels" | "drifting_channels" => ScenarioPreset::DriftingChannels,
            "diurnal" => ScenarioPreset::Diurnal,
            "churn-heavy" | "churn_heavy" => ScenarioPreset::ChurnHeavy,
            "mega-fleet" | "mega_fleet" => ScenarioPreset::MegaFleet,
            _ => anyhow::bail!(
                "unknown scenario preset '{s}' (expected \
                 static|drifting-channels|diurnal|churn-heavy|mega-fleet)"
            ),
        })
    }

    /// The preset's scenario spec.
    pub fn scenario(&self) -> Scenario {
        let name = self.as_str().to_string();
        match self {
            ScenarioPreset::Static => Scenario {
                name,
                channel: Drift::Static,
                compute: Drift::Static,
                churn: None,
                straggler: None,
                resolve_drift: None,
            },
            ScenarioPreset::DriftingChannels => Scenario {
                name,
                channel: Drift::GaussMarkov { rho: 0.9, sigma: 0.08, floor: 0.3, ceil: 1.7 },
                compute: Drift::GaussMarkov { rho: 0.95, sigma: 0.02, floor: 0.5, ceil: 1.5 },
                churn: None,
                straggler: None,
                resolve_drift: Some(0.15),
            },
            ScenarioPreset::Diurnal => Scenario {
                name,
                channel: Drift::Periodic { period: 48.0, amplitude: 0.5 },
                compute: Drift::Periodic { period: 96.0, amplitude: 0.25 },
                churn: None,
                straggler: None,
                resolve_drift: Some(0.2),
            },
            ScenarioPreset::ChurnHeavy => Scenario {
                name,
                channel: Drift::GaussMarkov { rho: 0.85, sigma: 0.05, floor: 0.4, ceil: 1.6 },
                compute: Drift::Static,
                churn: Some(ChurnModel {
                    leave_prob: 0.08,
                    join_prob: 0.25,
                    dropout_prob: 0.05,
                    min_active: 2,
                }),
                straggler: Some(StragglerModel { prob: 0.2, slowdown: Range::new(4.0, 16.0) }),
                resolve_drift: Some(0.25),
            },
            ScenarioPreset::MegaFleet => Scenario {
                name,
                channel: Drift::GaussMarkov { rho: 0.9, sigma: 0.05, floor: 0.5, ceil: 1.5 },
                compute: Drift::GaussMarkov { rho: 0.95, sigma: 0.02, floor: 0.6, ceil: 1.4 },
                churn: Some(ChurnModel {
                    leave_prob: 0.02,
                    join_prob: 0.1,
                    dropout_prob: 0.01,
                    min_active: 32,
                }),
                straggler: Some(StragglerModel { prob: 0.3, slowdown: Range::new(4.0, 24.0) }),
                resolve_drift: Some(0.2),
            },
        }
    }

    /// Fleet size the preset is designed around (`None` = caller's choice).
    pub fn suggested_devices(&self) -> Option<usize> {
        match self {
            ScenarioPreset::MegaFleet => Some(1024),
            _ => None,
        }
    }

    /// Strategy that stays tractable at the preset's scale. The full HASFL
    /// BCD solve is O(N^2) per sweep and infeasible at 1k+ devices; the
    /// mega-fleet preset pairs with the heterogeneity-aware BS solver at a
    /// fixed cut (Newton–Jacobi, O(N) per iteration).
    pub fn suggested_strategy(&self) -> Option<StrategyKind> {
        match self {
            ScenarioPreset::MegaFleet => Some(StrategyKind::HabsFixedCut),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_parse_roundtrip() {
        for p in ScenarioPreset::ALL {
            assert_eq!(ScenarioPreset::parse(p.as_str()).unwrap(), p);
        }
        assert!(ScenarioPreset::parse("bogus").is_err());
    }

    #[test]
    fn every_preset_roundtrips_through_json() {
        for p in ScenarioPreset::ALL {
            let s = p.scenario();
            let back = Scenario::from_json(&Json::parse(&s.to_json().dump()).unwrap()).unwrap();
            assert_eq!(s, back, "preset '{}'", p.as_str());
        }
    }

    #[test]
    fn scenario_save_load_roundtrip() {
        let s = ScenarioPreset::ChurnHeavy.scenario();
        let path = std::env::temp_dir().join("hasfl_scenario_rt.json");
        s.save(&path).unwrap();
        assert_eq!(Scenario::load(&path).unwrap(), s);
    }

    #[test]
    fn every_preset_validates_at_table1_scale() {
        for p in ScenarioPreset::ALL {
            p.scenario().validate(20).unwrap();
        }
    }

    #[test]
    fn empty_fleet_is_rejected() {
        // Regression for the Decisions::l_c() empty-fleet hole: construction
        // is refused at the validation layer, before any latency math runs.
        let err = ScenarioPreset::Static.scenario().validate(0).unwrap_err();
        assert!(err.to_string().contains("non-empty fleet"), "{err}");
    }

    #[test]
    fn bad_specs_are_rejected() {
        let mut s = ScenarioPreset::DriftingChannels.scenario();
        s.channel = Drift::GaussMarkov { rho: 1.5, sigma: 0.1, floor: 0.5, ceil: 1.5 };
        assert!(s.validate(4).is_err());

        let mut s = ScenarioPreset::Diurnal.scenario();
        s.compute = Drift::Periodic { period: 0.0, amplitude: 0.2 };
        assert!(s.validate(4).is_err());

        let mut s = ScenarioPreset::ChurnHeavy.scenario();
        s.churn = Some(ChurnModel {
            leave_prob: 0.1,
            join_prob: 0.1,
            dropout_prob: 0.1,
            min_active: 0,
        });
        assert!(s.validate(4).is_err());

        let mut s = ScenarioPreset::ChurnHeavy.scenario();
        s.resolve_drift = Some(-1.0);
        assert!(s.validate(4).is_err());
    }

    #[test]
    fn mega_fleet_targets_1k_devices() {
        assert!(ScenarioPreset::MegaFleet.suggested_devices().unwrap() >= 1000);
        assert!(ScenarioPreset::MegaFleet.suggested_strategy().is_some());
    }
}
