//! Analytic scenario driver: the latency model + BS/MS optimizer over a
//! [`ScenarioEngine`] stream, no PJRT runtime required.
//!
//! This is the scale path: a 1k+-device `mega-fleet` round costs one fleet
//! evolution, one (possibly skipped) strategy solve, and one O(N) latency
//! evaluation — `rust/benches/scenario_fleet.rs` uses it as the standing
//! scale benchmark. Executable training under a scenario goes through
//! `ExperimentBuilder::scenario` instead (same engine, real gradients).
//!
//! Re-solve cadence approximates the coordinator: decisions refresh on the
//! fixed aggregation window *and* whenever fleet drift crosses the
//! scenario's `resolve_drift` trigger (an early aggregation event). One
//! divergence from the executable path: a membership change re-solves
//! immediately (and is charged as an aggregation event), because the sim's
//! decision vectors are sized to the active set, while the `Trainer` keeps
//! roster-sized decisions and lets membership flips feed the drift trigger
//! instead.

use crate::config::{Config, Device, ModelKind};
use crate::convergence::BoundParams;
use crate::latency::{round_latency_subset, Decisions};
use crate::metrics::{FleetRound, FleetTrace};
use crate::model::{profile_for, ModelProfile};
use crate::optimizer::{decide, OptContext, StrategyInputs};
use crate::rng::Pcg32;

use super::{FleetSnapshot, Scenario, ScenarioEngine};

/// Alias: one simulated round's record (shared with the executable path's
/// fleet trace).
pub type SimRound = FleetRound;

/// Step-driven analytic simulation of training rounds over a dynamic fleet.
pub struct ScenarioSim {
    cfg: Config,
    scenario: Scenario,
    profile: ModelProfile,
    engine: ScenarioEngine,
    strategy_rng: Pcg32,
    inputs: StrategyInputs,
    bound: BoundParams,
    /// Decisions for the current active set (aligned with `active_ids`).
    dec: Decisions,
    /// Roster ids the decisions in force were solved for.
    active_ids: Vec<usize>,
    round: usize,
    sim_time: f64,
    resolves: usize,
    trace: FleetTrace,
}

impl ScenarioSim {
    /// Build a sim from a validated config + scenario. Analytic only: the
    /// model must be one of the profile-backed kinds (`vgg16`/`resnet18`).
    pub fn new(cfg: Config, scenario: Scenario) -> crate::Result<ScenarioSim> {
        scenario.validate(cfg.fleet.n_devices)?;
        // Zero-rate guard: the latency model divides by every fleet/server
        // resource, so reject configs that could sample a zero rate.
        cfg.fleet.validate()?;
        cfg.server.validate()?;
        anyhow::ensure!(
            cfg.model != ModelKind::Splitcnn8,
            "ScenarioSim is analytic; model '{}' requires the PJRT runtime \
             (attach scenarios to executable runs via ExperimentBuilder::scenario)",
            cfg.model.as_str()
        );
        let profile = profile_for(cfg.model, None);
        let bound = BoundParams::default_for(&profile, cfg.train.lr);
        let engine = ScenarioEngine::new(scenario.clone(), cfg.sample_fleet(), cfg.seed)?;
        let mut strategy_rng = Pcg32::new(cfg.seed, 0x57A7);
        let inputs = StrategyInputs { fixed_batch: cfg.fixed_batch, fixed_cut: cfg.fixed_cut };

        // Initial decisions over the full (round-0) fleet.
        let n = engine.roster_len();
        let dec = {
            let ctx = OptContext {
                profile: &profile,
                devices: engine.effective_roster(),
                server: &cfg.server,
                bound: &bound,
                interval: cfg.train.agg_interval,
                epsilon: cfg.train.epsilon,
                batch_cap: cfg.train.batch_cap,
            };
            decide(cfg.strategy, &ctx, &mut strategy_rng, inputs)
        };

        Ok(ScenarioSim {
            cfg,
            scenario,
            profile,
            engine,
            strategy_rng,
            inputs,
            bound,
            dec,
            active_ids: (0..n).collect(),
            round: 0,
            sim_time: 0.0,
            resolves: 0,
            trace: FleetTrace::default(),
        })
    }

    /// Re-solve BS/MS for the snapshot's active set and reset the drift
    /// reference. Decisions are solved over the *persistent* effective
    /// rates (straggler-free), not the round's realized rates, so a
    /// one-round slowdown is never baked into a whole decision window.
    fn resolve(&mut self, snap: &FleetSnapshot) {
        let roster = self.engine.effective_roster();
        let devices: Vec<Device> =
            snap.active.iter().map(|&i| roster[i].clone()).collect();
        let dec = {
            let ctx = OptContext {
                profile: &self.profile,
                devices: &devices,
                server: &self.cfg.server,
                bound: &self.bound,
                interval: self.cfg.train.agg_interval,
                epsilon: self.cfg.train.epsilon,
                batch_cap: self.cfg.train.batch_cap,
            };
            decide(self.cfg.strategy, &ctx, &mut self.strategy_rng, self.inputs)
        };
        self.dec = dec;
        self.active_ids = snap.active.clone();
        self.engine.mark_resolved();
        self.resolves += 1;
    }

    /// Advance one simulated round. Returns its record (also appended to
    /// [`ScenarioSim::trace`]).
    pub fn step(&mut self) -> FleetRound {
        let snap = self.engine.advance();
        self.round += 1;
        debug_assert_eq!(self.round, snap.round);

        // Membership changed since the decisions were solved: the decision
        // vectors no longer match the active set — re-solve now (and
        // charge the round as an aggregation event below: redistributing
        // sub-models to joiners/leavers is exactly the Eqn-39 exchange).
        let mut resolved = false;
        let membership_changed = snap.active != self.active_ids;
        if membership_changed {
            self.resolve(&snap);
            resolved = true;
        }

        // Round latency over the surviving devices (active minus mid-round
        // dropouts), under the decisions in force. `dec` and
        // `snap.devices` are both active-set-aligned, so the subset mask
        // is simply "not dropped".
        let mask: Vec<bool> =
            snap.active.iter().map(|id| !snap.dropped.contains(id)).collect();
        let lat =
            round_latency_subset(&self.profile, &snap.devices, &self.cfg.server, &self.dec, &mask);
        self.sim_time += lat.t_split;

        // Aggregation events: the fixed window, drift crossing the trigger
        // (which pulls the event forward), or a membership change.
        let window = self.round % self.cfg.train.agg_interval == 0;
        let drift_hit = self.scenario.resolve_drift.map_or(false, |thr| snap.drift >= thr);
        let mut t_agg = 0.0;
        if window || drift_hit || membership_changed {
            t_agg = lat.t_agg;
            self.sim_time += t_agg;
            // A membership change already re-solved this round; don't run
            // (and count) a second solve.
            if !resolved {
                self.resolve(&snap);
                resolved = true;
            }
        }

        let rec = FleetRound {
            round: self.round,
            n_active: snap.active.len(),
            n_dropped: snap.dropped.len(),
            n_joined: snap.joined.len(),
            n_left: snap.left.len(),
            drift: snap.drift,
            resolved,
            t_split: lat.t_split,
            t_agg,
            sim_time: self.sim_time,
            flushed: 0,
            stale_drops: 0,
            staleness_mean: 0.0,
        };
        self.trace.push(rec.clone());
        rec
    }

    /// Run `rounds` simulated rounds.
    pub fn run(&mut self, rounds: usize) -> &FleetTrace {
        for _ in 0..rounds {
            self.step();
        }
        &self.trace
    }

    /// Trace of every round stepped so far.
    pub fn trace(&self) -> &FleetTrace {
        &self.trace
    }

    /// Rounds stepped so far.
    pub fn round(&self) -> usize {
        self.round
    }

    /// Accumulated simulated wall-clock (seconds).
    pub fn sim_time(&self) -> f64 {
        self.sim_time
    }

    /// BS/MS re-solves during stepping (the initial solve at construction
    /// is not counted).
    pub fn resolves(&self) -> usize {
        self.resolves
    }

    /// The decisions currently in force.
    pub fn decisions(&self) -> &Decisions {
        &self.dec
    }

    /// The underlying scenario engine.
    pub fn engine(&self) -> &ScenarioEngine {
        &self.engine
    }

    /// The config the simulation was built from.
    pub fn config(&self) -> &Config {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StrategyKind;
    use crate::scenario::ScenarioPreset;

    fn sim(preset: ScenarioPreset, n: usize, strategy: StrategyKind, seed: u64) -> ScenarioSim {
        let mut cfg = Config::table1();
        cfg.fleet.n_devices = n;
        cfg.strategy = strategy;
        cfg.seed = seed;
        ScenarioSim::new(cfg, preset.scenario()).unwrap()
    }

    #[test]
    fn zero_rate_fleet_is_rejected_before_the_optimizer_runs() {
        // Regression for the latency-kernel division guard: a config that
        // could sample a zero uplink must be rejected up front, not
        // surface as inf/NaN objectives inside the BS/MS solve.
        let mut cfg = Config::table1();
        cfg.fleet.up_bps = crate::config::Range::new(0.0, 0.0);
        let err = ScenarioSim::new(cfg, ScenarioPreset::Static.scenario()).unwrap_err();
        assert!(err.to_string().contains("up_bps"), "{err}");

        let mut cfg = Config::table1();
        cfg.server.flops = 0.0;
        assert!(ScenarioSim::new(cfg, ScenarioPreset::Static.scenario()).is_err());

        // A valid config keeps every solved round latency finite (the
        // optimizer path the guard protects).
        let mut cfg = Config::table1();
        cfg.fleet.n_devices = 8;
        cfg.strategy = StrategyKind::Hasfl;
        let mut sim = ScenarioSim::new(cfg, ScenarioPreset::ChurnHeavy.scenario()).unwrap();
        sim.run(10);
        for r in &sim.trace().rounds {
            assert!(r.t_split.is_finite(), "round {}: t_split {}", r.round, r.t_split);
            assert!(r.t_agg.is_finite(), "round {}: t_agg {}", r.round, r.t_agg);
        }
    }

    #[test]
    fn rejects_executable_model_and_empty_fleet() {
        let mut cfg = Config::table1();
        cfg.model = crate::config::ModelKind::Splitcnn8;
        assert!(ScenarioSim::new(cfg, ScenarioPreset::Static.scenario()).is_err());

        let mut cfg = Config::table1();
        cfg.fleet.n_devices = 0;
        assert!(ScenarioSim::new(cfg, ScenarioPreset::Static.scenario()).is_err());
    }

    #[test]
    fn static_scenario_resolves_only_on_the_window() {
        let mut s = sim(ScenarioPreset::Static, 6, StrategyKind::Fixed, 5);
        let interval = s.config().train.agg_interval;
        s.run(2 * interval);
        for r in &s.trace().rounds {
            assert_eq!(r.resolved, r.round % interval == 0, "round {}", r.round);
            assert_eq!(r.n_active, 6);
            assert_eq!(r.n_dropped, 0);
            assert_eq!(r.drift, 0.0);
        }
        assert_eq!(s.resolves(), 2);
    }

    #[test]
    fn drift_trigger_pulls_resolves_forward() {
        // Drifting channels with a tight trigger: re-solves must land on
        // non-window rounds too (the window alone fires every 15th round).
        let mut spec = ScenarioPreset::DriftingChannels.scenario();
        spec.resolve_drift = Some(0.05);
        let mut cfg = Config::table1();
        cfg.fleet.n_devices = 8;
        cfg.strategy = StrategyKind::Fixed;
        cfg.seed = 9;
        let mut s = ScenarioSim::new(cfg, spec).unwrap();
        let interval = s.config().train.agg_interval;
        s.run(60);
        let off_window = s
            .trace()
            .rounds
            .iter()
            .filter(|r| r.resolved && r.round % interval != 0)
            .count();
        assert!(off_window > 0, "no drift-triggered re-solves in 60 drifting rounds");
        assert!(s.trace().drift_summary().unwrap().max > 0.0);
    }

    #[test]
    fn churn_heavy_produces_partial_rounds() {
        let mut s = sim(ScenarioPreset::ChurnHeavy, 12, StrategyKind::RbsRhams, 21);
        s.run(80);
        assert!(s.trace().partial_rounds() > 0, "no mid-round dropouts in 80 rounds");
        let any_membership = s
            .trace()
            .rounds
            .iter()
            .any(|r| r.n_joined > 0 || r.n_left > 0);
        assert!(any_membership, "no membership churn in 80 rounds");
        // Every round completed with at least one survivor and finite time.
        for r in &s.trace().rounds {
            assert!(r.n_active > r.n_dropped, "round {} had no survivors", r.round);
            assert!(r.t_split.is_finite() && r.t_split > 0.0);
        }
        assert!(s.sim_time().is_finite() && s.sim_time() > 0.0);
    }

    #[test]
    fn identical_seed_and_spec_give_bit_identical_traces() {
        for preset in [ScenarioPreset::DriftingChannels, ScenarioPreset::ChurnHeavy] {
            let mut a = sim(preset, 10, StrategyKind::Fixed, 33);
            let mut b = sim(preset, 10, StrategyKind::Fixed, 33);
            a.run(40);
            b.run(40);
            assert_eq!(a.trace(), b.trace(), "preset '{}'", preset.as_str());
            assert_eq!(a.decisions(), b.decisions());
        }
    }

    #[test]
    fn straggler_rounds_cost_more() {
        // Churn-heavy injects 4-16x slowdowns; the p95/p50 split-latency
        // ratio must reflect them (the straggler effect the paper attacks).
        let mut s = sim(ScenarioPreset::ChurnHeavy, 12, StrategyKind::Fixed, 41);
        s.run(100);
        let sum = s.trace().split_summary().unwrap();
        assert!(
            sum.p95 > sum.p50,
            "stragglers left no tail: p95 {} <= p50 {}",
            sum.p95,
            sum.p50
        );
    }
}
