//! Parameter store: host-side model parameters, SGD updates, and the
//! split/forge/aggregate plumbing the HASFL protocol needs.

use super::manifest::Manifest;
use crate::rng::Pcg32;

/// A host tensor (f32, row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Row-major tensor shape.
    pub shape: Vec<usize>,
    /// Flat element storage, `shape.iter().product()` long.
    pub data: Vec<f32>,
}

impl Tensor {
    /// All-zero tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product::<usize>().max(1);
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Element count.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// He-normal init (matches the Python initializer's distribution).
    pub fn he_init(shape: &[usize], rng: &mut Pcg32) -> Tensor {
        let n = shape.iter().product::<usize>().max(1);
        let fan_in: usize = if shape.len() > 1 {
            shape[..shape.len() - 1].iter().product()
        } else {
            1
        };
        let std = (2.0 / fan_in as f64).sqrt();
        let data = (0..n).map(|_| (rng.normal() * std) as f32).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    /// Squared L2 norm, accumulated in f64.
    pub fn l2_sq(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }
}

/// Full model parameters: 2 tensors per block `[w1, b1, w2, b2, ...]`.
#[derive(Debug, Clone, PartialEq)]
pub struct Params {
    /// Flat tensor list, `[w1, b1, w2, b2, ...]` in block order.
    pub tensors: Vec<Tensor>,
    /// Blocks in the model (tensors.len() == 2 * n_blocks).
    pub n_blocks: usize,
    /// Mutation counter, bumped by every in-place update (SGD, averaging).
    /// The runtime's parameter-buffer cache keys literals by this version,
    /// so invalidation lives next to mutation (DESIGN.md §8).
    pub version: u64,
}

impl Params {
    /// Initialize from the manifest's parameter shapes.
    pub fn init(manifest: &Manifest, seed: u64) -> Params {
        let mut rng = Pcg32::new(seed, 0x9A7A);
        let mut tensors = Vec::with_capacity(manifest.param_shapes.len() * 2);
        for ps in &manifest.param_shapes {
            tensors.push(Tensor::he_init(&ps.w, &mut rng));
            tensors.push(Tensor::zeros(&ps.b));
        }
        Params { tensors, n_blocks: manifest.param_shapes.len(), version: 0 }
    }

    /// Same shapes, all elements zero, version reset.
    pub fn zeros_like(&self) -> Params {
        Params {
            tensors: self.tensors.iter().map(|t| Tensor::zeros(&t.shape)).collect(),
            n_blocks: self.n_blocks,
            version: 0,
        }
    }

    /// Tensor index range `[lo, hi)` covering blocks `[from_block, to_block)`
    /// (0-based blocks).
    pub fn block_range(from_block: usize, to_block: usize) -> std::ops::Range<usize> {
        2 * from_block..2 * to_block
    }

    /// Client-side tensors for a cut (blocks 1..=cut -> indices 0..2*cut).
    pub fn client_slice(&self, cut: usize) -> &[Tensor] {
        &self.tensors[..2 * cut]
    }

    /// Server-side tensors for a cut (blocks cut+1..=L).
    pub fn server_slice(&self, cut: usize) -> &[Tensor] {
        &self.tensors[2 * cut..]
    }

    /// SGD update on a tensor index range: `w[i] -= lr * g[i]`.
    pub fn sgd_update_range(
        &mut self,
        range: std::ops::Range<usize>,
        grads: &[Tensor],
        lr: f64,
    ) {
        assert_eq!(range.len(), grads.len());
        for (t, g) in self.tensors[range].iter_mut().zip(grads) {
            debug_assert_eq!(t.shape, g.shape);
            for (w, &gv) in t.data.iter_mut().zip(&g.data) {
                *w -= (lr * gv as f64) as f32;
            }
        }
        self.version += 1;
    }

    /// Per-block squared L2 norms of a gradient list aligned to the model's
    /// blocks `[from_block..)` — used by the Assumption-2 estimator.
    pub fn block_sq_norms(grads: &[Tensor], from_block: usize) -> Vec<(usize, f64)> {
        grads
            .chunks(2)
            .enumerate()
            .map(|(i, pair)| (from_block + i, pair.iter().map(|t| t.l2_sq()).sum()))
            .collect()
    }

    /// Total trainable element count across all tensors.
    pub fn total_numel(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }
}

/// Average tensors element-wise over tensor index range `range` across many
/// parameter sets, writing the mean back into every set (synchronisation).
/// Bumps every set's version (the content changed for the whole fleet).
pub fn average_in_place(sets: &mut [Params], range: std::ops::Range<usize>) {
    if sets.is_empty() || range.is_empty() {
        return;
    }
    let n = sets.len() as f32;
    for s in sets.iter_mut() {
        s.version += 1;
    }
    for ti in range {
        let len = sets[0].tensors[ti].data.len();
        let mut mean = vec![0.0f32; len];
        for s in sets.iter() {
            for (m, &v) in mean.iter_mut().zip(&s.tensors[ti].data) {
                *m += v;
            }
        }
        for m in mean.iter_mut() {
            *m /= n;
        }
        for s in sets.iter_mut() {
            s.tensors[ti].data.copy_from_slice(&mean);
        }
    }
}

/// Weighted partial synchronisation: average tensor range `range` over the
/// `participants` subset (weights normalised internally), writing the
/// result into *every* set — contributors and non-contributors alike, so
/// the synced region stays fleet-identical (the invariant the runtime's
/// shared buffer-cache keying relies on, DESIGN.md §8). Used by
/// dynamic-fleet rounds where offline/dropped devices contribute nothing
/// but still receive the aggregate. Bumps every set's version.
pub fn weighted_average_in_place(
    sets: &mut [Params],
    range: std::ops::Range<usize>,
    participants: &[usize],
    weights: &[f64],
) {
    if sets.is_empty() || range.is_empty() || participants.is_empty() {
        return;
    }
    assert_eq!(participants.len(), weights.len());
    debug_assert!(participants.iter().all(|&p| p < sets.len()));
    let wsum: f64 = weights.iter().sum();
    if wsum <= 0.0 {
        return;
    }
    let scaled: Vec<f32> = weights.iter().map(|&w| (w / wsum) as f32).collect();
    for s in sets.iter_mut() {
        s.version += 1;
    }
    for ti in range {
        let len = sets[0].tensors[ti].data.len();
        let mut mean = vec![0.0f32; len];
        for (&p, &k) in participants.iter().zip(&scaled) {
            for (m, &v) in mean.iter_mut().zip(&sets[p].tensors[ti].data) {
                *m += k * v;
            }
        }
        for s in sets.iter_mut() {
            s.tensors[ti].data.copy_from_slice(&mean);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_params() -> Params {
        Params {
            tensors: vec![
                Tensor { shape: vec![2], data: vec![1.0, 2.0] },
                Tensor { shape: vec![1], data: vec![0.5] },
                Tensor { shape: vec![2], data: vec![3.0, 4.0] },
                Tensor { shape: vec![1], data: vec![1.5] },
            ],
            n_blocks: 2,
            version: 0,
        }
    }

    #[test]
    fn split_slices_cover_everything() {
        let p = toy_params();
        assert_eq!(p.client_slice(1).len(), 2);
        assert_eq!(p.server_slice(1).len(), 2);
        assert_eq!(p.client_slice(1).len() + p.server_slice(1).len(), p.tensors.len());
    }

    #[test]
    fn sgd_update_applies_lr() {
        let mut p = toy_params();
        let g = vec![
            Tensor { shape: vec![2], data: vec![1.0, 1.0] },
            Tensor { shape: vec![1], data: vec![2.0] },
        ];
        p.sgd_update_range(0..2, &g, 0.1);
        assert!((p.tensors[0].data[0] - 0.9).abs() < 1e-6);
        assert!((p.tensors[1].data[0] - 0.3).abs() < 1e-6);
        // untouched range
        assert_eq!(p.tensors[2].data, vec![3.0, 4.0]);
    }

    #[test]
    fn average_in_place_synchronises() {
        let mut a = toy_params();
        let mut b = toy_params();
        b.tensors[0].data = vec![3.0, 4.0];
        let mut sets = vec![a.clone(), b.clone()];
        average_in_place(&mut sets, 0..2);
        assert_eq!(sets[0].tensors[0].data, vec![2.0, 3.0]);
        assert_eq!(sets[1].tensors[0].data, vec![2.0, 3.0]);
        // range end untouched
        assert_eq!(sets[1].tensors[2].data, vec![3.0, 4.0]);
        a.tensors[0].data = vec![0.0; 2];
        b.tensors[0].data = vec![0.0; 2];
    }

    #[test]
    fn weighted_average_excludes_nonparticipants_but_syncs_everyone() {
        let mut a = toy_params(); // tensors[0] = [1, 2]
        a.tensors[0].data = vec![2.0, 2.0];
        let mut b = toy_params();
        b.tensors[0].data = vec![6.0, 6.0];
        let mut c = toy_params();
        c.tensors[0].data = vec![100.0, 100.0]; // non-participant
        let mut sets = vec![a, b, c];
        // Participants 0 and 1 with weights 1:3 -> mean 5.0; device 2
        // contributes nothing but receives the aggregate.
        weighted_average_in_place(&mut sets, 0..1, &[0, 1], &[1.0, 3.0]);
        for s in &sets {
            assert_eq!(s.tensors[0].data, vec![5.0, 5.0]);
            assert_eq!(s.version, 1);
        }
        // Range end untouched.
        assert_eq!(sets[2].tensors[1].data, vec![0.5]);
    }

    #[test]
    fn weighted_average_full_equal_weights_matches_plain_average() {
        let mut x = vec![toy_params(), toy_params()];
        x[1].tensors[0].data = vec![3.0, 4.0];
        let mut y = x.clone();
        average_in_place(&mut x, 0..2);
        weighted_average_in_place(&mut y, 0..2, &[0, 1], &[1.0, 1.0]);
        for (a, b) in x.iter().zip(&y) {
            for (ta, tb) in a.tensors.iter().zip(&b.tensors) {
                for (&va, &vb) in ta.data.iter().zip(&tb.data) {
                    assert!((va - vb).abs() < 1e-6, "{va} vs {vb}");
                }
            }
        }
    }

    #[test]
    fn he_init_scale_tracks_fan_in() {
        let mut rng = Pcg32::seeded(1);
        let t = Tensor::he_init(&[1000, 4], &mut rng);
        let var = t.l2_sq() / t.numel() as f64;
        let want = 2.0 / 1000.0;
        assert!((var - want).abs() / want < 0.25, "var {var} want {want}");
    }

    #[test]
    fn mutations_bump_the_version() {
        let mut p = toy_params();
        assert_eq!(p.version, 0);
        let g = vec![
            Tensor { shape: vec![2], data: vec![1.0, 1.0] },
            Tensor { shape: vec![1], data: vec![2.0] },
        ];
        p.sgd_update_range(0..2, &g, 0.1);
        assert_eq!(p.version, 1);

        let mut sets = vec![p.clone(), p.clone()];
        average_in_place(&mut sets, 0..2);
        assert_eq!(sets[0].version, 2);
        assert_eq!(sets[1].version, 2);
        // An empty range mutates nothing, so the version must not move.
        average_in_place(&mut sets, 1..1);
        assert_eq!(sets[0].version, 2);
    }

    #[test]
    fn block_sq_norms_pairs_tensors() {
        let g = vec![
            Tensor { shape: vec![2], data: vec![3.0, 4.0] },
            Tensor { shape: vec![1], data: vec![0.0] },
            Tensor { shape: vec![1], data: vec![2.0] },
            Tensor { shape: vec![1], data: vec![1.0] },
        ];
        let norms = Params::block_sq_norms(&g, 3);
        assert_eq!(norms, vec![(3, 25.0), (4, 5.0)]);
    }
}
