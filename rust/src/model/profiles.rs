//! Analytic per-layer cost profiles.
//!
//! The latency model (Eqns 28–40) and the memory constraint C4 only need
//! per-layer tables: forward/backward FLOPs (rho_j / varpi_j), activation
//! bytes at each potential cut (psi_j, chi_j), and parameter bytes
//! (delta_j). The executable SplitCNN-8 profile comes from the artifact
//! manifest; VGG-16 and ResNet-18 profiles are exact analytic counts for the
//! paper's CIFAR-scale architectures and drive the paper-scale simulations
//! (Figs 5–11) without executing those models.

use super::manifest::Manifest;

/// Cost of one cuttable layer (per data sample where applicable).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerCost {
    /// Layer name (e.g. `conv1`).
    pub name: String,
    /// Forward FLOPs per sample added by this layer.
    pub fwd_flops: f64,
    /// Backward FLOPs per sample added by this layer (~2x forward).
    pub bwd_flops: f64,
    /// Activation bytes per sample at this layer's output (psi_j = chi_j;
    /// activations and their gradients have identical f32 size).
    pub act_bytes: f64,
    /// Parameter bytes of this layer.
    pub param_bytes: f64,
    /// Trainable parameter count of this layer.
    pub n_params: usize,
}

/// A model as seen by the latency/convergence machinery.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    /// Profile name (`splitcnn8`, `vgg16`, `resnet18`).
    pub name: String,
    /// Per-layer cost rows, in execution order.
    pub layers: Vec<LayerCost>,
    /// Cut layers the system may choose (1-based; cut c => client keeps 1..=c).
    pub valid_cuts: Vec<usize>,
    // Precomputed cumulative tables (index 0 => 0.0, index j => sum 1..=j).
    rho_cum: Vec<f64>,
    varpi_cum: Vec<f64>,
    delta_cum: Vec<f64>,
    psi_cum: Vec<f64>,
}

impl ModelProfile {
    /// Build a profile and precompute its cumulative cost tables.
    pub fn new(name: &str, layers: Vec<LayerCost>, valid_cuts: Vec<usize>) -> Self {
        let l = layers.len();
        assert!(!layers.is_empty());
        for &c in &valid_cuts {
            assert!(c >= 1 && c < l, "cut {c} out of range 1..{l}");
        }
        let mut rho_cum = vec![0.0; l + 1];
        let mut varpi_cum = vec![0.0; l + 1];
        let mut delta_cum = vec![0.0; l + 1];
        let mut psi_cum = vec![0.0; l + 1];
        for (j, layer) in layers.iter().enumerate() {
            rho_cum[j + 1] = rho_cum[j] + layer.fwd_flops;
            varpi_cum[j + 1] = varpi_cum[j] + layer.bwd_flops;
            delta_cum[j + 1] = delta_cum[j] + layer.param_bytes;
            psi_cum[j + 1] = psi_cum[j] + layer.act_bytes;
        }
        ModelProfile { name: name.into(), layers, valid_cuts, rho_cum, varpi_cum, delta_cum, psi_cum }
    }

    /// Number of layers L.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// rho_j — cumulative forward FLOPs per sample of layers 1..=j.
    pub fn rho(&self, j: usize) -> f64 {
        self.rho_cum[j]
    }

    /// rho_L — full forward cost per sample.
    pub fn rho_total(&self) -> f64 {
        *self.rho_cum.last().unwrap()
    }

    /// varpi_j — cumulative backward FLOPs per sample of layers 1..=j.
    pub fn varpi(&self, j: usize) -> f64 {
        self.varpi_cum[j]
    }

    /// varpi_L — full backward cost per sample.
    pub fn varpi_total(&self) -> f64 {
        *self.varpi_cum.last().unwrap()
    }

    /// psi_j — activation bytes per sample at cut j.
    pub fn psi(&self, j: usize) -> f64 {
        assert!(j >= 1);
        self.layers[j - 1].act_bytes
    }

    /// chi_j — activation-gradient bytes per sample at cut j (== psi_j, f32).
    pub fn chi(&self, j: usize) -> f64 {
        self.psi(j)
    }

    /// delta_j — client-side sub-model bytes with cut j (cumulative params).
    pub fn delta(&self, j: usize) -> f64 {
        self.delta_cum[j]
    }

    /// delta_L — full model bytes.
    pub fn delta_total(&self) -> f64 {
        *self.delta_cum.last().unwrap()
    }

    /// psi~_j — cumulative activation bytes of layers 1..=j (memory C4).
    pub fn psi_tilde(&self, j: usize) -> f64 {
        self.psi_cum[j]
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.n_params).sum()
    }

    /// Client-side memory demand of (cut, batch) per constraint C4:
    /// b*(psi~_j + chi~_j) + theta~_j + delta_j, with SGD optimizer state
    /// theta~_j = 0.
    pub fn client_mem_bytes(&self, cut: usize, batch: u32) -> f64 {
        let b = batch as f64;
        b * (self.psi_tilde(cut) + self.psi_tilde(cut)) + self.delta(cut)
    }

    /// Build the SplitCNN-8 profile from the artifact manifest.
    pub fn from_manifest(m: &Manifest) -> Self {
        let layers = m
            .block_table
            .iter()
            .map(|r| LayerCost {
                name: r.name.clone(),
                fwd_flops: r.fwd_flops,
                bwd_flops: r.bwd_flops,
                act_bytes: r.act_bytes,
                param_bytes: r.param_bytes,
                n_params: r.n_params,
            })
            .collect();
        ModelProfile::new(&m.model, layers, m.valid_cuts.clone())
    }

    /// VGG-16 at 32x32 input (CIFAR variant: 13 convs + 3 FCs, 5 maxpools).
    pub fn vgg16() -> Self {
        let mut layers = Vec::new();
        // (cin, cout, spatial_in, pool_after)
        let convs: [(usize, usize, usize, bool); 13] = [
            (3, 64, 32, false),
            (64, 64, 32, true),
            (64, 128, 16, false),
            (128, 128, 16, true),
            (128, 256, 8, false),
            (256, 256, 8, false),
            (256, 256, 8, true),
            (256, 512, 4, false),
            (512, 512, 4, false),
            (512, 512, 4, true),
            (512, 512, 2, false),
            (512, 512, 2, false),
            (512, 512, 2, true),
        ];
        for (i, &(cin, cout, hw, pool)) in convs.iter().enumerate() {
            let macs = 9.0 * cin as f64 * cout as f64 * (hw * hw) as f64;
            let out_hw = if pool { hw / 2 } else { hw };
            let n = 9 * cin * cout + cout;
            layers.push(LayerCost {
                name: format!("conv{}", i + 1),
                fwd_flops: 2.0 * macs,
                bwd_flops: 4.0 * macs,
                act_bytes: 4.0 * (out_hw * out_hw * cout) as f64,
                param_bytes: 4.0 * n as f64,
                n_params: n,
            });
        }
        for (i, &(cin, cout)) in [(512usize, 512usize), (512, 512), (512, 10)].iter().enumerate() {
            let macs = (cin * cout) as f64;
            let n = cin * cout + cout;
            layers.push(LayerCost {
                name: format!("fc{}", i + 1),
                fwd_flops: 2.0 * macs,
                bwd_flops: 4.0 * macs,
                act_bytes: 4.0 * cout as f64,
                param_bytes: 4.0 * n as f64,
                n_params: n,
            });
        }
        let l = layers.len();
        ModelProfile::new("vgg16", layers, (1..l).collect())
    }

    /// ResNet-18 at 32x32 input (CIFAR variant: 3x3 stem, 8 basic blocks of
    /// 2 convs each, FC head — 17 convs + 1 FC). Stride-2 blocks fold their
    /// 1x1 downsample projection into the first conv unit of the block.
    pub fn resnet18() -> Self {
        let mut layers = Vec::new();
        let push_conv = |layers: &mut Vec<LayerCost>,
                         name: String,
                         cin: usize,
                         cout: usize,
                         hw_out: usize,
                         extra_macs: f64| {
            let macs = 9.0 * cin as f64 * cout as f64 * (hw_out * hw_out) as f64 + extra_macs;
            let n = 9 * cin * cout + cout;
            layers.push(LayerCost {
                name,
                fwd_flops: 2.0 * macs,
                bwd_flops: 4.0 * macs,
                act_bytes: 4.0 * (hw_out * hw_out * cout) as f64,
                param_bytes: 4.0 * n as f64,
                n_params: n,
            });
        };
        // Stem.
        push_conv(&mut layers, "conv1".into(), 3, 64, 32, 0.0);
        // (stage channels, spatial out, first-block-downsamples)
        let stages: [(usize, usize, bool); 4] =
            [(64, 32, false), (128, 16, true), (256, 8, true), (512, 4, true)];
        let mut cin = 64;
        let mut k = 1;
        for &(cout, hw, down) in &stages {
            for blk in 0..2 {
                let first_down = down && blk == 0;
                // Downsample 1x1 projection MACs folded into the first conv.
                let ds_macs = if first_down {
                    (cin * cout * hw * hw) as f64
                } else {
                    0.0
                };
                k += 1;
                push_conv(
                    &mut layers,
                    format!("conv{k}"),
                    if blk == 0 { cin } else { cout },
                    cout,
                    hw,
                    ds_macs,
                );
                k += 1;
                push_conv(&mut layers, format!("conv{k}"), cout, cout, hw, 0.0);
            }
            cin = cout;
        }
        // Global average pool folded into the FC unit.
        let (fin, fout) = (512usize, 10usize);
        let macs = (fin * fout) as f64;
        let n = fin * fout + fout;
        layers.push(LayerCost {
            name: "fc".into(),
            fwd_flops: 2.0 * macs,
            bwd_flops: 4.0 * macs,
            act_bytes: 4.0 * fout as f64,
            param_bytes: 4.0 * n as f64,
            n_params: n,
        });
        let l = layers.len();
        ModelProfile::new("resnet18", layers, (1..l).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_has_16_layers_and_plausible_size() {
        let p = ModelProfile::vgg16();
        assert_eq!(p.n_layers(), 16);
        // CIFAR VGG-16 is ~15M params (14.98M with 512-512-10 head).
        let n = p.n_params();
        assert!((14_000_000..16_000_000).contains(&n), "{n}");
    }

    #[test]
    fn resnet18_has_18_layers_and_plausible_size() {
        let p = ModelProfile::resnet18();
        assert_eq!(p.n_layers(), 18);
        let n = p.n_params();
        // CIFAR ResNet-18 is ~11.2M params.
        assert!((10_500_000..12_000_000).contains(&n), "{n}");
    }

    #[test]
    fn cumulative_tables_are_monotone() {
        for p in [ModelProfile::vgg16(), ModelProfile::resnet18()] {
            for j in 1..=p.n_layers() {
                assert!(p.rho(j) > p.rho(j - 1));
                assert!(p.varpi(j) > p.varpi(j - 1));
                assert!(p.delta(j) > p.delta(j - 1));
                assert!(p.psi_tilde(j) > p.psi_tilde(j - 1));
            }
        }
    }

    #[test]
    fn shallow_cuts_have_larger_activations_than_deep_cuts() {
        // The paper's key communication trade-off: early conv layers emit
        // larger activations than the bottleneck layers.
        let p = ModelProfile::vgg16();
        assert!(p.psi(1) > p.psi(13));
        assert!(p.psi(2) > p.psi(10));
    }

    #[test]
    fn bwd_is_twice_fwd() {
        let p = ModelProfile::vgg16();
        for j in 1..=p.n_layers() {
            let l = &p.layers[j - 1];
            assert!((l.bwd_flops - 2.0 * l.fwd_flops).abs() < 1e-6);
        }
    }

    #[test]
    fn client_mem_grows_with_batch_and_cut() {
        let p = ModelProfile::vgg16();
        assert!(p.client_mem_bytes(3, 16) > p.client_mem_bytes(3, 8));
        assert!(p.client_mem_bytes(5, 16) > p.client_mem_bytes(3, 16));
    }

    #[test]
    fn vgg16_full_forward_flops_order_of_magnitude() {
        // ~0.31 GFLOPs MAC*2 = ~0.63 GFLOPs fwd for CIFAR VGG-16.
        let p = ModelProfile::vgg16();
        let f = p.rho_total();
        assert!((4e8..9e8).contains(&f), "{f}");
    }
}
