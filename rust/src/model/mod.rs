//! Model substrate: artifact manifest, parameter store, and analytic
//! per-layer cost profiles (SplitCNN-8 / VGG-16 / ResNet-18).

pub mod manifest;
pub mod params;
pub mod profiles;

pub use manifest::{ArtifactEntry, BlockRow, Manifest, ParamShape, TensorSpec};
pub use params::{average_in_place, weighted_average_in_place, Params, Tensor};
pub use profiles::{LayerCost, ModelProfile};

use crate::config::ModelKind;

/// Resolve the profile for a configured model kind. `manifest` is required
/// for the executable SplitCNN-8 (its table is exported by the AOT step).
pub fn profile_for(kind: ModelKind, manifest: Option<&Manifest>) -> ModelProfile {
    match kind {
        ModelKind::Splitcnn8 => ModelProfile::from_manifest(
            manifest.expect("SplitCNN-8 profile requires the artifact manifest"),
        ),
        ModelKind::Vgg16 => ModelProfile::vgg16(),
        ModelKind::Resnet18 => ModelProfile::resnet18(),
    }
}
