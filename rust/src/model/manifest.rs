//! Artifact manifest: the contract between the Python AOT exporter and the
//! Rust runtime. Parses `python/compile/aot.py`'s `manifest.json` through
//! the in-repo JSON substrate.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::Json;

/// Tensor argument/output spec.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Argument/output name as exported by the AOT lowering.
    pub name: String,
    /// Row-major tensor shape.
    pub shape: Vec<usize>,
    /// Element dtype name (always `f32` for SplitCNN-8).
    pub dtype: String,
}

impl TensorSpec {
    /// Element count of the tensor (scalars count as 1).
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> crate::Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.req("name")?.as_str()?.to_string(),
            shape: j.req("shape")?.usize_vec()?,
            dtype: j.req("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One AOT artifact (a shape-specialised HLO module).
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// Canonical artifact name (see [`Manifest::split_name`]).
    pub name: String,
    /// HLO file path relative to the manifest directory.
    pub path: String,
    /// Input tensor specs, in call order.
    pub args: Vec<TensorSpec>,
    /// Output tensor specs, in return order.
    pub outputs: Vec<TensorSpec>,
    /// SHA-256 of the HLO text, for artifact integrity checks.
    pub sha256: String,
    /// Which model function this artifact implements (e.g. "client_fwd").
    pub func: String,
    /// Split point the artifact was specialised for (0 for monolithic).
    pub cut: usize,
    /// Batch bucket the artifact was specialised for.
    pub bucket: u32,
}

impl ArtifactEntry {
    fn from_json(j: &Json) -> crate::Result<ArtifactEntry> {
        Ok(ArtifactEntry {
            name: j.req("name")?.as_str()?.to_string(),
            path: j.req("path")?.as_str()?.to_string(),
            args: j
                .req("args")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<crate::Result<_>>()?,
            outputs: j
                .req("outputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<crate::Result<_>>()?,
            sha256: j.req("sha256")?.as_str()?.to_string(),
            func: j.req("fn")?.as_str()?.to_string(),
            cut: j.req("cut")?.as_usize()?,
            bucket: j.req("bucket")?.as_u32()?,
        })
    }
}

/// Per-block cost row (exported by `model.block_table`).
#[derive(Debug, Clone, PartialEq)]
pub struct BlockRow {
    /// Block name (e.g. `conv1`).
    pub name: String,
    /// Block kind (`conv` or `dense`).
    pub kind: String,
    /// Forward FLOPs per sample added by this block (rho_j increment).
    pub fwd_flops: f64,
    /// Backward FLOPs per sample added by this block (varpi_j increment).
    pub bwd_flops: f64,
    /// Activation bytes per sample at this block's output (psi_j == chi_j).
    pub act_bytes: f64,
    /// Parameter bytes of this block (delta_j increment).
    pub param_bytes: f64,
    /// Trainable parameter count of this block.
    pub n_params: usize,
}

impl BlockRow {
    fn from_json(j: &Json) -> crate::Result<BlockRow> {
        Ok(BlockRow {
            name: j.req("name")?.as_str()?.to_string(),
            kind: j.req("kind")?.as_str()?.to_string(),
            fwd_flops: j.req("fwd_flops")?.as_f64()?,
            bwd_flops: j.req("bwd_flops")?.as_f64()?,
            act_bytes: j.req("act_bytes")?.as_f64()?,
            param_bytes: j.req("param_bytes")?.as_f64()?,
            n_params: j.req("n_params")?.as_usize()?,
        })
    }
}

/// Parameter tensor shapes for one block.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamShape {
    /// Weight tensor shape.
    pub w: Vec<usize>,
    /// Bias tensor shape.
    pub b: Vec<usize>,
}

/// The full manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Model name (`splitcnn8`).
    pub model: String,
    /// Classifier output width.
    pub num_classes: usize,
    /// Input image side length in pixels.
    pub img: usize,
    /// Input channel count.
    pub in_ch: usize,
    /// Number of splittable blocks.
    pub num_blocks: usize,
    /// Cut points the exporter specialised artifacts for.
    pub valid_cuts: Vec<usize>,
    /// Batch buckets the exporter specialised artifacts for.
    pub buckets: Vec<u32>,
    /// Per-block parameter tensor shapes, in block order.
    pub param_shapes: Vec<ParamShape>,
    /// Per-block cost rows feeding the latency/convergence models.
    pub block_table: Vec<BlockRow>,
    /// Every exported artifact.
    pub artifacts: Vec<ArtifactEntry>,
    /// Directory the manifest was loaded from (artifact paths are
    /// relative to it).
    pub dir: PathBuf,
    pub(crate) index: HashMap<String, usize>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> crate::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            anyhow::anyhow!(
                "reading {}/manifest.json (run `make artifacts`): {e}",
                dir.display()
            )
        })?;
        let j = Json::parse(&text)?;
        let mut m = Manifest {
            model: j.req("model")?.as_str()?.to_string(),
            num_classes: j.req("num_classes")?.as_usize()?,
            img: j.req("img")?.as_usize()?,
            in_ch: j.req("in_ch")?.as_usize()?,
            num_blocks: j.req("num_blocks")?.as_usize()?,
            valid_cuts: j.req("valid_cuts")?.usize_vec()?,
            buckets: j.req("buckets")?.u32_vec()?,
            param_shapes: j
                .req("param_shapes")?
                .as_arr()?
                .iter()
                .map(|p| {
                    Ok(ParamShape {
                        w: p.req("w")?.usize_vec()?,
                        b: p.req("b")?.usize_vec()?,
                    })
                })
                .collect::<crate::Result<_>>()?,
            block_table: j
                .req("block_table")?
                .as_arr()?
                .iter()
                .map(BlockRow::from_json)
                .collect::<crate::Result<_>>()?,
            artifacts: j
                .req("artifacts")?
                .as_arr()?
                .iter()
                .map(ArtifactEntry::from_json)
                .collect::<crate::Result<_>>()?,
            dir: dir.to_path_buf(),
            index: HashMap::new(),
        };
        m.reindex();
        Ok(m)
    }

    /// Rebuild the name -> artifact index after mutating `artifacts`.
    pub fn reindex(&mut self) {
        self.index = self
            .artifacts
            .iter()
            .enumerate()
            .map(|(i, a)| (a.name.clone(), i))
            .collect();
    }

    /// Look up an artifact by canonical name.
    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.index.get(name).map(|&i| &self.artifacts[i])
    }

    /// Absolute path of a named artifact's HLO file, if present.
    pub fn artifact_path(&self, name: &str) -> Option<PathBuf> {
        self.get(name).map(|a| self.dir.join(&a.path))
    }

    /// Canonical artifact name for a split function.
    pub fn split_name(func: &str, cut: usize, bucket: u32) -> String {
        format!("{func}_c{cut}_b{bucket}")
    }

    /// Canonical artifact name for a monolithic function.
    pub fn full_name(func: &str, bucket: u32) -> String {
        format!("{func}_b{bucket}")
    }

    /// Smallest exported bucket that fits `batch`, if any.
    pub fn bucket_for(&self, batch: u32) -> Option<u32> {
        self.buckets.iter().copied().filter(|&b| b >= batch).min()
    }

    /// Largest exported bucket.
    pub fn max_bucket(&self) -> u32 {
        self.buckets.iter().copied().max().unwrap_or(1)
    }

    /// Total parameter tensors (2 per block: w, b).
    pub fn n_param_tensors(&self) -> usize {
        2 * self.num_blocks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest() -> Manifest {
        let mut m = Manifest {
            model: "splitcnn8".into(),
            num_classes: 10,
            img: 32,
            in_ch: 3,
            num_blocks: 8,
            valid_cuts: (1..8).collect(),
            buckets: vec![1, 2, 4, 8, 16, 32, 64],
            param_shapes: vec![],
            block_table: vec![],
            artifacts: vec![ArtifactEntry {
                name: "client_fwd_c3_b8".into(),
                path: "client_fwd_c3_b8.hlo.txt".into(),
                args: vec![TensorSpec {
                    name: "x".into(),
                    shape: vec![8, 32, 32, 3],
                    dtype: "f32".into(),
                }],
                outputs: vec![],
                sha256: "0".into(),
                func: "client_fwd".into(),
                cut: 3,
                bucket: 8,
            }],
            dir: PathBuf::new(),
            index: HashMap::new(),
        };
        m.reindex();
        m
    }

    #[test]
    fn bucket_for_rounds_up() {
        let m = toy_manifest();
        assert_eq!(m.bucket_for(1), Some(1));
        assert_eq!(m.bucket_for(3), Some(4));
        assert_eq!(m.bucket_for(33), Some(64));
        assert_eq!(m.bucket_for(64), Some(64));
        assert_eq!(m.bucket_for(65), None);
    }

    #[test]
    fn name_helpers() {
        assert_eq!(Manifest::split_name("client_fwd", 3, 8), "client_fwd_c3_b8");
        assert_eq!(Manifest::full_name("full_step", 16), "full_step_b16");
    }

    #[test]
    fn index_lookup() {
        let m = toy_manifest();
        assert!(m.get("client_fwd_c3_b8").is_some());
        assert!(m.get("nope").is_none());
        assert_eq!(m.get("client_fwd_c3_b8").unwrap().bucket, 8);
    }

    #[test]
    fn tensor_spec_numel() {
        let t = TensorSpec { name: "x".into(), shape: vec![2, 3, 4], dtype: "f32".into() };
        assert_eq!(t.numel(), 24);
        let s = TensorSpec { name: "loss".into(), shape: vec![], dtype: "f32".into() };
        assert_eq!(s.numel(), 1);
    }

    #[test]
    fn parse_manifest_json_fragment() {
        let text = r#"{
            "model": "splitcnn8", "num_classes": 10, "img": 32, "in_ch": 3,
            "num_blocks": 2, "valid_cuts": [1], "buckets": [4],
            "param_shapes": [{"w": [3, 4], "b": [4]}, {"w": [4, 2], "b": [2]}],
            "block_table": [
                {"name": "a", "kind": "dense", "fwd_flops": 24.0,
                 "bwd_flops": 48.0, "act_bytes": 16, "param_bytes": 64,
                 "n_params": 16},
                {"name": "b", "kind": "dense", "fwd_flops": 16.0,
                 "bwd_flops": 32.0, "act_bytes": 8, "param_bytes": 40,
                 "n_params": 10}
            ],
            "artifacts": [
                {"name": "full_fwd_b4", "path": "full_fwd_b4.hlo.txt",
                 "args": [{"name": "x", "shape": [4, 3], "dtype": "f32"}],
                 "outputs": [{"name": "y", "shape": [4, 2], "dtype": "f32"}],
                 "sha256": "abc", "fn": "full_fwd", "cut": 0, "bucket": 4}
            ]
        }"#;
        let dir = std::env::temp_dir().join("hasfl_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.num_blocks, 2);
        assert_eq!(m.param_shapes[0].w, vec![3, 4]);
        assert_eq!(m.block_table[1].n_params, 10);
        assert_eq!(m.get("full_fwd_b4").unwrap().func, "full_fwd");
    }
}
