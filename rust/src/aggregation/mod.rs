//! Model aggregation: the two synchronisation mechanisms of HASFL.
//!
//! 1. **Server-side common sub-model** (Eqn 4): the layers beyond the
//!    deepest cut `L_c` live on the edge server for *every* device and are
//!    averaged every round (zero communication cost — they are co-located).
//! 2. **Forged client-specific models** (Eqn 7, steps b1–b3): layers
//!    `1..=L_c` — each device's client-side sub-model concatenated with its
//!    server-side *non-common* part — are averaged on the fed server every
//!    `I` rounds.

use crate::latency::Decisions;
use crate::model::{average_in_place, weighted_average_in_place, Params};

/// Average the server-side common sub-model across devices (every round).
///
/// Common region: blocks `L_c..L` (0-based blocks, i.e. tensor indices
/// `2*L_c..2*L`). Because the paper's Eqn 4 averages *updated* sub-models
/// and all devices start each round synchronized, averaging parameters is
/// identical to averaging gradients.
pub fn aggregate_common(params: &mut [Params], dec: &Decisions) {
    if params.is_empty() {
        return;
    }
    let l = params[0].n_blocks;
    let l_c = dec.l_c().min(l);
    average_in_place(params, Params::block_range(l_c, l));
}

/// Average the forged client-specific models across devices (every I
/// rounds): blocks `0..L_c`. Combined with the per-round common
/// aggregation, the post-aggregation state has every device holding the
/// same global model.
pub fn aggregate_forged(params: &mut [Params], dec: &Decisions) {
    if params.is_empty() {
        return;
    }
    let l = params[0].n_blocks;
    let l_c = dec.l_c().min(l);
    average_in_place(params, Params::block_range(0, l_c));
}

/// Partial-participation variant of [`aggregate_common`] for dynamic
/// fleets: only this round's surviving participants contribute, weighted
/// by the samples they processed (the Eqn-39 aggregation event exchanges
/// exactly these sub-models). Every device — dropped and offline members
/// included — receives the aggregate, which keeps the common region
/// fleet-identical (the runtime's `COMMON_SET` cache invariant).
pub fn aggregate_common_partial(
    params: &mut [Params],
    dec: &Decisions,
    participants: &[usize],
    weights: &[f64],
) {
    if params.is_empty() {
        return;
    }
    let l = params[0].n_blocks;
    let l_c = dec.l_c().min(l);
    weighted_average_in_place(params, Params::block_range(l_c, l), participants, weights);
}

/// Partial-participation variant of [`aggregate_forged`]: the forged
/// client-specific models of the surviving participants are averaged with
/// Eqn-39 sample weights and broadcast to the whole roster, so rejoining
/// devices resume from the current global model.
pub fn aggregate_forged_partial(
    params: &mut [Params],
    dec: &Decisions,
    participants: &[usize],
    weights: &[f64],
) {
    if params.is_empty() {
        return;
    }
    let l = params[0].n_blocks;
    let l_c = dec.l_c().min(l);
    weighted_average_in_place(params, Params::block_range(0, l_c), participants, weights);
}

/// One cell's contribution to a round under hierarchical aggregation
/// (DESIGN.md §15): the participants of a contiguous device-id range with
/// their Eqn-39 sample weights and per-participant round statistics.
///
/// The per-participant `losses`/`corrects`/`batches` stay vectors rather
/// than pre-summed scalars on purpose: f64 addition is not associative,
/// so the root must form the global sums in exactly the flat path's
/// ascending-id order. Keeping the terms lets
/// [`merge_cell_aggregates`] reproduce that order bit-for-bit instead of
/// re-associating per-cell partial sums.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CellAggregate {
    /// Cell index (position in the topology's fixed cell order).
    pub cell: usize,
    /// Ascending ids of the cell's devices that completed the round.
    pub participants: Vec<usize>,
    /// Eqn-39 sample weights, aligned with `participants`.
    pub weights: Vec<f64>,
    /// Per-participant training loss, aligned with `participants`.
    pub losses: Vec<f64>,
    /// Per-participant correct-prediction count, aligned.
    pub corrects: Vec<f64>,
    /// Per-participant processed sample count, aligned.
    pub batches: Vec<u32>,
}

/// Root-side merge of a round's cell aggregates: the global participant
/// roster, Eqn-39 weights, and round-statistic sums, in canonical
/// (globally ascending) order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MergedRound {
    /// Ascending ids of every device that completed the round.
    pub participants: Vec<usize>,
    /// Eqn-39 sample weights, aligned with `participants`.
    pub weights: Vec<f64>,
    /// Sum of per-participant losses, accumulated in ascending-id order.
    pub loss_sum: f64,
    /// Sum of per-participant correct counts, ascending-id order.
    pub correct_sum: f64,
    /// Total samples processed by the round.
    pub batch_sum: u32,
}

/// Merge cell aggregates in the given (fixed) cell order.
///
/// Merge-order contract: cells hold contiguous ascending id ranges, so
/// concatenating their participant lists in cell order *is* the global
/// ascending order — the list the flat path builds directly. The f64
/// statistic sums run left-to-right over the concatenation, making the
/// merged result bit-identical to the flat path at any cell count
/// (`cells = 1` trivially, `cells = N` by contiguity), and the merge
/// associative: merging merges of sub-sequences equals merging the
/// flattened sequence. Empty cells (no participants, or no devices at
/// all) contribute nothing and are handled uniformly.
pub fn merge_cell_aggregates(cells: &[CellAggregate]) -> MergedRound {
    let n: usize = cells.iter().map(|c| c.participants.len()).sum();
    let mut out = MergedRound {
        participants: Vec::with_capacity(n),
        weights: Vec::with_capacity(n),
        loss_sum: 0.0,
        correct_sum: 0.0,
        batch_sum: 0,
    };
    for cell in cells {
        debug_assert!(
            cell.participants.windows(2).all(|w| w[0] < w[1]),
            "cell {} participants not ascending",
            cell.cell
        );
        debug_assert!(
            cell.participants
                .first()
                .zip(out.participants.last())
                .map_or(true, |(first, last)| last < first),
            "cell {} overlaps an earlier cell's id range",
            cell.cell
        );
        out.participants.extend_from_slice(&cell.participants);
        out.weights.extend_from_slice(&cell.weights);
        for &l in &cell.losses {
            out.loss_sum += l;
        }
        for &c in &cell.corrects {
            out.correct_sum += c;
        }
        for &b in &cell.batches {
            out.batch_sum += b;
        }
    }
    out
}

/// Fold polynomial staleness decay into a round's Eqn-39 sample weights
/// (buffered-asynchronous aggregation, DESIGN.md §16): each participant's
/// weight is scaled by `(1 + lag)^-decay`, where `lag` is the number of
/// buffer flushes applied since the participant's base model was
/// dispatched. `weights` and `lags` are aligned per participant; fresh
/// updates (`lag == 0`) keep their weight exactly. The scaled weights
/// feed [`aggregate_common_partial`]/[`aggregate_forged_partial`]
/// unchanged — those normalise by the weight sum, so the decay shifts
/// relative influence toward fresh updates rather than shrinking the
/// aggregate.
pub fn staleness_decayed_weights(weights: &[f64], lags: &[u64], decay: f64) -> Vec<f64> {
    assert_eq!(weights.len(), lags.len(), "weights and lags must align per participant");
    weights
        .iter()
        .zip(lags)
        .map(|(&w, &lag)| w * crate::asynch::staleness_weight(lag, decay))
        .collect()
}

/// Global model = average of every device's full model (used for
/// evaluation; matches the paper's analysis object w^t = mean_i w_i^t).
///
/// Single accumulate-then-scale pass over flat slices: start from a copy of
/// the first set, add the rest element-wise, then multiply once by 1/n —
/// one divide per *model* instead of the historical one divide per
/// (element × device). Agreement with the old per-element `/ n`
/// formulation is covered by a tolerance test below.
pub fn global_average(params: &[Params]) -> Params {
    assert!(!params.is_empty());
    let mut out = params[0].clone();
    out.version = 0;
    for p in &params[1..] {
        for (o, t) in out.tensors.iter_mut().zip(&p.tensors) {
            for (ov, &tv) in o.data.iter_mut().zip(&t.data) {
                *ov += tv;
            }
        }
    }
    let inv = 1.0 / params.len() as f32;
    for t in out.tensors.iter_mut() {
        for v in &mut t.data {
            *v *= inv;
        }
    }
    out
}

/// Max absolute divergence between two parameter sets over a block range
/// (test/diagnostic helper).
pub fn divergence(a: &Params, b: &Params, range: std::ops::Range<usize>) -> f32 {
    let mut worst = 0.0f32;
    for ti in range {
        for (&x, &y) in a.tensors[ti].data.iter().zip(&b.tensors[ti].data) {
            worst = worst.max((x - y).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Tensor;

    fn params_with(v: f32, n_blocks: usize) -> Params {
        Params {
            tensors: (0..2 * n_blocks)
                .map(|_| Tensor { shape: vec![2], data: vec![v, v] })
                .collect(),
            n_blocks,
            version: 0,
        }
    }

    #[test]
    fn common_aggregation_touches_only_deep_blocks() {
        let mut params = vec![params_with(0.0, 4), params_with(2.0, 4)];
        let dec = Decisions { batch: vec![8, 8], cut: vec![2, 2] };
        aggregate_common(&mut params, &dec);
        // blocks 2..4 averaged to 1.0
        assert_eq!(params[0].tensors[4].data, vec![1.0, 1.0]);
        assert_eq!(params[1].tensors[7].data, vec![1.0, 1.0]);
        // blocks 0..2 untouched
        assert_eq!(params[0].tensors[0].data, vec![0.0, 0.0]);
        assert_eq!(params[1].tensors[3].data, vec![2.0, 2.0]);
    }

    #[test]
    fn forged_aggregation_touches_only_shallow_blocks() {
        let mut params = vec![params_with(0.0, 4), params_with(2.0, 4)];
        let dec = Decisions { batch: vec![8, 8], cut: vec![1, 3] }; // L_c = 3
        aggregate_forged(&mut params, &dec);
        assert_eq!(params[0].tensors[0].data, vec![1.0, 1.0]);
        assert_eq!(params[0].tensors[5].data, vec![1.0, 1.0]);
        // block 3 (common) untouched
        assert_eq!(params[0].tensors[6].data, vec![0.0, 0.0]);
    }

    #[test]
    fn common_plus_forged_fully_synchronises() {
        let mut params = vec![params_with(0.0, 4), params_with(2.0, 4)];
        let dec = Decisions { batch: vec![8, 8], cut: vec![2, 3] };
        aggregate_common(&mut params, &dec);
        aggregate_forged(&mut params, &dec);
        assert_eq!(divergence(&params[0], &params[1], 0..8), 0.0);
    }

    #[test]
    fn staleness_decay_scales_weights_per_lag() {
        let weights = vec![8.0, 16.0, 4.0];
        let scaled = staleness_decayed_weights(&weights, &[0, 1, 3], 1.0);
        // lag 0 keeps its weight exactly; lag k shrinks by (1 + k)^-1.
        assert_eq!(scaled[0], 8.0);
        assert!((scaled[1] - 8.0).abs() < 1e-12);
        assert!((scaled[2] - 1.0).abs() < 1e-12);
        // decay 0 is the synchronous identity at any lag.
        assert_eq!(staleness_decayed_weights(&weights, &[0, 5, 9], 0.0), weights);
    }

    #[test]
    fn partial_aggregation_syncs_the_whole_roster() {
        // Device 2 dropped mid-round: it contributes nothing, but both
        // aggregation halves still leave the fleet fully synchronised.
        let mut params =
            vec![params_with(1.0, 4), params_with(3.0, 4), params_with(9.0, 4)];
        let dec = Decisions { batch: vec![8, 16, 8], cut: vec![2, 2, 2] };
        let (participants, weights) = (vec![0, 1], vec![8.0, 16.0]);
        aggregate_common_partial(&mut params, &dec, &participants, &weights);
        aggregate_forged_partial(&mut params, &dec, &participants, &weights);
        // Weighted mean of 1.0 (w=8) and 3.0 (w=16): 7/3.
        let want = (8.0 * 1.0 + 16.0 * 3.0) as f32 / 24.0;
        for p in &params {
            for t in &p.tensors {
                for &v in &t.data {
                    assert!((v - want).abs() < 1e-6, "{v} vs {want}");
                }
            }
        }
        assert_eq!(divergence(&params[0], &params[2], 0..8), 0.0);
    }

    #[test]
    fn global_average_is_mean() {
        let params = vec![params_with(1.0, 2), params_with(3.0, 2)];
        let g = global_average(&params);
        for t in &g.tensors {
            assert_eq!(t.data, vec![2.0, 2.0]);
        }
    }

    #[test]
    fn global_average_matches_per_element_divide_formulation() {
        // Bit-equivalence tolerance check: accumulate-then-scale vs the old
        // `sum of (v / n)` loop. The two round differently, but must agree
        // to float tolerance on realistic magnitudes.
        let mut rng = crate::rng::Pcg32::seeded(77);
        let n_blocks = 3;
        let sets: Vec<Params> = (0..5)
            .map(|_| Params {
                tensors: (0..2 * n_blocks)
                    .map(|_| Tensor {
                        shape: vec![17],
                        data: (0..17).map(|_| rng.normal() as f32).collect(),
                    })
                    .collect(),
                n_blocks,
                version: 0,
            })
            .collect();

        // Old formulation, inlined as the reference.
        let mut want = sets[0].zeros_like();
        let n = sets.len() as f32;
        for p in &sets {
            for (o, t) in want.tensors.iter_mut().zip(&p.tensors) {
                for (ov, &tv) in o.data.iter_mut().zip(&t.data) {
                    *ov += tv / n;
                }
            }
        }

        let got = global_average(&sets);
        for (g, w) in got.tensors.iter().zip(&want.tensors) {
            for (&a, &b) in g.data.iter().zip(&w.data) {
                assert!((a - b).abs() <= 1e-6 + 1e-6 * b.abs(), "{a} vs {b}");
            }
        }
    }

    fn cell(id: usize, participants: Vec<usize>, weights: Vec<f64>) -> CellAggregate {
        let n = participants.len();
        CellAggregate {
            cell: id,
            participants,
            weights,
            losses: (0..n).map(|k| 0.1 + k as f64).collect(),
            corrects: vec![1.0; n],
            batches: vec![4; n],
        }
    }

    #[test]
    fn merge_concatenates_in_cell_order() {
        let cells = [cell(0, vec![0, 2], vec![8.0, 4.0]), cell(1, vec![3, 5], vec![2.0, 6.0])];
        let m = merge_cell_aggregates(&cells);
        assert_eq!(m.participants, vec![0, 2, 3, 5]);
        assert_eq!(m.weights, vec![8.0, 4.0, 2.0, 6.0]);
        assert_eq!(m.batch_sum, 16);
        // Left-to-right over the concatenation: bit-identical to the flat
        // path's ascending-id sum.
        let want = ((0.1 + 1.1) + 0.1) + 1.1;
        assert_eq!(m.loss_sum.to_bits(), want.to_bits());
    }

    #[test]
    fn merge_handles_empty_and_single_device_cells() {
        // An entirely-empty cell (no devices), a cell whose every device
        // sat the round out (all quarantined/abandoned), and single-device
        // cells — the shard path's edge shapes.
        let cells = [
            cell(0, vec![], vec![]),       // cell exists, zero devices
            cell(1, vec![1], vec![8.0]),   // single-device cell
            cell(2, vec![], vec![]),       // every device quarantined
            cell(3, vec![7], vec![16.0]),  // single-device cell
        ];
        let m = merge_cell_aggregates(&cells);
        assert_eq!(m.participants, vec![1, 7]);
        assert_eq!(m.weights, vec![8.0, 16.0]);
        assert_eq!(m.batch_sum, 8);

        // All cells empty = the explicitly empty round.
        let none = merge_cell_aggregates(&[cell(0, vec![], vec![]), cell(1, vec![], vec![])]);
        assert!(none.participants.is_empty());
        assert_eq!(none.batch_sum, 0);
    }

    #[test]
    fn merge_is_associative_over_cell_groups() {
        // Merging merges of sub-sequences equals merging the flattened
        // sequence (the root may combine cells in fixed-order groups).
        let a = cell(0, vec![0], vec![3.0]);
        let b = cell(1, vec![2, 3], vec![5.0, 7.0]);
        let c = cell(2, vec![4], vec![9.0]);
        let flat = merge_cell_aggregates(&[a.clone(), b.clone(), c.clone()]);
        let left = merge_cell_aggregates(&[a.clone(), b.clone()]);
        let grouped = CellAggregate {
            cell: 0,
            participants: left.participants,
            weights: left.weights,
            losses: a.losses.iter().chain(&b.losses).copied().collect(),
            corrects: a.corrects.iter().chain(&b.corrects).copied().collect(),
            batches: a.batches.iter().chain(&b.batches).copied().collect(),
        };
        let two_level = merge_cell_aggregates(&[grouped, c]);
        assert_eq!(two_level.participants, flat.participants);
        assert_eq!(two_level.weights, flat.weights);
        assert_eq!(two_level.loss_sum.to_bits(), flat.loss_sum.to_bits());
        assert_eq!(two_level.correct_sum.to_bits(), flat.correct_sum.to_bits());
    }

    #[test]
    fn merged_partial_aggregation_is_bitwise_flat() {
        // The tentpole contract end-to-end at the aggregation layer: the
        // participant/weight lists a cell merge produces drive
        // aggregate_{common,forged}_partial to parameters bit-for-bit
        // equal to the flat path's, including empty, all-quarantined, and
        // single-device cells.
        let mut rng = crate::rng::Pcg32::seeded(99);
        let build = |rng: &mut crate::rng::Pcg32| -> Vec<Params> {
            (0..6)
                .map(|_| Params {
                    tensors: (0..8)
                        .map(|_| Tensor {
                            shape: vec![3],
                            data: (0..3).map(|_| rng.normal() as f32).collect(),
                        })
                        .collect(),
                    n_blocks: 4,
                    version: 0,
                })
                .collect()
        };
        let fleet = build(&mut rng);
        let dec = Decisions { batch: vec![8; 6], cut: vec![2; 6] };

        // Flat path: participants 1, 2, 4 (0 abandoned, 3 quarantined, 5
        // dropped), ascending, with their sample weights.
        let mut flat = fleet.clone();
        let (fp, fw) = (vec![1, 2, 4], vec![8.0, 6.0, 8.0]);
        aggregate_common_partial(&mut flat, &dec, &fp, &fw);
        aggregate_forged_partial(&mut flat, &dec, &fp, &fw);

        // Sharded path: cells [0..2], [2..3], [3..5], [5..6] — a
        // one-participant cell, a single-device cell, an all-quarantined
        // survivor-free cell, and an empty-participation cell.
        let cells = [
            cell(0, vec![1], vec![8.0]),
            cell(1, vec![2], vec![6.0]),
            cell(2, vec![4], vec![8.0]),
            cell(3, vec![], vec![]),
        ];
        let merged = merge_cell_aggregates(&cells);
        let mut sharded = fleet.clone();
        aggregate_common_partial(&mut sharded, &dec, &merged.participants, &merged.weights);
        aggregate_forged_partial(&mut sharded, &dec, &merged.participants, &merged.weights);

        for (a, b) in flat.iter().zip(&sharded) {
            for (ta, tb) in a.tensors.iter().zip(&b.tensors) {
                for (&x, &y) in ta.data.iter().zip(&tb.data) {
                    assert_eq!(x.to_bits(), y.to_bits(), "merged path diverged bitwise");
                }
            }
        }
    }

    #[test]
    fn heterogeneous_cuts_use_max_depth() {
        // L_c = max cut: forged region must cover the deepest client part.
        let dec = Decisions { batch: vec![1, 1, 1], cut: vec![1, 5, 3] };
        assert_eq!(dec.l_c(), 5);
    }
}
