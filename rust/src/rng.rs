//! Deterministic PRNG substrate (PCG-32 + distributions).
//!
//! All stochasticity in the coordinator — fleet sampling, data partitioning,
//! mini-batch sampling, random baselines — flows through this module so that
//! every experiment is exactly reproducible from a single seed. We implement
//! PCG-XSH-RR 64/32 (O'Neill 2014) rather than pulling in `rand` to keep the
//! runtime dependency surface minimal and the stream stable across versions.

/// PCG-XSH-RR 64/32 generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Raw generator state `(state, inc)` for checkpointing. Feeding the
    /// pair back through [`Pcg32::from_state_parts`] reproduces the stream
    /// exactly (no seeding draws happen on restore).
    pub fn state_parts(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from raw checkpointed state — the exact inverse
    /// of [`Pcg32::state_parts`].
    pub fn from_state_parts(state: u64, inc: u64) -> Pcg32 {
        Pcg32 { state, inc }
    }

    /// Derive an independent child generator (for per-device streams).
    pub fn fork(&mut self, stream: u64) -> Pcg32 {
        let seed = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        Pcg32::new(seed, stream)
    }

    /// Next raw 32-bit draw (the PCG-XSH-RR output function).
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit draw (two 32-bit draws, high word first).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's method, unbiased enough for
    /// simulation; exact rejection for small n).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0);
        // rejection sampling to remove modulo bias
        let zone = u32::MAX - (u32::MAX % n);
        loop {
            let v = self.next_u32();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn int_range(&mut self, lo: u32, hi: u32) -> u32 {
        assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.len() < 2 {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u32) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_roundtrip_resumes_the_stream() {
        let mut a = Pcg32::new(7, 3);
        for _ in 0..17 {
            a.next_u32();
        }
        let (state, inc) = a.state_parts();
        let mut b = Pcg32::from_state_parts(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42, 7);
        let mut b = Pcg32::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Pcg32::seeded(1);
        for _ in 0..1000 {
            let v = r.uniform(1.0, 2.0);
            assert!((1.0..2.0).contains(&v));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..500 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(5);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg32::seeded(11);
        let idx = r.sample_indices(100, 10);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
    }
}
