//! `hasfl-figures` — regenerate every table and figure of the paper's
//! evaluation section (see DESIGN.md §6 for the experiment index).
//!
//! ```text
//! hasfl-figures <table1|fig2|fig3|fig5|fig7|fig8|fig9|fig10|fig11|analytic|all>
//!               [--out-dir results] [--artifacts artifacts]
//!               [--rounds N] [--devices N] [--seed S]
//! ```

use std::path::PathBuf;

use hasfl::figures::{self, FigureOpts};
use hasfl::util::Args;

fn main() -> hasfl::Result<()> {
    let args = Args::from_env()?;
    let opts = FigureOpts {
        out_dir: PathBuf::from(args.get("out-dir").unwrap_or("results")),
        artifacts: PathBuf::from(args.get("artifacts").unwrap_or("artifacts")),
        rounds: args.get_opt::<usize>("rounds")?,
        devices: args.get_opt::<usize>("devices")?,
        seed: args.get_or("seed", 2025u64)?,
    };
    std::fs::create_dir_all(&opts.out_dir)?;

    let run = |name: &str, f: &dyn Fn(&FigureOpts) -> hasfl::Result<()>| -> hasfl::Result<()> {
        let t0 = std::time::Instant::now();
        eprintln!("[figures] {name} ...");
        f(&opts)?;
        eprintln!("[figures] {name} done in {:.1}s", t0.elapsed().as_secs_f64());
        Ok(())
    };

    match args.subcommand.as_deref() {
        Some("table1") => run("table1", &figures::table1)?,
        Some("fig2") => run("fig2", &figures::fig2)?,
        Some("fig3") => run("fig3", &figures::fig3)?,
        Some("fig5") | Some("fig6") => run("fig5+6", &figures::fig56)?,
        Some("fig7") => run("fig7", &figures::fig7)?,
        Some("fig8") => run("fig8", &figures::fig8)?,
        Some("fig9") => run("fig9", &figures::fig9)?,
        Some("fig10") => run("fig10", &figures::fig10)?,
        Some("fig11") => run("fig11", &figures::fig11)?,
        Some("analytic") => {
            run("table1", &figures::table1)?;
            run("fig7", &figures::fig7)?;
            run("fig8", &figures::fig8)?;
            run("fig9", &figures::fig9)?;
        }
        Some("all") => {
            run("table1", &figures::table1)?;
            run("fig2", &figures::fig2)?;
            run("fig3", &figures::fig3)?;
            run("fig5+6", &figures::fig56)?;
            run("fig7", &figures::fig7)?;
            run("fig8", &figures::fig8)?;
            run("fig9", &figures::fig9)?;
            run("fig10", &figures::fig10)?;
            run("fig11", &figures::fig11)?;
        }
        other => {
            eprintln!(
                "usage: hasfl-figures <table1|fig2|fig3|fig5|fig7|fig8|fig9|fig10|fig11|analytic|all> (got {other:?})"
            );
            std::process::exit(2);
        }
    }
    Ok(())
}
