//! The HASFL latency model — a faithful implementation of §V-A
//! (Eqns 28–40) of the paper.
//!
//! All quantities are per *training round* (split training, Eqn 38) or per
//! *aggregation event* (client-side model aggregation, Eqn 39). Rates are
//! bits/s, sizes bytes (converted with x8), compute FLOPS.

use crate::config::{Device, Server};
use crate::model::ModelProfile;

/// Per-device decisions for one round.
#[derive(Debug, Clone, PartialEq)]
pub struct Decisions {
    /// Batch size b_i per device.
    pub batch: Vec<u32>,
    /// Cut layer c_i per device (1-based; client keeps layers 1..=c_i).
    pub cut: Vec<usize>,
}

impl Decisions {
    /// The same `(batch, cut)` for all `n` devices.
    pub fn uniform(n: usize, batch: u32, cut: usize) -> Decisions {
        Decisions { batch: vec![batch; n], cut: vec![cut; n] }
    }

    /// Number of devices the decisions cover.
    pub fn n(&self) -> usize {
        debug_assert_eq!(self.batch.len(), self.cut.len());
        self.batch.len()
    }

    /// L_c — the maximum client-specific depth across devices (§IV).
    ///
    /// Empty fleets have no L_c; they are rejected up front at the
    /// `Scenario`/`Config` validation layer (`ExperimentBuilder` and
    /// `Scenario::validate`), so reaching here with zero devices is a
    /// caller bug, not a user-input condition.
    pub fn l_c(&self) -> usize {
        debug_assert!(
            !self.cut.is_empty(),
            "L_c of an empty fleet (empty fleets are rejected at config/scenario validation)"
        );
        self.cut.iter().copied().max().unwrap_or(0)
    }
}

/// Per-device latency breakdown for one split-training round.
#[derive(Debug, Clone, Default)]
pub struct DeviceLatency {
    /// T_i^F — client-side forward propagation (Eqn 28).
    pub client_fwd: f64,
    /// T_{a,i}^U — activation uploading (Eqn 29).
    pub act_up: f64,
    /// T_{g,i}^D — activations'-gradient downloading (Eqn 32).
    pub grad_down: f64,
    /// T_i^B — client-side backward pass (Eqn 33).
    pub client_bwd: f64,
}

/// Full latency breakdown of one round (+ aggregation stage).
#[derive(Debug, Clone)]
pub struct RoundLatency {
    /// Per-device client-side breakdowns.
    pub per_device: Vec<DeviceLatency>,
    /// T_s^F — server-side forward (Eqn 30).
    pub server_fwd: f64,
    /// T_s^B — server-side backward (Eqn 31).
    pub server_bwd: f64,
    /// T_S — split-training round latency (Eqn 38).
    pub t_split: f64,
    /// T_A — client-side model aggregation latency (Eqn 39).
    pub t_agg: f64,
}

/// Bits in a byte payload.
#[inline]
fn bits(bytes: f64) -> f64 {
    8.0 * bytes
}

/// Eqn 28: T_i^F = b_i * rho_{c_i} / f_i.
pub fn client_fwd_latency(p: &ModelProfile, d: &Device, b: u32, cut: usize) -> f64 {
    b as f64 * p.rho(cut) / d.flops
}

/// Eqn 29: T_{a,i}^U = b_i * psi_{c_i} / r_i^U.
pub fn act_upload_latency(p: &ModelProfile, d: &Device, b: u32, cut: usize) -> f64 {
    b as f64 * bits(p.psi(cut)) / d.up_bps
}

/// Eqn 30: T_s^F = sum_i b_i (rho_L - rho_{c_i}) / f_s.
pub fn server_fwd_latency(p: &ModelProfile, s: &Server, dec: &Decisions) -> f64 {
    let flops: f64 = dec
        .batch
        .iter()
        .zip(&dec.cut)
        .map(|(&b, &c)| b as f64 * (p.rho_total() - p.rho(c)))
        .sum();
    flops / s.flops
}

/// Eqn 31: T_s^B = sum_i b_i (varpi_L - varpi_{c_i}) / f_s.
pub fn server_bwd_latency(p: &ModelProfile, s: &Server, dec: &Decisions) -> f64 {
    let flops: f64 = dec
        .batch
        .iter()
        .zip(&dec.cut)
        .map(|(&b, &c)| b as f64 * (p.varpi_total() - p.varpi(c)))
        .sum();
    flops / s.flops
}

/// Eqn 32: T_{g,i}^D = b_i * chi_{c_i} / r_i^D.
pub fn grad_download_latency(p: &ModelProfile, d: &Device, b: u32, cut: usize) -> f64 {
    b as f64 * bits(p.chi(cut)) / d.down_bps
}

/// Eqn 33: T_i^B = b_i * varpi_{c_i} / f_i.
pub fn client_bwd_latency(p: &ModelProfile, d: &Device, b: u32, cut: usize) -> f64 {
    b as f64 * p.varpi(cut) / d.flops
}

/// Eqn 34: T_{c,i}^U = delta_{c_i} / r_{i,f}^U.
pub fn submodel_upload_latency(p: &ModelProfile, d: &Device, cut: usize) -> f64 {
    bits(p.delta(cut)) / d.fed_up_bps
}

/// Lambda_s (in bytes): N * max_i delta_{c_i} - sum_i delta_{c_i} — the
/// server-side non-common sub-models exchanged with the fed server.
pub fn noncommon_bytes(p: &ModelProfile, dec: &Decisions) -> f64 {
    let max_delta = dec.cut.iter().map(|&c| p.delta(c)).fold(0.0, f64::max);
    let sum_delta: f64 = dec.cut.iter().map(|&c| p.delta(c)).sum();
    dec.n() as f64 * max_delta - sum_delta
}

/// Eqn 35: T_s^U = Lambda_s / r_{s,f}.
pub fn server_upload_latency(p: &ModelProfile, s: &Server, dec: &Decisions) -> f64 {
    bits(noncommon_bytes(p, dec)) / s.to_fed_bps
}

/// Eqn 36: T_{c,i}^D = delta_{c_i} / r_{i,f}^D.
pub fn submodel_download_latency(p: &ModelProfile, d: &Device, cut: usize) -> f64 {
    bits(p.delta(cut)) / d.fed_down_bps
}

/// Eqn 37: T_s^D = Lambda_s / r_{f,s}.
pub fn server_download_latency(p: &ModelProfile, s: &Server, dec: &Decisions) -> f64 {
    bits(noncommon_bytes(p, dec)) / s.from_fed_bps
}

/// Compute the full round latency breakdown (Eqns 38–39).
pub fn round_latency(
    p: &ModelProfile,
    devices: &[Device],
    server: &Server,
    dec: &Decisions,
) -> RoundLatency {
    assert_eq!(devices.len(), dec.n());
    let per_device: Vec<DeviceLatency> = devices
        .iter()
        .zip(dec.batch.iter().zip(&dec.cut))
        .map(|(d, (&b, &c))| DeviceLatency {
            client_fwd: client_fwd_latency(p, d, b, c),
            act_up: act_upload_latency(p, d, b, c),
            grad_down: grad_download_latency(p, d, b, c),
            client_bwd: client_bwd_latency(p, d, b, c),
        })
        .collect();
    let server_fwd = server_fwd_latency(p, server, dec);
    let server_bwd = server_bwd_latency(p, server, dec);

    // Eqn 38: T_S = max_i{T_i^F + T_{a,i}^U} + T_s^F + T_s^B
    //             + max_i{T_{g,i}^D + T_i^B}.
    let up_phase = per_device
        .iter()
        .map(|l| l.client_fwd + l.act_up)
        .fold(0.0, f64::max);
    let down_phase = per_device
        .iter()
        .map(|l| l.grad_down + l.client_bwd)
        .fold(0.0, f64::max);
    let t_split = up_phase + server_fwd + server_bwd + down_phase;

    // Eqn 39: T_A = max{max_i T_{c,i}^U, T_s^U} + max{max_i T_{c,i}^D, T_s^D}.
    let up_agg = devices
        .iter()
        .zip(&dec.cut)
        .map(|(d, &c)| submodel_upload_latency(p, d, c))
        .fold(server_upload_latency(p, server, dec), f64::max);
    let down_agg = devices
        .iter()
        .zip(&dec.cut)
        .map(|(d, &c)| submodel_download_latency(p, d, c))
        .fold(server_download_latency(p, server, dec), f64::max);
    let t_agg = up_agg + down_agg;

    RoundLatency { per_device, server_fwd, server_bwd, t_split, t_agg }
}

/// [`round_latency`] over the masked subset of the fleet: devices with
/// `mask[i] == false` (offline members, mid-round dropouts) contribute to
/// no phase maximum and no server-side sum. Used by dynamic-fleet rounds
/// where only the surviving participants gate the round (the server
/// proceeds with the activations it received).
pub fn round_latency_subset(
    p: &ModelProfile,
    devices: &[Device],
    server: &Server,
    dec: &Decisions,
    mask: &[bool],
) -> RoundLatency {
    assert_eq!(devices.len(), mask.len());
    assert_eq!(devices.len(), dec.n());
    let idx: Vec<usize> = mask
        .iter()
        .enumerate()
        .filter_map(|(i, &m)| m.then_some(i))
        .collect();
    let sub_devices: Vec<Device> = idx.iter().map(|&i| devices[i].clone()).collect();
    let sub_dec = Decisions {
        batch: idx.iter().map(|&i| dec.batch[i]).collect(),
        cut: idx.iter().map(|&i| dec.cut[i]).collect(),
    };
    round_latency(p, &sub_devices, server, &sub_dec)
}

/// Eqn 40: total latency for R rounds with aggregation interval I:
/// T = R * T_S + floor(R / I) * T_A.
pub fn total_latency(round: &RoundLatency, rounds: usize, interval: usize) -> f64 {
    rounds as f64 * round.t_split + (rounds / interval.max(1)) as f64 * round.t_agg
}

/// Communication bytes of one round for one device (Fig 3b's comm axis):
/// activations up + activation-gradients down.
pub fn round_comm_bytes(p: &ModelProfile, b: u32, cut: usize) -> f64 {
    b as f64 * (p.psi(cut) + p.chi(cut))
}

/// Client-side compute FLOPs of one round for one device (Fig 3b's compute
/// axis): forward + backward of the client sub-model.
pub fn round_client_flops(p: &ModelProfile, b: u32, cut: usize) -> f64 {
    b as f64 * (p.rho(cut) + p.varpi(cut))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn setup() -> (ModelProfile, Vec<Device>, Server) {
        let cfg = Config::table1();
        (ModelProfile::vgg16(), cfg.sample_fleet(), cfg.server)
    }

    #[test]
    fn split_latency_scales_with_batch() {
        let (p, devs, s) = setup();
        let slow = round_latency(&p, &devs, &s, &Decisions::uniform(devs.len(), 32, 4));
        let fast = round_latency(&p, &devs, &s, &Decisions::uniform(devs.len(), 8, 4));
        assert!(slow.t_split > fast.t_split * 3.0);
    }

    #[test]
    fn deeper_cut_moves_compute_to_client() {
        let (p, devs, s) = setup();
        let shallow = round_latency(&p, &devs, &s, &Decisions::uniform(devs.len(), 16, 2));
        let deep = round_latency(&p, &devs, &s, &Decisions::uniform(devs.len(), 16, 12));
        assert!(deep.per_device[0].client_fwd > shallow.per_device[0].client_fwd);
        assert!(deep.server_fwd < shallow.server_fwd);
    }

    #[test]
    fn uniform_cut_has_zero_noncommon_traffic() {
        let (p, devs, s) = setup();
        let dec = Decisions::uniform(devs.len(), 16, 5);
        assert_eq!(noncommon_bytes(&p, &dec), 0.0);
        assert_eq!(server_upload_latency(&p, &s, &dec), 0.0);
    }

    #[test]
    fn heterogeneous_cuts_have_noncommon_traffic() {
        let (p, _, _) = setup();
        let mut dec = Decisions::uniform(4, 16, 3);
        dec.cut[0] = 6;
        // Lambda_s = N*max(delta) - sum(delta) > 0 when cuts differ.
        assert!(noncommon_bytes(&p, &dec) > 0.0);
    }

    #[test]
    fn round_is_sum_of_phases() {
        let (p, devs, s) = setup();
        let dec = Decisions::uniform(devs.len(), 16, 4);
        let r = round_latency(&p, &devs, &s, &dec);
        let up = r
            .per_device
            .iter()
            .map(|l| l.client_fwd + l.act_up)
            .fold(0.0, f64::max);
        let down = r
            .per_device
            .iter()
            .map(|l| l.grad_down + l.client_bwd)
            .fold(0.0, f64::max);
        assert!((r.t_split - (up + r.server_fwd + r.server_bwd + down)).abs() < 1e-12);
    }

    #[test]
    fn straggler_dominates_round() {
        // Slowing one device's uplink must slow the whole round (the
        // straggler effect the paper attacks).
        let (p, mut devs, s) = setup();
        let dec = Decisions::uniform(devs.len(), 16, 2);
        let base = round_latency(&p, &devs, &s, &dec).t_split;
        devs[7].up_bps /= 20.0;
        let slow = round_latency(&p, &devs, &s, &dec).t_split;
        assert!(slow > base * 1.5, "{slow} vs {base}");
    }

    #[test]
    fn subset_latency_ignores_masked_devices() {
        let (p, mut devs, s) = setup();
        let dec = Decisions::uniform(devs.len(), 16, 4);
        // Slow device 7 to a crawl; masking it out must restore the round.
        devs[7].up_bps /= 50.0;
        let full = round_latency(&p, &devs, &s, &dec);
        let mut mask = vec![true; devs.len()];
        mask[7] = false;
        let sub = round_latency_subset(&p, &devs, &s, &dec, &mask);
        assert!(sub.t_split < full.t_split);
        assert_eq!(sub.per_device.len(), devs.len() - 1);
        // An all-true mask reproduces the full round exactly.
        let all_mask = vec![true; devs.len()];
        let all = round_latency_subset(&p, &devs, &s, &dec, &all_mask);
        assert_eq!(all.t_split, full.t_split);
    }

    #[test]
    fn total_latency_counts_aggregations() {
        let (p, devs, s) = setup();
        let r = round_latency(&p, &devs, &s, &Decisions::uniform(devs.len(), 16, 4));
        let t = total_latency(&r, 30, 15);
        assert!((t - (30.0 * r.t_split + 2.0 * r.t_agg)).abs() < 1e-9);
    }

    #[test]
    fn shallow_cut_costs_more_comm() {
        let (p, _, _) = setup();
        assert!(round_comm_bytes(&p, 16, 1) > round_comm_bytes(&p, 16, 13));
        assert!(round_client_flops(&p, 16, 13) > round_client_flops(&p, 16, 1));
    }
}
