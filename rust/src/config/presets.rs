//! Presets mirroring the paper's experimental setup (Table I) plus smaller
//! presets used by the executable end-to-end runs on this CPU testbed.

use super::*;

impl Config {
    /// The paper's Table I setup: N=20 devices, f_i ~ U[1,2] TFLOPS,
    /// f_s = 20 TFLOPS, uplinks U[75,80] Mbps, downlinks U[360,380] Mbps,
    /// inter-server U[360,380] Mbps, gamma = 5e-4, I = 15.
    pub fn table1() -> Config {
        Config {
            seed: 2025,
            fleet: FleetConfig {
                n_devices: 20,
                flops: Range::new(1e12, 2e12),
                up_bps: Range::new(75e6, 80e6),
                down_bps: Range::new(360e6, 380e6),
                fed_up_bps: Range::new(75e6, 80e6),
                fed_down_bps: Range::new(360e6, 380e6),
                // 4 GiB edge device (Jetson-class); C4 is only binding for
                // very deep cuts at large batch on VGG-16.
                mem_bytes: 4.0 * 1024.0 * 1024.0 * 1024.0,
            },
            server: Server {
                flops: 20e12,
                to_fed_bps: 370e6,
                from_fed_bps: 370e6,
            },
            train: TrainConfig {
                lr: 5e-4,
                agg_interval: 15,
                rounds: 3000,
                eval_every: 15,
                batch_cap: 64,
                epsilon: 0.35,
                classes: 10,
                train_samples: 50_000,
                test_samples: 10_000,
            },
            model: ModelKind::Vgg16,
            partition: Partition::Iid,
            strategy: StrategyKind::Hasfl,
            fixed_batch: 16,
            fixed_cut: 4,
            engine_pool: 0,
            backend: BackendKind::Auto,
            scenario: None,
            faults: None,
            topology: None,
            async_spec: None,
        }
    }

    /// CPU-testbed preset for *executable* end-to-end training of SplitCNN-8
    /// through the PJRT runtime: fewer devices / rounds and a learning rate
    /// suited to the ~0.2M-parameter model, but the same Table I resource
    /// heterogeneity (so straggler structure is preserved).
    pub fn small() -> Config {
        let mut cfg = Config::table1();
        cfg.fleet.n_devices = 4;
        cfg.model = ModelKind::Splitcnn8;
        cfg.train.lr = 0.02;
        cfg.train.rounds = 200;
        cfg.train.agg_interval = 5;
        cfg.train.eval_every = 5;
        cfg.train.batch_cap = 32;
        cfg.train.epsilon = 0.5;
        cfg.train.train_samples = 2_048;
        cfg.train.test_samples = 512;
        cfg
    }

    /// Mid-size preset used by the figure harness's "small scale" runs:
    /// real training, N=8, enough rounds for the convergence ordering of
    /// the five strategies to emerge.
    pub fn figure_small() -> Config {
        let mut cfg = Config::small();
        cfg.fleet.n_devices = 8;
        cfg.train.rounds = 150;
        cfg.train.train_samples = 4_096;
        cfg.train.test_samples = 1_024;
        cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_constants() {
        let c = Config::table1();
        assert_eq!(c.fleet.n_devices, 20);
        assert_eq!(c.server.flops, 20e12);
        assert_eq!(c.train.agg_interval, 15);
        assert!((c.train.lr - 5e-4).abs() < 1e-12);
    }

    #[test]
    fn small_preset_is_executable_scale() {
        let c = Config::small();
        assert_eq!(c.model, ModelKind::Splitcnn8);
        assert!(c.fleet.n_devices <= 8);
        assert!(c.train.train_samples <= 10_000);
    }
}
