//! Configuration system: typed config with JSON files, presets mirroring
//! the paper's Table I, and deterministic fleet sampling.
//!
//! (De)serialization goes through the in-repo JSON substrate
//! [`crate::util::json`] — the build environment has no crates.io access,
//! so serde is not available; the hand-written codec is round-trip tested.

mod presets;


use crate::backend::BackendKind;
use crate::rng::Pcg32;
use crate::util::Json;

/// A closed interval used for uniform sampling of heterogeneous resources.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Range {
    /// Inclusive lower bound.
    pub lo: f64,
    /// Inclusive upper bound.
    pub hi: f64,
}

impl Range {
    /// `[lo, hi]` (panics when `hi < lo`).
    pub fn new(lo: f64, hi: f64) -> Self {
        assert!(hi >= lo, "bad range [{lo}, {hi}]");
        Range { lo, hi }
    }

    /// Uniform draw from the interval.
    pub fn sample(&self, rng: &mut Pcg32) -> f64 {
        rng.uniform(self.lo, self.hi)
    }

    /// Both bounds multiplied by `k`.
    pub fn scale(&self, k: f64) -> Range {
        Range::new(self.lo * k, self.hi * k)
    }

    /// Interval midpoint.
    pub fn mid(&self) -> f64 {
        0.5 * (self.lo + self.hi)
    }

    /// True when both bounds are finite and strictly positive — a valid
    /// rate/capability interval. The latency kernels divide by sampled
    /// values from these ranges, so a zero or non-finite bound silently
    /// poisons every objective with `inf`/`NaN`.
    pub fn is_positive(&self) -> bool {
        self.lo.is_finite() && self.hi.is_finite() && self.lo > 0.0
    }

    fn to_json(self) -> Json {
        Json::from_f64s(&[self.lo, self.hi])
    }

    fn from_json(j: &Json) -> crate::Result<Range> {
        let v = j.f64_vec()?;
        anyhow::ensure!(v.len() == 2, "range needs [lo, hi]");
        Ok(Range::new(v[0], v[1]))
    }
}

/// Per-device resources (one simulated edge device).
#[derive(Debug, Clone, PartialEq)]
pub struct Device {
    /// Computing capability `f_i` in FLOPS.
    pub flops: f64,
    /// Uplink rate to the edge server `r_i^U` in bit/s.
    pub up_bps: f64,
    /// Downlink rate from the edge server `r_i^D` in bit/s.
    pub down_bps: f64,
    /// Uplink rate to the fed server `r_{i,f}^U` in bit/s.
    pub fed_up_bps: f64,
    /// Downlink rate from the fed server `r_{i,f}^D` in bit/s.
    pub fed_down_bps: f64,
    /// Memory limit `v_{c,i}` in bytes (constraint C4).
    pub mem_bytes: f64,
}

/// Edge/fed server resources.
#[derive(Debug, Clone, PartialEq)]
pub struct Server {
    /// Edge-server computing capability `f_s` in FLOPS.
    pub flops: f64,
    /// Edge-server -> fed-server uplink `r_{s,f}` in bit/s.
    pub to_fed_bps: f64,
    /// Fed-server -> edge-server downlink `r_{f,s}` in bit/s.
    pub from_fed_bps: f64,
}

impl Server {
    /// The zero-rate guard for the edge/fed server resources (the latency
    /// kernels divide by every one of these).
    pub fn validate(&self) -> crate::Result<()> {
        for (name, v) in [
            ("flops", self.flops),
            ("to_fed_bps", self.to_fed_bps),
            ("from_fed_bps", self.from_fed_bps),
        ] {
            anyhow::ensure!(
                v.is_finite() && v > 0.0,
                "server {name} {v} must be finite and > 0 \
                 (latency kernels divide by it)"
            );
        }
        Ok(())
    }
}

/// Fleet sampling configuration (Table I ranges by default).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetConfig {
    /// Number of simulated edge devices N.
    pub n_devices: usize,
    /// Device compute range in FLOPS.
    pub flops: Range,
    /// Device->edge uplink range in bit/s.
    pub up_bps: Range,
    /// Edge->device downlink range in bit/s.
    pub down_bps: Range,
    /// Device<->fed-server rates (paper: same distribution as device<->edge).
    pub fed_up_bps: Range,
    /// Fed-server -> device downlink range in bit/s.
    pub fed_down_bps: Range,
    /// Per-device memory limit in bytes.
    pub mem_bytes: f64,
}

impl FleetConfig {
    /// Reject fleets that could sample a zero, negative, or non-finite
    /// resource. The latency kernels (Eqns 28–37) divide by
    /// `flops`/`up_bps`/`down_bps`/... with no guard, so a zero-rate
    /// device yields `inf`/`NaN` round latencies that silently poison the
    /// optimizer's objectives — the contract is that such devices are
    /// rejected here (and at `Scenario` validation, whose drift floors and
    /// slowdown bounds keep evolved rates positive), never reached.
    pub fn validate(&self) -> crate::Result<()> {
        for (name, r) in [
            ("flops", self.flops),
            ("up_bps", self.up_bps),
            ("down_bps", self.down_bps),
            ("fed_up_bps", self.fed_up_bps),
            ("fed_down_bps", self.fed_down_bps),
        ] {
            anyhow::ensure!(
                r.is_positive(),
                "fleet {name} range [{}, {}] must be finite and > 0 \
                 (zero rates yield infinite round latencies)",
                r.lo,
                r.hi
            );
        }
        anyhow::ensure!(
            self.mem_bytes.is_finite() && self.mem_bytes > 0.0,
            "fleet mem_bytes {} must be finite and > 0",
            self.mem_bytes
        );
        Ok(())
    }

    /// Sample a heterogeneous fleet deterministically.
    pub fn sample(&self, rng: &mut Pcg32) -> Vec<Device> {
        (0..self.n_devices)
            .map(|_| Device {
                flops: self.flops.sample(rng),
                up_bps: self.up_bps.sample(rng),
                down_bps: self.down_bps.sample(rng),
                fed_up_bps: self.fed_up_bps.sample(rng),
                fed_down_bps: self.fed_down_bps.sample(rng),
                mem_bytes: self.mem_bytes,
            })
            .collect()
    }
}

/// Which model drives the experiment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// The executable SplitCNN-8 (trained for real through PJRT).
    Splitcnn8,
    /// Analytic VGG-16 profile (paper-scale latency simulation only).
    Vgg16,
    /// Analytic ResNet-18 profile (paper-scale latency simulation only).
    Resnet18,
}

impl ModelKind {
    /// Canonical lowercase name — the inverse of [`ModelKind::parse`].
    pub fn as_str(&self) -> &'static str {
        match self {
            ModelKind::Splitcnn8 => "splitcnn8",
            ModelKind::Vgg16 => "vgg16",
            ModelKind::Resnet18 => "resnet18",
        }
    }

    /// Parse a model name (splitcnn8|vgg16|resnet18).
    pub fn parse(s: &str) -> crate::Result<ModelKind> {
        Ok(match s {
            "splitcnn8" => ModelKind::Splitcnn8,
            "vgg16" => ModelKind::Vgg16,
            "resnet18" => ModelKind::Resnet18,
            _ => anyhow::bail!("unknown model '{s}'"),
        })
    }
}

/// Data distribution across devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partition {
    /// Independent and identically distributed: shuffled uniform split.
    Iid,
    /// Paper non-IID: sort by label, split into `2N` shards, deal 2 random
    /// shards to each device (paper: 40 shards across 20 devices).
    NonIidShards,
}

impl Partition {
    /// Canonical name — the inverse of [`Partition::parse`].
    pub fn as_str(&self) -> &'static str {
        match self {
            Partition::Iid => "iid",
            Partition::NonIidShards => "non_iid_shards",
        }
    }

    /// Parse a partition name (iid|non_iid_shards).
    pub fn parse(s: &str) -> crate::Result<Partition> {
        Ok(match s {
            "iid" => Partition::Iid,
            "non_iid_shards" | "noniid" | "non-iid" => Partition::NonIidShards,
            _ => anyhow::bail!("unknown partition '{s}'"),
        })
    }
}

/// Training hyper-parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    /// Learning rate gamma (paper: 5e-4 for VGG-16; our ~0.2M-param model
    /// uses a larger default).
    pub lr: f64,
    /// Client-side aggregation interval I (paper: 15).
    pub agg_interval: usize,
    /// Total training rounds R for a run.
    pub rounds: usize,
    /// Evaluate test accuracy every this many rounds.
    pub eval_every: usize,
    /// Maximum batch size B (paper benchmarks draw from 1..=64).
    pub batch_cap: u32,
    /// Target convergence accuracy epsilon used by the optimizer.
    pub epsilon: f64,
    /// Number of classes (10 = CIFAR-10-like, 100 = CIFAR-100-like).
    pub classes: usize,
    /// Synthetic dataset size (train / test).
    pub train_samples: usize,
    /// Synthetic test-set size.
    pub test_samples: usize,
}

/// The BS/MS control strategy (HASFL + the paper's four benchmarks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyKind {
    /// Heterogeneity-aware BS + MS (the paper's proposal, Algorithm 2).
    Hasfl,
    /// Random BS + heterogeneity-aware MS.
    RbsHams,
    /// Heterogeneity-aware BS + random MS.
    HabsRms,
    /// Random BS + random MS.
    RbsRms,
    /// Random BS + resource-heterogeneity-aware MS heuristic [55].
    RbsRhams,
    /// Fixed uniform BS + fixed cut (ablation baselines, Figs 10-11).
    Fixed,
    /// Heterogeneity-aware BS at a fixed uniform cut (Fig 10 HABS arm).
    HabsFixedCut,
    /// Heterogeneity-aware MS at a fixed uniform BS (Fig 11 HAMS arm).
    HamsFixedBatch,
}

impl StrategyKind {
    /// Canonical lowercase name — the inverse of [`StrategyKind::parse`].
    pub fn as_str(&self) -> &'static str {
        match self {
            StrategyKind::Hasfl => "hasfl",
            StrategyKind::RbsHams => "rbs_hams",
            StrategyKind::HabsRms => "habs_rms",
            StrategyKind::RbsRms => "rbs_rms",
            StrategyKind::RbsRhams => "rbs_rhams",
            StrategyKind::Fixed => "fixed",
            StrategyKind::HabsFixedCut => "habs_fixed_cut",
            StrategyKind::HamsFixedBatch => "hams_fixed_batch",
        }
    }

    /// Parse a strategy name as accepted by `--strategy`.
    pub fn parse(s: &str) -> crate::Result<StrategyKind> {
        Ok(match s {
            "hasfl" => StrategyKind::Hasfl,
            "rbs_hams" | "rbs-hams" => StrategyKind::RbsHams,
            "habs_rms" | "habs-rms" => StrategyKind::HabsRms,
            "rbs_rms" | "rbs-rms" => StrategyKind::RbsRms,
            "rbs_rhams" | "rbs-rhams" => StrategyKind::RbsRhams,
            "fixed" => StrategyKind::Fixed,
            "habs_fixed_cut" => StrategyKind::HabsFixedCut,
            "hams_fixed_batch" => StrategyKind::HamsFixedBatch,
            _ => anyhow::bail!("unknown strategy '{s}'"),
        })
    }
}

/// Top-level experiment configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    /// Root seed every deterministic stream derives from.
    pub seed: u64,
    /// Fleet sampling ranges.
    pub fleet: FleetConfig,
    /// Edge/fed server resources.
    pub server: Server,
    /// Training hyper-parameters.
    pub train: TrainConfig,
    /// Model the experiment drives.
    pub model: ModelKind,
    /// Data distribution across devices.
    pub partition: Partition,
    /// BS/MS control strategy.
    pub strategy: StrategyKind,
    /// Fixed batch size used when `strategy` is one of the fixed variants.
    pub fixed_batch: u32,
    /// Fixed cut layer used when `strategy` is one of the fixed variants.
    pub fixed_cut: usize,
    /// Engine-pool width: lanes that execute devices concurrently.
    /// 0 = auto (min of fleet size, host parallelism, and 8). Numerics are
    /// identical at any width (verified by `rust/tests/parity_modes.rs`).
    pub engine_pool: usize,
    /// Execution backend (DESIGN.md §11). `Auto` resolves at session build
    /// time — PJRT when AOT artifacts exist, native otherwise — and the
    /// *resolved* kind is what sessions carry (and checkpoints embed), so
    /// resumes stay on the backend that produced the state. Numerics
    /// differ across backends within float tolerance, never within one.
    pub backend: BackendKind,
    /// Dynamic-fleet scenario evolving channels/compute/membership over
    /// rounds (`None` = the historical static fleet). See
    /// [`crate::scenario`].
    pub scenario: Option<crate::scenario::Scenario>,
    /// Seeded fault-injection spec (`None` = no injection and no fault
    /// tolerance: a device error fails the round, exactly the historical
    /// behaviour). See [`crate::fault`] and DESIGN.md §13.
    pub faults: Option<crate::fault::FaultSpec>,
    /// Hierarchical-aggregation topology: the fleet partitioned into
    /// cells, each owning a coordinator shard (`None` = the historical
    /// flat roster; numerics are bit-identical either way — see
    /// [`crate::topology`] and DESIGN.md §15).
    pub topology: Option<crate::topology::Topology>,
    /// Buffered-asynchronous round spec (`None` = the historical
    /// synchronous barrier, byte-identical to previous releases; `Some`
    /// switches to staleness-weighted buffer flushes — see
    /// [`crate::asynch`] and DESIGN.md §16).
    pub async_spec: Option<crate::asynch::AsyncSpec>,
}

impl Config {
    /// Serialize to the JSON form accepted by [`Config::from_json`].
    pub fn to_json(&self) -> Json {
        let mut fleet = Json::obj();
        fleet
            .set("n_devices", Json::Num(self.fleet.n_devices as f64))
            .set("flops", self.fleet.flops.to_json())
            .set("up_bps", self.fleet.up_bps.to_json())
            .set("down_bps", self.fleet.down_bps.to_json())
            .set("fed_up_bps", self.fleet.fed_up_bps.to_json())
            .set("fed_down_bps", self.fleet.fed_down_bps.to_json())
            .set("mem_bytes", Json::Num(self.fleet.mem_bytes));
        let mut server = Json::obj();
        server
            .set("flops", Json::Num(self.server.flops))
            .set("to_fed_bps", Json::Num(self.server.to_fed_bps))
            .set("from_fed_bps", Json::Num(self.server.from_fed_bps));
        let mut train = Json::obj();
        train
            .set("lr", Json::Num(self.train.lr))
            .set("agg_interval", Json::Num(self.train.agg_interval as f64))
            .set("rounds", Json::Num(self.train.rounds as f64))
            .set("eval_every", Json::Num(self.train.eval_every as f64))
            .set("batch_cap", Json::Num(self.train.batch_cap as f64))
            .set("epsilon", Json::Num(self.train.epsilon))
            .set("classes", Json::Num(self.train.classes as f64))
            .set("train_samples", Json::Num(self.train.train_samples as f64))
            .set("test_samples", Json::Num(self.train.test_samples as f64));
        let mut root = Json::obj();
        // u64 seeds exceed f64's 53-bit mantissa: serialize as string.
        root.set("seed", Json::Str(self.seed.to_string()))
            .set("fleet", fleet)
            .set("server", server)
            .set("train", train)
            .set("model", Json::Str(self.model.as_str().into()))
            .set("partition", Json::Str(self.partition.as_str().into()))
            .set("strategy", Json::Str(self.strategy.as_str().into()))
            .set("fixed_batch", Json::Num(self.fixed_batch as f64))
            .set("fixed_cut", Json::Num(self.fixed_cut as f64))
            .set("engine_pool", Json::Num(self.engine_pool as f64))
            .set("backend", Json::Str(self.backend.as_str().into()));
        if let Some(s) = &self.scenario {
            root.set("scenario", s.to_json());
        }
        if let Some(f) = &self.faults {
            root.set("faults", f.to_json());
        }
        if let Some(t) = &self.topology {
            root.set("topology", t.to_json());
        }
        if let Some(a) = &self.async_spec {
            root.set("async", a.to_json());
        }
        root
    }

    /// Decode a config, tolerating fields added after the file was saved.
    pub fn from_json(j: &Json) -> crate::Result<Config> {
        // Every decode error names the offending JSON path ('fleet.flops',
        // 'train.lr', ...): the serve daemon surfaces these verbatim as
        // HTTP 400 bodies, so clients get a pointer, not a bare type error.
        fn at<T>(path: &str, r: crate::Result<T>) -> crate::Result<T> {
            r.map_err(|e| anyhow::anyhow!("config field '{path}': {e}"))
        }
        let f = j.req("fleet").map_err(|e| anyhow::anyhow!("config section 'fleet': {e}"))?;
        let s = j.req("server").map_err(|e| anyhow::anyhow!("config section 'server': {e}"))?;
        let t = j.req("train").map_err(|e| anyhow::anyhow!("config section 'train': {e}"))?;
        let seed = at(
            "seed",
            j.req("seed").and_then(|v| match v {
                Json::Str(s) => s.parse::<u64>().map_err(|e| anyhow::anyhow!(e)),
                other => other.as_u64(),
            }),
        )?;
        Ok(Config {
            seed,
            fleet: FleetConfig {
                n_devices: at("fleet.n_devices", f.req("n_devices").and_then(|v| v.as_usize()))?,
                flops: at("fleet.flops", f.req("flops").and_then(Range::from_json))?,
                up_bps: at("fleet.up_bps", f.req("up_bps").and_then(Range::from_json))?,
                down_bps: at("fleet.down_bps", f.req("down_bps").and_then(Range::from_json))?,
                fed_up_bps: at(
                    "fleet.fed_up_bps",
                    f.req("fed_up_bps").and_then(Range::from_json),
                )?,
                fed_down_bps: at(
                    "fleet.fed_down_bps",
                    f.req("fed_down_bps").and_then(Range::from_json),
                )?,
                mem_bytes: at("fleet.mem_bytes", f.req("mem_bytes").and_then(|v| v.as_f64()))?,
            },
            server: Server {
                flops: at("server.flops", s.req("flops").and_then(|v| v.as_f64()))?,
                to_fed_bps: at("server.to_fed_bps", s.req("to_fed_bps").and_then(|v| v.as_f64()))?,
                from_fed_bps: at(
                    "server.from_fed_bps",
                    s.req("from_fed_bps").and_then(|v| v.as_f64()),
                )?,
            },
            train: TrainConfig {
                lr: at("train.lr", t.req("lr").and_then(|v| v.as_f64()))?,
                agg_interval: at(
                    "train.agg_interval",
                    t.req("agg_interval").and_then(|v| v.as_usize()),
                )?,
                rounds: at("train.rounds", t.req("rounds").and_then(|v| v.as_usize()))?,
                eval_every: at("train.eval_every", t.req("eval_every").and_then(|v| v.as_usize()))?,
                batch_cap: at("train.batch_cap", t.req("batch_cap").and_then(|v| v.as_u32()))?,
                epsilon: at("train.epsilon", t.req("epsilon").and_then(|v| v.as_f64()))?,
                classes: at("train.classes", t.req("classes").and_then(|v| v.as_usize()))?,
                train_samples: at(
                    "train.train_samples",
                    t.req("train_samples").and_then(|v| v.as_usize()),
                )?,
                test_samples: at(
                    "train.test_samples",
                    t.req("test_samples").and_then(|v| v.as_usize()),
                )?,
            },
            model: at("model", j.req("model").and_then(|v| v.as_str()).and_then(ModelKind::parse))?,
            partition: at(
                "partition",
                j.req("partition").and_then(|v| v.as_str()).and_then(Partition::parse),
            )?,
            strategy: at(
                "strategy",
                j.req("strategy").and_then(|v| v.as_str()).and_then(StrategyKind::parse),
            )?,
            fixed_batch: at("fixed_batch", j.req("fixed_batch").and_then(|v| v.as_u32()))?,
            fixed_cut: at("fixed_cut", j.req("fixed_cut").and_then(|v| v.as_usize()))?,
            // Absent in configs saved before the engine pool existed: auto.
            engine_pool: match j.get("engine_pool") {
                Some(v) => at("engine_pool", v.as_usize())?,
                None => 0,
            },
            // Absent in configs (and checkpoints) saved before the backend
            // abstraction existed: auto. Those all ran PJRT, and auto
            // resolves to PJRT wherever they could run at all (resuming a
            // pre-backend checkpoint requires its artifacts anyway).
            backend: match j.get("backend") {
                Some(v) => at("backend", v.as_str().and_then(BackendKind::parse))?,
                None => BackendKind::Auto,
            },
            // Absent in configs saved before the scenario engine existed
            // (and in static-fleet configs): no dynamic scenario.
            scenario: match j.get("scenario") {
                Some(v) => Some(at("scenario", crate::scenario::Scenario::from_json(v))?),
                None => None,
            },
            // Absent in configs saved before the fault layer existed: no
            // injection, no tolerance.
            faults: match j.get("faults") {
                Some(v) => Some(at("faults", crate::fault::FaultSpec::from_json(v))?),
                None => None,
            },
            // Absent in configs saved before hierarchical aggregation
            // existed: the flat roster.
            topology: match j.get("topology") {
                Some(v) => Some(at("topology", crate::topology::Topology::from_json(v))?),
                None => None,
            },
            // Absent in configs saved before buffered asynchrony existed:
            // the synchronous barrier.
            async_spec: match j.get("async") {
                Some(v) => Some(at("async", crate::asynch::AsyncSpec::from_json(v))?),
                None => None,
            },
        })
    }

    /// Read and decode a JSON config file.
    pub fn load(path: &std::path::Path) -> crate::Result<Config> {
        let text = std::fs::read_to_string(path)?;
        Config::from_json(&Json::parse(&text)?)
    }

    /// Write the config as JSON to `path`.
    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        std::fs::write(path, self.to_json().dump())?;
        Ok(())
    }

    /// Sample the device fleet for this config.
    pub fn sample_fleet(&self) -> Vec<Device> {
        let mut rng = Pcg32::new(self.seed, 0xF1EE7);
        self.fleet.sample(&mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_roundtrips_through_json() {
        let cfg = Config::table1();
        let text = cfg.to_json().dump();
        let back = Config::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn fleet_sampling_matches_table1_ranges() {
        let cfg = Config::table1();
        let fleet = cfg.sample_fleet();
        assert_eq!(fleet.len(), 20);
        for d in &fleet {
            assert!(d.flops >= 1e12 && d.flops <= 2e12);
            assert!(d.up_bps >= 75e6 && d.up_bps <= 80e6);
            assert!(d.down_bps >= 360e6 && d.down_bps <= 380e6);
        }
    }

    #[test]
    fn fleet_sampling_is_deterministic() {
        let cfg = Config::table1();
        assert_eq!(cfg.sample_fleet(), cfg.sample_fleet());
    }

    #[test]
    fn fleet_is_heterogeneous() {
        let fleet = Config::table1().sample_fleet();
        let f0 = fleet[0].flops;
        assert!(fleet.iter().any(|d| (d.flops - f0).abs() > 1e9));
    }

    #[test]
    fn zero_rate_fleets_and_servers_are_rejected() {
        // Regression: zero-rate devices (a valid mid-churn state if left
        // unvalidated) make the latency kernels divide by zero.
        assert!(Config::table1().fleet.validate().is_ok());
        assert!(Config::table1().server.validate().is_ok());

        let mut f = Config::table1().fleet;
        f.up_bps = Range::new(0.0, 1e6);
        assert!(f.validate().is_err());

        let mut f = Config::table1().fleet;
        f.flops = Range::new(1e9, f64::INFINITY);
        assert!(f.validate().is_err());

        let mut f = Config::table1().fleet;
        f.mem_bytes = 0.0;
        assert!(f.validate().is_err());

        let mut s = Config::table1().server;
        s.to_fed_bps = 0.0;
        assert!(s.validate().is_err());

        let mut s = Config::table1().server;
        s.flops = f64::NAN;
        assert!(s.validate().is_err());
    }

    #[test]
    fn range_sample_within_bounds() {
        let mut rng = Pcg32::seeded(4);
        let r = Range::new(3.0, 7.0);
        for _ in 0..100 {
            let v = r.sample(&mut rng);
            assert!((3.0..7.0).contains(&v));
        }
    }

    #[test]
    fn enum_parse_roundtrip() {
        for k in [
            StrategyKind::Hasfl,
            StrategyKind::RbsHams,
            StrategyKind::HabsRms,
            StrategyKind::RbsRms,
            StrategyKind::RbsRhams,
            StrategyKind::Fixed,
            StrategyKind::HabsFixedCut,
            StrategyKind::HamsFixedBatch,
        ] {
            assert_eq!(StrategyKind::parse(k.as_str()).unwrap(), k);
        }
        for m in [ModelKind::Splitcnn8, ModelKind::Vgg16, ModelKind::Resnet18] {
            assert_eq!(ModelKind::parse(m.as_str()).unwrap(), m);
        }
        for p in [Partition::Iid, Partition::NonIidShards] {
            assert_eq!(Partition::parse(p.as_str()).unwrap(), p);
        }
    }

    #[test]
    fn config_save_load_roundtrip() {
        let cfg = Config::small();
        let path = std::env::temp_dir().join("hasfl_cfg_test.json");
        cfg.save(&path).unwrap();
        let back = Config::load(&path).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn every_preset_roundtrips_through_json() {
        // Covers the full hand-written codec in util/json.rs: every preset
        // through the in-memory path (to_json/from_json) and the file path
        // (save/load).
        for (name, cfg) in [
            ("small", Config::small()),
            ("figure_small", Config::figure_small()),
            ("table1", Config::table1()),
        ] {
            let back = Config::from_json(&Json::parse(&cfg.to_json().dump()).unwrap()).unwrap();
            assert_eq!(cfg, back, "in-memory round-trip for preset '{name}'");

            let path = std::env::temp_dir().join(format!("hasfl_cfg_rt_{name}.json"));
            cfg.save(&path).unwrap();
            assert_eq!(Config::load(&path).unwrap(), cfg, "file round-trip for preset '{name}'");
        }
    }

    #[test]
    fn engine_pool_defaults_to_auto_for_legacy_configs() {
        // Configs saved before the engine pool existed have no
        // "engine_pool" key; they must load as 0 (auto).
        let mut j = Config::small().to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("engine_pool");
        }
        let cfg = Config::from_json(&j).unwrap();
        assert_eq!(cfg.engine_pool, 0);

        let mut cfg2 = Config::small();
        cfg2.engine_pool = 3;
        let back = Config::from_json(&Json::parse(&cfg2.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.engine_pool, 3);
    }

    #[test]
    fn scenario_field_roundtrips_and_defaults_to_none() {
        // Configs saved before the scenario engine existed have no
        // "scenario" key; they must load as None (static fleet).
        let cfg = Config::table1();
        assert!(cfg.scenario.is_none());
        let back = Config::from_json(&Json::parse(&cfg.to_json().dump()).unwrap()).unwrap();
        assert!(back.scenario.is_none());

        let mut cfg = Config::table1();
        cfg.scenario = Some(crate::scenario::ScenarioPreset::ChurnHeavy.scenario());
        let back = Config::from_json(&Json::parse(&cfg.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn faults_field_roundtrips_and_defaults_to_none() {
        // Configs saved before the fault layer existed have no "faults"
        // key; they must load as None (no injection, no tolerance).
        let cfg = Config::table1();
        assert!(cfg.faults.is_none());
        let back = Config::from_json(&Json::parse(&cfg.to_json().dump()).unwrap()).unwrap();
        assert!(back.faults.is_none());

        let mut cfg = Config::table1();
        cfg.faults = Some(crate::fault::FaultPreset::Chaos.spec());
        let back = Config::from_json(&Json::parse(&cfg.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn topology_field_roundtrips_and_defaults_to_none() {
        // Configs saved before hierarchical aggregation existed have no
        // "topology" key; they must load as None (flat roster).
        let cfg = Config::table1();
        assert!(cfg.topology.is_none());
        let back = Config::from_json(&Json::parse(&cfg.to_json().dump()).unwrap()).unwrap();
        assert!(back.topology.is_none());

        let mut cfg = Config::table1();
        cfg.topology = Some(crate::topology::Topology::with_cells(8));
        let back = Config::from_json(&Json::parse(&cfg.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back, cfg);

        // Errors inside the topology block name the field path.
        let mut j = cfg.to_json();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Obj(t)) = m.get_mut("topology") {
                t.insert("cells".into(), Json::Str("lots".into()));
            }
        }
        let err = Config::from_json(&j).unwrap_err();
        assert!(err.to_string().contains("topology"), "{err}");
    }

    #[test]
    fn async_field_roundtrips_and_defaults_to_none() {
        // Configs saved before buffered asynchrony existed have no
        // "async" key; they must load as None (synchronous barrier).
        let cfg = Config::table1();
        assert!(cfg.async_spec.is_none());
        let back = Config::from_json(&Json::parse(&cfg.to_json().dump()).unwrap()).unwrap();
        assert!(back.async_spec.is_none());

        let mut cfg = Config::table1();
        cfg.async_spec = Some(crate::asynch::AsyncSpec {
            buffer_k: 3,
            max_staleness: 6,
            decay: 0.75,
        });
        let back = Config::from_json(&Json::parse(&cfg.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back, cfg);

        // Errors inside the async block name the field path.
        let mut j = cfg.to_json();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Obj(a)) = m.get_mut("async") {
                a.insert("buffer_k".into(), Json::Str("many".into()));
            }
        }
        let err = Config::from_json(&j).unwrap_err();
        assert!(err.to_string().contains("async"), "{err}");
    }

    #[test]
    fn from_json_errors_name_the_field_path() {
        // Serve-daemon contract: a bad config field comes back as a 400
        // whose body names the offending JSON path.
        let mut j = Config::small().to_json();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Obj(t)) = m.get_mut("train") {
                t.insert("lr".into(), Json::Str("fast".into()));
            }
        }
        let err = Config::from_json(&j).unwrap_err();
        assert!(err.to_string().contains("train.lr"), "{err}");

        let mut j = Config::small().to_json();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Obj(f)) = m.get_mut("fleet") {
                f.remove("flops");
            }
        }
        let err = Config::from_json(&j).unwrap_err();
        assert!(err.to_string().contains("fleet.flops"), "{err}");

        let mut j = Config::small().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("strategy".into(), Json::Str("warp-speed".into()));
        }
        let err = Config::from_json(&j).unwrap_err();
        assert!(err.to_string().contains("'strategy'"), "{err}");
    }

    #[test]
    fn large_seed_survives_json() {
        // u64 seeds above 2^53 would be mangled by an f64 codec; the seed
        // is serialized as a string to avoid that.
        let mut cfg = Config::small();
        cfg.seed = u64::MAX - 12345;
        let back = Config::from_json(&Json::parse(&cfg.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.seed, cfg.seed);
    }
}
