//! # HASFL — Heterogeneity-aware Split Federated Learning
//!
//! Production-quality reproduction of *"HASFL: Heterogeneity-aware Split
//! Federated Learning over Edge Computing Systems"* (Lin et al., 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the coordinator: split-training round
//!   orchestration across simulated heterogeneous edge devices, the
//!   convergence-bound engine (Theorem 1 / Corollary 1), the latency model
//!   (Eqns 28–40), the joint batch-size + model-splitting optimizer
//!   (Algorithm 2: Newton–Jacobi BS solver + Dinkelbach/BCD MS solver),
//!   and the [`scenario`] engine that evolves fleet state over rounds
//!   (channel drift, device churn, stragglers — DESIGN.md §9).
//! - **L2 (python/compile/model.py)** — the split CNN fwd/bwd in JAX,
//!   AOT-lowered to HLO text artifacts at build time.
//! - **L1 (python/compile/kernels/)** — Pallas GEMM + softmax-xent kernels
//!   on the hot path of every layer.
//!
//! Python never runs at training time: [`runtime`] executes the model on
//! one of two interchangeable [`backend`]s — PJRT (loads the AOT
//! artifacts through the `xla` crate) or the pure-Rust native engine,
//! which needs no artifacts and no XLA toolchain at all (DESIGN.md §11).
//!
//! Drive the system through [`experiment`] — the builder/session/observer
//! API that every CLI subcommand, figure generator, example, and bench
//! uses. Long runs survive crashes through [`checkpoint`] — versioned,
//! atomic on-disk snapshots of the complete training state with
//! bit-identical warm restarts (DESIGN.md §10). [`serve`] hosts that API
//! as a long-running multi-tenant daemon (`hasfl serve`): sessions over
//! HTTP, NDJSON event streams, and checkpoint-on-shutdown restart
//! adoption (DESIGN.md §12). See `DESIGN.md` (repo root) for the
//! paper-to-module map and the experiment index (§6).

#![warn(missing_docs)]

pub mod aggregation;
pub mod asynch;
pub mod backend;
pub mod checkpoint;
pub mod config;
pub mod convergence;
pub mod coordinator;
pub mod data;
pub mod experiment;
pub mod fault;
pub mod figures;
pub mod latency;
pub mod metrics;
pub mod model;
pub mod optimizer;
pub mod rng;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod topology;
pub mod util;

pub use config::Config;
pub use experiment::{Experiment, Observer, Preset, RoundReport, Session};

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
