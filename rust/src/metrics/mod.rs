//! Training metrics: round records, the paper's converged-time detector,
//! CSV emitters for the figure harness, and latency percentile summaries
//! for the machine-readable bench reports (`BENCH_*.json`).

use std::io::Write;

use crate::util::Json;

/// Nearest-rank percentile over an ascending-sorted slice, `q` in `[0, 1]`.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty sample set");
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Percentile summary of a latency sample set. Unit-agnostic: outputs are
/// in whatever unit the samples were.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencySummary {
    /// Median (nearest-rank 50th percentile).
    pub p50: f64,
    /// Nearest-rank 95th percentile.
    pub p95: f64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Sample count.
    pub n: usize,
}

impl LatencySummary {
    /// Summarise raw samples (unsorted is fine); `None` when empty.
    pub fn from_samples(samples: &[f64]) -> Option<LatencySummary> {
        if samples.is_empty() {
            return None;
        }
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(LatencySummary {
            p50: percentile(&s, 0.50),
            p95: percentile(&s, 0.95),
            mean: s.iter().sum::<f64>() / s.len() as f64,
            min: s[0],
            max: s[s.len() - 1],
            n: s.len(),
        })
    }

    /// JSON object with a unit-suffixed key set, e.g. `p50_ms` for
    /// `unit = "ms"`.
    pub fn to_json(&self, unit: &str) -> Json {
        let mut j = Json::obj();
        j.set(&format!("p50_{unit}"), Json::Num(self.p50))
            .set(&format!("p95_{unit}"), Json::Num(self.p95))
            .set(&format!("mean_{unit}"), Json::Num(self.mean))
            .set(&format!("min_{unit}"), Json::Num(self.min))
            .set(&format!("max_{unit}"), Json::Num(self.max))
            .set("samples", Json::Num(self.n as f64));
        j
    }

    /// The same summary in a different unit (e.g. ns -> ms with 1e-6).
    pub fn scaled(&self, k: f64) -> LatencySummary {
        LatencySummary {
            p50: self.p50 * k,
            p95: self.p95 * k,
            mean: self.mean * k,
            min: self.min * k,
            max: self.max * k,
            n: self.n,
        }
    }
}

/// The paper's convergence rule, threshold half: "the test accuracy
/// increases by less than 0.02%" per evaluation round.
pub const CONVERGENCE_ACC_THRESHOLD: f64 = 0.0002;

/// The paper's convergence rule, window half: stagnation must persist for
/// five consecutive evaluation rounds.
pub const CONVERGENCE_WINDOW: usize = 5;

/// One training-round record.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    /// Training round index (0-based).
    pub round: usize,
    /// Simulated wall-clock (seconds) accumulated from the latency model.
    pub sim_time: f64,
    /// Mean training loss across devices this round.
    pub loss: f64,
    /// Test accuracy, present on evaluation rounds.
    pub test_acc: Option<f64>,
}

/// Run history + derived statistics.
#[derive(Debug, Clone, Default)]
pub struct History {
    /// Per-round records in round order.
    pub records: Vec<Record>,
}

impl History {
    /// Append a round record.
    pub fn push(&mut self, rec: Record) {
        self.records.push(rec);
    }

    /// Loss of the most recent round, if any.
    pub fn last_loss(&self) -> Option<f64> {
        self.records.last().map(|r| r.loss)
    }

    /// Evaluation points (round, sim_time, accuracy).
    pub fn eval_points(&self) -> Vec<(usize, f64, f64)> {
        self.records
            .iter()
            .filter_map(|r| r.test_acc.map(|a| (r.round, r.sim_time, a)))
            .collect()
    }

    /// Best test accuracy seen so far, if any evaluation ran.
    pub fn best_acc(&self) -> Option<f64> {
        self.eval_points()
            .iter()
            .map(|&(_, _, a)| a)
            .fold(None, |acc, a| Some(acc.map_or(a, |m: f64| m.max(a))))
    }

    /// The paper's convergence rule: "the test accuracy increases by less
    /// than `threshold` (0.02%) across `window` (five) consecutive
    /// \[evaluation\] rounds". Returns (round, sim_time, accuracy) of the
    /// convergence point, if reached.
    pub fn converged(&self, threshold: f64, window: usize) -> Option<(usize, f64, f64)> {
        let evals = self.eval_points();
        if evals.len() <= window {
            return None;
        }
        let mut running_max = evals[0].2;
        let mut stagnant = 0usize;
        for k in 1..evals.len() {
            let improvement = (evals[k].2 - running_max).max(0.0);
            if improvement < threshold {
                stagnant += 1;
                if stagnant >= window {
                    return Some(evals[k]);
                }
            } else {
                stagnant = 0;
            }
            running_max = running_max.max(evals[k].2);
        }
        None
    }

    /// Converged time with the paper's defaults, falling back to the last
    /// evaluation when the run ended before stagnation.
    pub fn converged_or_last(&self) -> Option<(usize, f64, f64)> {
        self.converged(CONVERGENCE_ACC_THRESHOLD, CONVERGENCE_WINDOW)
            .or_else(|| self.eval_points().last().copied())
    }

    /// The `round,sim_time,loss,test_acc` CSV as a string — the one
    /// rendering shared by [`History::write_csv`] and the serve daemon's
    /// `/history.csv` endpoint, so a streamed history is byte-identical to
    /// a written file.
    pub fn to_csv_string(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::from("round,sim_time,loss,test_acc\n");
        for r in &self.records {
            let _ = writeln!(
                out,
                "{},{:.6},{:.6},{}",
                r.round,
                r.sim_time,
                r.loss,
                r.test_acc.map_or(String::new(), |a| format!("{a:.6}"))
            );
        }
        out
    }

    /// Write `round,sim_time,loss,test_acc` CSV.
    pub fn write_csv(&self, path: &std::path::Path) -> crate::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv_string().as_bytes())?;
        Ok(())
    }
}

/// One round of a dynamic-fleet run (scenario engine attached): fleet
/// membership, drift since the last re-solve, and the round's latency —
/// the latency-vs-drift record that figures and benches plot.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetRound {
    /// Training round index (0-based).
    pub round: usize,
    /// Fleet members online this round.
    pub n_active: usize,
    /// Members that failed mid-round (completed no work).
    pub n_dropped: usize,
    /// Devices that joined the fleet at this round boundary.
    pub n_joined: usize,
    /// Devices that left the fleet at this round boundary.
    pub n_left: usize,
    /// Mean relative fleet deviation since the last BS/MS re-solve.
    pub drift: f64,
    /// Whether BS/MS were re-solved this round (window or drift trigger).
    pub resolved: bool,
    /// Split-training round latency over the surviving devices (Eqn 38).
    pub t_split: f64,
    /// Aggregation latency charged this round (0 outside aggregation
    /// events, Eqn 39).
    pub t_agg: f64,
    /// Simulated wall-clock (seconds) at the end of the round.
    pub sim_time: f64,
    /// Updates flushed from the asynchronous buffer this round (0 on
    /// synchronous-barrier runs; see DESIGN.md §16).
    pub flushed: usize,
    /// Updates dropped for exceeding `max_staleness` this round (0 on
    /// synchronous-barrier runs).
    pub stale_drops: usize,
    /// Mean version lag of the updates flushed this round (0 on
    /// synchronous-barrier runs, where every update has zero lag).
    pub staleness_mean: f64,
}

/// Per-round trace of a dynamic-fleet run + derived statistics. Equality
/// is bit-exact, which is what the scenario determinism suite asserts.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FleetTrace {
    /// Per-round records in round order.
    pub rounds: Vec<FleetRound>,
}

impl FleetTrace {
    /// Append a round record.
    pub fn push(&mut self, r: FleetRound) {
        self.rounds.push(r);
    }

    /// Number of recorded rounds.
    pub fn len(&self) -> usize {
        self.rounds.len()
    }

    /// True when no rounds have been recorded.
    pub fn is_empty(&self) -> bool {
        self.rounds.is_empty()
    }

    /// Number of rounds that ended in a BS/MS re-solve.
    pub fn resolves(&self) -> usize {
        self.rounds.iter().filter(|r| r.resolved).count()
    }

    /// Rounds where at least one device failed mid-round.
    pub fn partial_rounds(&self) -> usize {
        self.rounds.iter().filter(|r| r.n_dropped > 0).count()
    }

    /// Percentile summary of per-round split latency (seconds).
    pub fn split_summary(&self) -> Option<LatencySummary> {
        let s: Vec<f64> = self.rounds.iter().map(|r| r.t_split).collect();
        LatencySummary::from_samples(&s)
    }

    /// Percentile summary of per-round drift.
    pub fn drift_summary(&self) -> Option<LatencySummary> {
        let s: Vec<f64> = self.rounds.iter().map(|r| r.drift).collect();
        LatencySummary::from_samples(&s)
    }

    /// Write the trace as CSV (one row per round).
    pub fn write_csv(&self, path: &std::path::Path) -> crate::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(
            f,
            "round,n_active,n_dropped,n_joined,n_left,drift,resolved,t_split,t_agg,sim_time,flushed,stale_drops,staleness_mean"
        )?;
        for r in &self.rounds {
            writeln!(
                f,
                "{},{},{},{},{},{:.6},{},{:.6},{:.6},{:.6},{},{},{:.6}",
                r.round,
                r.n_active,
                r.n_dropped,
                r.n_joined,
                r.n_left,
                r.drift,
                r.resolved as u8,
                r.t_split,
                r.t_agg,
                r.sim_time,
                r.flushed,
                r.stale_drops,
                r.staleness_mean
            )?;
        }
        Ok(())
    }
}

/// Generic CSV table writer for figure data.
pub struct CsvTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvTable {
    /// Empty table with the given column headers.
    pub fn new(header: &[&str]) -> CsvTable {
        CsvTable { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    /// Append a row (must match the header width).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    /// Append a numeric row, formatted to six decimals.
    pub fn rowf(&mut self, cells: &[f64]) {
        self.row(&cells.iter().map(|v| format!("{v:.6}")).collect::<Vec<_>>());
    }

    /// Write header + rows as CSV, creating parent directories.
    pub fn write(&self, path: &std::path::Path) -> crate::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.header.join(","))?;
        for r in &self.rows {
            writeln!(f, "{}", r.join(","))?;
        }
        Ok(())
    }

    /// Number of data rows appended so far.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }
}

/// Per-cell execution stats of one engine-backed round under a
/// hierarchical topology (`Config::topology`, DESIGN.md §15): the cell's
/// membership, how many of its devices completed/were abandoned, and the
/// split-training latency its own stragglers gated. Carried by
/// `RoundReport::cells` (empty on flat-roster runs).
#[derive(Debug, Clone, PartialEq)]
pub struct CellStats {
    /// Cell index in the topology's fixed cell order.
    pub cell: usize,
    /// Devices in the cell's contiguous id range this round.
    pub devices: usize,
    /// Cell devices that completed the round.
    pub participants: usize,
    /// Cell devices abandoned by the fault layer this round.
    pub abandoned: usize,
    /// Eqn-38 split-training latency over the cell's survivors (seconds).
    pub t_split: f64,
}

impl CellStats {
    /// JSON form used by `RoundReport::to_json` and the serve layer.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("cell", Json::Num(self.cell as f64))
            .set("devices", Json::Num(self.devices as f64))
            .set("participants", Json::Num(self.participants as f64))
            .set("abandoned", Json::Num(self.abandoned as f64))
            .set("t_split", Json::Num(self.t_split));
        j
    }
}

/// One numeric leaf shared by two benchmark JSON documents (see
/// [`bench_diff`]).
#[derive(Debug, Clone, PartialEq)]
pub struct BenchDelta {
    /// Dotted path of the leaf, e.g. `latency.p95_ms`.
    pub path: String,
    /// Value in the base (older) document.
    pub base: f64,
    /// Value in the head (newer) document.
    pub head: f64,
    /// Relative change in percent; 0 when the base is 0 (no meaningful
    /// relative measure).
    pub delta_pct: f64,
}

/// Compare two benchmark JSON documents (`BENCH_*.json` as emitted by the
/// bench binaries via [`LatencySummary::to_json`]) by walking every
/// numeric leaf both share. Leaves present on only one side are skipped:
/// benches gain and lose fields across commits, and `hasfl bench-diff`
/// must keep working across that skew.
pub fn bench_diff(base: &Json, head: &Json) -> Vec<BenchDelta> {
    fn walk(base: &Json, head: &Json, path: &str, out: &mut Vec<BenchDelta>) {
        match (base, head) {
            (Json::Obj(b), Json::Obj(h)) => {
                for (key, bv) in b {
                    if let Some(hv) = h.get(key) {
                        let sub = if path.is_empty() {
                            key.clone()
                        } else {
                            format!("{path}.{key}")
                        };
                        walk(bv, hv, &sub, out);
                    }
                }
            }
            (Json::Num(b), Json::Num(h)) => {
                let delta_pct = if *b != 0.0 { (h - b) / b * 100.0 } else { 0.0 };
                out.push(BenchDelta { path: path.to_string(), base: *b, head: *h, delta_pct });
            }
            _ => {}
        }
    }
    let mut out = Vec::new();
    walk(base, head, "", &mut out);
    out
}

/// The deltas that count as regressions for CI gating: tail-latency leaves
/// (`p50*`/`p95*`) that grew by more than `max_regress_pct` percent.
/// Throughput-ish counters (bytes, hits) swing with environment noise and
/// never gate.
pub fn bench_regressions(deltas: &[BenchDelta], max_regress_pct: f64) -> Vec<&BenchDelta> {
    deltas
        .iter()
        .filter(|d| {
            let leaf = d.path.rsplit('.').next().unwrap_or(&d.path);
            (leaf.starts_with("p50") || leaf.starts_with("p95")) && d.delta_pct > max_regress_pct
        })
        .collect()
}

/// Compare the `meta` blocks of two benchmark JSON documents and report
/// every leaf where the two runs disagree (plus leaves present on only
/// one side). Bench numbers are only comparable like-for-like: a p95
/// regression measured on a different `pool_width` or `host_cores` is a
/// hardware delta, not a code delta, so `hasfl bench-diff` prints these
/// as warnings instead of gating on them.
pub fn bench_meta_mismatches(base: &Json, head: &Json) -> Vec<String> {
    fn leaf(j: &Json) -> Option<String> {
        match j {
            Json::Num(n) => Some(format!("{n}")),
            Json::Str(s) => Some(s.clone()),
            Json::Bool(b) => Some(format!("{b}")),
            _ => None,
        }
    }
    let mut out = Vec::new();
    let (Some(Json::Obj(b)), Some(Json::Obj(h))) = (base.get("meta"), head.get("meta")) else {
        // One side predates bench metadata (or neither records it):
        // nothing to compare, and bench-diff must keep working across
        // that skew. A non-object `meta` (e.g. `null` from a hand-edited
        // document) carries no comparable leaves either, so it counts as
        // absent rather than tripping a spurious one-sided warning.
        let has_meta = |j: &Json| matches!(j.get("meta"), Some(Json::Obj(_)));
        if has_meta(base) != has_meta(head) {
            out.push("meta: recorded on only one side".to_string());
        }
        return out;
    };
    for (key, bv) in b {
        match h.get(key) {
            None => out.push(format!("meta.{key}: base {} vs head <absent>", leaf(bv).unwrap_or_default())),
            Some(hv) => {
                if let (Some(bs), Some(hs)) = (leaf(bv), leaf(hv)) {
                    if bs != hs {
                        out.push(format!("meta.{key}: base {bs} vs head {hs}"));
                    }
                }
            }
        }
    }
    for (key, hv) in h {
        if !b.contains_key(key) {
            out.push(format!("meta.{key}: base <absent> vs head {}", leaf(hv).unwrap_or_default()));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn history_with_accs(accs: &[f64]) -> History {
        let mut h = History::default();
        for (i, &a) in accs.iter().enumerate() {
            h.push(Record { round: i, sim_time: i as f64, loss: 1.0, test_acc: Some(a) });
        }
        h
    }

    #[test]
    fn converged_detects_stagnation() {
        let h = history_with_accs(&[0.1, 0.3, 0.5, 0.6, 0.6, 0.6, 0.6, 0.6, 0.6]);
        let (round, _, acc) = h.converged(0.0002, 5).unwrap();
        assert_eq!(round, 8);
        assert!((acc - 0.6).abs() < 1e-12);
    }

    #[test]
    fn improvement_resets_the_window() {
        let h = history_with_accs(&[0.1, 0.1, 0.1, 0.1, 0.5, 0.5, 0.5, 0.5]);
        // only 4 stagnant evals after the jump: not converged yet
        assert!(h.converged(0.0002, 5).is_none());
    }

    #[test]
    fn converged_none_when_still_improving() {
        let h = history_with_accs(&[0.1, 0.2, 0.3, 0.4, 0.5, 0.6]);
        assert!(h.converged(0.0002, 5).is_none());
        assert!(h.converged_or_last().is_some());
    }

    #[test]
    fn best_acc_is_max() {
        let h = history_with_accs(&[0.1, 0.7, 0.5]);
        assert_eq!(h.best_acc(), Some(0.7));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let h = history_with_accs(&[0.1, 0.2]);
        let dir = std::env::temp_dir().join("hasfl_metrics_test");
        let path = dir.join("h.csv");
        h.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("round,sim_time,loss,test_acc"));
    }

    #[test]
    fn csv_table_enforces_width() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.rowf(&[1.0, 2.0]);
        assert_eq!(t.n_rows(), 1);
    }

    #[test]
    fn fleet_trace_stats_and_csv() {
        let mut t = FleetTrace::default();
        for i in 1..=4usize {
            t.push(FleetRound {
                round: i,
                n_active: 8 - i,
                n_dropped: i % 2,
                n_joined: 0,
                n_left: 0,
                drift: 0.1 * i as f64,
                resolved: i % 2 == 0,
                t_split: i as f64,
                t_agg: 0.0,
                sim_time: i as f64,
                flushed: 0,
                stale_drops: 0,
                staleness_mean: 0.0,
            });
        }
        assert_eq!(t.len(), 4);
        assert_eq!(t.resolves(), 2);
        assert_eq!(t.partial_rounds(), 2);
        assert_eq!(t.split_summary().unwrap().max, 4.0);
        assert!(t.drift_summary().unwrap().mean > 0.0);

        let path = std::env::temp_dir().join("hasfl_fleet_trace_test.csv");
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("round,n_active,n_dropped"));
        assert_eq!(text.lines().count(), 5);
    }

    #[test]
    fn percentile_nearest_rank() {
        let s: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        assert_eq!(percentile(&s, 0.50), 50.0);
        assert_eq!(percentile(&s, 0.95), 95.0);
        assert_eq!(percentile(&s, 0.0), 1.0);
        assert_eq!(percentile(&s, 1.0), 100.0);
        assert_eq!(percentile(&[7.0], 0.5), 7.0);
    }

    #[test]
    fn latency_summary_orders_samples() {
        let sum = LatencySummary::from_samples(&[3.0, 1.0, 2.0, 10.0]).unwrap();
        assert_eq!(sum.min, 1.0);
        assert_eq!(sum.max, 10.0);
        assert_eq!(sum.p50, 2.0);
        assert_eq!(sum.p95, 10.0);
        assert_eq!(sum.n, 4);
        assert!((sum.mean - 4.0).abs() < 1e-12);
        assert!(LatencySummary::from_samples(&[]).is_none());

        let ms = sum.scaled(1e-6);
        assert!((ms.max - 1e-5).abs() < 1e-18);
        let j = ms.to_json("ms");
        assert!(j.get("p95_ms").is_some());
        assert!(j.get("samples").is_some());
    }

    #[test]
    fn bench_diff_walks_shared_numeric_leaves() {
        let base = Json::parse(
            r#"{"latency": {"p50_ms": 10.0, "p95_ms": 20.0, "samples": 100},
                "gone": 1.0, "label": "a"}"#,
        )
        .unwrap();
        let head = Json::parse(
            r#"{"latency": {"p50_ms": 11.0, "p95_ms": 18.0, "samples": 100},
                "new": 2.0, "label": "b"}"#,
        )
        .unwrap();
        let deltas = bench_diff(&base, &head);
        let paths: Vec<&str> = deltas.iter().map(|d| d.path.as_str()).collect();
        // Shared numeric leaves only: no `gone`, no `new`, no strings.
        assert_eq!(paths, vec!["latency.p50_ms", "latency.p95_ms", "latency.samples"]);
        let p50 = &deltas[0];
        assert!((p50.delta_pct - 10.0).abs() < 1e-9, "{}", p50.delta_pct);

        // Only p50/p95 growth beyond the threshold gates.
        let regressions = bench_regressions(&deltas, 5.0);
        assert_eq!(regressions.len(), 1);
        assert_eq!(regressions[0].path, "latency.p50_ms");
        assert!(bench_regressions(&deltas, 15.0).is_empty());
    }

    #[test]
    fn bench_diff_zero_base_has_no_relative_delta() {
        let base = Json::parse(r#"{"p95_ms": 0.0}"#).unwrap();
        let head = Json::parse(r#"{"p95_ms": 5.0}"#).unwrap();
        let deltas = bench_diff(&base, &head);
        assert_eq!(deltas[0].delta_pct, 0.0);
    }

    #[test]
    fn bench_meta_mismatches_flag_environment_skew() {
        let base = Json::parse(
            r#"{"meta": {"pool_width": 4, "host_cores": 8, "backend": "native"},
                "latency": {"p95_ms": 20.0}}"#,
        )
        .unwrap();
        let same = bench_meta_mismatches(&base, &base);
        assert!(same.is_empty(), "{same:?}");

        let head = Json::parse(
            r#"{"meta": {"pool_width": 2, "host_cores": 8, "os": "linux"},
                "latency": {"p95_ms": 20.0}}"#,
        )
        .unwrap();
        let mismatches = bench_meta_mismatches(&base, &head);
        assert!(mismatches.iter().any(|m| m.contains("meta.pool_width") && m.contains("4") && m.contains("2")), "{mismatches:?}");
        assert!(mismatches.iter().any(|m| m.contains("meta.backend") && m.contains("<absent>")), "{mismatches:?}");
        assert!(mismatches.iter().any(|m| m.contains("meta.os") && m.contains("<absent>")), "{mismatches:?}");
        assert!(!mismatches.iter().any(|m| m.contains("host_cores")), "{mismatches:?}");

        // Never gates: meta leaves are not p50/p95 leaves.
        let deltas = bench_diff(&base, &head);
        assert!(bench_regressions(&deltas, 0.0).is_empty());
    }

    #[test]
    fn bench_meta_mismatches_tolerate_pre_metadata_documents() {
        let old = Json::parse(r#"{"latency": {"p95_ms": 20.0}}"#).unwrap();
        let new = Json::parse(r#"{"meta": {"pool_width": 4}, "latency": {"p95_ms": 20.0}}"#).unwrap();
        assert!(bench_meta_mismatches(&old, &old).is_empty());
        let skew = bench_meta_mismatches(&old, &new);
        assert_eq!(skew, vec!["meta: recorded on only one side".to_string()]);
    }

    #[test]
    fn bench_meta_non_object_counts_as_absent() {
        let null_meta = Json::parse(r#"{"meta": null, "latency": {"p95_ms": 20.0}}"#).unwrap();
        let no_meta = Json::parse(r#"{"latency": {"p95_ms": 20.0}}"#).unwrap();
        let real_meta =
            Json::parse(r#"{"meta": {"pool_width": 4}, "latency": {"p95_ms": 20.0}}"#).unwrap();
        // `"meta": null` vs no meta at all: both carry nothing comparable,
        // so no warning — this used to print a spurious one-sided WARNING.
        assert!(bench_meta_mismatches(&null_meta, &no_meta).is_empty());
        assert!(bench_meta_mismatches(&no_meta, &null_meta).is_empty());
        assert!(bench_meta_mismatches(&null_meta, &null_meta).is_empty());
        // But a real meta block against a null one is still one-sided.
        let skew = bench_meta_mismatches(&null_meta, &real_meta);
        assert_eq!(skew, vec!["meta: recorded on only one side".to_string()]);
    }

    #[test]
    fn cell_stats_json_shape() {
        let c = CellStats { cell: 2, devices: 5, participants: 4, abandoned: 1, t_split: 0.75 };
        let j = c.to_json();
        assert_eq!(j.get("cell"), Some(&Json::Num(2.0)));
        assert_eq!(j.get("devices"), Some(&Json::Num(5.0)));
        assert_eq!(j.get("participants"), Some(&Json::Num(4.0)));
        assert_eq!(j.get("abandoned"), Some(&Json::Num(1.0)));
        assert_eq!(j.get("t_split"), Some(&Json::Num(0.75)));
    }
}
