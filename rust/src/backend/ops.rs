//! Numeric kernels for the native backend: cache-blocked, register-tiled
//! row-major f32 GEMMs, SAME-padded im2col/col2im, 2x2 maxpool, and
//! weighted softmax cross-entropy — the same building blocks the L1
//! Pallas kernels provide to the JAX model.
//!
//! The GEMM kernels are hand-tiled ([`GEMM_MR`] x [`GEMM_NR`] register
//! tiles over packed B panels) so the autovectorizer turns the inner
//! loops into SIMD, and the heavy kernels fan independent output rows out
//! across a scoped thread pool. Neither changes a single bit of output:
//! every per-element reduction keeps one accumulator and a fixed
//! ascending reduction order, and parallel chunks never share an output
//! row, so the native backend stays bit-deterministic across runs, engine
//! lanes, thread budgets, and resume boundaries
//! (`rust/tests/backend_parity.rs`; DESIGN.md §14). Agreement with the
//! PJRT backend is within float tolerance only: XLA fuses and reorders
//! f32 reductions, so the two backends accumulate in different orders
//! (DESIGN.md §11).
//!
//! The naive kernels are retained as `*_ref`: they are the bit-identity
//! oracles for the tiled paths and the baseline of the `kernel_native`
//! bench series in `BENCH_e2e.json` (docs/PERFORMANCE.md).

/// Row height of the GEMM register tile: each micro-kernel invocation
/// accumulates this many rows of `C` at once. 4 rows x [`GEMM_NR`] lanes
/// keeps the whole accumulator tile plus one packed-B row inside the
/// vector register file on AVX2-class cores (DESIGN.md §14 documents how
/// to re-tune these constants).
pub const GEMM_MR: usize = 4;

/// Column width of the GEMM register tile and of the packed-B panels:
/// two AVX2 (one AVX-512) f32 vectors per accumulator row. Panels are
/// zero-padded to this width so the inner loop is always full-width and
/// branch-free; only the final writeback is clipped to the true width.
pub const GEMM_NR: usize = 16;

/// Below this many multiply-accumulates (`m·k·n`) a GEMM call runs the
/// naive reference directly: panel packing would cost more than it
/// saves, and both paths are bit-identical so the switch is invisible.
pub const GEMM_SMALL_MACS: usize = 1 << 14;

/// Minimum multiply-accumulates before a GEMM fans row-blocks out across
/// worker threads; below it the scoped-thread spawn overhead outweighs
/// the kernel itself.
pub const GEMM_PAR_MIN_MACS: usize = 1 << 21;

/// Minimum output elements before an im2col/col2im/pool/softmax kernel
/// fans rows out across worker threads.
pub const PAR_MIN_ELEMS: usize = 1 << 15;

/// Run `f(first_row, chunk)` over disjoint, contiguous row chunks of
/// `out` (each row `row_len` elements long) on up to `threads` scoped
/// worker threads. Chunk boundaries land on multiples of `granule` rows
/// so a blocked kernel's row tiles never straddle a split. Every output
/// row is written by exactly one worker and no reduction crosses a
/// chunk, so the result is bit-identical at every thread count
/// (DESIGN.md §14).
fn par_rows<F>(threads: usize, out: &mut [f32], row_len: usize, granule: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let rows = if row_len == 0 { 0 } else { out.len() / row_len };
    debug_assert_eq!(rows * row_len, out.len());
    let granule = granule.max(1);
    let granules = rows.div_ceil(granule);
    let workers = threads.clamp(1, granules.max(1));
    if workers <= 1 {
        f(0, out);
        return;
    }
    let chunk_rows = granules.div_ceil(workers) * granule;
    std::thread::scope(|s| {
        let f = &f;
        let mut rest = out;
        let mut row0 = 0usize;
        while !rest.is_empty() {
            let take = chunk_rows.min(rest.len() / row_len);
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take * row_len);
            rest = tail;
            if rest.is_empty() {
                // The final chunk runs on the calling thread.
                f(row0, head);
            } else {
                s.spawn(move || f(row0, head));
            }
            row0 += take;
        }
    });
}

/// Two-slice sibling of [`par_rows`] for kernels with paired outputs
/// (pooled values + routing indices, gradients + per-row stats): both
/// slices split at the same row boundaries, so each worker owns the same
/// rows of each.
fn par_rows2<T, U, F>(threads: usize, a: &mut [T], alen: usize, b: &mut [U], blen: usize, f: F)
where
    T: Send,
    U: Send,
    F: Fn(usize, &mut [T], &mut [U]) + Sync,
{
    let rows = if alen == 0 { 0 } else { a.len() / alen };
    debug_assert_eq!(rows * alen, a.len());
    debug_assert_eq!(rows * blen, b.len());
    let workers = threads.clamp(1, rows.max(1));
    if workers <= 1 {
        f(0, a, b);
        return;
    }
    let chunk_rows = rows.div_ceil(workers);
    std::thread::scope(|s| {
        let f = &f;
        let mut arest = a;
        let mut brest = b;
        let mut row0 = 0usize;
        while !arest.is_empty() {
            let take = chunk_rows.min(arest.len() / alen);
            let (ahead, atail) = std::mem::take(&mut arest).split_at_mut(take * alen);
            let (bhead, btail) = std::mem::take(&mut brest).split_at_mut(take * blen);
            arest = atail;
            brest = btail;
            if arest.is_empty() {
                f(row0, ahead, bhead);
            } else {
                s.spawn(move || f(row0, ahead, bhead));
            }
            row0 += take;
        }
    });
}

/// Row-major transpose: `dst[cols, rows]` from `src[rows, cols]`.
fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    debug_assert_eq!(src.len(), rows * cols);
    let mut dst = vec![0.0f32; src.len()];
    for r in 0..rows {
        for (cc, &v) in src[r * cols..(r + 1) * cols].iter().enumerate() {
            dst[cc * rows + r] = v;
        }
    }
    dst
}

/// Cache-blocked, register-tiled GEMM core: `C[m,n] = A[m,k] · B[k,n]`,
/// all row-major. `B` is packed once into [`GEMM_NR`]-wide, zero-padded
/// column panels the micro-kernel streams contiguously; each
/// [`GEMM_MR`] x [`GEMM_NR`] output tile keeps one accumulator per
/// element and sweeps the *full* `k` range in ascending order — the
/// exact reduction order of [`mm_ref`], which is what keeps the fast
/// kernels bit-identical to the reference while the fixed-width inner
/// loops autovectorize. Row-blocks of `C` are farmed out over `threads`
/// workers ([`par_rows`]); rows are independent, so the split cannot
/// reorder any reduction.
fn gemm_blocked(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, threads: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    let np = n.div_ceil(GEMM_NR);
    let mut packed = vec![0.0f32; np * k * GEMM_NR];
    for p in 0..np {
        let j0 = p * GEMM_NR;
        let w = GEMM_NR.min(n - j0);
        let panel = &mut packed[p * k * GEMM_NR..(p + 1) * k * GEMM_NR];
        for kk in 0..k {
            let src = &b[kk * n + j0..kk * n + j0 + w];
            panel[kk * GEMM_NR..kk * GEMM_NR + w].copy_from_slice(src);
        }
    }
    let packed = &packed[..];
    let t = if m * k * n >= GEMM_PAR_MIN_MACS { threads } else { 1 };
    par_rows(t, &mut c, n, GEMM_MR, move |row0, csub| {
        let rows = csub.len() / n;
        let mut i = 0usize;
        while i < rows {
            let mr = GEMM_MR.min(rows - i);
            let arows = &a[(row0 + i) * k..(row0 + i + mr) * k];
            for p in 0..np {
                let j0 = p * GEMM_NR;
                let w = GEMM_NR.min(n - j0);
                let panel = &packed[p * k * GEMM_NR..(p + 1) * k * GEMM_NR];
                let mut acc = [[0.0f32; GEMM_NR]; GEMM_MR];
                if mr == GEMM_MR {
                    // Hot path: splitting A's rows up front lets the
                    // bounds checks hoist out of the k-loop, so the body
                    // is GEMM_MR broadcasts against one packed row.
                    let (a0, r1) = arows.split_at(k);
                    let (a1, r2) = r1.split_at(k);
                    let (a2, a3) = r2.split_at(k);
                    for (kk, prow) in panel.chunks_exact(GEMM_NR).enumerate() {
                        let av = [a0[kk], a1[kk], a2[kk], a3[kk]];
                        for (accr, &avr) in acc.iter_mut().zip(&av) {
                            for (cv, &pv) in accr.iter_mut().zip(prow) {
                                *cv += avr * pv;
                            }
                        }
                    }
                } else {
                    // Remainder rows (m % GEMM_MR) take the generic path.
                    for (kk, prow) in panel.chunks_exact(GEMM_NR).enumerate() {
                        for (r, accr) in acc.iter_mut().take(mr).enumerate() {
                            let avr = arows[r * k + kk];
                            for (cv, &pv) in accr.iter_mut().zip(prow) {
                                *cv += avr * pv;
                            }
                        }
                    }
                }
                for (r, accr) in acc.iter().take(mr).enumerate() {
                    let dst = (i + r) * n + j0;
                    csub[dst..dst + w].copy_from_slice(&accr[..w]);
                }
            }
            i += mr;
        }
    });
    c
}

/// `C[m,n] = A[m,k] · B[k,n]` (row-major), cache-blocked and
/// register-tiled, with row-blocks parallelized over up to `threads`
/// scoped workers. Bit-identical to [`mm_ref`] at every thread count:
/// the tiled kernel keeps one accumulator per output element and the
/// full ascending-`k` reduction order (DESIGN.md §14). Shapes below
/// [`GEMM_SMALL_MACS`] multiply-accumulates run the reference directly.
pub fn mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, threads: usize) -> Vec<f32> {
    if m * k * n < GEMM_SMALL_MACS {
        return mm_ref(a, b, m, k, n);
    }
    gemm_blocked(a, b, m, k, n, threads)
}

/// `C[k,n] = A[m,k]ᵀ · B[m,n]` (row-major) — the `dW = Xᵀ·dY` shape —
/// tiled and parallelized like [`mm`], bit-identical to
/// [`mm_at_b_ref`]: transposing `A` turns the over-`m` reduction into
/// `gemm_blocked`'s ascending over-`k` form without changing a single
/// product or its accumulation order.
pub fn mm_at_b(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, threads: usize) -> Vec<f32> {
    if m * k * n < GEMM_SMALL_MACS {
        return mm_at_b_ref(a, b, m, k, n);
    }
    let at = transpose(a, m, k);
    gemm_blocked(&at, b, k, m, n, threads)
}

/// `C[m,k] = A[m,n] · B[k,n]ᵀ` (row-major) — the `dX = dY·Wᵀ` shape —
/// tiled and parallelized like [`mm`], bit-identical to
/// [`mm_a_bt_ref`]: packing `Bᵀ` turns each reference dot product into
/// `gemm_blocked`'s axpy form; per output element the products and
/// their order are unchanged. (The reference's inner dot never
/// autovectorizes — f32 reduction order is not associative — so this
/// shape gains the most from tiling.)
pub fn mm_a_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, threads: usize) -> Vec<f32> {
    if m * n * k < GEMM_SMALL_MACS {
        return mm_a_bt_ref(a, b, m, n, k);
    }
    let bt = transpose(b, k, n);
    gemm_blocked(a, &bt, m, n, k, threads)
}

/// Naive reference `C[m,n] = A[m,k] · B[k,n]` (row-major). i-k-j loop
/// order: the inner loop is a contiguous axpy over a row of B, and the
/// k-accumulation order is fixed. Retained as the bit-identity oracle
/// for [`mm`] and as the pre-tiling baseline the `kernel_native` bench
/// series measures against (docs/PERFORMANCE.md).
pub fn mm_ref(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
    c
}

/// Naive reference `C[k,n] = A[m,k]ᵀ · B[m,n]` (row-major) — the
/// bit-identity oracle for [`mm_at_b`].
pub fn mm_at_b_ref(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    let mut c = vec![0.0f32; k * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let crow = &mut c[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    c
}

/// Naive reference `C[m,k] = A[m,n] · B[k,n]ᵀ` (row-major) — the
/// bit-identity oracle for [`mm_a_bt`].
pub fn mm_a_bt_ref(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * k];
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        let crow = &mut c[i * k..(i + 1) * k];
        for (kk, cv) in crow.iter_mut().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            *cv = acc;
        }
    }
    c
}

/// Add bias `b[n]` to every row of `z[m,n]`, optionally applying ReLU.
pub fn add_bias_act(z: &mut [f32], bias: &[f32], n: usize, relu: bool) {
    debug_assert_eq!(z.len() % n, 0);
    debug_assert_eq!(bias.len(), n);
    for row in z.chunks_mut(n) {
        for (v, &bv) in row.iter_mut().zip(bias) {
            *v += bv;
            if relu && *v < 0.0 {
                *v = 0.0;
            }
        }
    }
}

/// SAME-padded 3x3 im2col over NHWC input: output `[b*h*w, 9*c]` with
/// feature order `(i, j, c)` — matching `model._im2col` in Python, so the
/// `[3,3,cin,cout] -> [9*cin, cout]` weight reshape lines up row-major.
/// Output rows (one per input row of one image) are gathered in parallel
/// across up to `threads` workers; each output element is written exactly
/// once, so the result is bit-identical at every thread count.
pub fn im2col3x3(x: &[f32], b: usize, h: usize, w: usize, c: usize, threads: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), b * h * w * c);
    let kdim = 9 * c;
    let row_len = w * kdim;
    let mut cols = vec![0.0f32; b * h * row_len];
    let t = if cols.len() >= PAR_MIN_ELEMS { threads } else { 1 };
    par_rows(t, &mut cols, row_len, 1, |row0, sub| {
        for (rr, orow) in sub.chunks_mut(row_len).enumerate() {
            let (bi, y) = ((row0 + rr) / h, (row0 + rr) % h);
            for xx in 0..w {
                let out_base = xx * kdim;
                for i in 0..3usize {
                    let sy = y + i;
                    if sy < 1 || sy > h {
                        continue; // zero padding row
                    }
                    for j in 0..3usize {
                        let sx = xx + j;
                        if sx < 1 || sx > w {
                            continue; // zero padding column
                        }
                        let src = ((bi * h + (sy - 1)) * w + (sx - 1)) * c;
                        let dst = out_base + (i * 3 + j) * c;
                        orow[dst..dst + c].copy_from_slice(&x[src..src + c]);
                    }
                }
            }
        }
    });
    cols
}

/// Scatter-add transpose of [`im2col3x3`]: fold `dcols[b*h*w, 9*c]` back
/// into an NHWC gradient `[b,h,w,c]`. Parallelized per image — the
/// scatter-add is confined to one image, so per-element accumulation
/// order (ascending `y`, `x`, tap) is identical at every thread count.
pub fn col2im3x3_add(
    dcols: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    threads: usize,
) -> Vec<f32> {
    let kdim = 9 * c;
    debug_assert_eq!(dcols.len(), b * h * w * kdim);
    let img = h * w * c;
    let mut dx = vec![0.0f32; b * img];
    let t = if dx.len() >= PAR_MIN_ELEMS { threads } else { 1 };
    par_rows(t, &mut dx, img, 1, |img0, sub| {
        for (ii, dimg) in sub.chunks_mut(img).enumerate() {
            let bi = img0 + ii;
            for y in 0..h {
                for xx in 0..w {
                    let col_base = ((bi * h + y) * w + xx) * kdim;
                    for i in 0..3usize {
                        let sy = y + i;
                        if sy < 1 || sy > h {
                            continue;
                        }
                        for j in 0..3usize {
                            let sx = xx + j;
                            if sx < 1 || sx > w {
                                continue;
                            }
                            let dst = ((sy - 1) * w + (sx - 1)) * c;
                            let src = col_base + (i * 3 + j) * c;
                            let taps = dimg[dst..dst + c].iter_mut();
                            for (dv, &gv) in taps.zip(&dcols[src..src + c]) {
                                *dv += gv;
                            }
                        }
                    }
                }
            }
        }
    });
    dx
}

/// 2x2 maxpool over NHWC input `[b,h,w,c]` (h, w even): returns the
/// pooled tensor `[b,h/2,w/2,c]` and, per pooled element, the flat index
/// of the winning input element (first maximum in row-major window order
/// — the tie-break only matters on exactly-equal activations). Pooled
/// rows are scanned in parallel across up to `threads` workers; windows
/// never span a pooled row, so results are bit-identical at every thread
/// count.
pub fn maxpool2(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    threads: usize,
) -> (Vec<f32>, Vec<u32>) {
    debug_assert_eq!(x.len(), b * h * w * c);
    let (oh, ow) = (h / 2, w / 2);
    let row_len = ow * c;
    let mut out = vec![0.0f32; b * oh * row_len];
    let mut idx = vec![0u32; b * oh * row_len];
    let t = if x.len() >= PAR_MIN_ELEMS { threads } else { 1 };
    par_rows2(t, &mut out, row_len, &mut idx, row_len, |row0, osub, isub| {
        let pairs = osub.chunks_mut(row_len).zip(isub.chunks_mut(row_len));
        for (rr, (orow, irow)) in pairs.enumerate() {
            let (bi, oy) = ((row0 + rr) / oh, (row0 + rr) % oh);
            for ox in 0..ow {
                for ch in 0..c {
                    // Seed from the window's first element (not -inf/0):
                    // an all-NaN window then propagates NaN and routes its
                    // gradient inside the window instead of to index 0.
                    let first = ((bi * h + 2 * oy) * w + 2 * ox) * c + ch;
                    let mut best = x[first];
                    let mut best_at = first as u32;
                    for dy in 0..2usize {
                        for dx in 0..2usize {
                            let src = ((bi * h + (2 * oy + dy)) * w + (2 * ox + dx)) * c + ch;
                            let v = x[src];
                            if v > best {
                                best = v;
                                best_at = src as u32;
                            }
                        }
                    }
                    orow[ox * c + ch] = best;
                    irow[ox * c + ch] = best_at;
                }
            }
        }
    });
    (out, idx)
}

/// Backward of [`maxpool2`]: route each pooled gradient to its winning
/// input position.
pub fn maxpool2_bwd(dout: &[f32], idx: &[u32], in_len: usize) -> Vec<f32> {
    debug_assert_eq!(dout.len(), idx.len());
    let mut dx = vec![0.0f32; in_len];
    for (&g, &at) in dout.iter().zip(idx) {
        dx[at as usize] += g;
    }
    dx
}

/// Weighted softmax cross-entropy over `logits[b, classes]`: returns
/// `(loss, correct, dlogits)` where
/// `loss = Σ_r w_r·(lse_r - Σ_c onehot·logits) / max(Σ w, 1)`,
/// `correct = Σ_r w_r·[argmax logits == argmax onehot]`, and
/// `dlogits[r] = (w_r / max(Σ w, 1)) · (softmax(logits_r) - onehot_r)` —
/// the exact forward/VJP pair of the Pallas `softmax_xent` kernel under
/// the model's weighted-mean reduction.
///
/// The per-row work (log-sum-exp, gradient row, hit flag) fans out over
/// up to `threads` workers; the loss/correct totals are then reduced
/// sequentially in ascending row order, so both scalars and the gradient
/// are bit-identical at every thread count.
pub fn softmax_xent(
    logits: &[f32],
    onehot: &[f32],
    weights: &[f32],
    b: usize,
    classes: usize,
    threads: usize,
) -> (f32, f32, Vec<f32>) {
    debug_assert_eq!(logits.len(), b * classes);
    debug_assert_eq!(onehot.len(), b * classes);
    debug_assert_eq!(weights.len(), b);
    let wsum: f32 = weights.iter().sum();
    let denom = wsum.max(1.0);
    let mut dlogits = vec![0.0f32; b * classes];
    // Per-row `(lse, logit·onehot, hit)` triples, filled in parallel.
    let mut stats = vec![0.0f32; 3 * b];
    let t = if dlogits.len() >= PAR_MIN_ELEMS { threads } else { 1 };
    par_rows2(t, &mut dlogits, classes, &mut stats, 3, |row0, dsub, ssub| {
        let pairs = dsub.chunks_mut(classes).zip(ssub.chunks_mut(3));
        for (rr, (drow, srow)) in pairs.enumerate() {
            let r = row0 + rr;
            let lrow = &logits[r * classes..(r + 1) * classes];
            let yrow = &onehot[r * classes..(r + 1) * classes];
            let wr = weights[r];

            let maxv = lrow.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut expsum = 0.0f32;
            for &v in lrow {
                expsum += (v - maxv).exp();
            }
            srow[0] = maxv + expsum.ln();
            srow[1] = lrow.iter().zip(yrow).map(|(&l, &y)| l * y).sum();
            srow[2] = if argmax(lrow) == argmax(yrow) { 1.0 } else { 0.0 };

            let scale = wr / denom;
            for ((dv, &lv), &yv) in drow.iter_mut().zip(lrow).zip(yrow) {
                let p = (lv - maxv).exp() / expsum;
                *dv = scale * (p - yv);
            }
        }
    });
    // Sequential ascending-row reduction: the same accumulation the naive
    // kernel performed inline, so totals are thread-count-invariant.
    let mut loss = 0.0f32;
    let mut correct = 0.0f32;
    for (r, srow) in stats.chunks(3).enumerate() {
        let wr = weights[r];
        loss += wr * (srow[0] - srow[1]);
        if srow[2] != 0.0 {
            correct += wr;
        }
    }
    (loss / denom, correct, dlogits)
}

/// First index of the maximum value (the `jnp.argmax` tie-break).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

/// Column-wise sum of `g[m,n]` — the bias gradient.
pub fn col_sum(g: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(g.len() % n, 0);
    let mut out = vec![0.0f32; n];
    for row in g.chunks(n) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mm_matches_hand_result() {
        // [2,3] x [3,2]
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
        let c = mm(&a, &b, 2, 3, 2, 1);
        assert_eq!(c, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn transposed_gemms_agree_with_plain_mm() {
        let mut rng = crate::rng::Pcg32::seeded(7);
        let (m, k, n) = (5, 4, 3);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
        // A^T B via explicit transpose + mm.
        let at = transpose(&a, m, k);
        let want = mm(&at, &b, k, m, n, 1);
        let got = mm_at_b(&a, &b, m, k, n, 1);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5);
        }
        // A B^T via explicit transpose + mm.
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let wt = transpose(&w, k, n);
        let want = mm(&b, &wt, m, n, k, 1);
        let got = mm_a_bt(&b, &w, m, n, k, 1);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn tiled_gemms_bit_match_the_naive_reference() {
        // Odd/remainder shapes (not multiples of GEMM_MR/GEMM_NR), shapes
        // large enough to take the blocked and parallel paths, and 1 vs N
        // threads: every combination must be *bit*-identical to the naive
        // reference, not merely close.
        let mut rng = crate::rng::Pcg32::seeded(42);
        let shapes = [
            (1usize, 1usize, 1usize),
            (GEMM_MR - 1, 3, GEMM_NR - 1),
            (GEMM_MR + 1, 7, GEMM_NR + 1),
            (2 * GEMM_MR + 3, 31, 2 * GEMM_NR + 5),
            (37, 129, 65),
            (64, 80, 48),
            // Above GEMM_PAR_MIN_MACS: the scoped-thread split engages.
            (129, 65, 257),
        ];
        for &(m, k, n) in &shapes {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let want = mm_ref(&a, &b, m, k, n);
            for threads in [1, 3] {
                assert_eq!(gemm_blocked(&a, &b, m, k, n, threads), want, "mm {m}x{k}x{n}");
                assert_eq!(mm(&a, &b, m, k, n, threads), want, "mm wrap {m}x{k}x{n}");
            }

            // dW shape: A[m,k] (as X) against G[m,n] (as dY).
            let g: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
            let want = mm_at_b_ref(&a, &g, m, k, n);
            let at = transpose(&a, m, k);
            for threads in [1, 3] {
                assert_eq!(gemm_blocked(&at, &g, k, m, n, threads), want, "at_b {m}x{k}x{n}");
                assert_eq!(mm_at_b(&a, &g, m, k, n, threads), want, "at_b wrap {m}x{k}x{n}");
            }

            // dX shape: G[m,n] (as dY) against B[k,n] (as W).
            let want = mm_a_bt_ref(&g, &b, m, n, k);
            let bt = transpose(&b, k, n);
            for threads in [1, 3] {
                assert_eq!(gemm_blocked(&g, &bt, m, n, k, threads), want, "a_bt {m}x{k}x{n}");
                assert_eq!(mm_a_bt(&g, &b, m, n, k, threads), want, "a_bt wrap {m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn empty_dims_yield_empty_or_zero_results() {
        assert!(mm(&[], &[], 0, 3, 4, 2).is_empty());
        assert!(mm(&[0.0; 6], &[], 2, 3, 0, 2).is_empty());
        assert_eq!(mm(&[], &[], 2, 0, 3, 2), vec![0.0; 6]);
        assert!(gemm_blocked(&[], &[], 0, 0, 0, 4).is_empty());
        assert_eq!(mm_at_b(&[], &[], 0, 2, 3, 1), vec![0.0; 6]);
        assert!(mm_a_bt(&[], &[], 0, 3, 2, 1).is_empty());
    }

    #[test]
    fn thread_count_never_changes_bits_for_window_kernels() {
        // Shapes at/above PAR_MIN_ELEMS so the parallel paths engage.
        let mut rng = crate::rng::Pcg32::seeded(13);
        let (b, h, w, c) = (4, 16, 16, 32);
        let x: Vec<f32> = (0..b * h * w * c).map(|_| rng.normal() as f32).collect();
        assert_eq!(im2col3x3(&x, b, h, w, c, 5), im2col3x3(&x, b, h, w, c, 1));
        let g: Vec<f32> = (0..b * h * w * 9 * c).map(|_| rng.normal() as f32).collect();
        assert_eq!(col2im3x3_add(&g, b, h, w, c, 5), col2im3x3_add(&g, b, h, w, c, 1));
        let (o5, i5) = maxpool2(&x, b, h, w, c, 5);
        let (o1, i1) = maxpool2(&x, b, h, w, c, 1);
        assert_eq!(o5, o1);
        assert_eq!(i5, i1);

        let (rows, classes) = (256, 128);
        let logits: Vec<f32> = (0..rows * classes).map(|_| rng.normal() as f32).collect();
        let mut onehot = vec![0.0f32; rows * classes];
        for r in 0..rows {
            onehot[r * classes + (r * 7) % classes] = 1.0;
        }
        let weights: Vec<f32> = (0..rows).map(|r| if r % 3 == 0 { 0.0 } else { 1.0 }).collect();
        let many = softmax_xent(&logits, &onehot, &weights, rows, classes, 5);
        let one = softmax_xent(&logits, &onehot, &weights, rows, classes, 1);
        assert_eq!(many.0.to_bits(), one.0.to_bits());
        assert_eq!(many.1.to_bits(), one.1.to_bits());
        assert_eq!(many.2, one.2);
    }

    #[test]
    fn im2col_identity_kernel_center_tap() {
        // With a single channel, the center tap (i=1, j=1) of each output
        // row is the input pixel itself.
        let (b, h, w, c) = (1, 4, 4, 1);
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let cols = im2col3x3(&x, b, h, w, c, 1);
        for p in 0..16 {
            assert_eq!(cols[p * 9 + 4], x[p]);
        }
        // Top-left output pixel: taps above/left are zero padding.
        assert_eq!(cols[0], 0.0); // (i=0, j=0)
        assert_eq!(cols[1], 0.0); // (i=0, j=1)
        assert_eq!(cols[3], 0.0); // (i=1, j=0)
        assert_eq!(cols[5], x[1]); // (i=1, j=2) -> right neighbour
        assert_eq!(cols[7], x[4]); // (i=2, j=1) -> below neighbour
    }

    #[test]
    fn col2im_is_the_transpose_of_im2col() {
        // <im2col(x), g> == <x, col2im(g)> for random x, g — the defining
        // property of an adjoint pair.
        let mut rng = crate::rng::Pcg32::seeded(3);
        let (b, h, w, c) = (2, 4, 4, 3);
        let x: Vec<f32> = (0..b * h * w * c).map(|_| rng.normal() as f32).collect();
        let g: Vec<f32> = (0..b * h * w * 9 * c).map(|_| rng.normal() as f32).collect();
        let cols = im2col3x3(&x, b, h, w, c, 1);
        let folded = col2im3x3_add(&g, b, h, w, c, 1);
        let lhs: f64 = cols.iter().zip(&g).map(|(&a, &b)| a as f64 * b as f64).sum();
        let rhs: f64 = x.iter().zip(&folded).map(|(&a, &b)| a as f64 * b as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }

    #[test]
    fn maxpool_picks_window_maxima_and_routes_gradients() {
        let (b, h, w, c) = (1, 2, 2, 1);
        let x = [1.0, 3.0, 2.0, 0.5];
        let (out, idx) = maxpool2(&x, b, h, w, c, 1);
        assert_eq!(out, vec![3.0]);
        assert_eq!(idx, vec![1]);
        let dx = maxpool2_bwd(&[2.5], &idx, 4);
        assert_eq!(dx, vec![0.0, 2.5, 0.0, 0.0]);
    }

    #[test]
    fn softmax_xent_uniform_logits_is_ln_classes() {
        let (b, classes) = (2, 10);
        let logits = vec![0.0f32; b * classes];
        let mut onehot = vec![0.0f32; b * classes];
        onehot[3] = 1.0;
        onehot[classes + 7] = 1.0;
        let weights = vec![1.0f32; b];
        let (loss, _, dlogits) = softmax_xent(&logits, &onehot, &weights, b, classes, 1);
        assert!((loss - (10.0f32).ln()).abs() < 1e-5);
        // Gradient sums to zero per row (softmax minus onehot).
        let s: f32 = dlogits[..classes].iter().sum();
        assert!(s.abs() < 1e-6);
    }

    #[test]
    fn zero_weight_rows_contribute_nothing() {
        let (b, classes) = (2, 4);
        let mut logits = vec![0.5f32; b * classes];
        logits[classes..].copy_from_slice(&[9.0, -3.0, 1.0, 4.0]); // padded row
        let mut onehot = vec![0.0f32; b * classes];
        onehot[1] = 1.0;
        onehot[classes + 2] = 1.0;
        let (loss_pad, correct_pad, d_pad) =
            softmax_xent(&logits, &onehot, &[1.0, 0.0], b, classes, 1);
        let (loss_solo, correct_solo, d_solo) =
            softmax_xent(&logits[..classes], &onehot[..classes], &[1.0], 1, classes, 1);
        assert!((loss_pad - loss_solo).abs() < 1e-6);
        assert!((correct_pad - correct_solo).abs() < 1e-6);
        for (a, b) in d_pad[..classes].iter().zip(&d_solo) {
            assert!((a - b).abs() < 1e-6);
        }
        assert!(d_pad[classes..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }
}
