//! Numeric kernels for the native backend: row-major f32 GEMMs, SAME-padded
//! im2col/col2im, 2x2 maxpool, and weighted softmax cross-entropy — the
//! same building blocks the L1 Pallas kernels provide to the JAX model.
//!
//! Every reduction runs in a fixed sequential order, so the native backend
//! is bit-deterministic across runs, engine lanes, and resume boundaries
//! (`rust/tests/backend_parity.rs`). Agreement with the PJRT backend is
//! within float tolerance only: XLA fuses and reorders f32 reductions, so
//! the two backends accumulate in different orders (DESIGN.md §11).

/// `C[m,n] = A[m,k] · B[k,n]` (row-major). i-k-j loop order: the inner
/// loop is a contiguous axpy over a row of B, which the compiler
/// vectorizes, and the k-accumulation order is fixed.
pub fn mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
    c
}

/// `C[k,n] = A[m,k]ᵀ · B[m,n]` (row-major) — the `dW = Xᵀ·dY` shape.
pub fn mm_at_b(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    let mut c = vec![0.0f32; k * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let crow = &mut c[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    c
}

/// `C[m,k] = A[m,n] · B[k,n]ᵀ` (row-major) — the `dX = dY·Wᵀ` shape.
pub fn mm_a_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * k];
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        let crow = &mut c[i * k..(i + 1) * k];
        for (kk, cv) in crow.iter_mut().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            *cv = acc;
        }
    }
    c
}

/// Add bias `b[n]` to every row of `z[m,n]`, optionally applying ReLU.
pub fn add_bias_act(z: &mut [f32], bias: &[f32], n: usize, relu: bool) {
    debug_assert_eq!(z.len() % n, 0);
    debug_assert_eq!(bias.len(), n);
    for row in z.chunks_mut(n) {
        for (v, &bv) in row.iter_mut().zip(bias) {
            *v += bv;
            if relu && *v < 0.0 {
                *v = 0.0;
            }
        }
    }
}

/// SAME-padded 3x3 im2col over NHWC input: output `[b*h*w, 9*c]` with
/// feature order `(i, j, c)` — matching `model._im2col` in Python, so the
/// `[3,3,cin,cout] -> [9*cin, cout]` weight reshape lines up row-major.
pub fn im2col3x3(x: &[f32], b: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), b * h * w * c);
    let kdim = 9 * c;
    let mut cols = vec![0.0f32; b * h * w * kdim];
    for bi in 0..b {
        for y in 0..h {
            for xx in 0..w {
                let out_base = ((bi * h + y) * w + xx) * kdim;
                for i in 0..3usize {
                    let sy = y + i;
                    if sy < 1 || sy > h {
                        continue; // zero padding row
                    }
                    for j in 0..3usize {
                        let sx = xx + j;
                        if sx < 1 || sx > w {
                            continue; // zero padding column
                        }
                        let src = ((bi * h + (sy - 1)) * w + (sx - 1)) * c;
                        let dst = out_base + (i * 3 + j) * c;
                        cols[dst..dst + c].copy_from_slice(&x[src..src + c]);
                    }
                }
            }
        }
    }
    cols
}

/// Scatter-add transpose of [`im2col3x3`]: fold `dcols[b*h*w, 9*c]` back
/// into an NHWC gradient `[b,h,w,c]`.
pub fn col2im3x3_add(dcols: &[f32], b: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let kdim = 9 * c;
    debug_assert_eq!(dcols.len(), b * h * w * kdim);
    let mut dx = vec![0.0f32; b * h * w * c];
    for bi in 0..b {
        for y in 0..h {
            for xx in 0..w {
                let col_base = ((bi * h + y) * w + xx) * kdim;
                for i in 0..3usize {
                    let sy = y + i;
                    if sy < 1 || sy > h {
                        continue;
                    }
                    for j in 0..3usize {
                        let sx = xx + j;
                        if sx < 1 || sx > w {
                            continue;
                        }
                        let dst = ((bi * h + (sy - 1)) * w + (sx - 1)) * c;
                        let src = col_base + (i * 3 + j) * c;
                        for (dv, &gv) in dx[dst..dst + c].iter_mut().zip(&dcols[src..src + c]) {
                            *dv += gv;
                        }
                    }
                }
            }
        }
    }
    dx
}

/// 2x2 maxpool over NHWC input `[b,h,w,c]` (h, w even): returns the pooled
/// tensor `[b,h/2,w/2,c]` and, per pooled element, the flat index of the
/// winning input element (first maximum in row-major window order — the
/// tie-break only matters on exactly-equal activations).
pub fn maxpool2(x: &[f32], b: usize, h: usize, w: usize, c: usize) -> (Vec<f32>, Vec<u32>) {
    debug_assert_eq!(x.len(), b * h * w * c);
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![0.0f32; b * oh * ow * c];
    let mut idx = vec![0u32; b * oh * ow * c];
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let out_base = ((bi * oh + oy) * ow + ox) * c;
                for ch in 0..c {
                    // Seed from the window's first element (not -inf/0):
                    // an all-NaN window then propagates NaN and routes its
                    // gradient inside the window instead of to index 0.
                    let first = ((bi * h + 2 * oy) * w + 2 * ox) * c + ch;
                    let mut best = x[first];
                    let mut best_at = first as u32;
                    for dy in 0..2usize {
                        for dx in 0..2usize {
                            let src = ((bi * h + (2 * oy + dy)) * w + (2 * ox + dx)) * c + ch;
                            let v = x[src];
                            if v > best {
                                best = v;
                                best_at = src as u32;
                            }
                        }
                    }
                    out[out_base + ch] = best;
                    idx[out_base + ch] = best_at;
                }
            }
        }
    }
    (out, idx)
}

/// Backward of [`maxpool2`]: route each pooled gradient to its winning
/// input position.
pub fn maxpool2_bwd(dout: &[f32], idx: &[u32], in_len: usize) -> Vec<f32> {
    debug_assert_eq!(dout.len(), idx.len());
    let mut dx = vec![0.0f32; in_len];
    for (&g, &at) in dout.iter().zip(idx) {
        dx[at as usize] += g;
    }
    dx
}

/// Weighted softmax cross-entropy over `logits[b, classes]`: returns
/// `(loss, correct, dlogits)` where
/// `loss = Σ_r w_r·(lse_r - Σ_c onehot·logits) / max(Σ w, 1)`,
/// `correct = Σ_r w_r·[argmax logits == argmax onehot]`, and
/// `dlogits[r] = (w_r / max(Σ w, 1)) · (softmax(logits_r) - onehot_r)` —
/// the exact forward/VJP pair of the Pallas `softmax_xent` kernel under
/// the model's weighted-mean reduction.
pub fn softmax_xent(
    logits: &[f32],
    onehot: &[f32],
    weights: &[f32],
    b: usize,
    classes: usize,
) -> (f32, f32, Vec<f32>) {
    debug_assert_eq!(logits.len(), b * classes);
    debug_assert_eq!(onehot.len(), b * classes);
    debug_assert_eq!(weights.len(), b);
    let wsum: f32 = weights.iter().sum();
    let denom = wsum.max(1.0);
    let mut loss = 0.0f32;
    let mut correct = 0.0f32;
    let mut dlogits = vec![0.0f32; b * classes];
    for r in 0..b {
        let lrow = &logits[r * classes..(r + 1) * classes];
        let yrow = &onehot[r * classes..(r + 1) * classes];
        let wr = weights[r];

        let maxv = lrow.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut expsum = 0.0f32;
        for &v in lrow {
            expsum += (v - maxv).exp();
        }
        let lse = maxv + expsum.ln();
        let dot: f32 = lrow.iter().zip(yrow).map(|(&l, &y)| l * y).sum();
        loss += wr * (lse - dot);

        let scale = wr / denom;
        let drow = &mut dlogits[r * classes..(r + 1) * classes];
        for ((dv, &lv), &yv) in drow.iter_mut().zip(lrow).zip(yrow) {
            let p = (lv - maxv).exp() / expsum;
            *dv = scale * (p - yv);
        }

        let pred = argmax(lrow);
        let truth = argmax(yrow);
        if pred == truth {
            correct += wr;
        }
    }
    (loss / denom, correct, dlogits)
}

/// First index of the maximum value (the `jnp.argmax` tie-break).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

/// Column-wise sum of `g[m,n]` — the bias gradient.
pub fn col_sum(g: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(g.len() % n, 0);
    let mut out = vec![0.0f32; n];
    for row in g.chunks(n) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mm_matches_hand_result() {
        // [2,3] x [3,2]
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
        let c = mm(&a, &b, 2, 3, 2);
        assert_eq!(c, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn transposed_gemms_agree_with_plain_mm() {
        let mut rng = crate::rng::Pcg32::seeded(7);
        let (m, k, n) = (5, 4, 3);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
        // A^T B via explicit transpose + mm.
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let want = mm(&at, &b, k, m, n);
        let got = mm_at_b(&a, &b, m, k, n);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5);
        }
        // A B^T via explicit transpose + mm.
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut wt = vec![0.0f32; n * k];
        for i in 0..k {
            for j in 0..n {
                wt[j * k + i] = w[i * n + j];
            }
        }
        let want = mm(&b, &wt, m, n, k);
        let got = mm_a_bt(&b, &w, m, n, k);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn im2col_identity_kernel_center_tap() {
        // With a single channel, the center tap (i=1, j=1) of each output
        // row is the input pixel itself.
        let (b, h, w, c) = (1, 4, 4, 1);
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let cols = im2col3x3(&x, b, h, w, c);
        for p in 0..16 {
            assert_eq!(cols[p * 9 + 4], x[p]);
        }
        // Top-left output pixel: taps above/left are zero padding.
        assert_eq!(cols[0], 0.0); // (i=0, j=0)
        assert_eq!(cols[1], 0.0); // (i=0, j=1)
        assert_eq!(cols[3], 0.0); // (i=1, j=0)
        assert_eq!(cols[5], x[1]); // (i=1, j=2) -> right neighbour
        assert_eq!(cols[7], x[4]); // (i=2, j=1) -> below neighbour
    }

    #[test]
    fn col2im_is_the_transpose_of_im2col() {
        // <im2col(x), g> == <x, col2im(g)> for random x, g — the defining
        // property of an adjoint pair.
        let mut rng = crate::rng::Pcg32::seeded(3);
        let (b, h, w, c) = (2, 4, 4, 3);
        let x: Vec<f32> = (0..b * h * w * c).map(|_| rng.normal() as f32).collect();
        let g: Vec<f32> = (0..b * h * w * 9 * c).map(|_| rng.normal() as f32).collect();
        let cols = im2col3x3(&x, b, h, w, c);
        let folded = col2im3x3_add(&g, b, h, w, c);
        let lhs: f64 = cols.iter().zip(&g).map(|(&a, &b)| a as f64 * b as f64).sum();
        let rhs: f64 = x.iter().zip(&folded).map(|(&a, &b)| a as f64 * b as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }

    #[test]
    fn maxpool_picks_window_maxima_and_routes_gradients() {
        let (b, h, w, c) = (1, 2, 2, 1);
        let x = [1.0, 3.0, 2.0, 0.5];
        let (out, idx) = maxpool2(&x, b, h, w, c);
        assert_eq!(out, vec![3.0]);
        assert_eq!(idx, vec![1]);
        let dx = maxpool2_bwd(&[2.5], &idx, 4);
        assert_eq!(dx, vec![0.0, 2.5, 0.0, 0.0]);
    }

    #[test]
    fn softmax_xent_uniform_logits_is_ln_classes() {
        let (b, classes) = (2, 10);
        let logits = vec![0.0f32; b * classes];
        let mut onehot = vec![0.0f32; b * classes];
        onehot[3] = 1.0;
        onehot[classes + 7] = 1.0;
        let weights = vec![1.0f32; b];
        let (loss, _, dlogits) = softmax_xent(&logits, &onehot, &weights, b, classes);
        assert!((loss - (10.0f32).ln()).abs() < 1e-5);
        // Gradient sums to zero per row (softmax minus onehot).
        let s: f32 = dlogits[..classes].iter().sum();
        assert!(s.abs() < 1e-6);
    }

    #[test]
    fn zero_weight_rows_contribute_nothing() {
        let (b, classes) = (2, 4);
        let mut logits = vec![0.5f32; b * classes];
        logits[classes..].copy_from_slice(&[9.0, -3.0, 1.0, 4.0]); // padded row
        let mut onehot = vec![0.0f32; b * classes];
        onehot[1] = 1.0;
        onehot[classes + 2] = 1.0;
        let (loss_pad, correct_pad, d_pad) =
            softmax_xent(&logits, &onehot, &[1.0, 0.0], b, classes);
        let (loss_solo, correct_solo, d_solo) =
            softmax_xent(&logits[..classes], &onehot[..classes], &[1.0], 1, classes);
        assert!((loss_pad - loss_solo).abs() < 1e-6);
        assert!((correct_pad - correct_solo).abs() < 1e-6);
        for (a, b) in d_pad[..classes].iter().zip(&d_solo) {
            assert!((a - b).abs() < 1e-6);
        }
        assert!(d_pad[classes..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }
}
