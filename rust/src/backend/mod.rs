//! Execution backends (DESIGN.md §11).
//!
//! The training path executes SplitCNN-8 step functions by *artifact
//! name* through [`crate::runtime::EngineHandle`]; this module provides
//! the two interchangeable implementations behind that contract plus the
//! selection machinery:
//!
//! - **PJRT** ([`crate::runtime::Engine`]) — compiles the AOT-lowered HLO
//!   artifacts (`make artifacts`, needs Python/JAX once at build time)
//!   and executes them through the XLA PJRT CPU client.
//! - **Native** ([`NativeEngine`]) — Rust conv/pool/dense/softmax-CE
//!   forward+backward over the blocked, SIMD-friendly, row-parallel
//!   kernels in [`ops`] (DESIGN.md §14), with an in-Rust [`ModelSpec`]
//!   that synthesizes the manifest. No artifacts, no Python, no XLA
//!   toolchain; runs anywhere the crate compiles, which is what lets
//!   hosted CI run the full engine-backed battery unconditionally.
//!
//! Selection: [`BackendKind::Auto`] resolves to PJRT when
//! `<artifacts>/manifest.json` exists and to native otherwise. Sessions
//! resolve once at build time and embed the *resolved* backend in the
//! config (and therefore in checkpoints), so a resumed run always re-uses
//! the backend that produced the checkpoint — bit-identical warm restarts
//! depend on it. Numerics: the native backend is bit-deterministic across
//! sequential/pooled/resumed modes; across backends agreement is within
//! float tolerance only (XLA reorders f32 reductions), verified by
//! `rust/tests/backend_parity.rs`.

mod native;
pub mod ops;
mod spec;

pub use native::NativeEngine;
pub use spec::{BlockKind, BlockSpec, ModelSpec};

use std::path::Path;

/// Which execution backend a session should use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// PJRT when AOT artifacts exist, native otherwise.
    #[default]
    Auto,
    /// The pure-Rust engine (always available).
    Native,
    /// The PJRT engine over AOT artifacts (requires `make artifacts`).
    Pjrt,
}

impl BackendKind {
    /// Canonical lowercase name (`auto`/`native`/`pjrt`) — the inverse of
    /// [`BackendKind::parse`], used for CLI flags and thread names.
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Auto => "auto",
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// Parse a backend name as accepted by `--backend` (auto|native|pjrt).
    pub fn parse(s: &str) -> crate::Result<BackendKind> {
        Ok(match s {
            "auto" => BackendKind::Auto,
            "native" => BackendKind::Native,
            "pjrt" => BackendKind::Pjrt,
            _ => anyhow::bail!("unknown backend '{s}' (expected auto|native|pjrt)"),
        })
    }

    /// The backend requested through the `HASFL_BACKEND` environment
    /// variable, if any. `ci.sh --backend <kind>` exports it so the whole
    /// battery — tests, benches, examples — runs on one backend without
    /// per-driver plumbing; an explicit builder/CLI choice still wins.
    pub fn from_env() -> Option<BackendKind> {
        let v = std::env::var("HASFL_BACKEND").ok()?;
        match BackendKind::parse(&v) {
            Ok(k) => Some(k),
            Err(_) => {
                eprintln!("HASFL_BACKEND='{v}' is not auto|native|pjrt; ignoring");
                None
            }
        }
    }

    /// Resolve `Auto` against an artifacts directory: PJRT when
    /// `manifest.json` exists there, native otherwise. Concrete kinds
    /// resolve to themselves.
    pub fn resolve(&self, artifacts_dir: &Path) -> BackendKind {
        match self {
            BackendKind::Auto => {
                if artifacts_dir.join("manifest.json").exists() {
                    BackendKind::Pjrt
                } else {
                    BackendKind::Native
                }
            }
            concrete => *concrete,
        }
    }
}

/// Whether `HASFL_REQUIRE_ENGINE=1` is set: hosted CI's no-blind-spot mode,
/// under which an engine-backed test that cannot obtain *any* execution
/// backend must fail instead of self-skipping.
pub fn engine_required() -> bool {
    std::env::var("HASFL_REQUIRE_ENGINE").map(|v| v == "1").unwrap_or(false)
}

/// Report an engine-backed test/bench skip with the standardized
/// `SKIPPED: <reason>` line. Under `HASFL_REQUIRE_ENGINE=1` this panics
/// instead: the native backend makes an engine available on every
/// machine, so reaching this in required mode means a skip path regressed
/// into the gate of record.
pub fn skip_engine_test(reason: &str) {
    println!("SKIPPED: {reason}");
    eprintln!("SKIPPED: {reason}");
    assert!(
        !engine_required(),
        "HASFL_REQUIRE_ENGINE=1: engine-backed suites must not skip ({reason})"
    );
}

/// Report a *PJRT-specific* skip (cross-backend parity halves, PJRT
/// engine internals) with the standardized `SKIPPED: <reason>` line.
/// These are allowed even under `HASFL_REQUIRE_ENGINE=1`: the native
/// battery still gates the training contract on every machine, and the
/// non-blocking `pjrt-parity` CI job provides the PJRT coverage where
/// artifacts can be built.
pub fn skip_pjrt_only(reason: &str) {
    println!("SKIPPED: {reason}");
    eprintln!("SKIPPED: {reason}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parse_roundtrip() {
        for k in [BackendKind::Auto, BackendKind::Native, BackendKind::Pjrt] {
            assert_eq!(BackendKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(BackendKind::parse("xla").is_err());
        assert_eq!(BackendKind::default(), BackendKind::Auto);
    }

    #[test]
    fn auto_resolves_by_manifest_presence() {
        let dir = std::env::temp_dir().join("hasfl_backend_resolve_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert_eq!(BackendKind::Auto.resolve(&dir), BackendKind::Native);
        std::fs::write(dir.join("manifest.json"), "{}").unwrap();
        assert_eq!(BackendKind::Auto.resolve(&dir), BackendKind::Pjrt);
        // Concrete kinds never change.
        assert_eq!(BackendKind::Native.resolve(&dir), BackendKind::Native);
        let _ = std::fs::remove_dir_all(&dir);
        assert_eq!(BackendKind::Pjrt.resolve(&dir), BackendKind::Pjrt);
    }
}
