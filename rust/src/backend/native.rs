//! The native execution backend: SplitCNN-8's five step functions
//! (`client_fwd`, `server_step`, `client_bwd`, `full_step`, `full_fwd`)
//! implemented in plain Rust over the kernels in [`super::ops`].
//!
//! [`NativeEngine`] serves the exact artifact-name contract of the PJRT
//! engine — same names, same argument/output specs, same bucket-padding
//! semantics (weighted reductions make padded numerics equal true-batch
//! numerics) — so the runtime, coordinator, and every driver run unchanged
//! on a machine with no AOT artifacts and no XLA toolchain. Within the
//! native backend all reductions run in a fixed sequential order, making
//! results bit-deterministic across runs, engine lanes, and resumes;
//! against PJRT the agreement is within float tolerance (DESIGN.md §11).
//!
//! Each engine carries a worker-thread budget for the blocked kernels in
//! [`super::ops`] (DESIGN.md §14). The budget is a wall-clock knob only:
//! parallel kernels partition work over independent output rows, so a
//! 1-thread and an N-thread engine produce bit-identical outputs
//! (pinned by tests here and in `rust/tests/backend_parity.rs`).

use std::collections::HashMap;
use std::time::Instant;

use super::ops;
use super::spec::{BlockKind, BlockSpec, ModelSpec};
use crate::model::Manifest;
use crate::runtime::{BufKey, EngineStats, ExecInput, HostTensor};

/// Per-block forward residuals needed by the backward pass.
enum Cache {
    Conv {
        /// im2col of the block input, `[b*hw*hw, 9*cin]`.
        cols: Vec<f32>,
        /// Post-bias post-ReLU pre-pool activations, `[b*hw*hw, cout]`.
        z: Vec<f32>,
        /// Winning input index per pooled element (empty when `!pool`).
        pool_idx: Vec<u32>,
        /// Input spatial side (pre-pool).
        hw: usize,
        cin: usize,
        cout: usize,
        pool: bool,
        relu: bool,
    },
    Dense {
        /// Flattened block input, `[b, cin]`.
        x2d: Vec<f32>,
        /// Post-bias post-activation output, `[b, cout]`.
        z: Vec<f32>,
        /// Shape of the (possibly unflattened) block input.
        in_shape: Vec<usize>,
        cin: usize,
        cout: usize,
        relu: bool,
    },
}

/// Activation tensor moving between blocks.
struct Act {
    data: Vec<f32>,
    shape: Vec<usize>,
}

/// Pure-Rust SplitCNN-8 engine. Lives on one pool lane, like the PJRT
/// engine; the type itself is `Send`, but lane threads keep the two
/// backends symmetric (and per-lane stats meaningful).
pub struct NativeEngine {
    spec: ModelSpec,
    manifest: Manifest,
    /// Worker-thread budget for the blocked kernels in [`super::ops`]
    /// (1 = fully sequential). A wall-clock knob, not state: thread count
    /// never changes a bit of output (DESIGN.md §14).
    threads: usize,
    /// Buffer-cache bookkeeping: the native backend has no device literals
    /// to pack, but it tracks `(version, shape)` per [`BufKey`] so the
    /// hit/miss/byte statistics — and their invalidation semantics — stay
    /// identical to the PJRT backend's.
    buffers: HashMap<BufKey, (u64, Vec<usize>)>,
    stats: EngineStats,
}

impl NativeEngine {
    /// Build a single-threaded native engine for `classes`-way SplitCNN-8
    /// (tests and micro-drivers; pool lanes get their budget through
    /// [`NativeEngine::with_threads`]).
    pub fn new(spec: ModelSpec) -> NativeEngine {
        NativeEngine::with_threads(spec, 1)
    }

    /// Build a native engine whose kernels may fan work out over up to
    /// `threads` scoped worker threads (clamped to >= 1). The lane
    /// architecture resolves this per-lane so pooled lanes never
    /// oversubscribe the machine ([`crate::runtime::EngineSpec`]).
    pub fn with_threads(spec: ModelSpec, threads: usize) -> NativeEngine {
        let manifest = spec.manifest();
        NativeEngine {
            spec,
            manifest,
            threads: threads.max(1),
            buffers: HashMap::new(),
            stats: EngineStats { pool_width: 1, ..EngineStats::default() },
        }
    }

    /// The kernel worker-thread budget this engine runs with.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The manifest of artifacts this engine serves (synthesized from the
    /// model spec — same names and specs as the PJRT manifest on disk).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Per-engine execution statistics (executions, cache traffic, time).
    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// Name-contract analogue of the PJRT compile warm-up. Nothing is
    /// compiled natively, so this only validates the artifact name and
    /// never reports a cache miss.
    pub fn warm(&mut self, name: &str) -> crate::Result<bool> {
        anyhow::ensure!(self.manifest.get(name).is_some(), "unknown artifact {name}");
        Ok(false)
    }

    /// Live entries in the buffer-cache bookkeeping (parity with
    /// [`crate::runtime::Engine::buffer_len`]).
    pub fn buffer_len(&self) -> usize {
        self.buffers.len()
    }

    /// Execute an artifact with the given inputs; returns all outputs in
    /// manifest order. The input contract (count, shapes, cached-input
    /// versioning) is checked exactly like the PJRT engine's.
    pub fn execute(&mut self, name: &str, inputs: &[ExecInput]) -> crate::Result<Vec<HostTensor>> {
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact {name}"))?;
        if inputs.len() != entry.args.len() {
            anyhow::bail!("{name}: {} inputs given, {} expected", inputs.len(), entry.args.len());
        }
        for (inp, spec) in inputs.iter().zip(&entry.args) {
            let t = inp.tensor();
            if t.shape != spec.shape {
                anyhow::bail!(
                    "{name}: arg {} shape {:?} != spec {:?}",
                    spec.name,
                    t.shape,
                    spec.shape
                );
            }
            if t.data.len() != spec.numel() {
                anyhow::bail!("{name}: arg {} data len mismatch", spec.name);
            }
        }
        let (func, cut, bucket) = (entry.func.clone(), entry.cut, entry.bucket);

        // Buffer-cache accounting: a versioned input whose (version, shape)
        // matches the bookkeeping is a hit (the PJRT backend would serve
        // its packed literal); anything else is a miss/upload.
        for inp in inputs {
            match inp {
                ExecInput::Fresh(t) => {
                    self.stats.upload_bytes += (t.data.len() * 4) as u64;
                }
                ExecInput::Cached { key, version, tensor } => {
                    let nbytes = (tensor.data.len() * 4) as u64;
                    match self.buffers.get(key) {
                        Some((v, shape)) if v == version && *shape == tensor.shape => {
                            self.stats.buffer_hits += 1;
                            self.stats.buffer_hit_bytes += nbytes;
                        }
                        _ => {
                            self.stats.buffer_misses += 1;
                            self.stats.upload_bytes += nbytes;
                            self.buffers.insert(*key, (*version, tensor.shape.clone()));
                        }
                    }
                }
            }
        }

        let t0 = Instant::now();
        let outputs = self.dispatch(&func, cut, bucket as usize, inputs)?;
        self.stats.executions += 1;
        self.stats.exec_secs += t0.elapsed().as_secs_f64();
        for o in &outputs {
            self.stats.download_bytes += (o.data.len() * 4) as u64;
        }
        Ok(outputs)
    }

    fn dispatch(
        &self,
        func: &str,
        cut: usize,
        bucket: usize,
        inputs: &[ExecInput],
    ) -> crate::Result<Vec<HostTensor>> {
        let l = self.spec.n_blocks();
        let t = self.threads;
        match func {
            "client_fwd" => {
                let x = inputs[0].tensor();
                let params = tensors(&inputs[1..]);
                let blocks = &self.spec.blocks[..cut];
                let (act, _) = forward(blocks, &params, x.data.clone(), x.shape.clone(), false, t);
                Ok(vec![HostTensor { shape: act.shape, data: act.data }])
            }
            "server_step" => {
                let a = inputs[0].tensor();
                let onehot = inputs[1].tensor();
                let weights = inputs[2].tensor();
                let params = tensors(&inputs[3..]);
                let blocks = &self.spec.blocks[cut..];
                let (logits, caches) =
                    forward(blocks, &params, a.data.clone(), a.shape.clone(), true, t);
                let (loss, correct, dlogits) = ops::softmax_xent(
                    &logits.data,
                    &onehot.data,
                    &weights.data,
                    bucket,
                    self.spec.classes,
                    t,
                );
                let (dx, grads) = backward(blocks, &params, &caches, dlogits, t);
                let mut out = vec![
                    HostTensor::scalar(loss),
                    HostTensor::scalar(correct),
                    HostTensor { shape: a.shape.clone(), data: dx },
                ];
                out.extend(grads);
                Ok(out)
            }
            "client_bwd" => {
                let x = inputs[0].tensor();
                let ga = inputs[1].tensor();
                let params = tensors(&inputs[2..]);
                let blocks = &self.spec.blocks[..cut];
                let (_, caches) =
                    forward(blocks, &params, x.data.clone(), x.shape.clone(), true, t);
                let (_, grads) = backward(blocks, &params, &caches, ga.data.clone(), t);
                Ok(grads)
            }
            "full_step" => {
                let x = inputs[0].tensor();
                let onehot = inputs[1].tensor();
                let weights = inputs[2].tensor();
                let params = tensors(&inputs[3..]);
                let blocks = &self.spec.blocks[..l];
                let (logits, caches) =
                    forward(blocks, &params, x.data.clone(), x.shape.clone(), true, t);
                let (loss, correct, dlogits) = ops::softmax_xent(
                    &logits.data,
                    &onehot.data,
                    &weights.data,
                    bucket,
                    self.spec.classes,
                    t,
                );
                let (_, grads) = backward(blocks, &params, &caches, dlogits, t);
                let mut out = vec![HostTensor::scalar(loss), HostTensor::scalar(correct)];
                out.extend(grads);
                Ok(out)
            }
            "full_fwd" => {
                let x = inputs[0].tensor();
                let params = tensors(&inputs[1..]);
                let blocks = &self.spec.blocks[..l];
                let (act, _) = forward(blocks, &params, x.data.clone(), x.shape.clone(), false, t);
                Ok(vec![HostTensor { shape: act.shape, data: act.data }])
            }
            other => anyhow::bail!("native backend: unknown function '{other}'"),
        }
    }
}

/// Borrow the tensors out of a parameter input slice.
fn tensors(inputs: &[ExecInput]) -> Vec<&HostTensor> {
    inputs.iter().map(|i| i.tensor()).collect()
}

/// Run `blocks` forward from activation `(data, shape)`. With `keep`, the
/// per-block residuals for the backward pass are retained. `threads` is
/// the kernel worker budget (bit-neutral; DESIGN.md §14).
fn forward(
    blocks: &[BlockSpec],
    params: &[&HostTensor],
    data: Vec<f32>,
    shape: Vec<usize>,
    keep: bool,
    threads: usize,
) -> (Act, Vec<Cache>) {
    debug_assert_eq!(params.len(), 2 * blocks.len());
    let mut act = Act { data, shape };
    let mut caches = Vec::with_capacity(if keep { blocks.len() } else { 0 });
    for (i, blk) in blocks.iter().enumerate() {
        let (w, bias) = (params[2 * i], params[2 * i + 1]);
        match blk.kind {
            BlockKind::Conv { pool } => {
                let (b, hw) = (act.shape[0], act.shape[1]);
                debug_assert_eq!(act.shape, vec![b, hw, hw, blk.cin]);
                let m = b * hw * hw;
                let cols = ops::im2col3x3(&act.data, b, hw, hw, blk.cin, threads);
                let mut z = ops::mm(&cols, &w.data, m, 9 * blk.cin, blk.cout, threads);
                ops::add_bias_act(&mut z, &bias.data, blk.cout, blk.relu);
                let cache = |z: Vec<f32>, pool_idx: Vec<u32>| Cache::Conv {
                    cols,
                    z,
                    pool_idx,
                    hw,
                    cin: blk.cin,
                    cout: blk.cout,
                    pool,
                    relu: blk.relu,
                };
                let ohw = if pool { hw / 2 } else { hw };
                let out = if pool {
                    let (p, idx) = ops::maxpool2(&z, b, hw, hw, blk.cout, threads);
                    if keep {
                        caches.push(cache(z, idx));
                    }
                    p
                } else {
                    if keep {
                        caches.push(cache(z.clone(), Vec::new()));
                    }
                    z
                };
                act = Act { data: out, shape: vec![b, ohw, ohw, blk.cout] };
            }
            BlockKind::Dense => {
                let b = act.shape[0];
                let in_shape = act.shape.clone();
                debug_assert_eq!(act.data.len(), b * blk.cin);
                let x2d = act.data;
                let mut z = ops::mm(&x2d, &w.data, b, blk.cin, blk.cout, threads);
                ops::add_bias_act(&mut z, &bias.data, blk.cout, blk.relu);
                if keep {
                    caches.push(Cache::Dense {
                        x2d,
                        z: z.clone(),
                        in_shape,
                        cin: blk.cin,
                        cout: blk.cout,
                        relu: blk.relu,
                    });
                }
                act = Act { data: z, shape: vec![b, blk.cout] };
            }
        }
    }
    (act, caches)
}

/// Pull `dout` (gradient at the final activation of `blocks`) back through
/// the cached forward pass. Returns the gradient at the block-range input
/// and the parameter gradients `[dw1, db1, ...]` in block order. `threads`
/// is the kernel worker budget (bit-neutral; DESIGN.md §14).
fn backward(
    blocks: &[BlockSpec],
    params: &[&HostTensor],
    caches: &[Cache],
    dout: Vec<f32>,
    threads: usize,
) -> (Vec<f32>, Vec<HostTensor>) {
    debug_assert_eq!(caches.len(), blocks.len());
    let mut grads: Vec<HostTensor> = Vec::with_capacity(2 * blocks.len());
    let mut d = dout;
    for (i, blk) in blocks.iter().enumerate().rev() {
        let w = params[2 * i];
        match &caches[i] {
            Cache::Conv { cols, z, pool_idx, hw, cin, cout, pool, relu } => {
                let m = z.len() / cout;
                let b = m / (hw * hw);
                let mut dz = if *pool { ops::maxpool2_bwd(&d, pool_idx, z.len()) } else { d };
                if *relu {
                    for (g, &v) in dz.iter_mut().zip(z) {
                        if v <= 0.0 {
                            *g = 0.0;
                        }
                    }
                }
                let db = ops::col_sum(&dz, *cout);
                let dw = ops::mm_at_b(cols, &dz, m, 9 * cin, *cout, threads);
                let dcols = ops::mm_a_bt(&dz, &w.data, m, *cout, 9 * cin, threads);
                d = ops::col2im3x3_add(&dcols, b, *hw, *hw, *cin, threads);
                grads.push(HostTensor { shape: vec![*cout], data: db });
                grads.push(HostTensor { shape: vec![3, 3, *cin, *cout], data: dw });
            }
            Cache::Dense { x2d, z, in_shape, cin, cout, relu } => {
                let b = z.len() / cout;
                let mut dz = d;
                if *relu {
                    for (g, &v) in dz.iter_mut().zip(z) {
                        if v <= 0.0 {
                            *g = 0.0;
                        }
                    }
                }
                let db = ops::col_sum(&dz, *cout);
                let dw = ops::mm_at_b(x2d, &dz, b, *cin, *cout, threads);
                d = ops::mm_a_bt(&dz, &w.data, b, *cout, *cin, threads);
                debug_assert_eq!(d.len(), in_shape.iter().product::<usize>());
                grads.push(HostTensor { shape: vec![*cout], data: db });
                grads.push(HostTensor { shape: vec![*cin, *cout], data: dw });
            }
        }
    }
    // Pushed (db, dw) per block in reverse; flip to [dw1, db1, dw2, ...].
    grads.reverse();
    (d, grads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Params;

    fn engine() -> NativeEngine {
        NativeEngine::new(ModelSpec::splitcnn8(10))
    }

    /// Deterministic pseudo-batch (mirrors the integration-test helper).
    fn fake_batch(
        bucket: usize,
        classes: usize,
        true_b: usize,
    ) -> (HostTensor, HostTensor, HostTensor) {
        let mut rng = crate::rng::Pcg32::seeded(99);
        let px = 32 * 32 * 3;
        let x: Vec<f32> = (0..bucket * px).map(|_| rng.normal() as f32 * 0.5).collect();
        let mut onehot = vec![0.0f32; bucket * classes];
        let mut weights = vec![0.0f32; bucket];
        for r in 0..bucket {
            onehot[r * classes + (r % classes)] = 1.0;
            if r < true_b {
                weights[r] = 1.0;
            }
        }
        (
            HostTensor { shape: vec![bucket, 32, 32, 3], data: x },
            HostTensor { shape: vec![bucket, classes], data: onehot },
            HostTensor { shape: vec![bucket], data: weights },
        )
    }

    fn fresh(ts: &[HostTensor]) -> Vec<ExecInput> {
        ts.iter().cloned().map(ExecInput::Fresh).collect()
    }

    fn param_inputs(p: &Params) -> Vec<ExecInput> {
        p.tensors
            .iter()
            .map(|t| ExecInput::Fresh(HostTensor { shape: t.shape.clone(), data: t.data.clone() }))
            .collect()
    }

    #[test]
    fn full_fwd_produces_finite_logits() {
        let mut e = engine();
        let params = Params::init(e.manifest(), 1);
        let (x, _, _) = fake_batch(8, 10, 8);
        let mut inputs = fresh(&[x]);
        inputs.extend(param_inputs(&params));
        let out = e.execute("full_fwd_b8", &inputs).expect("exec");
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![8, 10]);
        assert!(out[0].data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn full_step_loss_near_ln10_at_init() {
        let mut e = engine();
        let params = Params::init(e.manifest(), 2);
        let (x, y, w) = fake_batch(16, 10, 16);
        let mut inputs = fresh(&[x, y, w]);
        inputs.extend(param_inputs(&params));
        let out = e.execute("full_step_b16", &inputs).expect("exec");
        let loss = out[0].data[0];
        assert!((1.5..4.0).contains(&loss), "init loss {loss}");
        assert_eq!(out.len(), 2 + params.tensors.len());
        for g in &out[2..] {
            assert!(g.data.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn split_equals_full_natively() {
        // The core SFL invariant inside the native backend:
        // client_fwd -> server_step -> client_bwd == full_step.
        let mut e = engine();
        let params = Params::init(e.manifest(), 3);
        let (x, y, w) = fake_batch(8, 10, 8);

        let mut inputs = fresh(&[x.clone(), y.clone(), w.clone()]);
        inputs.extend(param_inputs(&params));
        let full = e.execute("full_step_b8", &inputs).expect("full");

        for cut in [1usize, 3, 5, 7] {
            let mut cf_in = fresh(&[x.clone()]);
            cf_in.extend(param_inputs(&params)[..2 * cut].to_vec());
            let a = e
                .execute(&Manifest::split_name("client_fwd", cut, 8), &cf_in)
                .expect("cf")
                .remove(0);
            let mut ss_in = fresh(&[a, y.clone(), w.clone()]);
            ss_in.extend(param_inputs(&params)[2 * cut..].to_vec());
            let mut ss_out =
                e.execute(&Manifest::split_name("server_step", cut, 8), &ss_in).expect("ss");
            let loss = ss_out.remove(0).data[0];
            let _correct = ss_out.remove(0);
            let ga = ss_out.remove(0);
            let mut cb_in = fresh(&[x.clone(), ga]);
            cb_in.extend(param_inputs(&params)[..2 * cut].to_vec());
            let cb_out =
                e.execute(&Manifest::split_name("client_bwd", cut, 8), &cb_in).expect("cb");

            assert!((loss - full[0].data[0]).abs() < 1e-5, "cut {cut} loss");
            let split_grads: Vec<&HostTensor> = cb_out.iter().chain(ss_out.iter()).collect();
            assert_eq!(split_grads.len(), full.len() - 2);
            for (k, (sg, fg)) in split_grads.iter().zip(&full[2..]).enumerate() {
                assert_eq!(sg.shape, fg.shape, "cut {cut} grad tensor {k} shape");
                for (a, b) in sg.data.iter().zip(&fg.data) {
                    assert!(
                        (a - b).abs() < 1e-5 + 1e-4 * b.abs(),
                        "cut {cut} grad tensor {k}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn padded_bucket_matches_unpadded_batch() {
        // Zero-weighted padding rows must contribute nothing, even with
        // garbage pixels (the exactness contract bucket padding relies on).
        let mut e = engine();
        let params = Params::init(e.manifest(), 4);
        let (x, y, w) = fake_batch(8, 10, 5);

        let mut inputs = fresh(&[x.clone(), y.clone(), w.clone()]);
        inputs.extend(param_inputs(&params));
        let base = e.execute("full_step_b8", &inputs).expect("base");

        let mut x2 = x.clone();
        let px = 32 * 32 * 3;
        for v in x2.data[5 * px..].iter_mut() {
            *v = 123.456;
        }
        let mut inputs = fresh(&[x2, y, w]);
        inputs.extend(param_inputs(&params));
        let scrambled = e.execute("full_step_b8", &inputs).expect("scrambled");

        assert!((base[0].data[0] - scrambled[0].data[0]).abs() < 1e-6, "loss differs");
        for (a, b) in base[2..].iter().zip(&scrambled[2..]) {
            for (x1, x2) in a.data.iter().zip(&b.data) {
                assert!((x1 - x2).abs() < 1e-6, "padded rows leaked into grads");
            }
        }
    }

    #[test]
    fn gradients_match_finite_differences() {
        // Spot-check the hand-written backward pass against central
        // differences on a few parameters of every block.
        let mut e = engine();
        let mut params = Params::init(e.manifest(), 5);
        let (x, y, w) = fake_batch(2, 10, 2);

        let run_loss = |e: &mut NativeEngine, p: &Params| -> f64 {
            let mut inputs = fresh(&[x.clone(), y.clone(), w.clone()]);
            inputs.extend(param_inputs(p));
            e.execute("full_step_b2", &inputs).unwrap()[0].data[0] as f64
        };
        let mut inputs = fresh(&[x.clone(), y.clone(), w.clone()]);
        inputs.extend(param_inputs(&params));
        let out = e.execute("full_step_b2", &inputs).unwrap();

        let eps = 1e-2f32;
        for ti in (0..params.tensors.len()).step_by(3) {
            let idx = params.tensors[ti].data.len() / 2;
            let analytic = out[2 + ti].data[idx] as f64;
            let orig = params.tensors[ti].data[idx];
            params.tensors[ti].data[idx] = orig + eps;
            let hi = run_loss(&mut e, &params);
            params.tensors[ti].data[idx] = orig - eps;
            let lo = run_loss(&mut e, &params);
            params.tensors[ti].data[idx] = orig;
            let numeric = (hi - lo) / (2.0 * eps as f64);
            assert!(
                (analytic - numeric).abs() < 2e-3 + 0.05 * numeric.abs(),
                "tensor {ti}: analytic {analytic} vs numeric {numeric}"
            );
        }
    }

    #[test]
    fn execution_is_bit_deterministic() {
        let mut e1 = engine();
        let mut e2 = engine();
        let params = Params::init(e1.manifest(), 6);
        let (x, y, w) = fake_batch(4, 10, 4);
        let mut inputs = fresh(&[x, y, w]);
        inputs.extend(param_inputs(&params));
        let a = e1.execute("full_step_b4", &inputs).unwrap();
        let b = e2.execute("full_step_b4", &inputs).unwrap();
        for (ta, tb) in a.iter().zip(&b) {
            assert_eq!(ta.data, tb.data, "native execution must be bit-deterministic");
        }
    }

    #[test]
    fn engine_rejects_bad_shapes_and_names() {
        let mut e = engine();
        let bad = HostTensor { shape: vec![4, 32, 32, 3], data: vec![0.0; 4 * 32 * 32 * 3] };
        assert!(e.execute("full_fwd_b8", &[ExecInput::Fresh(bad)]).is_err());
        assert!(e.execute("nonexistent_artifact", &[]).is_err());
        assert!(!e.warm("client_fwd_c3_b8").unwrap());
        assert!(e.warm("nonexistent_artifact").is_err());
    }

    #[test]
    fn buffer_bookkeeping_counts_hits_and_misses() {
        use std::sync::Arc;
        let mut e = engine();
        let params = Params::init(e.manifest(), 7);
        let (x, _, _) = fake_batch(4, 10, 4);
        let cached = |version: u64| -> Vec<ExecInput> {
            let mut inputs = vec![ExecInput::Fresh(x.clone())];
            inputs.extend(params.tensors.iter().enumerate().map(|(s, t)| {
                ExecInput::cached(
                    BufKey { set: 0, slot: s as u32 },
                    version,
                    Arc::new(HostTensor { shape: t.shape.clone(), data: t.data.clone() }),
                )
            }));
            inputs
        };
        let n = params.tensors.len() as u64;
        e.execute("full_fwd_b4", &cached(1)).unwrap();
        e.execute("full_fwd_b4", &cached(1)).unwrap();
        assert_eq!(e.stats().buffer_misses, n);
        assert_eq!(e.stats().buffer_hits, n);
        e.execute("full_fwd_b4", &cached(2)).unwrap();
        assert_eq!(e.stats().buffer_misses, 2 * n);
        assert_eq!(e.stats().buffer_hits, n);
        assert_eq!(e.buffer_len(), n as usize);
        assert_eq!(e.stats().executions, 3);
        assert_eq!(e.stats().compiles, 0);
    }

    #[test]
    fn thread_budget_is_bit_neutral() {
        // A 1-thread engine and an N-thread engine must produce
        // bit-identical outputs for the full step path: parallel kernels
        // partition only independent output rows and never reorder a
        // reduction (DESIGN.md §14). Bucket 32 pushes the big conv GEMMs
        // past the parallel work thresholds, so the split really engages.
        let mut e1 = engine();
        let mut e4 = NativeEngine::with_threads(ModelSpec::splitcnn8(10), 4);
        assert_eq!(e1.threads(), 1);
        assert_eq!(e4.threads(), 4);
        let params = Params::init(e1.manifest(), 8);
        let (x, y, w) = fake_batch(32, 10, 32);
        let mut inputs = fresh(&[x, y, w]);
        inputs.extend(param_inputs(&params));
        let a = e1.execute("full_step_b32", &inputs).unwrap();
        let b = e4.execute("full_step_b32", &inputs).unwrap();
        for (ta, tb) in a.iter().zip(&b) {
            assert_eq!(ta.data, tb.data, "thread budget changed native numerics");
        }
    }
}
