//! In-Rust SplitCNN-8 model specification.
//!
//! The PJRT backend learns the model's shape contract from
//! `manifest.json`, written at AOT-export time by `python/compile/aot.py`.
//! The native backend has no build step, so this module *synthesizes* the
//! same [`Manifest`] — identical artifact names, tensor specs, parameter
//! shapes, and per-block cost table — directly from the architecture
//! definition. Everything downstream (`model/profiles.rs`,
//! `StepArtifacts`, the optimizer's block costs) is backend-agnostic as a
//! result: it consumes a `Manifest` and never cares whether the entries
//! are backed by HLO files on disk or by native Rust kernels.
//!
//! The two definitions must stay in lockstep with
//! `python/compile/model.py`; `rust/tests/backend_parity.rs` cross-checks
//! the synthesized manifest against an on-disk `manifest.json` whenever
//! AOT artifacts are present.

use crate::model::{ArtifactEntry, BlockRow, Manifest, ParamShape, TensorSpec};

/// Input image side (CIFAR-scale).
pub const IMG: usize = 32;
/// Input channels.
pub const IN_CH: usize = 3;
/// Batch buckets exported by the AOT step; the native backend keeps the
/// same power-of-two set so bucket padding (zero-weighted rows) and every
/// downstream decision about batch sizes are identical across backends.
pub const BUCKETS: [u32; 7] = [1, 2, 4, 8, 16, 32, 64];

/// The layer type of one cuttable block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockKind {
    /// 3x3 SAME conv + bias (+ ReLU), optionally followed by 2x2 maxpool.
    Conv { pool: bool },
    /// Dense (flattening its input) + bias (+ ReLU).
    Dense,
}

/// One cuttable block of SplitCNN-8 (mirrors `model.Block` in Python).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockSpec {
    /// Block name as it appears in the manifest (`conv1`, `fc2`, ...).
    pub name: &'static str,
    /// Conv-vs-dense shape of the block.
    pub kind: BlockKind,
    /// Input channels (conv) or input features (dense).
    pub cin: usize,
    /// Output channels (conv) or output features (dense).
    pub cout: usize,
    /// Whether a ReLU follows the bias add.
    pub relu: bool,
    /// Spatial side of the *output* feature map (1 for dense blocks).
    pub out_hw: usize,
}

impl BlockSpec {
    /// Spatial side of the *input* feature map (conv blocks pool after
    /// the conv, so a pooling block's input is twice its output side).
    pub fn in_hw(&self) -> usize {
        match self.kind {
            BlockKind::Conv { pool } => {
                if pool {
                    self.out_hw * 2
                } else {
                    self.out_hw
                }
            }
            BlockKind::Dense => 1,
        }
    }

    /// Parameter tensor shapes `(w, b)`.
    pub fn param_shape(&self) -> ParamShape {
        match self.kind {
            BlockKind::Conv { .. } => ParamShape {
                w: vec![3, 3, self.cin, self.cout],
                b: vec![self.cout],
            },
            BlockKind::Dense => ParamShape { w: vec![self.cin, self.cout], b: vec![self.cout] },
        }
    }

    fn n_params(&self) -> usize {
        match self.kind {
            BlockKind::Conv { .. } => 9 * self.cin * self.cout + self.cout,
            BlockKind::Dense => self.cin * self.cout + self.cout,
        }
    }

    /// Cost row matching `model.block_table` in Python exactly (the
    /// optimizer's decisions must not depend on the backend).
    fn block_row(&self) -> BlockRow {
        let (macs, act_elems) = match self.kind {
            BlockKind::Conv { .. } => {
                let in_hw = self.in_hw();
                (
                    (9 * self.cin * self.cout * in_hw * in_hw) as f64,
                    self.out_hw * self.out_hw * self.cout,
                )
            }
            BlockKind::Dense => ((self.cin * self.cout) as f64, self.cout),
        };
        BlockRow {
            name: self.name.to_string(),
            kind: match self.kind {
                BlockKind::Conv { .. } => "conv".to_string(),
                BlockKind::Dense => "dense".to_string(),
            },
            fwd_flops: 2.0 * macs,
            bwd_flops: 4.0 * macs,
            act_bytes: 4.0 * act_elems as f64,
            param_bytes: 4.0 * self.n_params() as f64,
            n_params: self.n_params(),
        }
    }
}

/// The executable SplitCNN-8 architecture, parameterized by class count.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    /// Output class count (width of the final dense block).
    pub classes: usize,
    /// The eight cuttable blocks, input to output.
    pub blocks: Vec<BlockSpec>,
}

impl ModelSpec {
    /// SplitCNN-8 (mirrors `model._build_arch` in Python).
    pub fn splitcnn8(classes: usize) -> ModelSpec {
        let conv = |name, cin, cout, pool, out_hw| BlockSpec {
            name,
            kind: BlockKind::Conv { pool },
            cin,
            cout,
            relu: true,
            out_hw,
        };
        let dense = |name, cin, cout, relu| BlockSpec {
            name,
            kind: BlockKind::Dense,
            cin,
            cout,
            relu,
            out_hw: 1,
        };
        ModelSpec {
            classes,
            blocks: vec![
                conv("conv1", IN_CH, 16, false, 32),
                conv("conv2", 16, 16, true, 16),
                conv("conv3", 16, 32, false, 16),
                conv("conv4", 32, 32, true, 8),
                conv("conv5", 32, 64, true, 4),
                dense("fc1", 4 * 4 * 64, 128, true),
                dense("fc2", 128, 64, true),
                dense("fc3", 64, classes, false),
            ],
        }
    }

    /// Number of blocks L (= 8).
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Valid cut layers (1-based; cut `c` keeps blocks `1..=c` on-device).
    pub fn valid_cuts(&self) -> Vec<usize> {
        (1..self.n_blocks()).collect()
    }

    /// Per-block parameter shapes, in block order.
    pub fn param_shapes(&self) -> Vec<ParamShape> {
        self.blocks.iter().map(|b| b.param_shape()).collect()
    }

    /// Shape of the smashed data at cut `cut` for batch `bucket`.
    pub fn activation_shape(&self, cut: usize, bucket: usize) -> Vec<usize> {
        let blk = &self.blocks[cut - 1];
        match blk.kind {
            BlockKind::Conv { .. } => vec![bucket, blk.out_hw, blk.out_hw, blk.cout],
            BlockKind::Dense => vec![bucket, blk.cout],
        }
    }

    /// Synthesize the full artifact manifest: one entry per exported
    /// (function, cut, bucket), exactly as `python/compile/aot.py` writes
    /// it, so the native backend serves the same artifact-name contract.
    pub fn manifest(&self) -> Manifest {
        let spec = |name: &str, shape: &[usize]| TensorSpec {
            name: name.to_string(),
            shape: shape.to_vec(),
            dtype: "f32".to_string(),
        };
        let shapes = self.param_shapes();
        let param_entries = |prefix: &str, blocks: std::ops::Range<usize>| -> Vec<TensorSpec> {
            let mut out = Vec::with_capacity(2 * blocks.len());
            for bi in blocks {
                out.push(spec(&format!("{prefix}.block{}.w", bi + 1), &shapes[bi].w));
                out.push(spec(&format!("{prefix}.block{}.b", bi + 1), &shapes[bi].b));
            }
            out
        };
        let grad_entries = |blocks: std::ops::Range<usize>| -> Vec<TensorSpec> {
            let mut out = Vec::with_capacity(2 * blocks.len());
            for bi in blocks {
                out.push(spec(&format!("grad.block{}.w", bi + 1), &shapes[bi].w));
                out.push(spec(&format!("grad.block{}.b", bi + 1), &shapes[bi].b));
            }
            out
        };
        fn entry(
            name: String,
            func: &str,
            cut: usize,
            bucket: u32,
            args: Vec<TensorSpec>,
            outputs: Vec<TensorSpec>,
        ) -> ArtifactEntry {
            ArtifactEntry {
                path: format!("<native:{name}>"),
                name,
                args,
                outputs,
                sha256: "native".to_string(),
                func: func.to_string(),
                cut,
                bucket,
            }
        }

        let l = self.n_blocks();
        let mut artifacts = Vec::new();
        for &bucket in &BUCKETS {
            let b = bucket as usize;
            let x = spec("x", &[b, IMG, IMG, IN_CH]);
            let onehot = spec("onehot", &[b, self.classes]);
            let weights = spec("weights", &[b]);
            for cut in self.valid_cuts() {
                let a_shape = self.activation_shape(cut, b);

                let mut args = vec![x.clone()];
                args.extend(param_entries("client", 0..cut));
                artifacts.push(entry(
                    Manifest::split_name("client_fwd", cut, bucket),
                    "client_fwd",
                    cut,
                    bucket,
                    args,
                    vec![spec("a", &a_shape)],
                ));

                let mut args = vec![spec("a", &a_shape), onehot.clone(), weights.clone()];
                args.extend(param_entries("server", cut..l));
                let mut outputs =
                    vec![spec("loss", &[]), spec("correct", &[]), spec("grad_a", &a_shape)];
                outputs.extend(grad_entries(cut..l));
                artifacts.push(entry(
                    Manifest::split_name("server_step", cut, bucket),
                    "server_step",
                    cut,
                    bucket,
                    args,
                    outputs,
                ));

                let mut args = vec![x.clone(), spec("grad_a", &a_shape)];
                args.extend(param_entries("client", 0..cut));
                artifacts.push(entry(
                    Manifest::split_name("client_bwd", cut, bucket),
                    "client_bwd",
                    cut,
                    bucket,
                    args,
                    grad_entries(0..cut),
                ));
            }

            let mut args = vec![x.clone(), onehot.clone(), weights.clone()];
            args.extend(param_entries("model", 0..l));
            let mut outputs = vec![spec("loss", &[]), spec("correct", &[])];
            outputs.extend(grad_entries(0..l));
            artifacts.push(entry(
                Manifest::full_name("full_step", bucket),
                "full_step",
                0,
                bucket,
                args,
                outputs,
            ));

            let mut args = vec![x.clone()];
            args.extend(param_entries("model", 0..l));
            artifacts.push(entry(
                Manifest::full_name("full_fwd", bucket),
                "full_fwd",
                0,
                bucket,
                args,
                vec![spec("logits", &[b, self.classes])],
            ));
        }

        let mut m = Manifest {
            model: "splitcnn8".to_string(),
            num_classes: self.classes,
            img: IMG,
            in_ch: IN_CH,
            num_blocks: l,
            valid_cuts: self.valid_cuts(),
            buckets: BUCKETS.to_vec(),
            param_shapes: shapes,
            block_table: self.blocks.iter().map(|b| b.block_row()).collect(),
            artifacts,
            dir: std::path::PathBuf::new(),
            index: Default::default(),
        };
        m.reindex();
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitcnn8_matches_the_python_architecture() {
        let s = ModelSpec::splitcnn8(10);
        assert_eq!(s.n_blocks(), 8);
        assert_eq!(s.valid_cuts(), vec![1, 2, 3, 4, 5, 6, 7]);
        let shapes = s.param_shapes();
        assert_eq!(shapes[0].w, vec![3, 3, 3, 16]);
        assert_eq!(shapes[4].w, vec![3, 3, 32, 64]);
        assert_eq!(shapes[5].w, vec![1024, 128]);
        assert_eq!(shapes[7].w, vec![64, 10]);
        assert_eq!(shapes[7].b, vec![10]);
    }

    #[test]
    fn activation_shapes_track_pooling() {
        let s = ModelSpec::splitcnn8(10);
        assert_eq!(s.activation_shape(1, 8), vec![8, 32, 32, 16]);
        assert_eq!(s.activation_shape(2, 8), vec![8, 16, 16, 16]);
        assert_eq!(s.activation_shape(5, 8), vec![8, 4, 4, 64]);
        assert_eq!(s.activation_shape(6, 8), vec![8, 128]);
        assert_eq!(s.activation_shape(7, 8), vec![8, 64]);
    }

    #[test]
    fn synthesized_manifest_has_the_full_artifact_set() {
        let m = ModelSpec::splitcnn8(10).manifest();
        // 7 buckets x (7 cuts x 3 split fns + 2 full fns) = 7 x 23 = 161.
        assert_eq!(m.artifacts.len(), 161);
        assert_eq!(m.num_blocks, 8);
        assert_eq!(m.buckets, vec![1, 2, 4, 8, 16, 32, 64]);
        let e = m.get("server_step_c3_b16").expect("entry");
        assert_eq!(e.func, "server_step");
        assert_eq!(e.args[0].shape, vec![16, 16, 16, 32]);
        assert_eq!(e.args[1].shape, vec![16, 10]);
        // loss, correct, grad_a + 2 tensors per server block (5 blocks).
        assert_eq!(e.outputs.len(), 3 + 2 * 5);
        assert_eq!(e.outputs[2].shape, vec![16, 16, 16, 32]);
        let e = m.get("full_fwd_b64").expect("entry");
        assert_eq!(e.outputs[0].shape, vec![64, 10]);
    }

    #[test]
    fn block_table_matches_the_manifest_contract() {
        // Spot-check against the numbers `python/compile/model.block_table`
        // exports (and `rust/artifacts/manifest.json` carries): conv1 at
        // 32x32 with 3 -> 16 channels.
        let m = ModelSpec::splitcnn8(10).manifest();
        let r = &m.block_table[0];
        assert_eq!(r.fwd_flops, 884736.0);
        assert_eq!(r.bwd_flops, 1769472.0);
        assert_eq!(r.act_bytes, 65536.0);
        assert_eq!(r.param_bytes, 1792.0);
        assert_eq!(r.n_params, 448);
        // fc3 head tracks the class count.
        let r = &m.block_table[7];
        assert_eq!(r.n_params, 64 * 10 + 10);
        let m100 = ModelSpec::splitcnn8(100).manifest();
        assert_eq!(m100.block_table[7].n_params, 64 * 100 + 100);
    }

    #[test]
    fn profile_from_synthesized_manifest_works() {
        let m = ModelSpec::splitcnn8(10).manifest();
        let p = crate::model::ModelProfile::from_manifest(&m);
        assert_eq!(p.n_layers(), 8);
        assert!(p.rho_total() > 0.0);
        // The communication trade-off the paper exploits survives: early
        // cuts emit larger activations than the bottleneck.
        assert!(p.psi(1) > p.psi(5));
    }
}
