//! Machine-readable payload builders shared by the CLI and the daemon.
//!
//! `hasfl info --json`, the daemon's `GET /info`, and `GET /healthz` all
//! serve the same [`info_json`] document, so probes and scripts parse one
//! schema regardless of which door they knock on.

use std::path::Path;

use crate::backend::{BackendKind, ModelSpec};
use crate::model::Manifest;
use crate::runtime::{EngineHandle, EngineStats};
use crate::util::Json;

/// Backend/model/engine info as one JSON document. `kind` must already be
/// resolved (never [`BackendKind::Auto`]). The engine block is best-effort:
/// it spawns one engine lane, warms the smallest artifact, and reports the
/// execution statistics; when the backend cannot initialize the block is
/// replaced by an `engine_error` string so `info` stays usable.
pub fn info_json(kind: BackendKind, artifacts: &Path) -> crate::Result<Json> {
    let m = match kind {
        BackendKind::Pjrt => Manifest::load(artifacts)?,
        // No class flag here; the native spec defaults to the 10-class
        // model every preset trains.
        _ => ModelSpec::splitcnn8(10).manifest(),
    };
    let hlo_bytes: u64 = if kind == BackendKind::Pjrt {
        m.artifacts
            .iter()
            .filter_map(|a| std::fs::metadata(m.dir.join(&a.path)).ok())
            .map(|md| md.len())
            .sum()
    } else {
        0
    };

    let mut model = Json::obj();
    model
        .set("name", Json::Str(m.model.clone()))
        .set("classes", Json::Num(m.num_classes as f64))
        .set("blocks", Json::Num(m.num_blocks as f64))
        .set("cuts", Json::from_usizes(&m.valid_cuts))
        .set(
            "buckets",
            Json::Arr(m.buckets.iter().map(|&b| Json::Num(b as f64)).collect()),
        )
        .set("artifacts", Json::Num(m.artifacts.len() as f64))
        .set("hlo_bytes", Json::Num(hlo_bytes as f64));

    // Host block: environment facts probes and bench tooling compare
    // across machines (bench-diff flags skew on these).
    let mut host = Json::obj();
    host.set("host_cores", Json::Num(crate::util::host_cores() as f64));

    let mut j = Json::obj();
    j.set("service", Json::Str("hasfl".into()))
        .set("backend", Json::Str(kind.as_str().into()))
        .set("host", host)
        .set("model", model);
    match engine_smoke(kind, artifacts, &m) {
        Ok(stats) => {
            j.set("engine", engine_stats_json(&stats));
        }
        Err(e) => {
            j.set("engine_error", Json::Str(e.to_string()));
        }
    }
    Ok(j)
}

/// Engine execution statistics as JSON.
pub fn engine_stats_json(stats: &EngineStats) -> Json {
    let mut j = Json::obj();
    j.set("pool_width", Json::Num(stats.pool_width as f64))
        .set("executions", Json::Num(stats.executions as f64))
        .set("compiles", Json::Num(stats.compiles as f64))
        .set("upload_bytes", Json::Num(stats.upload_bytes as f64))
        .set("download_bytes", Json::Num(stats.download_bytes as f64))
        .set("buffer_hits", Json::Num(stats.buffer_hits as f64))
        .set("buffer_misses", Json::Num(stats.buffer_misses as f64))
        .set("buffer_hit_bytes", Json::Num(stats.buffer_hit_bytes as f64));
    j
}

/// Spawn one engine lane, warm the smallest monolithic artifact, and
/// return its execution statistics (the `info` runtime smoke).
pub fn engine_smoke(
    kind: BackendKind,
    artifacts: &Path,
    m: &Manifest,
) -> crate::Result<EngineStats> {
    let engine = match kind {
        BackendKind::Pjrt => EngineHandle::spawn(artifacts.to_path_buf())?,
        _ => EngineHandle::spawn_native(m.num_classes)?,
    };
    let smallest = m.buckets.iter().copied().min().unwrap_or(1);
    engine.warm_blocking(&Manifest::full_name("full_fwd", smallest))?;
    let stats = engine.stats_blocking()?;
    engine.shutdown();
    Ok(stats)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests may unwrap; the deny covers the daemon
mod tests {
    use super::*;

    #[test]
    fn native_info_json_shape() {
        let j = info_json(BackendKind::Native, Path::new("/nonexistent")).unwrap();
        assert_eq!(j.get("service").unwrap().as_str().unwrap(), "hasfl");
        assert_eq!(j.get("backend").unwrap().as_str().unwrap(), "native");
        let model = j.get("model").unwrap();
        assert_eq!(model.get("name").unwrap().as_str().unwrap(), "splitcnn8");
        assert_eq!(model.get("classes").unwrap().as_usize().unwrap(), 10);
        assert!(!model.get("cuts").unwrap().as_arr().unwrap().is_empty());
        // Host facts for like-for-like bench comparisons.
        let host = j.get("host").unwrap();
        assert!(host.get("host_cores").unwrap().as_usize().unwrap() >= 1);
        // The native backend always initializes, so the engine block is
        // present with one warmed lane.
        let engine = j.get("engine").unwrap();
        assert_eq!(engine.get("pool_width").unwrap().as_usize().unwrap(), 1);
        // And the document is valid JSON end to end.
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }
}
